"""Modality frontends (STUBS per the assignment carve-out) + real projector.

``input_specs`` provides precomputed patch/frame embeddings of shape
(batch, prefix_len, feature_dim) — we do NOT build the ViT / EnCodec.  The
projector that maps frontend features into the decoder's d_model IS part of
the language model and is implemented here (2-layer MLP, InternVL-style).

The projector weights are replicated: at these sizes the matmuls are noise
and replication keeps the prefix path collective-free (minimal-sync theme).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.common import Dist, ParamDef, activation, rms_norm


def frontend_defs(cfg: ModelConfig, dist: Dist) -> Dict[str, ParamDef]:
    f = cfg.frontend
    d = cfg.d_model
    return {
        "norm": ParamDef((f.feature_dim,), P(None), init="zeros"),
        "w1": ParamDef((f.feature_dim, d), P(None, None), init="scaled", scale_dim=0),
        "w2": ParamDef((d, d), P(None, None), init="scaled", scale_dim=0),
    }


def project_features(params: Dict[str, jax.Array], features: jax.Array,
                     cfg: ModelConfig) -> jax.Array:
    """(b, prefix_len, feature_dim) -> (b, prefix_len, d_model), replicated."""
    h = rms_norm(features.astype(jnp.bfloat16), params["norm"], cfg.rms_eps)
    h = activation("gelu")(h @ params["w1"])
    return h @ params["w2"]
