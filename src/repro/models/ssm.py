"""Mamba-2 SSD block (state-space duality, arXiv:2405.21060), per-shard.

Sharding: the inner channels / SSD heads are sharded over the model axis
(z, x, dt head-sharded; B, C group-replicated since n_groups=1); the
out-projection is row-parallel, so the block contributes exactly **one**
reduction — SSM blocks satisfy the paper's one-sync-per-layer bound natively.

Prefill uses the chunked SSD form (intra-chunk quadratic term + inter-chunk
state scan); decode is the O(1) recurrent update.  State (h, conv tail) is
carried functionally like a KV cache.

Recurrence per head (P = head_dim, N = state_dim):
    h_i = a_i * h_{i-1} + (dt_i x_i) B_i^T          h: (P, N)
    y_i = h_i C_i + D x_i
with a_i = exp(dt_i * A), A = -exp(A_log) < 0.

Chunked SSD identities used below (cs = inclusive cumsum of log a in-chunk):
    intra:  Y[i] += sum_{j<=i} exp(cs[i]-cs[j]) (C_i·B_j) (dt_j x_j)
    into-state: S = sum_j exp(cs[L-1]-cs[j]) (dt_j x_j) B_j^T
    inter:  Y[i] += exp(cs[i]) C_i · H_chunk_start
    carry:  H' = exp(cs[L-1]) H + S

Normalization: gated per-head RMS norm (norm over head_dim, collective-free
under TP — deviation from the reference's full-d_inner RMSNormGated, noted
in DESIGN.md).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.common import Dist, ParamDef

N_GROUPS = 1  # mamba2-1.3b uses a single B/C group


def _dims(cfg: ModelConfig, tp: int):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    n_heads = d_in // s.head_dim
    if n_heads % tp:
        raise ValueError(f"ssd heads {n_heads} not divisible by tp {tp}")
    return d_in, n_heads, n_heads // tp


def ssd_defs(cfg: ModelConfig, dist: Dist) -> Dict[str, ParamDef]:
    s, d, M = cfg.ssm, cfg.d_model, dist.model_axis
    d_in, n_heads, _ = _dims(cfg, dist.tp)
    gn = N_GROUPS * s.state_dim
    return {
        "w_z": ParamDef((d, d_in), P(None, M), init="scaled", scale_dim=0),
        "w_x": ParamDef((d, d_in), P(None, M), init="scaled", scale_dim=0),
        "w_bc": ParamDef((d, 2 * gn), P(None, None), init="scaled", scale_dim=0),
        "w_dt": ParamDef((d, n_heads), P(None, M), init="scaled", scale_dim=0),
        "dt_bias": ParamDef((n_heads,), P(M), init="zeros", dtype=jnp.float32),
        "A_log": ParamDef((n_heads,), P(M), init="zeros", dtype=jnp.float32),
        "D": ParamDef((n_heads,), P(M), init="zeros", dtype=jnp.float32),
        "conv_w": ParamDef((s.conv_width, d_in + 2 * gn),
                           P(None, None), init="scaled", scale_dim=0),
        "norm": ParamDef((d_in,), P(M), init="zeros"),
        "w_out": ParamDef((d_in, d), P(M, None), init="scaled", scale_dim=0),
    }


def init_ssd_state(cfg: ModelConfig, dist: Dist, batch_local: int) -> Dict[str, jax.Array]:
    s = cfg.ssm
    d_in, _, local_h = _dims(cfg, dist.tp)
    gn = N_GROUPS * s.state_dim
    conv_ch = d_in // dist.tp + 2 * gn
    return {
        "h": jnp.zeros((batch_local, local_h, s.head_dim, s.state_dim), jnp.float32),
        "conv": jnp.zeros((batch_local, s.conv_width - 1, conv_ch), jnp.bfloat16),
    }


def _conv_weight_local(params, cfg: ModelConfig, dist: Dist):
    """Depthwise conv weight slice: local x channels + replicated B/C."""
    s = cfg.ssm
    d_in, _, _ = _dims(cfg, dist.tp)
    w = params["conv_w"]                                # (W, d_in + 2gn)
    if dist.tp == 1:
        return w
    loc = d_in // dist.tp
    idx = jax.lax.axis_index(dist.model_axis)
    wx = jax.lax.dynamic_slice_in_dim(w[:, :d_in], idx * loc, loc, axis=1)
    return jnp.concatenate([wx, w[:, d_in:]], axis=1)   # (W, loc + 2gn)


def _causal_conv(u: jax.Array, w: jax.Array, tail: Optional[jax.Array],
                 valid_len: Optional[jax.Array] = None):
    """u (b,s,ch), w (W,ch) depthwise; tail (b,W-1,ch) carries history.

    ``valid_len`` (b,) makes the carried tail end at each row's own last REAL
    input (right-padded admission prefill) instead of the padded end.

    Returns (silu(conv(u)) (b,s,ch), new_tail)."""
    from repro.models.common import conv_tail

    W = w.shape[0]
    if tail is None:
        tail = jnp.zeros((u.shape[0], W - 1, u.shape[2]), u.dtype)
    ext = jnp.concatenate([tail, u], axis=1)            # (b, s+W-1, ch)
    out = sum(ext[:, i : i + u.shape[1]] * w[i] for i in range(W))
    new_tail = conv_tail(ext, W, valid_len, tail)
    return jax.nn.silu(out.astype(jnp.float32)).astype(u.dtype), new_tail


def _segsum(log_a: jax.Array) -> jax.Array:
    """(..., L) -> (..., L, L): seg[i,j] = sum_{t=j+1..i} log_a[t] (i>=j),
    -inf above the diagonal.  Diagonal is 0."""
    L = log_a.shape[-1]
    cs = jnp.cumsum(log_a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((L, L), bool))
    return jnp.where(mask, diff, -jnp.inf)


def _per_head_rmsnorm_gated(y: jax.Array, z: jax.Array, gamma: jax.Array,
                            eps: float) -> jax.Array:
    """y,z: (b,s,local_dim); norm over each head's channels after gating."""
    y = y * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(y * y, axis=-1, keepdims=True)
    return y * jax.lax.rsqrt(var + eps) * (1.0 + gamma.astype(jnp.float32))


def ssd_forward(
    params: Dict[str, jax.Array],
    x_in: jax.Array,              # (b, s, d) replicated over model axis
    cfg: ModelConfig,
    dist: Dist,
    *,
    state: Optional[Dict[str, jax.Array]] = None,
    length_mask: Optional[jax.Array] = None,   # (b, s) bool: True = real token
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """Returns (UNREDUCED partial (b,s,d), new_state or None).

    ``length_mask`` (right-padded admission prefill) turns padding steps into
    exact identity updates — dt is zeroed there, so a = exp(0·A) = 1 and the
    input contribution dt·x vanishes; the conv tail ends at each row's true
    length.  The carried state then matches an unpadded per-row prefill."""
    s_cfg = cfg.ssm
    b, s, d = x_in.shape
    d_in, n_heads, local_h = _dims(cfg, dist.tp)
    P_dim, N = s_cfg.head_dim, s_cfg.state_dim

    z = x_in @ params["w_z"]                            # (b,s,d_in/tp)
    xr = x_in @ params["w_x"]
    bc = x_in @ params["w_bc"]                          # (b,s,2gn) replicated
    dt_raw = x_in @ params["w_dt"]                      # (b,s,local_h)

    conv_in = jnp.concatenate([xr, bc], axis=-1)
    w_conv = _conv_weight_local(params, cfg, dist)
    tail = state["conv"] if state is not None else None
    valid_len = (length_mask.sum(-1).astype(jnp.int32)
                 if length_mask is not None else None)
    conv_out, new_tail = _causal_conv(conv_in, w_conv, tail, valid_len)
    loc = xr.shape[-1]
    xr = conv_out[..., :loc]
    Bm, Cm = jnp.split(conv_out[..., loc:], 2, axis=-1)  # (b,s,gn) each

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    dt = jnp.clip(dt, s_cfg.dt_min, 10.0)                # (b,s,local_h)
    if length_mask is not None:
        dt = jnp.where(length_mask[..., None], dt, 0.0)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))    # (local_h,) negative
    log_a = dt * A                                       # (b,s,local_h)
    xh = xr.reshape(b, s, local_h, P_dim).astype(jnp.float32)
    Bh = Bm.reshape(b, s, N_GROUPS, N)[:, :, 0].astype(jnp.float32)   # (b,s,N)
    Ch = Cm.reshape(b, s, N_GROUPS, N)[:, :, 0].astype(jnp.float32)
    xdt = xh * dt[..., None]                             # (b,s,h,P)

    h0 = state["h"] if state is not None else jnp.zeros(
        (b, local_h, P_dim, N), jnp.float32
    )

    if s == 1:
        a = jnp.exp(log_a[:, 0])                         # (b,h)
        h_new = h0 * a[..., None, None] + jnp.einsum(
            "bhp,bn->bhpn", xdt[:, 0], Bh[:, 0]
        )
        y = jnp.einsum("bhpn,bn->bhp", h_new, Ch[:, 0])
        y = y + params["D"][None, :, None] * xh[:, 0]
        y = y[:, None]                                   # (b,1,h,P)
        new_state = {"h": h_new, "conv": new_tail}
    else:
        L = min(s_cfg.chunk, s)
        if s % L:
            raise ValueError(f"seq {s} not divisible by ssd chunk {L}")
        nc = s // L
        la = log_a.reshape(b, nc, L, local_h).transpose(0, 3, 1, 2)   # (b,h,c,L)
        xc = xdt.reshape(b, nc, L, local_h, P_dim).transpose(0, 3, 1, 2, 4)  # (b,h,c,L,P)
        Bc = Bh.reshape(b, nc, L, N)                                   # (b,c,L,N)
        Cc = Ch.reshape(b, nc, L, N)
        cs = jnp.cumsum(la, axis=-1)                                   # (b,h,c,L)
        seg = _segsum(la)                                              # (b,h,c,L,L)
        # intra-chunk
        sc = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)                     # (b,c,i,j)
        M = jnp.exp(seg) * sc[:, None]                                 # (b,h,c,i,j)
        y_intra = jnp.einsum("bhcij,bhcjp->bhcip", M, xc)
        # chunk summaries -> inter-chunk scan
        decay_end = jnp.exp(cs[..., -1:] - cs)                         # (b,h,c,L)
        S = jnp.einsum("bhcj,bhcjp,bcjn->bhcpn", decay_end, xc, Bc)    # (b,h,c,P,N)
        chunk_decay = jnp.exp(cs[..., -1])                             # (b,h,c)

        def scan_fn(H, inp):
            S_c, dec_c = inp                                           # (b,h,P,N),(b,h)
            H_next = H * dec_c[..., None, None] + S_c
            return H_next, H                                           # emit state BEFORE chunk

        S_t = S.transpose(2, 0, 1, 3, 4)                               # (c,b,h,P,N)
        dec_t = chunk_decay.transpose(2, 0, 1)                         # (c,b,h)
        from repro.models.common import maybe_scan
        H_final, H_before = maybe_scan(scan_fn, h0, (S_t, dec_t))
        H_before = H_before.transpose(1, 2, 0, 3, 4)                   # (b,h,c,P,N)
        y_inter = jnp.einsum("bhci,bcin,bhcpn->bhcip", jnp.exp(cs), Cc, H_before)
        y = y_intra + y_inter                                          # (b,h,c,L,P)
        y = y.transpose(0, 2, 3, 1, 4).reshape(b, s, local_h, P_dim)
        y = y + params["D"][None, None, :, None] * xh
        new_state = {"h": H_final, "conv": new_tail} if state is not None else None

    y = y.reshape(y.shape[0], y.shape[1], local_h * P_dim)
    y = _per_head_rmsnorm_gated(
        y.reshape(*y.shape[:2], local_h, P_dim),
        z.astype(jnp.float32).reshape(*z.shape[:2], local_h, P_dim),
        params["norm"].reshape(local_h, P_dim),
        cfg.rms_eps,
    ).reshape(*y.shape[:2], local_h * P_dim)
    partial = y.astype(x_in.dtype) @ params["w_out"]     # unreduced
    return partial, new_state
