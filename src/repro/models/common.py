"""Shared model building blocks: param machinery, norms, RoPE, activations.

Everything in ``repro.models`` is written as *per-shard* code intended to run
inside ``jax.shard_map`` over the mesh axes in :class:`Dist`.  Collectives are
explicit ``jax.lax`` calls (see :mod:`repro.core.collectives`), which is what
makes the paper's communication schedule a countable property of the program.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ParallelConfig

Pytree = Any


# ---------------------------------------------------------------------------
# Distribution context
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Dist:
    """Names + sizes of the mesh axes the per-shard code runs under."""

    model_axis: str = "model"
    data_axis: str = "data"
    pod_axis: Optional[str] = None
    tp: int = 1
    dp: int = 1
    pods: int = 1

    @property
    def data_axes(self) -> Tuple[str, ...]:
        """Axes over which the batch is sharded (pod is outer data parallel)."""
        if self.pod_axis is not None:
            return (self.pod_axis, self.data_axis)
        return (self.data_axis,)

    def model_idx(self):
        return jax.lax.axis_index(self.model_axis)


def pad_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclass(frozen=True)
class ShardPlan:
    """How attention heads / vocab / experts land on the ``model`` axis.

    Q heads are padded to a multiple of tp (zero-initialised padding heads are
    exact no-ops under the row-parallel out-projection + psum).  When
    n_kv < tp each KV head is replicated over ``rep = tp // n_kv`` adjacent
    shards and the per-KV-group Q heads are padded to a multiple of ``rep``.
    """

    tp: int
    n_heads: int            # true head count
    n_kv_heads: int         # true kv head count
    n_heads_p: int          # padded q heads (multiple of tp)
    n_kv_p: int             # padded kv heads
    kv_rep: int             # how many model shards share one kv head
    local_q: int            # q heads per shard
    local_kv: int           # kv heads per shard
    vocab_p: int            # padded vocab (multiple of tp)
    local_vocab: int

    @staticmethod
    def make(cfg: ModelConfig, tp: int) -> "ShardPlan":
        n_q, n_kv = cfg.n_heads, cfg.n_kv_heads
        if n_kv >= tp:
            # shard kv heads directly; pad both q and kv to multiples of tp
            n_kv_p = pad_to(n_kv, tp)
            g = max(1, n_q // n_kv)
            if n_q % n_kv:
                raise ValueError(f"{cfg.name}: n_heads {n_q} not a multiple of n_kv {n_kv}")
            n_q_p = n_kv_p * g
            kv_rep = 1
        else:
            if tp % n_kv:
                raise ValueError(f"{cfg.name}: tp {tp} not a multiple of n_kv {n_kv}")
            kv_rep = tp // n_kv
            g = n_q // n_kv
            if n_q % n_kv:
                raise ValueError(f"{cfg.name}: ragged GQA groups unsupported")
            g_p = pad_to(g, kv_rep)
            n_q_p = n_kv * g_p
            n_kv_p = n_kv
        vocab_p = pad_to(cfg.vocab_size, tp)
        return ShardPlan(
            tp=tp,
            n_heads=n_q,
            n_kv_heads=n_kv,
            n_heads_p=n_q_p,
            n_kv_p=n_kv_p,
            kv_rep=kv_rep,
            local_q=n_q_p // tp,
            local_kv=max(1, n_kv_p // tp),
            vocab_p=vocab_p,
            local_vocab=vocab_p // tp,
        )


# ---------------------------------------------------------------------------
# Parameter definition machinery
# ---------------------------------------------------------------------------


@dataclass
class ParamDef:
    """Declarative parameter: global shape + partition spec + initializer."""

    shape: Tuple[int, ...]
    spec: P
    init: str = "normal"        # normal | zeros | ones | scaled
    scale_dim: int = -1         # fan-in dim index for "scaled"
    dtype: Any = jnp.bfloat16

    def initialize(self, key) -> jax.Array:
        if self.init == "zeros":
            return jnp.zeros(self.shape, self.dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, self.dtype)
        fan_in = self.shape[self.scale_dim] if self.init == "scaled" else None
        std = 0.02 if fan_in is None else 1.0 / math.sqrt(fan_in)
        return (jax.random.normal(key, self.shape, jnp.float32) * std).astype(self.dtype)


def is_def(x) -> bool:
    return isinstance(x, ParamDef)


def materialize(defs: Pytree, key) -> Pytree:
    leaves, treedef = jax.tree.flatten(defs, is_leaf=is_def)
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(treedef, [d.initialize(k) for d, k in zip(leaves, keys)])


def specs_of(defs: Pytree) -> Pytree:
    return jax.tree.map(lambda d: d.spec, defs, is_leaf=is_def)


def shapes_of(defs: Pytree) -> Pytree:
    """ShapeDtypeStructs with shardings attached — used by the dry-run."""
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype), defs, is_leaf=is_def
    )


def stack_defs(defs: Pytree, n: int) -> Pytree:
    """Stack a layer's defs ``n`` times along a new leading (scan) axis."""

    def s(d: ParamDef) -> ParamDef:
        return ParamDef(
            shape=(n,) + d.shape,
            spec=P(None, *d.spec),
            init=d.init,
            scale_dim=d.scale_dim if d.scale_dim < 0 else d.scale_dim + 1,
            dtype=d.dtype,
        )

    return jax.tree.map(s, defs, is_leaf=is_def)


# ---------------------------------------------------------------------------
# Numerics
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, gamma: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + gamma.astype(jnp.float32))).astype(x.dtype)


def activation(name: str) -> Callable[[jax.Array], jax.Array]:
    return {"silu": jax.nn.silu, "gelu": partial(jax.nn.gelu, approximate=True)}[name]


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, head_dim); positions: (..., seq) int32."""
    head_dim = x.shape[-1]
    freqs = rope_freqs(head_dim, theta)                     # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def conv_tail(ext: jax.Array, W: int, valid_len: Optional[jax.Array],
              tail: jax.Array) -> jax.Array:
    """Carried depthwise-conv history from the extended input ``ext``
    (b, s+W-1, ch) = [old tail ; new inputs].

    ``valid_len`` (b,) ends each row's tail at its own last REAL input
    (right-padded admission prefill); rows where valid_len == s reduce to
    the plain last-(W-1) slice."""
    if W == 1:
        return tail
    if valid_len is not None:
        idx = valid_len[:, None] + jnp.arange(W - 1, dtype=jnp.int32)[None, :]
        return jnp.take_along_axis(ext, idx[..., None], axis=1)
    return ext[:, -(W - 1):]


def causal_mask(q_len: int, kv_len: int, q_offset) -> jax.Array:
    """(q_len, kv_len) bool mask; q position i is at absolute q_offset + i."""
    qi = jnp.arange(q_len)[:, None] + q_offset
    kj = jnp.arange(kv_len)[None, :]
    return kj <= qi


def window_mask(q_len: int, kv_len: int, q_offset, window: int) -> jax.Array:
    qi = jnp.arange(q_len)[:, None] + q_offset
    kj = jnp.arange(kv_len)[None, :]
    return (kj <= qi) & (kj > qi - window)


# ---------------------------------------------------------------------------
# Scan handling for cost probes
# ---------------------------------------------------------------------------

import contextvars

# The dry-run cost probes set this: XLA cost_analysis counts while-loop bodies
# once, so probe traces unroll every inner (chunk) scan into straight-line HLO.
UNROLL_SCANS = contextvars.ContextVar("repro_unroll_scans", default=False)


def maybe_scan(body, init, xs, length=None):
    """jax.lax.scan, or a Python-unrolled equivalent under UNROLL_SCANS."""
    if not UNROLL_SCANS.get():
        return jax.lax.scan(body, init, xs, length=length)
    n = length if xs is None else jax.tree.leaves(xs)[0].shape[0]
    carry, ys = init, []
    for i in range(n):
        x_i = None if xs is None else jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, x_i)
        ys.append(y)
    if not ys or all(y is None for y in jax.tree.leaves(ys[0], is_leaf=lambda v: v is None)):
        return carry, None
    stacked = jax.tree.map(lambda *z: jnp.stack(z), *ys)
    return carry, stacked
