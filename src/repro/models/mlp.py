"""Dense FFN: gated (SwiGLU/GeGLU) or plain 2-matmul, column→row parallel.

Output is the UNREDUCED row-parallel partial — the block applies SyncPolicy.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core import wquant
from repro.models.common import Dist, ParamDef, activation


def mlp_defs(cfg: ModelConfig, dist: Dist, d_ff: int = 0) -> Dict[str, ParamDef]:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    M = dist.model_axis
    defs = {
        "w_up": ParamDef((d, f), P(None, M), init="scaled", scale_dim=0),
        "w_down": ParamDef((f, d), P(M, None), init="scaled", scale_dim=0),
    }
    if cfg.gated_mlp:
        defs["w_gate"] = ParamDef((d, f), P(None, M), init="scaled", scale_dim=0)
    return defs


def mlp_forward(params: Dict[str, jax.Array], x: jax.Array, cfg: ModelConfig) -> jax.Array:
    act = activation(cfg.act)
    up = wquant.matmul(x, params["w_up"])
    if cfg.gated_mlp:
        h = act(wquant.matmul(x, params["w_gate"])) * up
    else:
        h = act(up)
    return wquant.matmul(h, params["w_down"])          # unreduced partial
