"""Decoder assembly: sub-layers -> super-blocks -> scanned layer groups.

Layers are grouped into homogeneous *scan groups* (jax.lax.scan over stacked
params) so HLO size and 512-device compile time are depth-independent.
Heterogeneous patterns (Griffin's R,R,A; DeepSeek's dense layer 0) become
multiple groups: full repeating periods are scanned, remainders unrolled.

The collective schedule per sub-layer is decided HERE, via SyncPolicy —
this is where the paper's §2.2 (one-shot sync for parallel-residual) and its
sequence-parallel generalization live.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core.sync_policy import SyncPolicy
from repro.models import attention as attn
from repro.models import mlp as mlp_mod
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import ssm as ssm_mod
from repro.models.common import Dist, ParamDef, ShardPlan, rms_norm

ATTN_KINDS = ("attn", "local_attn")


@dataclass(frozen=True)
class SubLayer:
    kind: str                   # attn | local_attn | ssd | rglru
    is_moe: bool

    @property
    def has_ffn(self) -> bool:
        return self.kind != "ssd"


@dataclass(frozen=True)
class GroupSpec:
    subs: Tuple[SubLayer, ...]
    n: int                      # scan length (1 = unrolled single block)


def layer_signature(cfg: ModelConfig, layer: int) -> SubLayer:
    kind = cfg.block_kind(layer)
    is_moe = cfg.moe is not None and layer not in cfg.dense_ffn_layers and kind != "ssd"
    return SubLayer(kind, is_moe)


def build_groups(cfg: ModelConfig) -> Tuple[GroupSpec, ...]:
    p = len(cfg.layer_pattern)
    sigs = [layer_signature(cfg, i) for i in range(cfg.n_layers)]
    if cfg.force_unroll:
        return tuple(GroupSpec((s,), 1) for s in sigs)
    groups = []
    i = 0
    while i < cfg.n_layers:
        # a full aligned period that matches the pattern's own signature?
        def period_ok(start: int) -> bool:
            if start % p or start + p > cfg.n_layers:
                return False
            return all(
                sigs[start + j] == SubLayer(
                    cfg.layer_pattern[j],
                    cfg.moe is not None
                    and (start + j) not in cfg.dense_ffn_layers
                    and cfg.layer_pattern[j] != "ssd",
                )
                for j in range(p)
            )

        if period_ok(i):
            unit = tuple(sigs[i + j] for j in range(p))
            cnt = 0
            while period_ok(i) and tuple(sigs[i + j] for j in range(p)) == unit:
                cnt += 1
                i += p
            groups.append(GroupSpec(unit, cnt))
        else:
            groups.append(GroupSpec((sigs[i],), 1))
            i += 1
    return tuple(groups)


# ---------------------------------------------------------------------------
# Parameter definitions
# ---------------------------------------------------------------------------


def sub_defs(cfg: ModelConfig, plan: ShardPlan, dist: Dist, sub: SubLayer) -> Dict[str, Any]:
    d = cfg.d_model
    defs: Dict[str, Any] = {"norm1": ParamDef((d,), P(None), init="zeros")}
    if sub.kind in ATTN_KINDS:
        defs["mixer"] = attn.attn_defs(cfg, plan, dist)
    elif sub.kind == "ssd":
        defs["mixer"] = ssm_mod.ssd_defs(cfg, dist)
    elif sub.kind == "rglru":
        defs["mixer"] = rglru_mod.rglru_defs(cfg, dist)
    else:
        raise ValueError(sub.kind)
    if sub.has_ffn:
        if not cfg.parallel_residual:
            defs["norm2"] = ParamDef((d,), P(None), init="zeros")
        if sub.is_moe:
            defs["ffn"] = moe_mod.moe_defs(cfg, dist)
        else:
            defs["ffn"] = mlp_mod.mlp_defs(cfg, dist)
    return defs


def group_defs(cfg: ModelConfig, plan: ShardPlan, dist: Dist, g: GroupSpec) -> Dict[str, Any]:
    from repro.models.common import stack_defs

    defs = {f"sub{i}": sub_defs(cfg, plan, dist, s) for i, s in enumerate(g.subs)}
    return stack_defs(defs, g.n) if g.n > 1 else defs


# ---------------------------------------------------------------------------
# Caches
# ---------------------------------------------------------------------------


def sub_cache(cfg: ModelConfig, plan: ShardPlan, dist: Dist, sub: SubLayer,
              batch_local: int, cache_len_local: int,
              quant: bool = False, ring_slack: int = 0) -> Dict[str, jax.Array]:
    if sub.kind in ATTN_KINDS:
        clen = attn.cache_len_for(cfg, sub.kind, cache_len_local, 1, ring_slack)
        return attn.init_cache(cfg, plan, dist, batch_local, clen, kind=sub.kind,
                               quant=quant)
    if sub.kind == "ssd":
        return ssm_mod.init_ssd_state(cfg, dist, batch_local)
    if sub.kind == "rglru":
        return rglru_mod.init_rglru_state(cfg, dist, batch_local)
    raise ValueError(sub.kind)


def group_cache(cfg: ModelConfig, plan: ShardPlan, dist: Dist, g: GroupSpec,
                batch_local: int, cache_len_local: int,
                kv_seq_shard_dp: int = 1, quant: bool = False,
                batched_pos: bool = False,
                paged: Optional[Tuple[int, int]] = None,
                ring_slack: int = 0) -> Dict[str, Any]:
    def one(sub: SubLayer):
        if sub.kind in ATTN_KINDS:
            clen = attn.cache_len_for(cfg, sub.kind, cache_len_local,
                                      kv_seq_shard_dp, ring_slack)
            return attn.init_cache(cfg, plan, dist, batch_local, clen, kind=sub.kind,
                                   quant=quant, batched_pos=batched_pos,
                                   paged=paged)
        return sub_cache(cfg, plan, dist, sub, batch_local, cache_len_local)

    caches = {f"sub{i}": one(s) for i, s in enumerate(g.subs)}
    if g.n > 1:
        caches = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (g.n,) + x.shape), caches
        )
    return caches


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _mixer_forward(p, xa, positions, cfg, plan, dist, sub: SubLayer, cache,
                   cur_pos, kv_seq_axis, use_pallas, length_mask=None,
                   block_tables=None, flash_prefill=False):
    if sub.kind in ATTN_KINDS:
        # dense/paged caches need no length mask (padded K/V entries are
        # dead by position masking); the sliding-window RING chunk writer
        # does — every in-range ring index is live, so pad columns must be
        # dropped at the write
        if cfg.mla is not None:
            return attn.mla_forward(
                p, xa, positions, cfg, plan, dist, cache=cache, cur_pos=cur_pos,
                kv_seq_axis=kv_seq_axis, use_pallas=use_pallas,
                flash_prefill=flash_prefill, block_tables=block_tables,
            )
        return attn.gqa_forward(
            p, xa, positions, cfg, plan, dist, kind=sub.kind, cache=cache,
            cur_pos=cur_pos, kv_seq_axis=kv_seq_axis, use_pallas=use_pallas,
            flash_prefill=flash_prefill, block_tables=block_tables,
            length_mask=length_mask,
        )
    if sub.kind == "ssd":
        return ssm_mod.ssd_forward(p, xa, cfg, dist, state=cache,
                                   length_mask=length_mask)
    if sub.kind == "rglru":
        return rglru_mod.rglru_forward(p, xa, cfg, dist, state=cache,
                                       use_pallas=use_pallas,
                                       length_mask=length_mask)
    raise ValueError(sub.kind)


def sublayer_forward(
    p: Dict[str, Any],
    x: jax.Array,                 # residual (maybe seq-sharded)
    positions: jax.Array,
    cfg: ModelConfig,
    plan: ShardPlan,
    dist: Dist,
    policy: SyncPolicy,
    sub: SubLayer,
    *,
    cache=None,
    cur_pos=None,
    kv_seq_axis=None,
    use_pallas=False,
    length_mask=None,
    block_tables=None,
    flash_prefill=False,
):
    """-> (x', new_cache, aux)."""
    aux = jnp.zeros((), jnp.float32)
    xa = policy.gather_in(rms_norm(x, p["norm1"], cfg.rms_eps), tag="pre_mixer")

    if cfg.parallel_residual and sub.has_ffn and sub.kind in ATTN_KINDS:
        # paper §2.2: attention + FFN read the same normed input
        attn_p, new_cache = _mixer_forward(
            p["mixer"], xa, positions, cfg, plan, dist, sub, cache, cur_pos,
            kv_seq_axis, use_pallas, length_mask, block_tables, flash_prefill,
        )
        ffn_p = mlp_mod.mlp_forward(p["ffn"], xa, cfg)
        if policy.one_shot:
            x = x + policy.reduce_out(attn_p + ffn_p, tag="one_shot")
        else:  # the 2-sync baseline the paper improves on
            x = x + policy.reduce_out(attn_p, tag="attn_reduce") \
                  + policy.reduce_out(ffn_p, tag="ffn_reduce")
        return x, new_cache, aux

    mix_p, new_cache = _mixer_forward(
        p["mixer"], xa, positions, cfg, plan, dist, sub, cache, cur_pos,
        kv_seq_axis, use_pallas, length_mask, block_tables, flash_prefill,
    )
    x = x + policy.reduce_out(mix_p, tag="mixer_reduce")
    if sub.has_ffn:
        xf = policy.gather_in(rms_norm(x, p["norm2"], cfg.rms_eps), tag="pre_ffn")
        if sub.is_moe:
            ffn_p, aux = moe_mod.moe_forward(p["ffn"], xf, cfg, dist)
        else:
            ffn_p = mlp_mod.mlp_forward(p["ffn"], xf, cfg)
        x = x + policy.reduce_out(ffn_p, tag="ffn_reduce")
    return x, new_cache, aux


def group_forward(
    gp: Dict[str, Any],
    x: jax.Array,
    positions: jax.Array,
    cfg: ModelConfig,
    plan: ShardPlan,
    dist: Dist,
    policy: SyncPolicy,
    g: GroupSpec,
    *,
    caches=None,
    cur_pos=None,
    kv_seq_axis=None,
    use_pallas=False,
    remat=False,
    length_mask=None,
    block_tables=None,
    flash_prefill=False,
):
    """-> (x', new_caches, aux)."""

    def superblock(x, aux, p_layer, cache_layer):
        new_caches = {}
        for i, sub in enumerate(g.subs):
            c = cache_layer[f"sub{i}"] if cache_layer is not None else None
            x, c_new, a = sublayer_forward(
                p_layer[f"sub{i}"], x, positions, cfg, plan, dist, policy, sub,
                cache=c, cur_pos=cur_pos, kv_seq_axis=kv_seq_axis,
                use_pallas=use_pallas, length_mask=length_mask,
                block_tables=block_tables, flash_prefill=flash_prefill,
            )
            if c_new is not None:
                new_caches[f"sub{i}"] = c_new
            aux = aux + a
        return x, aux, (new_caches if new_caches else None)

    if g.n == 1:
        blk = jax.checkpoint(superblock) if (remat and caches is None) else superblock
        x, aux, new_caches = blk(x, jnp.zeros((), jnp.float32), gp, caches)
        return x, new_caches, aux

    def index_params(i):
        # params are a scan closure constant indexed per iteration — scanning
        # them as xs makes XLA:CPU stage the whole stacked tree into temp
        # buffers (observed: +150 MB/layer of temp on the dry-run).
        return jax.tree.map(
            lambda p: jax.lax.dynamic_index_in_dim(p, i, 0, keepdims=False), gp
        )

    if caches is None:
        def body(carry, _):
            x, aux, i = carry
            x, aux, _ = superblock(x, aux, index_params(i), None)
            return (x, aux, i + 1), None

        if remat:
            body = jax.checkpoint(body)
        (x, aux, _), _ = jax.lax.scan(
            body, (x, jnp.zeros((), jnp.float32), jnp.int32(0)), None, length=g.n
        )
        return x, None, aux

    # Caches ride in the CARRY and are updated in place with
    # dynamic_update_slice — scanning them as xs/ys would double-buffer the
    # whole stacked KV cache (observed: 3x cache bytes of temp at 32k).
    def body_cached(carry, _):
        x, aux, stacked, i = carry
        cache_layer = jax.tree.map(
            lambda c: jax.lax.dynamic_index_in_dim(c, i, 0, keepdims=False),
            stacked,
        )
        x, aux, new_c = superblock(x, aux, index_params(i), cache_layer)
        stacked = jax.tree.map(
            lambda cs, cl: jax.lax.dynamic_update_index_in_dim(cs, cl, i, 0),
            stacked, new_c,
        )
        return (x, aux, stacked, i + 1), None

    (x, aux, new_caches, _), _ = jax.lax.scan(
        body_cached, (x, jnp.zeros((), jnp.float32), caches, jnp.int32(0)),
        None, length=g.n,
    )
    return x, new_caches, aux
