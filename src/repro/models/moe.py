"""Mixture-of-experts FFN with expert-parallel sharding over the model axis.

Design (see DESIGN.md §5): activations entering the FFN are replicated over
the model axis (they come out of the attention psum / SP all-gather), so
expert parallelism needs **no extra all-to-all**: every shard routes the full
token set, index-gathers only the tokens destined for *its* experts, and the
layer's single existing reduction (psum / psum_scatter) merges expert outputs
— the MoE analogue of the paper's minimize-synchronization principle.

Expert weight storage is uniform: ``(n_blocks, d, dff_block)`` with
``n_blocks = max(E, tp)`` sharded on dim 0.  When E < tp each expert's d_ff is
split over ``ffn_tp = tp // E`` shards (Mixtral: 8 experts x 2-way FFN TP);
when E >= tp each shard owns ``E // tp`` whole experts (DeepSeekMoE: 4/shard).

Routing is softmax→top-k→renormalize; dispatch is index-based (argsort +
capacity clipping, GShard-style) — no O(T·E·C) one-hot matmuls.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, MoEConfig
from repro.core import wquant
from repro.models.common import Dist, ParamDef, activation


def moe_plan(m: MoEConfig, tp: int) -> Tuple[int, int, int, int]:
    """-> (n_blocks, dff_block, local_blocks, ffn_tp)."""
    E = m.n_experts
    if E >= tp:
        if E % tp:
            raise ValueError(f"n_experts {E} not divisible by tp {tp}")
        return E, m.expert_d_ff, E // tp, 1
    if tp % E:
        raise ValueError(f"tp {tp} not divisible by n_experts {E}")
    ffn_tp = tp // E
    if m.expert_d_ff % ffn_tp:
        raise ValueError("expert_d_ff not divisible by ffn_tp")
    return tp, m.expert_d_ff // ffn_tp, 1, ffn_tp


def capacity(m: MoEConfig, tokens: int) -> int:
    """Expert capacity. Decode-sized batches get C = T (provably drop-free);
    large prefill/train batches use the GShard capacity-factor clipping."""
    if tokens <= 256:
        return tokens
    return max(4, int(math.ceil(tokens * m.top_k / m.n_experts * m.capacity_factor)))


def moe_defs(cfg: ModelConfig, dist: Dist) -> Dict[str, ParamDef]:
    m = cfg.moe
    d, M = cfg.d_model, dist.model_axis
    n_blocks, dff_b, _, _ = moe_plan(m, dist.tp)
    defs = {
        "router": ParamDef((d, m.n_experts), P(None, None), init="scaled",
                           scale_dim=0, dtype=jnp.float32),
        "w_up": ParamDef((n_blocks, d, dff_b), P(M, None, None), init="scaled", scale_dim=1),
        "w_down": ParamDef((n_blocks, dff_b, d), P(M, None, None), init="scaled", scale_dim=1),
    }
    if cfg.gated_mlp:
        defs["w_gate"] = ParamDef((n_blocks, d, dff_b), P(M, None, None),
                                  init="scaled", scale_dim=1)
    if m.n_shared:
        from repro.models.mlp import mlp_defs

        defs["shared"] = mlp_defs(cfg, dist, d_ff=m.shared_d_ff)
    return defs


def route(router_w: jax.Array, x: jax.Array, m: MoEConfig):
    """-> (topk experts (T,k), topk gates (T,k), aux load-balance loss)."""
    logits = x.astype(jnp.float32) @ router_w                   # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, m.top_k)
    gates = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    # Switch/GShard load-balance aux: E * sum_i f_i * P_i
    T = x.shape[0]
    ones = jnp.zeros((T, m.n_experts), jnp.float32).at[
        jnp.arange(T)[:, None], top_e
    ].add(1.0 / m.top_k)
    f = ones.mean(axis=0)
    P_mean = probs.mean(axis=0)
    aux = m.n_experts * jnp.sum(f * P_mean)
    return top_e, gates, aux


def moe_forward(
    params: Dict[str, jax.Array],
    x: jax.Array,                 # (b, s, d) replicated over model axis
    cfg: ModelConfig,
    dist: Dist,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (UNREDUCED partial (b,s,d), aux loss scalar)."""
    m = cfg.moe
    b, s, d = x.shape
    T = b * s
    xf = x.reshape(T, d)
    n_blocks, dff_b, local_blocks, ffn_tp = moe_plan(m, dist.tp)
    C = capacity(m, T)
    act = activation(cfg.act)

    top_e, gates, aux = route(params["router"], xf, m)

    # ---- dispatch bookkeeping (identical on every shard; cheap) ----------
    k = m.top_k
    flat_e = top_e.reshape(-1)                                  # (T*k,)
    flat_tok = jnp.arange(T * k) // k
    flat_gate = gates.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    sorted_tok = flat_tok[order]
    sorted_gate = flat_gate[order]
    counts = jnp.bincount(flat_e, length=m.n_experts)
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)[:-1]])
    pos = jnp.arange(T * k) - starts[sorted_e]
    keep = pos < C

    # ---- this shard's experts --------------------------------------------
    shard = dist.model_idx() if dist.tp > 1 else jnp.int32(0)
    blk0 = shard * local_blocks
    e_lo = (blk0 * m.n_experts) // n_blocks                     # first local expert
    local_E = max(1, local_blocks * m.n_experts // n_blocks)
    mine = keep & (sorted_e >= e_lo) & (sorted_e < e_lo + local_E)
    slot = (sorted_e - e_lo) * C + pos                          # (T*k,)
    slot = jnp.where(mine, slot, local_E * C)                   # dump row

    x_disp = jnp.zeros((local_E * C + 1, d), x.dtype)
    x_disp = x_disp.at[slot].add(xf[sorted_tok])
    xe = x_disp[: local_E * C].reshape(local_E, C, d)

    # ---- expert FFN (einsum over the local expert blocks) -----------------
    # local_blocks == local_E except when ffn_tp > 1 (then both are 1).
    # Quantized expert blocks ((E, K, N): per-expert scales, block dim
    # sharded like the weight) dequantize here — the batched expert einsum
    # stays on the reference path; the fused kernel serves the 2-D
    # projections where the per-token sweep actually concentrates.
    w_up = wquant.to_dense(params["w_up"])
    w_down = wquant.to_dense(params["w_down"])
    up = jnp.einsum("ecd,edf->ecf", xe, w_up)
    if cfg.gated_mlp:
        up = act(jnp.einsum("ecd,edf->ecf", xe,
                            wquant.to_dense(params["w_gate"]))) * up
    else:
        up = act(up)
    ye = jnp.einsum("ecf,efd->ecd", up, w_down)                 # partial if ffn_tp>1
    ye = jnp.concatenate([ye.reshape(local_E * C, d),
                          jnp.zeros((1, d), ye.dtype)])         # dump row back

    # ---- combine: scatter-add weighted expert outputs ---------------------
    out = jnp.zeros((T, d), jnp.float32)
    contrib = ye[slot].astype(jnp.float32) * jnp.where(mine, sorted_gate, 0.0)[:, None]
    out = out.at[sorted_tok].add(contrib)
    partial = out.reshape(b, s, d).astype(x.dtype)

    if m.n_shared:
        from repro.models.mlp import mlp_forward

        partial = partial + mlp_forward(params["shared"], x, cfg)
    return partial, aux
