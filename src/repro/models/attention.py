"""Attention: GQA/MHA (+QKV bias, RoPE, sliding window) and MLA.

Per-shard code (runs under shard_map).  All outputs of the out-projection are
returned **unreduced** — the block assembly applies the SyncPolicy so the
collective schedule (paper §2.2) is decided in exactly one place.

KV caches carry an explicit per-slot absolute-position array, which uniformly
handles full caches, sliding-window ring buffers, and the sequence-sharded
long-context cache (cache sequence sharded over the ``data`` axis, partial
attention merged with a log-sum-exp psum — the sub-quadratic long_500k path).
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core import collectives as cc
from repro.core import wquant
from repro.core.sync_policy import SyncPolicy
from repro.core.zero_copy import fused_out_projection
from repro.models.common import Dist, ParamDef, ShardPlan, apply_rope

KV_CHUNK = 1024  # flash-style kv chunk for prefill
VERIFY_WIDTH = 8  # query widths at/below this take the narrow-q (verify)
                  # flash-kernel specialization on the chunk path


# ---------------------------------------------------------------------------
# Parameter definitions
# ---------------------------------------------------------------------------


def attn_defs(cfg: ModelConfig, plan: ShardPlan, dist: Dist) -> Dict[str, ParamDef]:
    if cfg.mla is not None:
        return _mla_defs(cfg, plan, dist)
    d, hd = cfg.d_model, cfg.resolved_head_dim
    M = dist.model_axis
    kv_sharded = plan.n_kv_p >= plan.tp
    kv_cols = (plan.n_kv_p if kv_sharded else plan.n_kv_heads) * hd
    kv_spec = P(None, M) if kv_sharded else P(None, None)
    defs = {
        "w_q": ParamDef((d, plan.n_heads_p * hd), P(None, M), init="scaled", scale_dim=0),
        "w_k": ParamDef((d, kv_cols), kv_spec, init="scaled", scale_dim=0),
        "w_v": ParamDef((d, kv_cols), kv_spec, init="scaled", scale_dim=0),
        "w_o": ParamDef((plan.n_heads_p, hd, d), P(M, None, None), init="scaled", scale_dim=1),
    }
    if cfg.qkv_bias:
        bias_spec = P(M) if kv_sharded else P(None)
        defs["b_q"] = ParamDef((plan.n_heads_p * hd,), P(M), init="zeros")
        defs["b_k"] = ParamDef((kv_cols,), bias_spec, init="zeros")
        defs["b_v"] = ParamDef((kv_cols,), bias_spec, init="zeros")
    return defs


def _mla_defs(cfg: ModelConfig, plan: ShardPlan, dist: Dist) -> Dict[str, ParamDef]:
    m, d, M = cfg.mla, cfg.d_model, dist.model_axis
    qd = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "w_dq": ParamDef((d, m.q_lora_rank), P(None, None), init="scaled", scale_dim=0),
        "q_norm": ParamDef((m.q_lora_rank,), P(None), init="zeros"),
        "w_uq": ParamDef((m.q_lora_rank, plan.n_heads_p * qd), P(None, M), init="scaled", scale_dim=0),
        "w_dkv": ParamDef((d, m.kv_lora_rank + m.qk_rope_head_dim), P(None, None), init="scaled", scale_dim=0),
        "kv_norm": ParamDef((m.kv_lora_rank,), P(None), init="zeros"),
        "w_uk": ParamDef((m.kv_lora_rank, plan.n_heads_p * m.qk_nope_head_dim), P(None, M), init="scaled", scale_dim=0),
        "w_uv": ParamDef((m.kv_lora_rank, plan.n_heads_p * m.v_head_dim), P(None, M), init="scaled", scale_dim=0),
        "w_o": ParamDef((plan.n_heads_p, m.v_head_dim, d), P(M, None, None), init="scaled", scale_dim=1),
    }


# ---------------------------------------------------------------------------
# KV cache
# ---------------------------------------------------------------------------


def init_cache(
    cfg: ModelConfig,
    plan: ShardPlan,
    dist: Dist,
    batch_local: int,
    cache_len_local: int,
    *,
    kind: str,
    dtype=jnp.bfloat16,
    quant: bool = False,
    batched_pos: bool = False,
    paged: Optional[Tuple[int, int]] = None,
) -> Dict[str, jax.Array]:
    """Per-shard cache buffers for one layer (stacked by the scan outside).

    quant=True stores K/V as int8 with a per-(batch, head, slot) bf16 absmax
    scale — halves cache HBM residency + read traffic (beyond-paper).

    batched_pos=True gives every batch row (slot) its own position array —
    the continuous-batching engine decodes with a per-slot position vector,
    so validity masks must be trackable per row.

    paged=(n_blocks_local, block_size) swaps the dense per-slot K/V stripes
    for a global block pool addressed through per-slot block tables (see
    runtime.kvcache): memory scales with blocks actually allocated, not
    n_slots x max_seq.  Position arrays stay per-slot dense over the padded
    view length, so validity masking is unchanged."""
    if paged is not None:
        n_blocks, bs = paged
        view = -(-cache_len_local // bs) * bs
        pos = jnp.full((batch_local, view), -1, jnp.int32)
        if cfg.mla is not None:
            m = cfg.mla
            return {
                "ckv": jnp.zeros((n_blocks, bs, m.kv_lora_rank), dtype),
                "krope": jnp.zeros((n_blocks, bs, m.qk_rope_head_dim), dtype),
                "pos": pos,
            }
        hd = cfg.resolved_head_dim
        shape = (n_blocks, plan.local_kv, bs, hd)
        if quant:
            return {
                "k": jnp.zeros(shape, jnp.int8),
                "v": jnp.zeros(shape, jnp.int8),
                "k_scale": jnp.zeros(shape[:3], dtype),
                "v_scale": jnp.zeros(shape[:3], dtype),
                "pos": pos,
            }
        return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
                "pos": pos}
    pos_shape = (batch_local, cache_len_local) if batched_pos else (cache_len_local,)
    pos = jnp.full(pos_shape, -1, jnp.int32)
    if cfg.mla is not None:
        m = cfg.mla   # latent cache is already 10-30x smaller; no quant
        return {
            "ckv": jnp.zeros((batch_local, cache_len_local, m.kv_lora_rank), dtype),
            "krope": jnp.zeros((batch_local, cache_len_local, m.qk_rope_head_dim), dtype),
            "pos": pos,
        }
    hd = cfg.resolved_head_dim
    shape = (batch_local, plan.local_kv, cache_len_local, hd)
    if quant:
        return {
            "k": jnp.zeros(shape, jnp.int8),
            "v": jnp.zeros(shape, jnp.int8),
            "k_scale": jnp.zeros(shape[:3], dtype),
            "v_scale": jnp.zeros(shape[:3], dtype),
            "pos": pos,
        }
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        "pos": pos,
    }


def _quantize_kv(x: jax.Array):
    """(b,h,s,hd) -> (int8 values, (b,h,s) scales)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax, 1e-6) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.bfloat16)


def _dequantize_kv(q: jax.Array, scale: jax.Array, dtype=jnp.bfloat16):
    return (q.astype(jnp.float32) * scale.astype(jnp.float32)[..., None]).astype(dtype)


def cache_len_for(cfg: ModelConfig, kind: str, seq_len: int, kv_seq_shard_dp: int,
                  ring_slack: int = 0) -> int:
    """Per-shard cache length: windowed archs cap at window (plus
    ``ring_slack`` spare ring entries so a spec-decode verify writing
    ``spec_k`` draft tokens past the frontier never clobbers an in-window
    entry), seq-sharding divides over the data axis."""
    if cfg.window and kind == "local_attn":
        eff = min(seq_len, cfg.window + ring_slack)
    else:
        eff = seq_len
    if kv_seq_shard_dp > 1 and eff == seq_len:
        eff = -(-seq_len // kv_seq_shard_dp)
    return eff


# ---------------------------------------------------------------------------
# Core attention math
# ---------------------------------------------------------------------------


def _grouped_scores(q: jax.Array, k: jax.Array) -> jax.Array:
    """q (b,hq,Sq,hd) x k (b,hkv,Sk,hd) -> (b,hq,Sq,Sk) fp32, GQA groups.

    Inputs stay in their storage dtype (bf16) with fp32 ACCUMULATION
    (preferred_element_type) — casting the KV cache to fp32 first would
    materialise a 2x-sized copy of the whole cache per layer (§Perf H1:
    measured 97.5 -> 43.7 GB/device on qwen2.5-32b decode_32k)."""
    b, hq, sq, hd = q.shape
    hkv = k.shape[1]
    g = hq // hkv
    qg = q.reshape(b, hkv, g, sq, hd)
    s = jnp.einsum("bkgqd,bksd->bkgqs", qg, k,
                   preferred_element_type=jnp.float32)
    return s.reshape(b, hq, sq, k.shape[2])


def _grouped_attend(w: jax.Array, v: jax.Array) -> jax.Array:
    b, hq, sq, sk = w.shape
    hkv = v.shape[1]
    g = hq // hkv
    wg = w.reshape(b, hkv, g, sq, sk)
    out = jnp.einsum("bkgqs,bksd->bkgqd", wg.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, hq, sq, v.shape[3])


def chunked_causal_attention(
    q: jax.Array,                 # (b, hq, Sq, hd) — RoPE already applied
    k: jax.Array,                 # (b, hkv, Sk, hd)
    v: jax.Array,
    q_positions: jax.Array,       # (Sq,) absolute positions, or (b, Sq) per-row
    kv_positions: jax.Array,      # (Sk,) absolute positions (-1 = empty slot),
                                  # or (b, Sk) per-row (ring caches: view
                                  # index != position, each row's pos stripe
                                  # names what its ring slots hold)
    window: int,                  # 0 = full causal
    scale: float,
) -> jax.Array:
    """Flash-style streaming softmax over KV chunks (pure jnp oracle path).

    Batched ``q_positions`` (b, Sq) serve the paged cached-prefix prefill:
    each row's suffix queries start at its own absolute offset while
    attending one shared KV view (view index == absolute position).
    Batched ``kv_positions`` (b, Sk) serve layouts where view index !=
    position (the sliding-window ring cache): masking follows the per-row
    position stripe instead of an implied arange."""
    b, hq, sq, hd = q.shape
    sk = k.shape[2]
    chunk = min(KV_CHUNK, sk)
    n_chunks = -(-sk // chunk)
    pad = n_chunks * chunk - sk
    batched_kv = kv_positions.ndim == 2
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        kv_positions = jnp.pad(
            kv_positions,
            ((0, 0), (0, pad)) if batched_kv else (0, pad),
            constant_values=-1)
    kc = k.reshape(b, k.shape[1], n_chunks, chunk, k.shape[3]).transpose(2, 0, 1, 3, 4)
    vc = v.reshape(b, v.shape[1], n_chunks, chunk, v.shape[3]).transpose(2, 0, 1, 3, 4)
    pc = (kv_positions.reshape(b, n_chunks, chunk).transpose(1, 0, 2)
          if batched_kv else kv_positions.reshape(n_chunks, chunk))
    batched_q = q_positions.ndim == 2

    def step(carry, inputs):
        m, l, acc = carry
        k_i, v_i, p_i = inputs
        s = _grouped_scores(q, k_i) * scale                      # (b,hq,Sq,chunk)
        if batched_q or batched_kv:
            qp = (q_positions[:, :, None] if batched_q
                  else q_positions[None, :, None])               # (b|1,Sq,1)
            pkv = p_i[:, None, :] if batched_kv else p_i[None, None, :]
            valid = (pkv >= 0) & (pkv <= qp)
            if window:
                valid &= pkv > qp - window
            s = jnp.where(valid[:, None], s, -jnp.inf)
        else:
            valid = (p_i[None, :] >= 0) & (p_i[None, :] <= q_positions[:, None])
            if window:
                valid &= p_i[None, :] > q_positions[:, None] - window
            s = jnp.where(valid[None, None], s, -jnp.inf)
        m_new = jnp.maximum(m, s.max(axis=-1))
        # guard: fully-masked rows keep m = -inf; exp(-inf - -inf) -> use where
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe[..., None])
        corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + _grouped_attend(p, v_i)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hq, sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, hq, sq), jnp.float32)
    acc0 = jnp.zeros((b, hq, sq, v.shape[3]), jnp.float32)  # v_dim may != hd (MLA)
    from repro.models.common import maybe_scan
    (m, l, acc), _ = maybe_scan(step, (m0, l0, acc0), (kc, vc, pc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.astype(q.dtype)


def mla_latent_attention(
    qa: jax.Array,                # (b, h, Sq, rank) absorbed nope queries, bf16
    qr: jax.Array,                # (b, h, Sq, rope) RoPE'd rope queries, bf16
    kv_src: jax.Array,            # (b, Sk, rank) latent cache / fresh latents
    krope_src: jax.Array,         # (b, Sk, rope)
    q_positions: jax.Array,       # (Sq,) shared or (b, Sq) per-row
    kv_positions: jax.Array,      # (Sk,) shared or (b, Sk) per-row (-1 = empty)
    scale: float,
) -> jax.Array:
    """Streaming two-dot latent attention (MLA prefill/chunk/verify path).

    Per-chunk math mirrors the decode branch exactly — separate nope/rope
    score dots (§Perf H2: no cache-sized concat), one-pass masked softmax,
    fp32 accumulation, fp32 output (no bf16 round-trip).  For caches at or
    below KV_CHUNK entries the stream is a single chunk and the result is
    bit-identical to decode at the same state — the property the
    chunked==whole and spec==plain greedy admission identities rest on."""
    b, h, sq, _ = qa.shape
    sk = kv_src.shape[1]
    chunk = min(KV_CHUNK, sk)
    n_chunks = -(-sk // chunk)
    pad = n_chunks * chunk - sk
    batched_kv = kv_positions.ndim == 2
    if pad:
        kv_src = jnp.pad(kv_src, ((0, 0), (0, pad), (0, 0)))
        krope_src = jnp.pad(krope_src, ((0, 0), (0, pad), (0, 0)))
        kv_positions = jnp.pad(
            kv_positions,
            ((0, 0), (0, pad)) if batched_kv else (0, pad),
            constant_values=-1)
    kc = kv_src.reshape(b, n_chunks, chunk, -1).transpose(1, 0, 2, 3)
    rc = krope_src.reshape(b, n_chunks, chunk, -1).transpose(1, 0, 2, 3)
    pc = (kv_positions.reshape(b, n_chunks, chunk).transpose(1, 0, 2)
          if batched_kv else kv_positions.reshape(n_chunks, chunk))
    qpos = q_positions if q_positions.ndim == 2 else q_positions[None, :]

    def step(carry, inputs):
        m, l, acc = carry
        k_i, r_i, p_i = inputs
        s_nope = jnp.einsum("bhsr,btr->bhst", qa, k_i,
                            preferred_element_type=jnp.float32)
        s_rope = jnp.einsum("bhse,bte->bhst", qr, r_i,
                            preferred_element_type=jnp.float32)
        sc = (s_nope + s_rope) * scale                       # (b,h,Sq,chunk)
        pkv = p_i[:, None, :] if p_i.ndim == 2 else p_i[None, None, :]
        valid = (pkv >= 0) & (pkv <= qpos[:, :, None])       # (b|1,Sq,chunk)
        sc = jnp.where(valid[:, None], sc, -jnp.inf)
        m_new = jnp.maximum(m, sc.max(axis=-1))
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(sc - m_safe[..., None])
        corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhst,btr->bhsr", p.astype(qa.dtype), k_i,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, h, sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    acc0 = jnp.zeros((b, h, sq, kv_src.shape[-1]), jnp.float32)
    from repro.models.common import maybe_scan
    (m, l, acc), _ = maybe_scan(step, (m0, l0, acc0), (kc, rc, pc))
    return acc / jnp.maximum(l, 1e-30)[..., None]   # fp32 — decode-congruent


def banded_causal_attention(
    q: jax.Array,                 # (b, hq, S, hd) — RoPE applied
    k: jax.Array,                 # (b, hkv, S, hd)
    v: jax.Array,
    positions: jax.Array,         # (S,) absolute
    window: int,
    scale: float,
    q_chunk: int = 1024,
) -> jax.Array:
    """Sliding-window prefill in O(S·window) instead of O(S^2) (§Perf H6).

    Scans query chunks; each attends only its [pos-window, pos] KV band,
    sliced with a front-padded cache so slice bounds are static."""
    b, hq, S, hd = q.shape
    cq = min(q_chunk, S)
    if S % cq:
        return chunked_causal_attention(q, k, v, positions, positions, window, scale)
    n_q = S // cq
    band = window + cq            # covers every query in the chunk
    pad = band                    # front pad so (start >= 0) always
    kp = jnp.pad(k, ((0, 0), (0, 0), (pad, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (pad, 0), (0, 0)))
    pp = jnp.pad(positions, (pad, 0), constant_values=-1)
    qc = q.reshape(b, hq, n_q, cq, hd).transpose(2, 0, 1, 3, 4)   # (n_q,b,hq,cq,hd)
    pc = positions.reshape(n_q, cq)

    def one(i, q_i, qpos_i):
        start = pad + (i + 1) * cq - band
        k_i = jax.lax.dynamic_slice_in_dim(kp, start, band, axis=2)
        v_i = jax.lax.dynamic_slice_in_dim(vp, start, band, axis=2)
        p_i = jax.lax.dynamic_slice_in_dim(pp, start, band, axis=0)
        s = _grouped_scores(q_i, k_i) * scale                     # (b,hq,cq,band)
        ok = (p_i[None, :] >= 0) & (p_i[None, :] <= qpos_i[:, None])
        ok &= p_i[None, :] > qpos_i[:, None] - window
        s = jnp.where(ok[None, None], s, -jnp.inf)
        m = s.max(axis=-1)
        m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
        p = jnp.exp(s - m_safe[..., None])
        l = p.sum(axis=-1)
        o = _grouped_attend(p, v_i) / jnp.maximum(l, 1e-30)[..., None]
        return o.astype(q.dtype)

    def body(_, inp):
        i, q_i, qp_i = inp
        return None, one(i, q_i, qp_i)

    from repro.models.common import maybe_scan
    _, out = maybe_scan(body, None,
                        (jnp.arange(n_q, dtype=jnp.int32), qc, pc))
    return out.transpose(1, 2, 0, 3, 4).reshape(b, hq, S, hd)


def _prefill_attention(q, k, v, positions, window, scale, *,
                       use_flash: bool = False):
    """Dispatch: fused Pallas flash-prefill kernel on the hot path
    (full-causal, fresh K/V: view index == position), banded O(S*window)
    path for long windowed prefill (§Perf H6), pure-JAX chunked flash scan
    as the reference + fallback (windowed prefill, MLA)."""
    S = q.shape[2]
    if use_flash and not window and positions.ndim == 1:
        from repro.kernels import ops as kops

        qpos = jnp.broadcast_to(positions[None, :], (q.shape[0], S))
        return kops.flash_prefill(q, k, v, qpos, scale)
    if window and S >= 4 * window and S % min(1024, S) == 0:
        return banded_causal_attention(q, k, v, positions, window, scale)
    return chunked_causal_attention(q, k, v, positions, positions, window, scale)


def decode_attention_shardable(
    q: jax.Array,                 # (b, hq, 1, hd)
    k: jax.Array,                 # (b, hkv, S_local, hd) cache slice
    v: jax.Array,
    kv_positions: jax.Array,      # (S_local,) shared or (b, S_local) per-slot
    cur_pos: jax.Array,           # int32 query position: scalar or (b,) per-slot
    window: int,
    scale: float,
    dist: Dist,
    *,
    seq_axis: Optional[str] = None,   # data axis name when cache is seq-sharded
    use_pallas: bool = False,
) -> jax.Array:
    """Single-token attention over the (possibly seq-sharded) cache.

    When ``seq_axis`` is given, each shard holds a slice of the cache
    sequence; partials are merged with a log-sum-exp psum of (num, denom) —
    O(b·h·hd) bytes instead of gathering the O(S) cache.

    With per-slot positions (continuous batching) ``cur_pos`` is a (b,)
    vector and ``kv_positions`` is (b, S): every slot masks against its own
    progress, so slots at different depths decode in one program.
    """
    batched = cur_pos.ndim == 1
    if batched:
        kvp = kv_positions if kv_positions.ndim == 2 else kv_positions[None, :]
        valid = (kvp >= 0) & (kvp <= cur_pos[:, None])
        if window:
            valid &= kvp > cur_pos[:, None] - window
        vmask = valid[:, None, None, :]                          # (b,1,1,S)
    else:
        valid = (kv_positions >= 0) & (kv_positions <= cur_pos)
        if window:
            valid &= kv_positions > cur_pos - window
        vmask = valid[None, None, None, :]
    if (use_pallas and not batched and q.shape[-1] % 128 == 0
            and k.shape[2] % 128 == 0):
        from repro.kernels import ops as kops

        m, l, acc = kops.decode_attention_partial(q, k, v, valid, scale)
    else:
        s = _grouped_scores(q, k) * scale                        # (b,hq,1,S)
        s = jnp.where(vmask, s, -jnp.inf)
        m = s.max(axis=-1)                                       # (b,hq,1)
        m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
        p = jnp.exp(s - m_safe[..., None])
        l = p.sum(axis=-1)
        acc = _grouped_attend(p, v)                              # (b,hq,1,hd)
    if seq_axis is not None:
        m_g = jax.lax.pmax(m, seq_axis)
        m_gs = jnp.where(jnp.isfinite(m_g), m_g, 0.0)
        corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_gs), 0.0)
        l, acc = cc.psum(
            (l * corr, acc * corr[..., None]), seq_axis, tag="lse_merge"
        )
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# Cache update helpers
# ---------------------------------------------------------------------------


def _write_prefill(cache_side: jax.Array, new: jax.Array, positions: jax.Array, S: int,
                   seq_axis: Optional[str] = None):
    """Write (b,h,s,hd) prefill K/V into an (b,h,S,hd) cache; keeps last S.

    With ``seq_axis`` (sequence-sharded cache) each shard takes its own slice
    of the prefill; requires s == S * axis_size."""
    new = new.astype(cache_side.dtype)
    s = new.shape[2]
    if seq_axis is not None:
        from repro import compat

        ns = compat.axis_size(seq_axis)
        if s > S * ns:
            raise ValueError(f"seq-sharded prefill needs s <= S*shards ({s} > {S}*{ns})")
        if s < S * ns:  # pad; padded slots keep pos = -1 (masked, decode-writable)
            new = jnp.pad(new, ((0, 0), (0, 0), (0, S * ns - s), (0, 0)))
            positions = jnp.pad(positions, (0, S * ns - s), constant_values=-1)
        idx = jax.lax.axis_index(seq_axis)
        new = jax.lax.dynamic_slice_in_dim(new, idx * S, S, axis=2)
        pos = jax.lax.dynamic_slice_in_dim(positions, idx * S, S, axis=0)
        return jax.lax.dynamic_update_slice_in_dim(cache_side, new, 0, axis=2), pos
    if s <= S:
        out = jax.lax.dynamic_update_slice_in_dim(cache_side, new, 0, axis=2)
        pos = positions[:S] if s == S else jnp.concatenate(
            [positions, jnp.full((S - s,), -1, jnp.int32)]
        )
        return out, pos
    # window cache smaller than prefill: keep the last S tokens, ring layout
    tail = new[:, :, -S:, :]
    tail_pos = positions[-S:]
    slots = tail_pos % S
    out = cache_side.at[:, :, slots, :].set(tail)
    pos = jnp.zeros((S,), jnp.int32).at[slots].set(tail_pos)
    return out, pos


def _write_prefill_chunk(cache_side: jax.Array, new: jax.Array,
                         starts: jax.Array) -> jax.Array:
    """Scatter a (b,h,C,hd) prefill CHUNK into the dense (b,h,S,hd) slot
    cache with each row at its own view offset ``starts[b]`` — the resume
    point of chunked admission (chunk k of a prompt lands at
    [k*C, k*C + C)) and of the spec-decode verify step.  Writes past the
    cache end are DROPPED, not clamped: chunk-tail padding (and rejected
    verify drafts) on a row whose frontier reaches the last cache entry
    would otherwise race the real write at S-1 with an undefined
    duplicate-index winner.  In-range tail garbage stays dead because the
    engine's position-row rewrite marks only [0, start + len) valid."""
    b, h, C, hd = new.shape
    vpos = starts[:, None] + jnp.arange(C, dtype=jnp.int32)[None, :]   # (b,C)
    return cache_side.at[jnp.arange(b)[:, None], :, vpos, :].set(
        new.transpose(0, 2, 1, 3).astype(cache_side.dtype), mode="drop")


def _write_prefill_chunk_scale(cache_side: jax.Array, new: jax.Array,
                               starts: jax.Array) -> jax.Array:
    """Scale variant: (b,h,C) chunk into the (b,h,S) scale stripe."""
    b, h, C = new.shape
    vpos = starts[:, None] + jnp.arange(C, dtype=jnp.int32)[None, :]
    return cache_side.at[jnp.arange(b)[:, None], :, vpos].set(
        new.transpose(0, 2, 1).astype(cache_side.dtype), mode="drop")


def _write_prefill_chunk_ring(cache_side: jax.Array, new: jax.Array,
                              positions: jax.Array,
                              real: jax.Array) -> jax.Array:
    """Scatter a (b,h,C,hd) chunk into the (b,h,S,hd) RING cache, each token
    at its ring slot ``position % S``.  Chunk-pad columns (``real`` False)
    are dropped: unlike the dense chunk writer — whose in-range tail garbage
    stays dead behind the position row — every in-range ring index is a live
    in-window entry, so pad garbage must never land."""
    b, h, C, hd = new.shape
    S = cache_side.shape[2]
    wslot = jnp.where(real, positions % S, S)                      # S = drop
    return cache_side.at[jnp.arange(b)[:, None], :, wslot, :].set(
        new.transpose(0, 2, 1, 3).astype(cache_side.dtype), mode="drop")


def _write_prefill_chunk_ring_scale(cache_side: jax.Array, new: jax.Array,
                                    positions: jax.Array,
                                    real: jax.Array) -> jax.Array:
    """Scale variant: (b,h,C) chunk into the (b,h,S) ring scale stripe."""
    b, h, C = new.shape
    S = cache_side.shape[2]
    wslot = jnp.where(real, positions % S, S)
    return cache_side.at[jnp.arange(b)[:, None], :, wslot].set(
        new.transpose(0, 2, 1).astype(cache_side.dtype), mode="drop")


def _write_decode(cache_side: jax.Array, new: jax.Array, cur_pos: jax.Array,
                  S: int, ring: bool, seq_shard: Optional[Tuple[str, int]]):
    """Write one token (b,h,1,hd) at its slot; returns updated cache."""
    new = new.astype(cache_side.dtype)
    if ring:
        slot = cur_pos % S
        return jax.lax.dynamic_update_slice_in_dim(cache_side, new, slot, axis=2)
    if seq_shard is None:
        return jax.lax.dynamic_update_slice_in_dim(cache_side, new, cur_pos, axis=2)
    axis, S_local = seq_shard
    owner = cur_pos // S_local
    slot = cur_pos - owner * S_local
    mine = jax.lax.axis_index(axis) == owner
    updated = jax.lax.dynamic_update_slice_in_dim(cache_side, new, slot, axis=2)
    return jnp.where(mine, updated, cache_side)


def _write_pos(pos_arr: jax.Array, cur_pos: jax.Array, S: int, ring: bool,
               seq_shard: Optional[Tuple[str, int]]):
    one = cur_pos[None].astype(jnp.int32)
    if ring:
        return jax.lax.dynamic_update_slice(pos_arr, one, (cur_pos % S,))
    if seq_shard is None:
        return jax.lax.dynamic_update_slice(pos_arr, one, (cur_pos,))
    axis, S_local = seq_shard
    owner = cur_pos // S_local
    slot = cur_pos - owner * S_local
    mine = jax.lax.axis_index(axis) == owner
    updated = jax.lax.dynamic_update_slice(pos_arr, one, (slot,))
    return jnp.where(mine, updated, pos_arr)


def _slot_index(pos: jax.Array, S: int, ring: bool) -> jax.Array:
    """Per-slot write index from a (b,) position vector.

    Empty/overrun slots are clamped in range; their rows are either masked
    (pos entry -1) or already retired, so the clamped write is harmless and
    keeps the gather/scatter free of out-of-bounds semantics."""
    slot = jnp.maximum(pos, 0)
    return slot % S if ring else jnp.minimum(slot, S - 1)


def _write_decode_batched(cache_side: jax.Array, new: jax.Array,
                          pos: jax.Array, S: int, ring: bool):
    """Write one token (b,h,1,hd) with EACH row at its own slot pos[b]."""
    new = new.astype(cache_side.dtype)
    slot = _slot_index(pos, S, ring)
    b = cache_side.shape[0]
    return cache_side.at[jnp.arange(b), :, slot, :].set(new[:, :, 0, :])


def _write_pos_batched(pos_arr: jax.Array, pos: jax.Array, S: int, ring: bool):
    """pos_arr (b,S): record each row's absolute position at its own slot."""
    slot = _slot_index(pos, S, ring)
    b = pos_arr.shape[0]
    return pos_arr.at[jnp.arange(b), slot].set(pos.astype(jnp.int32))


# ---------------------------------------------------------------------------
# Paged addressing (block pool + per-slot block tables)
#
# Pool leaves have a leading block dim instead of a batch dim; a slot's view
# of the cache is the concatenation of its table's blocks, so view index ==
# absolute position.  Out-of-range or unallocated view positions map to the
# reserved null block 0 — a write sink that is never validly read (its view
# entries carry pos = -1).  Gathering the view materialises a dense-shaped
# TRANSIENT per layer (the jnp reference path); persistent storage is the
# pool, and the Pallas decode kernel gathers block-by-block instead.
# ---------------------------------------------------------------------------


def _paged_view(pool: jax.Array, bt: jax.Array) -> jax.Array:
    """K/V pool (nb, h, bs, hd) gathered through bt (b, nbps) -> per-slot
    dense view (b, h, nbps*bs, hd); view index == absolute position."""
    b, nbps = bt.shape
    g = pool[bt].transpose(0, 2, 1, 3, 4)        # (b, h, nbps, bs, hd)
    return g.reshape(b, g.shape[1], nbps * pool.shape[2], pool.shape[3])


def _paged_view_seq(pool: jax.Array, bt: jax.Array) -> jax.Array:
    """Sequence-major pool (nb, bs, r) -> (b, nbps*bs, r) (MLA latents)."""
    b, nbps = bt.shape
    g = pool[bt]                                 # (b, nbps, bs, r)
    return g.reshape(b, nbps * pool.shape[1], pool.shape[2])


def _paged_view_scale(pool: jax.Array, bt: jax.Array) -> jax.Array:
    """Scale pool (nb, h, bs) -> (b, h, nbps*bs)."""
    b, nbps = bt.shape
    g = pool[bt].transpose(0, 2, 1, 3)           # (b, h, nbps, bs)
    return g.reshape(b, g.shape[1], nbps * pool.shape[2])


def _paged_decode_targets(bt: jax.Array, pos: jax.Array, bs: int):
    """(b,) write positions -> (physical block id, in-block offset); rows
    whose position falls outside the table (frozen/overrun slots) redirect
    to the null block."""
    nbps = bt.shape[1]
    p = jnp.maximum(pos, 0)
    vi, off = p // bs, p % bs
    phys = jnp.where(vi < nbps,
                     bt[jnp.arange(bt.shape[0]), jnp.minimum(vi, nbps - 1)], 0)
    return phys, off


def _paged_write_decode(pool: jax.Array, new: jax.Array, bt: jax.Array,
                        pos: jax.Array) -> jax.Array:
    """One token per row at its own position: pool (nb,h,bs,hd), new (b,h,1,hd)."""
    phys, off = _paged_decode_targets(bt, pos, pool.shape[2])
    return pool.at[phys, :, off, :].set(new[:, :, 0, :].astype(pool.dtype))


def _paged_write_decode_seq(pool: jax.Array, new: jax.Array, bt: jax.Array,
                            pos: jax.Array) -> jax.Array:
    """Sequence-major decode write: pool (nb,bs,r), new (b,1,r)."""
    phys, off = _paged_decode_targets(bt, pos, pool.shape[1])
    return pool.at[phys, off, :].set(new[:, 0, :].astype(pool.dtype))


def _paged_write_decode_scale(pool: jax.Array, new: jax.Array, bt: jax.Array,
                              pos: jax.Array) -> jax.Array:
    """Scale decode write: pool (nb,h,bs), new (b,h,1)."""
    phys, off = _paged_decode_targets(bt, pos, pool.shape[2])
    return pool.at[phys, :, off].set(new[:, :, 0].astype(pool.dtype))


def _paged_flat_targets(bt: jax.Array, starts: jax.Array, Lp: int, bs: int):
    """Flattened (b*Lp,) physical block ids + offsets for a prefill whose
    row b covers view positions [starts[b], starts[b]+Lp)."""
    nbps = bt.shape[1]
    vpos = starts[:, None] + jnp.arange(Lp, dtype=jnp.int32)[None, :]  # (b,Lp)
    vi, off = vpos // bs, vpos % bs
    phys = jnp.where(vi < nbps,
                     jnp.take_along_axis(bt, jnp.minimum(vi, nbps - 1), axis=1),
                     0)
    return phys.reshape(-1), off.reshape(-1)


def _paged_write_prefill(pool: jax.Array, new: jax.Array, bt: jax.Array,
                         starts: jax.Array) -> jax.Array:
    """Scatter prefill K/V (b,h,Lp,hd) into the pool at each row's own view
    offsets.  Padding tokens land in the row's private tail block or the
    null block — never in a shared (registered, hence full) prefix block."""
    b, h, Lp, hd = new.shape
    phys, off = _paged_flat_targets(bt, starts, Lp, pool.shape[2])
    flat = new.transpose(0, 2, 1, 3).reshape(b * Lp, h, hd)
    return pool.at[phys, :, off, :].set(flat.astype(pool.dtype))


def _paged_write_prefill_seq(pool: jax.Array, new: jax.Array, bt: jax.Array,
                             starts: jax.Array) -> jax.Array:
    """Sequence-major prefill scatter: pool (nb,bs,r), new (b,Lp,r)."""
    b, Lp, r = new.shape
    phys, off = _paged_flat_targets(bt, starts, Lp, pool.shape[1])
    return pool.at[phys, off, :].set(new.reshape(b * Lp, r).astype(pool.dtype))


def _paged_write_prefill_scale(pool: jax.Array, new: jax.Array, bt: jax.Array,
                               starts: jax.Array) -> jax.Array:
    """Scale prefill scatter: pool (nb,h,bs), new (b,h,Lp)."""
    b, h, Lp = new.shape
    phys, off = _paged_flat_targets(bt, starts, Lp, pool.shape[2])
    return pool.at[phys, :, off].set(
        new.transpose(0, 2, 1).reshape(b * Lp, h).astype(pool.dtype))


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _slice_kv_weight(w, plan: ShardPlan, dist: Dist, hd: int):
    """Replicated (d, n_kv*hd) KV weight -> this shard's (d, local_kv*hd).

    Quantized weights slice q AND scale along the output-column dim (both
    carry the replicated spec in this layout, so the slice is local)."""
    if plan.n_kv_p >= plan.tp:
        return w  # already sharded by pjit/shard_map in_specs
    kv_head = dist.model_idx() // plan.kv_rep
    start = kv_head * plan.local_kv * hd
    if isinstance(w, wquant.QuantWeight):
        return wquant.slice_cols(w, start, plan.local_kv * hd)
    return jax.lax.dynamic_slice_in_dim(w, start, plan.local_kv * hd,
                                        axis=w.ndim - 1)


def gqa_forward(
    params: Dict[str, jax.Array],
    x: jax.Array,                 # (b, s, d) replicated over model axis
    positions: jax.Array,         # (s,) absolute
    cfg: ModelConfig,
    plan: ShardPlan,
    dist: Dist,
    *,
    kind: str,                    # "attn" | "local_attn"
    cache: Optional[Dict[str, jax.Array]] = None,
    cur_pos: Optional[jax.Array] = None,    # scalar, decode only
    kv_seq_axis: Optional[str] = None,
    use_pallas: bool = False,
    flash_prefill: bool = False,
    block_tables: Optional[jax.Array] = None,   # (b, nbps) -> paged cache
    length_mask: Optional[jax.Array] = None,    # (b, s) bool: real (non-pad)
                                                # chunk columns (ring writes)
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """Returns (partial out (b,s,d) — UNREDUCED over model axis, new_cache)."""
    b, s, d = x.shape
    hd = cfg.resolved_head_dim
    window = cfg.window if kind == "local_attn" else 0
    scale = 1.0 / math.sqrt(hd)
    decode = cache is not None and s == 1
    use_flash = use_pallas and flash_prefill

    q = wquant.matmul(x, params["w_q"])
    if "b_q" in params:
        q = q + params["b_q"]
    w_k = _slice_kv_weight(params["w_k"], plan, dist, hd)
    w_v = _slice_kv_weight(params["w_v"], plan, dist, hd)
    k = wquant.matmul(x, w_k)
    v = wquant.matmul(x, w_v)
    if "b_k" in params:
        b_k = _slice_kv_weight(params["b_k"][None], plan, dist, hd)[0]
        b_v = _slice_kv_weight(params["b_v"][None], plan, dist, hd)[0]
        k, v = k + b_k, v + b_v

    q = q.reshape(b, s, plan.local_q, hd).transpose(0, 2, 1, 3)
    k = k.reshape(b, s, plan.local_kv, hd).transpose(0, 2, 1, 3)
    v = v.reshape(b, s, plan.local_kv, hd).transpose(0, 2, 1, 3)
    rope_pos = positions[None, None, :] if positions.ndim == 1 else positions[:, None, :]
    q = apply_rope(q, rope_pos, cfg.rope_theta)
    k = apply_rope(k, rope_pos, cfg.rope_theta)

    new_cache = None
    if cache is not None and block_tables is not None:
        # -- paged: scatter/gather K/V through the per-slot block table ----
        bt = block_tables
        quant = "k_scale" in cache
        if decode:
            if cur_pos.ndim != 1:
                raise ValueError("paged cache serves the slot engine only "
                                 "(per-slot decode positions)")
            S_view = cache["pos"].shape[-1]
            if quant:
                kq, ksc = _quantize_kv(k)
                vq, vsc = _quantize_kv(v)
                ck = _paged_write_decode(cache["k"], kq, bt, cur_pos)
                cv = _paged_write_decode(cache["v"], vq, bt, cur_pos)
                cks = _paged_write_decode_scale(cache["k_scale"], ksc, bt, cur_pos)
                cvs = _paged_write_decode_scale(cache["v_scale"], vsc, bt, cur_pos)
                cpos = _write_pos_batched(cache["pos"], cur_pos, S_view, False)
                new_cache = {"k": ck, "v": cv, "k_scale": cks, "v_scale": cvs,
                             "pos": cpos}
                k_read = _dequantize_kv(_paged_view(ck, bt), _paged_view_scale(cks, bt))
                v_read = _dequantize_kv(_paged_view(cv, bt), _paged_view_scale(cvs, bt))
            else:
                ck = _paged_write_decode(cache["k"], k, bt, cur_pos)
                cv = _paged_write_decode(cache["v"], v, bt, cur_pos)
                cpos = _write_pos_batched(cache["pos"], cur_pos, S_view, False)
                new_cache = {"k": ck, "v": cv, "pos": cpos}
                k_read, v_read = None, None      # Pallas path gathers per block
            if not quant and use_pallas:
                from repro.kernels import ops as kops

                valid = (cpos >= 0) & (cpos <= cur_pos[:, None])
                if window:
                    valid &= cpos > cur_pos[:, None] - window
                m, l, acc = kops.paged_decode_attention(q, ck, cv, bt, valid, scale)
                out = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)
            else:
                if k_read is None:
                    k_read, v_read = _paged_view(ck, bt), _paged_view(cv, bt)
                out = decode_attention_shardable(
                    q, k_read, v_read, cpos, cur_pos, window, scale, dist,
                    seq_axis=None, use_pallas=False,
                )
        else:
            starts = (positions[:, 0] if positions.ndim == 2
                      else jnp.zeros((b,), jnp.int32))
            if quant:
                kq, ksc = _quantize_kv(k)
                vq, vsc = _quantize_kv(v)
                ck = _paged_write_prefill(cache["k"], kq, bt, starts)
                cv = _paged_write_prefill(cache["v"], vq, bt, starts)
                cks = _paged_write_prefill_scale(cache["k_scale"], ksc, bt, starts)
                cvs = _paged_write_prefill_scale(cache["v_scale"], vsc, bt, starts)
                new_cache = {"k": ck, "v": cv, "k_scale": cks, "v_scale": cvs,
                             "pos": cache["pos"]}
            else:
                ck = _paged_write_prefill(cache["k"], k, bt, starts)
                cv = _paged_write_prefill(cache["v"], v, bt, starts)
                new_cache = {"k": ck, "v": cv, "pos": cache["pos"]}
            # pos rows are rewritten whole by the engine (set_slot_positions)
            if positions.ndim == 2:
                # cached-prefix / chunked admission: suffix or chunk queries
                # attend the slot's full view (resident blocks + just-written
                # tokens); view index == absolute position, so a plain arange
                # is the KV position vector and causality does all the
                # masking.  The Pallas path gathers block-by-block through
                # the table; the jnp path materialises the dense view.
                if not quant and use_flash and not window:
                    from repro.kernels import ops as kops

                    # narrow chunks (spec-decode verify: Sq = spec_k+1) get
                    # their sublane-rounded q tile inside the kernel; KV
                    # blocking is pinned to the pool block size either way
                    out = kops.paged_flash_prefill(q, ck, cv, bt,
                                                   positions, scale)
                else:
                    if quant:
                        k_att = _dequantize_kv(_paged_view(ck, bt), _paged_view_scale(cks, bt))
                        v_att = _dequantize_kv(_paged_view(cv, bt), _paged_view_scale(cvs, bt))
                    else:
                        k_att, v_att = _paged_view(ck, bt), _paged_view(cv, bt)
                    kv_pos = jnp.arange(k_att.shape[2], dtype=jnp.int32)
                    out = chunked_causal_attention(q, k_att, v_att, positions,
                                                   kv_pos, window, scale)
            else:
                # no shared prefix in the batch: math identical to the dense
                # slot engine (attend the fresh K/V only; int8 attends the
                # dequantized values — exactly what decode will read back)
                if quant:
                    k_att, v_att = _dequantize_kv(kq, ksc), _dequantize_kv(vq, vsc)
                else:
                    k_att, v_att = k, v
                out = _prefill_attention(q, k_att, v_att, positions, window,
                                         scale, use_flash=use_flash)
    elif cache is not None:
        S = cache["k"].shape[2]
        ring = bool(window) and kv_seq_axis is None
        quant = "k_scale" in cache
        if decode:
            batched = cur_pos.ndim == 1        # per-slot positions (cont. batching)
            if batched and kv_seq_axis is not None:
                raise ValueError("per-slot decode positions are incompatible "
                                 "with kv_seq_shard (batch=1 long-context path)")
            seq_shard = (kv_seq_axis, S) if kv_seq_axis else None
            if batched:
                wd = lambda side, new: _write_decode_batched(side, new, cur_pos, S, ring)
                wp = lambda pa: _write_pos_batched(pa, cur_pos, S, ring)
            else:
                wd = lambda side, new: _write_decode(side, new, cur_pos, S, ring, seq_shard)
                wp = lambda pa: _write_pos(pa, cur_pos, S, ring, seq_shard)
            if quant:
                kq, ksc = _quantize_kv(k)
                vq, vsc = _quantize_kv(v)
                ck = wd(cache["k"], kq)
                cv = wd(cache["v"], vq)
                cks = wd(cache["k_scale"][..., None], ksc[..., None])[..., 0]
                cvs = wd(cache["v_scale"][..., None], vsc[..., None])[..., 0]
                cpos = wp(cache["pos"])
                new_cache = {"k": ck, "v": cv, "k_scale": cks, "v_scale": cvs,
                             "pos": cpos}
                k_read = _dequantize_kv(ck, cks)
                v_read = _dequantize_kv(cv, cvs)
            else:
                ck = wd(cache["k"], k)
                cv = wd(cache["v"], v)
                cpos = wp(cache["pos"])
                new_cache = {"k": ck, "v": cv, "pos": cpos}
                k_read, v_read = ck, cv
            out = decode_attention_shardable(
                q, k_read, v_read, cpos, cur_pos, window, scale, dist,
                seq_axis=kv_seq_axis, use_pallas=use_pallas,
            )
        elif positions.ndim == 2:
            # -- chunked admission (dense slot cache): scatter this chunk at
            # each row's own resume offset and attend the row's cache stripe
            # [0, start + C) — earlier chunks are read back from the cache,
            # so a chunk resumes exactly where the last one wrote.  Position
            # rows are rewritten whole by the engine (set_slot_positions);
            # causality (view index == absolute position) masks both the
            # not-yet-written tail and chunk-pad garbage.
            if kv_seq_axis is not None:
                raise ValueError("chunked prefill is incompatible with "
                                 "kv_seq_shard (batch=1 long-context path)")
            if window:
                # -- sliding-window RING chunk (view index != position).  A
                # ring has no dead tail: writing position p claims slot
                # p % S, clobbering the entry for p - S that THIS chunk's
                # earlier queries still attend.  So attend the PRE-write
                # cache — its per-row position stripe names what each ring
                # slot holds — concatenated with the fresh chunk K/V, then
                # scatter the chunk afterwards.  Post-chunk, every clobbered
                # position is >= window behind all later queries, so the
                # written ring is consistent for the next step.  The same
                # branch serves the spec-decode verify chunk (all columns
                # real; ring slack from cache_len_for keeps rejected drafts
                # from clobbering in-window entries).
                real = (length_mask.astype(bool) if length_mask is not None
                        else jnp.ones((b, s), bool))
                if quant:
                    kq, ksc = _quantize_kv(k)
                    vq, vsc = _quantize_kv(v)
                    k_old = _dequantize_kv(cache["k"], cache["k_scale"])
                    v_old = _dequantize_kv(cache["v"], cache["v_scale"])
                    k_new = _dequantize_kv(kq, ksc)
                    v_new = _dequantize_kv(vq, vsc)
                    ck = _write_prefill_chunk_ring(cache["k"], kq, positions, real)
                    cv = _write_prefill_chunk_ring(cache["v"], vq, positions, real)
                    cks = _write_prefill_chunk_ring_scale(
                        cache["k_scale"], ksc, positions, real)
                    cvs = _write_prefill_chunk_ring_scale(
                        cache["v_scale"], vsc, positions, real)
                    new_cache = {"k": ck, "v": cv, "k_scale": cks,
                                 "v_scale": cvs, "pos": cache["pos"]}
                else:
                    k_old, v_old = cache["k"], cache["v"]
                    k_new, v_new = k, v
                    ck = _write_prefill_chunk_ring(cache["k"], k, positions, real)
                    cv = _write_prefill_chunk_ring(cache["v"], v, positions, real)
                    new_cache = {"k": ck, "v": cv, "pos": cache["pos"]}
                k_att = jnp.concatenate([k_old, k_new.astype(k_old.dtype)], axis=2)
                v_att = jnp.concatenate([v_old, v_new.astype(v_old.dtype)], axis=2)
                kv_pos = jnp.concatenate(
                    [cache["pos"], jnp.where(real, positions, -1)], axis=1)
                out = chunked_causal_attention(q, k_att, v_att, positions,
                                               kv_pos, window, scale)
                partial = fused_out_projection(out, params["w_o"])
                return partial, new_cache
            starts = positions[:, 0]
            if quant:
                kq, ksc = _quantize_kv(k)
                vq, vsc = _quantize_kv(v)
                ck = _write_prefill_chunk(cache["k"], kq, starts)
                cv = _write_prefill_chunk(cache["v"], vq, starts)
                cks = _write_prefill_chunk_scale(cache["k_scale"], ksc, starts)
                cvs = _write_prefill_chunk_scale(cache["v_scale"], vsc, starts)
                new_cache = {"k": ck, "v": cv, "k_scale": cks, "v_scale": cvs,
                             "pos": cache["pos"]}
                k_att = _dequantize_kv(ck, cks)
                v_att = _dequantize_kv(cv, cvs)
            else:
                ck = _write_prefill_chunk(cache["k"], k, starts)
                cv = _write_prefill_chunk(cache["v"], v, starts)
                new_cache = {"k": ck, "v": cv, "pos": cache["pos"]}
                k_att, v_att = ck, cv
            if use_flash:
                from repro.kernels import ops as kops

                if s <= VERIFY_WIDTH:   # spec-decode verify chunk
                    out = kops.flash_verify(q, k_att, v_att, positions, scale)
                else:
                    out = kops.flash_prefill(q, k_att, v_att, positions, scale)
            else:
                kv_pos = jnp.arange(S, dtype=jnp.int32)
                out = chunked_causal_attention(q, k_att, v_att, positions,
                                               kv_pos, 0, scale)
        else:
            batched_pos_cache = cache["pos"].ndim == 2
            if quant:
                kq, ksc = _quantize_kv(k)
                vq, vsc = _quantize_kv(v)
                ck, cpos = _write_prefill(cache["k"], kq, positions, S, kv_seq_axis)
                cv, _ = _write_prefill(cache["v"], vq, positions, S, kv_seq_axis)
                cks, _ = _write_prefill(cache["k_scale"][..., None],
                                        ksc[..., None], positions, S, kv_seq_axis)
                cvs, _ = _write_prefill(cache["v_scale"][..., None],
                                        vsc[..., None], positions, S, kv_seq_axis)
                if batched_pos_cache:
                    cpos = jnp.broadcast_to(cpos[None], (b, S))
                new_cache = {"k": ck, "v": cv, "k_scale": cks[..., 0],
                             "v_scale": cvs[..., 0], "pos": cpos}
            else:
                ck, cpos = _write_prefill(cache["k"], k, positions, S, kv_seq_axis)
                cv, _ = _write_prefill(cache["v"], v, positions, S, kv_seq_axis)
                if batched_pos_cache:
                    cpos = jnp.broadcast_to(cpos[None], (b, S))
                new_cache = {"k": ck, "v": cv, "pos": cpos}
            if quant:
                # attend the DEQUANTIZED values — exactly what decode reads
                # back — so prefill and decode see one consistent cache (and
                # chunked admission, which must read the cache, is
                # bit-identical to whole-prompt admission under int8)
                k_att, v_att = _dequantize_kv(kq, ksc), _dequantize_kv(vq, vsc)
            else:
                k_att, v_att = k, v
            out = _prefill_attention(q, k_att, v_att, positions, window, scale,
                                     use_flash=use_flash)
    else:
        out = _prefill_attention(q, k, v, positions, window, scale,
                                 use_flash=use_flash)

    partial = fused_out_projection(out, params["w_o"])  # zero-copy epilogue
    return partial, new_cache


def mla_forward(
    params: Dict[str, jax.Array],
    x: jax.Array,
    positions: jax.Array,
    cfg: ModelConfig,
    plan: ShardPlan,
    dist: Dist,
    *,
    cache: Optional[Dict[str, jax.Array]] = None,
    cur_pos: Optional[jax.Array] = None,
    kv_seq_axis: Optional[str] = None,
    use_pallas: bool = False,
    flash_prefill: bool = False,   # accepted for interface parity; MLA
                                   # prefill stays on the pure-JAX scan
    block_tables: Optional[jax.Array] = None,   # (b, nbps) -> paged cache
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """Multi-head latent attention (DeepSeek-V2 style, absorbed matmuls).

    Cache holds only (kv_lora_rank + rope_dim) floats/token — MLA's whole
    point; it is replicated over the model axis and optionally seq-sharded
    over the data axis for long_500k.
    """
    from repro.models.common import rms_norm

    m = cfg.mla
    b, s, d = x.shape
    h = plan.local_q
    decode = cache is not None and s == 1
    scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)

    # --- queries ---------------------------------------------------------
    rope_pos = positions[None, None, :] if positions.ndim == 1 else positions[:, None, :]
    q_lat = rms_norm(x @ params["w_dq"], params["q_norm"], cfg.rms_eps)
    q = (q_lat @ params["w_uq"]).reshape(b, s, h, m.qk_nope_head_dim + m.qk_rope_head_dim)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope.transpose(0, 2, 1, 3), rope_pos,
                        cfg.rope_theta)                       # (b,h,s,rope)
    # absorb W_uk into q: (b,s,h,nope) @ (rank, h, nope) -> (b,h,s,rank)
    w_uk = params["w_uk"].reshape(m.kv_lora_rank, h, m.qk_nope_head_dim)
    q_abs = jnp.einsum("bshn,rhn->bhsr", q_nope.astype(jnp.float32),
                       w_uk.astype(jnp.float32))

    # --- latent kv -------------------------------------------------------
    dkv = x @ params["w_dkv"]
    ckv_new, krope_new = jnp.split(dkv, [m.kv_lora_rank], axis=-1)
    ckv_new = rms_norm(ckv_new, params["kv_norm"], cfg.rms_eps)
    krope_new = apply_rope(krope_new[:, None], rope_pos,
                           cfg.rope_theta)[:, 0]              # (b,s,rope)

    if cache is not None and block_tables is not None:
        # -- paged: sequence-major latent pools through the block table ----
        bt = block_tables
        S_view = cache["pos"].shape[-1]
        if decode:
            if cur_pos.ndim != 1:
                raise ValueError("paged cache serves the slot engine only")
            ckv = _paged_write_decode_seq(cache["ckv"], ckv_new, bt, cur_pos)
            krope = _paged_write_decode_seq(cache["krope"], krope_new, bt, cur_pos)
            cpos = _write_pos_batched(cache["pos"], cur_pos, S_view, False)
            new_cache = {"ckv": ckv, "krope": krope, "pos": cpos}
            kv_src = _paged_view_seq(ckv, bt)
            krope_src = _paged_view_seq(krope, bt)
            kv_pos = cpos
        else:
            starts = (positions[:, 0] if positions.ndim == 2
                      else jnp.zeros((b,), jnp.int32))
            ckv = _paged_write_prefill_seq(cache["ckv"], ckv_new, bt, starts)
            krope = _paged_write_prefill_seq(cache["krope"], krope_new, bt, starts)
            # pos rows rewritten whole by the engine (set_slot_positions)
            new_cache = {"ckv": ckv, "krope": krope, "pos": cache["pos"]}
            if positions.ndim == 2:   # cached-prefix admission: use the view
                kv_src = _paged_view_seq(ckv, bt)
                krope_src = _paged_view_seq(krope, bt)
                kv_pos = jnp.arange(S_view, dtype=jnp.int32)
            else:                     # fresh latents only — dense-identical
                kv_src, krope_src, kv_pos = ckv_new, krope_new, positions
    elif cache is not None:
        S = cache["ckv"].shape[1]
        if decode:
            batched = cur_pos.ndim == 1
            if batched and kv_seq_axis is not None:
                raise ValueError("per-slot decode positions are incompatible "
                                 "with kv_seq_shard (batch=1 long-context path)")
            seq_shard = (kv_seq_axis, S) if kv_seq_axis else None
            # reuse the generic writers via a dummy head axis
            if batched:
                ckv = _write_decode_batched(cache["ckv"][:, None],
                                            ckv_new[:, None], cur_pos, S, False)[:, 0]
                krope = _write_decode_batched(cache["krope"][:, None],
                                              krope_new[:, None], cur_pos, S, False)[:, 0]
                cpos = _write_pos_batched(cache["pos"], cur_pos, S, False)
            else:
                ckv = _write_decode(cache["ckv"][:, None], ckv_new[:, None], cur_pos,
                                    S, False, seq_shard)[:, 0]
                krope = _write_decode(cache["krope"][:, None], krope_new[:, None],
                                      cur_pos, S, False, seq_shard)[:, 0]
                cpos = _write_pos(cache["pos"], cur_pos, S, False, seq_shard)
        elif positions.ndim == 2:
            # -- chunked admission (dense latent cache): scatter this chunk
            # of latents at each row's own resume offset (the generic chunk
            # writer via a dummy head axis) and attend the row's cache
            # stripe [0, start + C) as MQA over the latent.  View index ==
            # absolute position in the latent cache, so a plain arange is
            # the KV position vector; causality masks the unwritten tail
            # and position rows are rewritten whole by the engine.  The
            # same branch serves the spec-decode verify chunk.
            if kv_seq_axis is not None:
                raise ValueError("chunked prefill is incompatible with "
                                 "kv_seq_shard (batch=1 long-context path)")
            starts = positions[:, 0]
            ckv = _write_prefill_chunk(cache["ckv"][:, None],
                                       ckv_new[:, None], starts)[:, 0]
            krope = _write_prefill_chunk(cache["krope"][:, None],
                                         krope_new[:, None], starts)[:, 0]
            new_cache = {"ckv": ckv, "krope": krope, "pos": cache["pos"]}
            kv_src, krope_src = ckv, krope
            kv_pos = jnp.arange(S, dtype=jnp.int32)
        else:
            ckv, cpos = _write_prefill(cache["ckv"][:, None], ckv_new[:, None],
                                       positions, S, kv_seq_axis)
            ckv = ckv[:, 0]
            krope, _ = _write_prefill(cache["krope"][:, None], krope_new[:, None],
                                      positions, S, kv_seq_axis)
            krope = krope[:, 0]
            if cache["pos"].ndim == 2:
                cpos = jnp.broadcast_to(cpos[None], (b, S))
        if positions.ndim != 2 or decode:
            new_cache = {"ckv": ckv, "krope": krope, "pos": cpos}
        if decode:
            kv_src, krope_src, kv_pos = ckv, krope, cpos
        elif positions.ndim != 2:
            # whole prefill attends over the full freshly-computed latents
            kv_src, krope_src, kv_pos = ckv_new, krope_new, positions
    else:
        new_cache = None
        kv_src, krope_src = ckv_new, krope_new
        kv_pos = positions

    if decode:
        # §Perf H2: two-dot scores (nope·ckv + rope·krope) instead of
        # concat([ckv, krope]) — the concat materialised a cache-sized copy
        # per layer per decode step. fp32 accumulation, bf16 operands.
        qa = q_abs.astype(x.dtype)                                  # (b,h,1,r)
        qr = q_rope.astype(x.dtype)                                 # (b,h,1,e)
        s_nope = jnp.einsum("bhsr,btr->bhst", qa, kv_src,
                            preferred_element_type=jnp.float32)
        s_rope = jnp.einsum("bhse,bte->bhst", qr, krope_src,
                            preferred_element_type=jnp.float32)
        sc = (s_nope + s_rope) * scale                              # (b,h,1,t)
        if cur_pos.ndim == 1:                  # per-slot positions: (b,S) mask
            kvp = kv_pos if kv_pos.ndim == 2 else kv_pos[None, :]
            valid = (kvp >= 0) & (kvp <= cur_pos[:, None])
            sc = jnp.where(valid[:, None, None, :], sc, -jnp.inf)
        else:
            valid = (kv_pos >= 0) & (kv_pos <= cur_pos)
            sc = jnp.where(valid[None, None, None, :], sc, -jnp.inf)
        mx = sc.max(axis=-1)
        mx_safe = jnp.where(jnp.isfinite(mx), mx, 0.0)
        p = jnp.exp(sc - mx_safe[..., None])
        l = p.sum(axis=-1)
        acc = jnp.einsum("bhst,btr->bhsr", p.astype(x.dtype), kv_src,
                         preferred_element_type=jnp.float32)
        if kv_seq_axis is not None:
            m_g = jax.lax.pmax(mx, kv_seq_axis)
            m_gs = jnp.where(jnp.isfinite(m_g), m_g, 0.0)
            corr = jnp.where(jnp.isfinite(mx), jnp.exp(mx - m_gs), 0.0)
            l, acc = cc.psum((l * corr, acc * corr[..., None]), kv_seq_axis,
                             tag="lse_merge")
        o_lat = acc / jnp.maximum(l, 1e-30)[..., None]
    else:
        # prefill / chunked admission / spec verify: the SAME two-dot latent
        # math as decode, streamed over KV chunks (fp32 o_lat, no bf16
        # round-trip through a concat MQA view).  Congruent numerics across
        # decode/prefill/chunk are what make the chunked==whole and
        # spec==plain greedy identities hold bitwise for MLA.
        o_lat = mla_latent_attention(
            q_abs.astype(x.dtype), q_rope.astype(x.dtype),
            kv_src, krope_src, positions, kv_pos, scale)
    # value up-projection (absorbed): (b,h,s,rank) @ (rank,h,vd) -> (b,h,s,vd)
    w_uv = params["w_uv"].reshape(m.kv_lora_rank, h, m.v_head_dim)
    o = jnp.einsum("bhsr,rhv->bhsv", o_lat, w_uv.astype(jnp.float32)).astype(x.dtype)
    partial = fused_out_projection(o, params["w_o"])
    return partial, new_cache
