"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

Block: two column-parallel input branches (gate branch: GeLU; recurrent
branch: causal depthwise conv -> RG-LRU), elementwise product, row-parallel
out-projection — exactly **one** reduction per block.

RG-LRU (all elementwise over the lru_width channels, block-diagonal gate
projections with n_blocks = n_heads, blocks sharded over the model axis):
    r_t = sigmoid(W_a u_t + b_a)          recurrence gate
    i_t = sigmoid(W_x u_t + b_x)          input gate
    log a_t = -c * softplus(Lambda) * r_t
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * u_t)

Prefill uses jax.lax.associative_scan over the sequence (the recurrence
h = a*h' + b is associative); decode is the O(1) step.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.common import Dist, ParamDef, activation


def _dims(cfg: ModelConfig, tp: int):
    w = cfg.rglru.lru_width or cfg.d_model
    n_blocks = cfg.n_heads
    if w % n_blocks or n_blocks % tp:
        raise ValueError(f"lru_width {w} / n_blocks {n_blocks} / tp {tp} mismatch")
    return w, n_blocks, w // n_blocks


def rglru_defs(cfg: ModelConfig, dist: Dist) -> Dict[str, ParamDef]:
    d, M = cfg.d_model, dist.model_axis
    w, n_blocks, bs = _dims(cfg, dist.tp)
    return {
        "w_gate": ParamDef((d, w), P(None, M), init="scaled", scale_dim=0),
        "w_x": ParamDef((d, w), P(None, M), init="scaled", scale_dim=0),
        "conv_w": ParamDef((cfg.rglru.conv_width, w), P(None, M),
                           init="scaled", scale_dim=0),
        # block-diagonal gate projections, blocks sharded over model axis
        "gate_a_w": ParamDef((n_blocks, bs, bs), P(M, None, None),
                             init="scaled", scale_dim=1),
        "gate_a_b": ParamDef((n_blocks, bs), P(M, None), init="zeros"),
        "gate_x_w": ParamDef((n_blocks, bs, bs), P(M, None, None),
                             init="scaled", scale_dim=1),
        "gate_x_b": ParamDef((n_blocks, bs), P(M, None), init="zeros"),
        "Lambda": ParamDef((w,), P(M), init="normal", dtype=jnp.float32),
        "w_out": ParamDef((w, d), P(M, None), init="scaled", scale_dim=0),
    }


def init_rglru_state(cfg: ModelConfig, dist: Dist, batch_local: int) -> Dict[str, jax.Array]:
    w, _, _ = _dims(cfg, dist.tp)
    w_local = w // dist.tp
    return {
        "h": jnp.zeros((batch_local, w_local), jnp.float32),
        "conv": jnp.zeros((batch_local, cfg.rglru.conv_width - 1, w_local),
                          jnp.bfloat16),
    }


def _causal_conv(u: jax.Array, w: jax.Array, tail: Optional[jax.Array],
                 valid_len: Optional[jax.Array] = None):
    from repro.models.common import conv_tail

    W = w.shape[0]
    if tail is None:
        tail = jnp.zeros((u.shape[0], W - 1, u.shape[2]), u.dtype)
    ext = jnp.concatenate([tail, u], axis=1)
    out = sum(ext[:, i : i + u.shape[1]] * w[i] for i in range(W))
    return out, conv_tail(ext, W, valid_len, tail)


def _block_diag(u: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """u (b,s,local_w) -> block-diagonal linear; w (local_blocks, bs, bs)."""
    nb, bs, _ = w.shape
    ub = u.reshape(*u.shape[:2], nb, bs)
    out = jnp.einsum("bsnx,nxy->bsny", ub.astype(jnp.float32),
                     w.astype(jnp.float32)) + b.astype(jnp.float32)
    return out.reshape(u.shape)


def rglru_forward(
    params: Dict[str, jax.Array],
    x_in: jax.Array,              # (b, s, d) replicated over model axis
    cfg: ModelConfig,
    dist: Dist,
    *,
    state: Optional[Dict[str, jax.Array]] = None,
    use_pallas: bool = False,
    length_mask: Optional[jax.Array] = None,   # (b, s) bool: True = real token
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """Returns (UNREDUCED partial (b,s,d), new_state or None).

    ``length_mask`` makes padding steps exact identities (a = 1, input term
    0) so the carried recurrent state equals an unpadded per-row prefill."""
    c = cfg.rglru.c_constant
    gate = activation("gelu")(x_in @ params["w_gate"])   # (b,s,w_local)
    u = x_in @ params["w_x"]
    tail = state["conv"] if state is not None else None
    valid_len = (length_mask.sum(-1).astype(jnp.int32)
                 if length_mask is not None else None)
    u, new_tail = _causal_conv(u, params["conv_w"], tail, valid_len)

    r = jax.nn.sigmoid(_block_diag(u, params["gate_a_w"], params["gate_a_b"]))
    i = jax.nn.sigmoid(_block_diag(u, params["gate_x_w"], params["gate_x_b"]))
    log_a = -c * jax.nn.softplus(params["Lambda"]) * r   # (b,s,w_local) fp32
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12))
    bx = beta * i * u.astype(jnp.float32)                # (b,s,w_local)
    if length_mask is not None:
        lm = length_mask[..., None]
        a = jnp.where(lm, a, 1.0)
        bx = jnp.where(lm, bx, 0.0)

    h0 = state["h"] if state is not None else jnp.zeros(
        (x_in.shape[0], u.shape[-1]), jnp.float32
    )
    if x_in.shape[1] == 1:
        h = a[:, 0] * h0 + bx[:, 0]
        hs = h[:, None]
        new_state = {"h": h, "conv": new_tail}
    elif use_pallas:
        # Pallas linear scan: state lives in VMEM, one HBM read of (a, bx)
        # and one write of h — vs O(log S) HBM-level intermediates of
        # associative_scan (the Griffin paper's own kernel choice).
        from repro.kernels import ops as kops

        hs, hT = kops.lru_scan(a, bx, h0)
        new_state = {"h": hT, "conv": new_tail} if state is not None else None
    else:
        # h_t = a_t h_{t-1} + bx_t with h_{-1} = h0: fold h0 into step 0
        bx = bx.at[:, 0].add(a[:, 0] * h0)

        def combine(lhs, rhs):
            a1, b1 = lhs
            a2, b2 = rhs
            return a1 * a2, a2 * b1 + b2

        _, hs = jax.lax.associative_scan(combine, (a, bx), axis=1)
        new_state = {"h": hs[:, -1], "conv": new_tail} if state is not None else None

    y = (hs * gate.astype(jnp.float32)).astype(x_in.dtype)
    partial = y @ params["w_out"]                        # unreduced
    return partial, new_state
