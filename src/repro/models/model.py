"""Top-level model: params, forward, and the per-shard step functions.

Everything here is per-shard code for ``jax.shard_map``; the launcher
(`repro.launch`) wraps these in shard_map + jit with the right specs.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ParallelConfig
from repro.core import embedding as emb
from repro.core import wquant
from repro.core.sync_policy import SyncPolicy
from repro.models import multimodal, transformer as tfm
from repro.models.common import (
    Dist,
    ParamDef,
    ShardPlan,
    is_def,
    materialize,
    rms_norm,
    shapes_of,
    specs_of,
)

Pytree = Any


@dataclass(frozen=True)
class ModelCtx:
    """Everything static the per-shard step functions need."""

    cfg: ModelConfig
    plan: ShardPlan
    dist: Dist
    parallel: ParallelConfig

    @staticmethod
    def make(cfg: ModelConfig, parallel: ParallelConfig,
             *, pod_axis: Optional[str] = None) -> "ModelCtx":
        dist = Dist(
            model_axis="model", data_axis="data", pod_axis=pod_axis,
            tp=parallel.tp, dp=parallel.dp, pods=parallel.pods,
        )
        return ModelCtx(cfg, ShardPlan.make(cfg, parallel.tp), dist, parallel)

    def policy(self, *, seq_sharded: bool) -> SyncPolicy:
        return SyncPolicy(
            dist=self.dist,
            seq_sharded=seq_sharded and self.parallel.seq_parallel and self.dist.tp > 1,
            one_shot=self.parallel.one_shot_sync,
        )


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------


def model_defs(ctx: ModelCtx) -> Dict[str, Any]:
    cfg, plan, dist = ctx.cfg, ctx.plan, ctx.dist
    groups = tfm.build_groups(cfg)
    defs: Dict[str, Any] = {
        "embed": emb.embed_defs(cfg, plan, dist),
        "groups": tuple(tfm.group_defs(cfg, plan, dist, g) for g in groups),
        "final_norm": ParamDef((cfg.d_model,), P(None), init="zeros"),
    }
    if not cfg.tie_embeddings:
        defs["lm_head"] = ParamDef(
            (cfg.n_codebooks, cfg.d_model, plan.vocab_p),
            P(None, None, dist.model_axis),
            init="scaled",
            scale_dim=1,
        )
    if cfg.frontend is not None:
        defs["frontend"] = multimodal.frontend_defs(cfg, dist)
    return defs


def init_params(ctx: ModelCtx, key) -> Pytree:
    return materialize(model_defs(ctx), key)


# ---------------------------------------------------------------------------
# Weight-only quantization (quantize-at-load transform)
#
# Every serving projection — attention q/k/v/o, MLP up/gate/down (incl. MoE
# shared experts), MoE expert blocks, lm_head — is replaced by a
# :class:`repro.core.wquant.QuantWeight` (packed values + scales).  Embed
# tables (row gathers, not sweeps), norms, biases, routers, and the MLA
# latent projections (absorbed-matmul reshapes; latent ranks are tiny) stay
# bf16.  The walker below is the single source of truth for WHICH leaves
# quantize, shared by the param transform, the spec tree, and the
# byte-accounting helper, so all three stay consistent.
# ---------------------------------------------------------------------------

_WQ_ATTN_KEYS = ("w_q", "w_k", "w_v", "w_o")
_WQ_FFN_KEYS = ("w_up", "w_gate", "w_down")


def _map_wq_leaves(ctx: ModelCtx, tree: Pytree, leaf_fn) -> Pytree:
    """Rebuild ``tree`` (params / specs / defs-valued) with every
    weight-quant-eligible leaf replaced by ``leaf_fn(param_def, leaf,
    site)``.  ``site`` is how the serving forward consumes the leaf:
    "matmul" (2-D projection — fused dequant kernel eligible) or "einsum"
    (batched contraction served by ``wquant.to_dense``: the attention
    out-projection and MoE expert blocks)."""
    cfg = ctx.cfg
    defs = model_defs(ctx)
    groups = tfm.build_groups(cfg)

    def map_keys(dtree, vtree, keys, einsum_keys=()):
        out = dict(vtree)
        for k in keys:
            if k in out:
                out[k] = leaf_fn(dtree[k], out[k],
                                 "einsum" if k in einsum_keys else "matmul")
        return out

    new_groups = []
    for g, gdefs, gtree in zip(groups, defs["groups"], tree["groups"]):
        gt = {}
        for i, sub in enumerate(g.subs):
            st = dict(gtree[f"sub{i}"])
            sd = gdefs[f"sub{i}"]
            if sub.kind in tfm.ATTN_KINDS and cfg.mla is None:
                st["mixer"] = map_keys(sd["mixer"], st["mixer"], _WQ_ATTN_KEYS,
                                       einsum_keys=("w_o",))
            if sub.has_ffn:
                ffn = map_keys(sd["ffn"], st["ffn"], _WQ_FFN_KEYS,
                               einsum_keys=_WQ_FFN_KEYS if sub.is_moe else ())
                if "shared" in ffn:   # shared experts run mlp_forward (2-D)
                    ffn["shared"] = map_keys(sd["ffn"]["shared"],
                                             ffn["shared"], _WQ_FFN_KEYS)
                st["ffn"] = ffn
            gt[f"sub{i}"] = st
        new_groups.append(gt)
    out = dict(tree)
    out["groups"] = tuple(new_groups)
    if "lm_head" in tree:
        # multi-codebook heads are served via dequantize+einsum even on the
        # pallas backend (_lm_head routes the kernel only when ncb == 1)
        out["lm_head"] = leaf_fn(defs["lm_head"], tree["lm_head"],
                                 "matmul" if cfg.n_codebooks == 1
                                 else "einsum")
    return out


def _wq_k_shards(ctx: ModelCtx, d: ParamDef) -> int:
    """TP shard count of the reduction dim (axis -2): the int4 group clamp
    keeps groups shard-local, so scale sharding needs no communication."""
    entries = tuple(d.spec)
    if len(entries) >= 2 and entries[-2] == ctx.dist.model_axis:
        return ctx.dist.tp
    return 1


def quantize_params(ctx: ModelCtx, params: Pytree) -> Pytree:
    """Quantize-at-load: bf16 projection weights -> QuantWeight leaves per
    ``ctx.parallel.weight_quant`` / ``wq_group_size``.  Idempotent (already-
    quantized leaves pass through); ineligible shapes stay bf16 — the spec
    tree applies the same predicate, so trees always match."""
    par = ctx.parallel
    backend = "pallas" if par.use_pallas else "ref"

    def f(d: ParamDef, leaf, site):
        if isinstance(leaf, wquant.QuantWeight):
            return leaf
        ks = _wq_k_shards(ctx, d)
        if not wquant.quantizable(d.shape, par.weight_quant,
                                  par.wq_group_size, ks):
            return leaf
        return wquant.quantize(leaf, par.weight_quant, par.wq_group_size,
                               k_shards=ks, backend=backend)

    return _map_wq_leaves(ctx, params, f)


def param_specs(ctx: ModelCtx) -> Pytree:
    specs = specs_of(model_defs(ctx))
    par = ctx.parallel
    if par.weight_quant == "none":
        return specs
    backend = "pallas" if par.use_pallas else "ref"

    def f(d: ParamDef, spec, site):
        ks = _wq_k_shards(ctx, d)
        if not wquant.quantizable(d.shape, par.weight_quant,
                                  par.wq_group_size, ks):
            return spec
        return wquant.spec_for(d.shape, spec, par.weight_quant,
                               par.wq_group_size, k_shards=ks,
                               backend=backend)

    return _map_wq_leaves(ctx, specs, f)


def decode_weight_bytes(ctx: ModelCtx) -> Dict[str, int]:
    """Bytes of weight stream a decode token sweeps, from shapes alone.

    ``swept``: all projection weights + lm_head (+ tiny norms/biases at
    their stored width) — the unique weight STORAGE decode reads every
    token.  ``quantized`` / ``dense`` split the swept set by whether the
    quantize transform covers the leaf under the current ``weight_quant``
    mode.  ``quantized_ref_einsum`` is the subset of ``quantized`` served
    through ``wquant.to_dense`` (the attention out-projection and MoE
    expert blocks): their packed stream counts as swept storage, but
    realizing it as HBM traffic needs the dequant fused into the
    contraction — XLA operand fusion or the batched kernels on the
    ROADMAP backlog; until then those leaves also materialise a bf16
    transient per step, which is activation-like traffic on top of this
    number (dominant on MoE archs — read the ratio accordingly).  Embed
    tables are excluded: a token embeds by row gather, not a full-table
    sweep."""
    import math

    par = ctx.parallel
    counted = []                             # (ParamDef, k_shards, ok, site)

    def mark(_d: ParamDef, leaf, site):
        # ``leaf`` is the ParamDef from the tree we walk below (the walker
        # rebuilds its own defs internally, so only the leaf's id is the
        # one the rest-loop can exclude against)
        ks = _wq_k_shards(ctx, leaf)
        ok = (par.weight_quant != "none"
              and wquant.quantizable(leaf.shape, par.weight_quant,
                                     par.wq_group_size, ks))
        counted.append((leaf, ks, ok, site))
        return leaf

    defs = model_defs(ctx)
    _map_wq_leaves(ctx, defs, mark)
    quantized = dense = ref_einsum = 0
    for d, ks, ok, site in counted:
        if ok:
            b = wquant.quant_bytes(d.shape, par.weight_quant,
                                   par.wq_group_size, ks)
            quantized += b
            if site == "einsum":
                ref_einsum += b
        else:
            dense += math.prod(d.shape) * jnp.dtype(d.dtype).itemsize

    # non-projection leaves swept per token (norms, biases, routers, MLA):
    # everything in the defs tree except the counted projections, embed
    # (row gather), and the frontend projector (prefill-only — decode
    # never reads it)
    counted_ids = {id(d) for d, _, _, _ in counted}
    for leaf in jax.tree.leaves({k: v for k, v in defs.items()
                                 if k not in ("embed", "frontend")},
                                is_leaf=is_def):
        if is_def(leaf) and id(leaf) not in counted_ids:
            dense += math.prod(leaf.shape) * jnp.dtype(leaf.dtype).itemsize
    # tied embeddings: the head IS the table, and _lm_head einsums the whole
    # (ncb, V, d) table every token — a full sweep, not a row gather (and it
    # stays bf16: the quantize transform keeps embed tables dense)
    if ctx.cfg.tie_embeddings:
        t = defs["embed"]["table"]
        dense += math.prod(t.shape) * jnp.dtype(t.dtype).itemsize
    return {"quantized": quantized, "dense": dense,
            "quantized_ref_einsum": ref_einsum,
            "swept": quantized + dense}


def param_shapes(ctx: ModelCtx) -> Pytree:
    shapes = shapes_of(model_defs(ctx))
    par = ctx.parallel
    if par.weight_quant == "none":
        return shapes
    backend = "pallas" if par.use_pallas else "ref"

    # mirror the quantize transform so shapes/specs/params trees stay
    # structurally identical under weight_quant (tree_maps rely on it)
    def f(d: ParamDef, sds, site):
        ks = _wq_k_shards(ctx, d)
        if not wquant.quantizable(d.shape, par.weight_quant,
                                  par.wq_group_size, ks):
            return sds
        return wquant.shapes_for(d.shape, par.weight_quant,
                                 par.wq_group_size, k_shards=ks,
                                 backend=backend)

    return _map_wq_leaves(ctx, shapes, f)


# ---------------------------------------------------------------------------
# Packed-weight persistence (quantize-at-load -> disk)
#
# A 72B-scale start otherwise materializes the full bf16 tree before packing
# it down; persisting the packed QuantWeight tree lets later starts restore
# int8/int4 payloads + scales directly.  QuantWeight is a pytree whose
# children flatten under stable paths, so the flat-key npz checkpointer
# round-trips it as-is; ``param_shapes`` mirrors the quantize transform and
# supplies the ``like`` tree for the shape-checked restore.
# ---------------------------------------------------------------------------


def _wq_meta(ctx: ModelCtx) -> Dict[str, Any]:
    """Everything the packing layout depends on: mode/group decide payload
    widths, tp decides scale shapes (the int4 group clamp is shard-local),
    backend decides the packed layout."""
    par = ctx.parallel
    return {"arch": ctx.cfg.name, "weight_quant": par.weight_quant,
            "wq_group_size": par.wq_group_size, "tp": ctx.dist.tp,
            "backend": "pallas" if par.use_pallas else "ref"}


def has_quantized(path: str) -> bool:
    from repro.training import checkpoint

    return checkpoint.load_meta(path) is not None


def save_quantized(ctx: ModelCtx, params: Pytree, path: str) -> None:
    """Persist an already-quantized param tree (packed payloads + scales)."""
    from repro.training import checkpoint

    checkpoint.save(path, params, meta=_wq_meta(ctx))


def load_quantized(ctx: ModelCtx, path: str) -> Pytree:
    """Restore a packed QuantWeight tree saved by :func:`save_quantized`.
    The stored meta must match the current config — a silent layout
    mismatch would produce garbage weights, so it is an error instead."""
    from repro.training import checkpoint

    meta = checkpoint.load_meta(path)
    if meta is None:
        raise FileNotFoundError(f"no quantized checkpoint at {path}")
    want = _wq_meta(ctx)
    got = {k: meta.get("meta", {}).get(k) for k in want}
    if got != want:
        raise ValueError(
            f"quantized checkpoint {path} was packed for {got}, "
            f"engine wants {want}")
    tree, _ = checkpoint.restore(path, param_shapes(ctx))
    return tree


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _lm_head(params, x, ctx: ModelCtx) -> jax.Array:
    """x (b,s,d) -> local logits (b,s,[ncb,]V_local), fp32."""
    cfg = ctx.cfg
    if cfg.tie_embeddings:
        table = params["embed"]["table"]      # (ncb, V_local, d) vocab-sharded
        logits = jnp.einsum("bsd,cvd->bscv", x.astype(jnp.float32),
                            table.astype(jnp.float32))
        return logits[:, :, 0] if cfg.n_codebooks == 1 else logits
    head = params["lm_head"]
    if isinstance(head, wquant.QuantWeight):
        if cfg.n_codebooks == 1 and head.backend == "pallas":
            # the biggest single per-token weight sweep goes through the
            # fused dequant GEMV/GEMM (fp32 logits out of the kernel)
            flat = wquant.matmul(x, wquant.index_batch(head, 0),
                                 out_dtype=jnp.float32)
            return flat
        logits = jnp.einsum("bsd,cdv->bscv", x.astype(jnp.float32),
                            wquant.dequantize(head).astype(jnp.float32))
    else:
        logits = jnp.einsum("bsd,cdv->bscv", x.astype(jnp.float32),
                            head.astype(jnp.float32))
    return logits[:, :, 0] if cfg.n_codebooks == 1 else logits


def forward(
    params: Pytree,
    tokens: jax.Array,               # (b_local, s) or (b_local, s, ncb)
    ctx: ModelCtx,
    *,
    features: Optional[jax.Array] = None,   # (b_local, prefix, feat) stub output
    caches: Optional[Tuple] = None,
    cur_pos: Optional[jax.Array] = None,    # int32 (decode): scalar, or (b,) per-slot
    kv_seq_axis: Optional[str] = None,
    seq_sharded: bool = False,
    last_only: bool = False,
    id_broadcast: Optional[bool] = None,
    skip_head: bool = False,
    length_mask: Optional[jax.Array] = None,  # (b, s) bool, right-padded prefill
    block_tables: Optional[jax.Array] = None, # (b, nbps): paged KV addressing
    start_pos: Optional[jax.Array] = None,    # (b,): per-slot prefill offset
                                              # (cached-prefix admission)
) -> Tuple[jax.Array, Optional[Tuple], jax.Array]:
    """-> (local logits, new_caches, aux_loss). Logits are vocab-sharded.

    skip_head=True returns the final-norm hidden states instead of logits
    (the chunked vocab-parallel loss applies the head itself)."""
    cfg, plan, dist = ctx.cfg, ctx.plan, ctx.dist
    policy = ctx.policy(seq_sharded=seq_sharded)
    if id_broadcast is None:
        id_broadcast = ctx.parallel.id_broadcast
    decode = cur_pos is not None and tokens.shape[1] == 1

    x = emb.embed_lookup(params["embed"], tokens, cfg, plan, dist,
                         id_broadcast=id_broadcast)
    if cfg.frontend is not None and features is not None:
        prefix = multimodal.project_features(params["frontend"], features, cfg)
        x = jnp.concatenate([prefix.astype(x.dtype), x], axis=1)

    s_total = x.shape[1]
    if decode:
        # per-slot decode (continuous batching): each row rotates/masks at
        # its own position; shared decode keeps the (1,) broadcast form.
        positions = cur_pos[:, None] if cur_pos.ndim == 1 else cur_pos[None]
    elif start_pos is not None:
        # cached-prefix / chunked admission: each row's prompt suffix or
        # chunk starts at its own absolute offset (tokens 0..start-1 are
        # already resident in the row's cache or shared prefix blocks)
        positions = (start_pos[:, None]
                     + jnp.arange(s_total, dtype=jnp.int32)[None, :])
    else:
        positions = jnp.arange(s_total, dtype=jnp.int32)

    x = policy.shard_residual(x)
    groups = tfm.build_groups(cfg)
    new_caches = [] if caches is not None else None
    aux = jnp.zeros((), jnp.float32)
    for gi, g in enumerate(groups):
        c = caches[gi] if caches is not None else None
        x, c_new, a = tfm.group_forward(
            params["groups"][gi], x, positions, cfg, plan, dist, policy, g,
            caches=c, cur_pos=cur_pos, kv_seq_axis=kv_seq_axis,
            use_pallas=ctx.parallel.use_pallas, remat=ctx.parallel.remat and not decode,
            length_mask=length_mask, block_tables=block_tables,
            flash_prefill=ctx.parallel.flash_prefill,
        )
        aux = aux + a
        if new_caches is not None:
            new_caches.append(c_new)
    x = policy.unshard_residual(x)
    x = rms_norm(x, params["final_norm"], cfg.rms_eps)
    if last_only:
        x = x[:, -1:]
    if skip_head:
        return x, (tuple(new_caches) if new_caches is not None else None), aux
    logits = _lm_head(params, x, ctx)
    return logits, (tuple(new_caches) if new_caches is not None else None), aux


def lm_head_local(params, x, ctx: ModelCtx) -> jax.Array:
    """Public head application (used by the chunked loss)."""
    return _lm_head(params, x, ctx)


def init_caches(ctx: ModelCtx, batch_local: int, cache_len: int,
                *, kv_seq_shard_dp: int = 1, batched_pos: bool = False,
                paged: Optional[Tuple[int, int]] = None,
                ring_slack: int = 0) -> Tuple:
    """``paged=(n_blocks_local, block_size)`` builds the paged layout:
    attention layers get block pools, recurrent layers keep their per-slot
    constant-size state unchanged.  ``ring_slack`` adds spare entries to
    sliding-window ring caches (spec-decode verify headroom)."""
    groups = tfm.build_groups(ctx.cfg)
    return tuple(
        tfm.group_cache(ctx.cfg, ctx.plan, ctx.dist, g, batch_local, cache_len,
                        kv_seq_shard_dp, quant=ctx.parallel.kv_quant,
                        batched_pos=batched_pos, paged=paged,
                        ring_slack=ring_slack)
        for g in groups
    )


