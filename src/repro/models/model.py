"""Top-level model: params, forward, and the per-shard step functions.

Everything here is per-shard code for ``jax.shard_map``; the launcher
(`repro.launch`) wraps these in shard_map + jit with the right specs.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ParallelConfig
from repro.core import embedding as emb
from repro.core.sync_policy import SyncPolicy
from repro.models import multimodal, transformer as tfm
from repro.models.common import (
    Dist,
    ParamDef,
    ShardPlan,
    materialize,
    rms_norm,
    shapes_of,
    specs_of,
)

Pytree = Any


@dataclass(frozen=True)
class ModelCtx:
    """Everything static the per-shard step functions need."""

    cfg: ModelConfig
    plan: ShardPlan
    dist: Dist
    parallel: ParallelConfig

    @staticmethod
    def make(cfg: ModelConfig, parallel: ParallelConfig,
             *, pod_axis: Optional[str] = None) -> "ModelCtx":
        dist = Dist(
            model_axis="model", data_axis="data", pod_axis=pod_axis,
            tp=parallel.tp, dp=parallel.dp, pods=parallel.pods,
        )
        return ModelCtx(cfg, ShardPlan.make(cfg, parallel.tp), dist, parallel)

    def policy(self, *, seq_sharded: bool) -> SyncPolicy:
        return SyncPolicy(
            dist=self.dist,
            seq_sharded=seq_sharded and self.parallel.seq_parallel and self.dist.tp > 1,
            one_shot=self.parallel.one_shot_sync,
        )


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------


def model_defs(ctx: ModelCtx) -> Dict[str, Any]:
    cfg, plan, dist = ctx.cfg, ctx.plan, ctx.dist
    groups = tfm.build_groups(cfg)
    defs: Dict[str, Any] = {
        "embed": emb.embed_defs(cfg, plan, dist),
        "groups": tuple(tfm.group_defs(cfg, plan, dist, g) for g in groups),
        "final_norm": ParamDef((cfg.d_model,), P(None), init="zeros"),
    }
    if not cfg.tie_embeddings:
        defs["lm_head"] = ParamDef(
            (cfg.n_codebooks, cfg.d_model, plan.vocab_p),
            P(None, None, dist.model_axis),
            init="scaled",
            scale_dim=1,
        )
    if cfg.frontend is not None:
        defs["frontend"] = multimodal.frontend_defs(cfg, dist)
    return defs


def init_params(ctx: ModelCtx, key) -> Pytree:
    return materialize(model_defs(ctx), key)


def param_specs(ctx: ModelCtx) -> Pytree:
    return specs_of(model_defs(ctx))


def param_shapes(ctx: ModelCtx) -> Pytree:
    return shapes_of(model_defs(ctx))


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _lm_head(params, x, ctx: ModelCtx) -> jax.Array:
    """x (b,s,d) -> local logits (b,s,[ncb,]V_local), fp32."""
    cfg = ctx.cfg
    if cfg.tie_embeddings:
        table = params["embed"]["table"]      # (ncb, V_local, d) vocab-sharded
        logits = jnp.einsum("bsd,cvd->bscv", x.astype(jnp.float32),
                            table.astype(jnp.float32))
    else:
        logits = jnp.einsum("bsd,cdv->bscv", x.astype(jnp.float32),
                            params["lm_head"].astype(jnp.float32))
    return logits[:, :, 0] if cfg.n_codebooks == 1 else logits


def forward(
    params: Pytree,
    tokens: jax.Array,               # (b_local, s) or (b_local, s, ncb)
    ctx: ModelCtx,
    *,
    features: Optional[jax.Array] = None,   # (b_local, prefix, feat) stub output
    caches: Optional[Tuple] = None,
    cur_pos: Optional[jax.Array] = None,    # int32 (decode): scalar, or (b,) per-slot
    kv_seq_axis: Optional[str] = None,
    seq_sharded: bool = False,
    last_only: bool = False,
    id_broadcast: Optional[bool] = None,
    skip_head: bool = False,
    length_mask: Optional[jax.Array] = None,  # (b, s) bool, right-padded prefill
    block_tables: Optional[jax.Array] = None, # (b, nbps): paged KV addressing
    start_pos: Optional[jax.Array] = None,    # (b,): per-slot prefill offset
                                              # (cached-prefix admission)
) -> Tuple[jax.Array, Optional[Tuple], jax.Array]:
    """-> (local logits, new_caches, aux_loss). Logits are vocab-sharded.

    skip_head=True returns the final-norm hidden states instead of logits
    (the chunked vocab-parallel loss applies the head itself)."""
    cfg, plan, dist = ctx.cfg, ctx.plan, ctx.dist
    policy = ctx.policy(seq_sharded=seq_sharded)
    if id_broadcast is None:
        id_broadcast = ctx.parallel.id_broadcast
    decode = cur_pos is not None and tokens.shape[1] == 1

    x = emb.embed_lookup(params["embed"], tokens, cfg, plan, dist,
                         id_broadcast=id_broadcast)
    if cfg.frontend is not None and features is not None:
        prefix = multimodal.project_features(params["frontend"], features, cfg)
        x = jnp.concatenate([prefix.astype(x.dtype), x], axis=1)

    s_total = x.shape[1]
    if decode:
        # per-slot decode (continuous batching): each row rotates/masks at
        # its own position; shared decode keeps the (1,) broadcast form.
        positions = cur_pos[:, None] if cur_pos.ndim == 1 else cur_pos[None]
    elif start_pos is not None:
        # cached-prefix / chunked admission: each row's prompt suffix or
        # chunk starts at its own absolute offset (tokens 0..start-1 are
        # already resident in the row's cache or shared prefix blocks)
        positions = (start_pos[:, None]
                     + jnp.arange(s_total, dtype=jnp.int32)[None, :])
    else:
        positions = jnp.arange(s_total, dtype=jnp.int32)

    x = policy.shard_residual(x)
    groups = tfm.build_groups(cfg)
    new_caches = [] if caches is not None else None
    aux = jnp.zeros((), jnp.float32)
    for gi, g in enumerate(groups):
        c = caches[gi] if caches is not None else None
        x, c_new, a = tfm.group_forward(
            params["groups"][gi], x, positions, cfg, plan, dist, policy, g,
            caches=c, cur_pos=cur_pos, kv_seq_axis=kv_seq_axis,
            use_pallas=ctx.parallel.use_pallas, remat=ctx.parallel.remat and not decode,
            length_mask=length_mask, block_tables=block_tables,
            flash_prefill=ctx.parallel.flash_prefill,
        )
        aux = aux + a
        if new_caches is not None:
            new_caches.append(c_new)
    x = policy.unshard_residual(x)
    x = rms_norm(x, params["final_norm"], cfg.rms_eps)
    if last_only:
        x = x[:, -1:]
    if skip_head:
        return x, (tuple(new_caches) if new_caches is not None else None), aux
    logits = _lm_head(params, x, ctx)
    return logits, (tuple(new_caches) if new_caches is not None else None), aux


def lm_head_local(params, x, ctx: ModelCtx) -> jax.Array:
    """Public head application (used by the chunked loss)."""
    return _lm_head(params, x, ctx)


def init_caches(ctx: ModelCtx, batch_local: int, cache_len: int,
                *, kv_seq_shard_dp: int = 1, batched_pos: bool = False,
                paged: Optional[Tuple[int, int]] = None) -> Tuple:
    """``paged=(n_blocks_local, block_size)`` builds the paged layout:
    attention layers get block pools, recurrent layers keep their per-slot
    constant-size state unchanged."""
    groups = tfm.build_groups(ctx.cfg)
    return tuple(
        tfm.group_cache(ctx.cfg, ctx.plan, ctx.dist, g, batch_local, cache_len,
                        kv_seq_shard_dp, quant=ctx.parallel.kv_quant,
                        batched_pos=batched_pos, paged=paged)
        for g in groups
    )


