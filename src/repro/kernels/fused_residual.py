"""Pallas TPU kernel: dual-matmul accumulate epilogue (paper §2.2 + §2.3).

Computes ``out = A @ Wa + B @ Wb`` in one kernel: the attention out-projection
partial (A @ Wa) and the FFN down-projection partial (B @ Wb) of a
parallel-residual block are accumulated into a SINGLE fp32 VMEM tile, which is
written once to the buffer the following all-reduce reads.  That is the
paper's "one-time synchronization" local-sum plus its "zero-copy" handoff,
expressed as MXU tiling:

* both matmuls share the same (block_t, block_d) output tile -> one HBM write
  instead of two writes + one read + one add;
* K is streamed in MXU-aligned slabs so VMEM holds only
  block_t*(ka+kb) + (ka+kb)*block_d + block_t*block_d floats.

Target: TPU; validated with interpret=True against ``ref.fused_residual_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro import compat


def _fused_kernel(a_ref, wa_ref, b_ref, wb_ref, o_ref, acc_ref, *, n_k: int):
    kdx = pl.program_id(2)

    @pl.when(kdx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], wa_ref[...], preferred_element_type=jnp.float32
    ) + jnp.dot(b_ref[...], wb_ref[...], preferred_element_type=jnp.float32)

    @pl.when(kdx == n_k - 1)
    def _emit():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_t", "block_d", "block_k", "interpret")
)
def fused_dual_matmul(
    a: jax.Array,        # (T, Ka)
    wa: jax.Array,       # (Ka, D)
    b: jax.Array,        # (T, Kb)
    wb: jax.Array,       # (Kb, D)
    *,
    block_t: int = 128,
    block_d: int = 256,
    block_k: int = 512,
    interpret: bool = True,
) -> jax.Array:
    """-> (T, D) = a@wa + b@wb, accumulated in one output tile."""
    T, Ka = a.shape
    Kb = b.shape[1]
    D = wa.shape[1]
    bt = min(block_t, T)
    bd = min(block_d, D)
    # pad K dims to a common block count so the grid is shared
    bk = min(block_k, max(Ka, Kb))
    n_k = -(-max(Ka, Kb) // bk)
    a_p = jnp.pad(a, ((0, (-T) % bt), (0, n_k * bk - Ka)))
    b_p = jnp.pad(b, ((0, (-T) % bt), (0, n_k * bk - Kb)))
    wa_p = jnp.pad(wa, ((0, n_k * bk - Ka), (0, (-D) % bd)))
    wb_p = jnp.pad(wb, ((0, n_k * bk - Kb), (0, (-D) % bd)))
    Tp, Dp = a_p.shape[0], wa_p.shape[1]
    import jax.experimental.pallas.tpu as pltpu

    out = pl.pallas_call(
        functools.partial(_fused_kernel, n_k=n_k),
        grid=(Tp // bt, Dp // bd, n_k),
        in_specs=[
            pl.BlockSpec((bt, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bd), lambda i, j, k: (k, j)),
            pl.BlockSpec((bt, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bd), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bt, bd), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Tp, Dp), a.dtype),
        scratch_shapes=[pltpu.VMEM((bt, bd), jnp.float32)],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(a_p, wa_p, b_p, wb_p)
    return out[:T, :D]
