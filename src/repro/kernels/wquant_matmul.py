"""Pallas TPU kernels: fused dequantize-and-matmul over packed weights.

The decode-side weight sweep is the single largest bandwidth consumer once
the KV cache is quantized (arXiv 2407.07304 §weight-only quantization); the
fused kernel reads the PACKED low-precision weight stream straight from HBM
and dequantizes per tile inside VMEM, so the bf16 weight never exists in
memory — the whole point of weight-only quantization on a bandwidth-bound
decode.

Two weight formats share the grid shape (T tiles, N tiles, K steps):

* **int8, per-output-channel scales** — the scale depends only on the
  output column, so it commutes with the K reduction: the kernel
  accumulates ``x @ q`` in fp32 across K steps and applies the (1, bn)
  scale row once at emit — one multiply per output element instead of one
  per weight element.
* **int4, group-wise scales** — two values per byte, one scale per
  ``group``-length K segment.  The K block is pinned to the group length,
  so each grid step unpacks one (group/2, bn) byte slab into a (group, bn)
  fp32 tile, scales it with its own (1, bn) scale row, and accumulates.

GEMV vs GEMM is a blocking choice, not a separate kernel (the same move
``flash_verify`` makes on the attention side): decode calls come in with
T = batch (a handful of rows) — the T tile rounds up to whole sublane
groups (multiples of 8, zero-padded rows) and the N block widens so the
weight streams through fewer, fuller slabs; prefill/verify calls tile T at
128.  ``dequant_matmul`` picks the blocking from T.

Target: TPU; validated with interpret=True against
``ref.dequant_matmul_ref`` (tests/test_wquant.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro import compat
from repro.core.wquant import unpack4


def _dq8_kernel(x_ref, q_ref, s_ref, o_ref, acc_ref, *, n_k: int):
    kdx = pl.program_id(2)

    @pl.when(kdx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...].astype(jnp.float32),
                            q_ref[...].astype(jnp.float32),
                            preferred_element_type=jnp.float32)

    @pl.when(kdx == n_k - 1)
    def _emit():
        s = s_ref[...].astype(jnp.float32)           # (1, bn)
        o_ref[...] = (acc_ref[...] * s).astype(o_ref.dtype)


def _dq4_kernel(x_ref, q_ref, s_ref, o_ref, acc_ref, *, n_k: int):
    kdx = pl.program_id(2)

    @pl.when(kdx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # one packed (g//2, bn) byte slab -> (g, bn) values; the nibble
    # convention lives in ONE place (wquant.unpack4 — plain jnp ops, so it
    # traces inside the kernel body too)
    w = unpack4(q_ref[...]).astype(jnp.float32)
    w = w * s_ref[...].astype(jnp.float32)           # (g, bn) * (1, bn)
    acc_ref[...] += jnp.dot(x_ref[...].astype(jnp.float32), w,
                            preferred_element_type=jnp.float32)

    @pl.when(kdx == n_k - 1)
    def _emit():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "mode", "group", "out_dtype", "block_t", "block_n", "block_k",
    "interpret"))
def dequant_matmul(
    x: jax.Array,        # (T, K) activations (bf16); K is the PER-SHARD
                         # reduction length under shard_map — always derived
                         # from x.shape, never from QuantWeight's global aux
    q: jax.Array,        # int8 (K, N) | uint8 (K//2, N) packed int4
    scale: jax.Array,    # bf16 (N,) int8 | (K//group, N) int4
    *,
    mode: str,
    group: int,
    out_dtype=None,
    block_t: int = 128,
    block_n: int = 256,
    block_k: int = 512,
    interpret: bool = True,
) -> jax.Array:
    """-> (T, N) = x @ dequant(q, scale), fp32 accumulation, fused dequant.

    Decode-narrow x (T <= 16) takes the GEMV blocking automatically: the T
    tile rounds up to whole sublane groups and N widens to a single block
    when it fits, so the packed weight streams once through full slabs."""
    import jax.experimental.pallas.tpu as pltpu

    T, K = x.shape
    N = q.shape[-1]
    out_dtype = out_dtype or x.dtype
    gemv = T <= 16
    bt = -(-T // 8) * 8 if gemv else min(block_t, -(-T // 8) * 8)
    bn = min(block_n if not gemv else max(block_n, 512), N)
    if mode == "int4":
        bk = group                                   # one scale row per step
    else:
        bk = min(block_k, K)
    pad_t, pad_n, pad_k = (-T) % bt, (-N) % bn, (-K) % bk
    if pad_t or pad_k:
        x = jnp.pad(x, ((0, pad_t), (0, pad_k)))
    Tp, Np, Kp = T + pad_t, N + pad_n, K + pad_k
    n_k = Kp // bk
    if mode == "int4":
        if pad_k:
            raise ValueError("int4 K must be a multiple of the group")
        qp = jnp.pad(q, ((0, 0), (0, pad_n))) if pad_n else q
        sp = jnp.pad(scale, ((0, 0), (0, pad_n))) if pad_n else scale
        kernel = functools.partial(_dq4_kernel, n_k=n_k)
        q_spec = pl.BlockSpec((bk // 2, bn), lambda i, j, kk: (kk, j))
        s_spec = pl.BlockSpec((1, bn), lambda i, j, kk: (kk, j))
    else:
        qp = jnp.pad(q, ((0, pad_k), (0, pad_n)))
        sp = jnp.pad(scale[None, :], ((0, 0), (0, pad_n)))
        kernel = functools.partial(_dq8_kernel, n_k=n_k)
        q_spec = pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j))
        s_spec = pl.BlockSpec((1, bn), lambda i, j, kk: (0, j))

    out = pl.pallas_call(
        kernel,
        grid=(Tp // bt, Np // bn, n_k),
        in_specs=[
            pl.BlockSpec((bt, bk), lambda i, j, kk: (i, kk)),
            q_spec,
            s_spec,
        ],
        out_specs=pl.BlockSpec((bt, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Tp, Np), out_dtype),
        scratch_shapes=[pltpu.VMEM((bt, bn), jnp.float32)],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, qp, sp)
    return out[:T, :N]
