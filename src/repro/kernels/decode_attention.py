"""Pallas TPU kernel: single-token GQA flash-decode over the KV cache.

The latency-critical op of the whole paper: one query token attends over a
long cache.  Grid is (batch x kv_head, cache_blocks); each step loads one
(block_s, head_dim) K/V slab into VMEM, updates running (m, l, acc) flash
statistics for the g query heads sharing that KV head, and never materialises
the (S,) score row in HBM.  Emits the PARTIAL (m, l, acc) triple rather than
the normalized output so the caller can LSE-merge across a sequence-sharded
cache (the long_500k path) — the kernel composes with the distributed
schedule instead of forcing an all-gather.

HBM traffic: one read of K/V (the roofline floor for decode attention).
Target: TPU; validated with interpret=True against ``ref.decode_attention_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro import compat

NEG = -3.0e38


def _decode_kernel(q_ref, k_ref, v_ref, valid_ref, m_ref, l_ref, acc_ref,
                   ms_ref, ls_ref, as_ref, *, scale: float, n_s: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        ms_ref[...] = jnp.full_like(ms_ref, NEG)
        ls_ref[...] = jnp.zeros_like(ls_ref)
        as_ref[...] = jnp.zeros_like(as_ref)

    q = q_ref[...].astype(jnp.float32)                   # (g, hd)
    k = k_ref[...].astype(jnp.float32)                   # (bs, hd)
    v = v_ref[...].astype(jnp.float32)                   # (bs, hd)
    ok = valid_ref[...] != 0                             # (bs,)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # (g, bs)
    s = jnp.where(ok[None, :], s, NEG)
    m_prev = ms_ref[...][:, 0]                           # (g,)
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    # explicit zeroing: on a fully-masked block exp(NEG - NEG) would be 1
    p = jnp.where(ok[None, :], jnp.exp(s - m_new[:, None]), 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_new = ls_ref[...][:, 0] * corr + p.sum(axis=1)
    acc_new = as_ref[...] * corr[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32
    )
    ms_ref[...] = m_new[:, None]
    ls_ref[...] = l_new[:, None]
    as_ref[...] = acc_new

    @pl.when(j == n_s - 1)
    def _emit():
        m_ref[...] = ms_ref[...]
        l_ref[...] = ls_ref[...]
        acc_ref[...] = as_ref[...]


@functools.partial(jax.jit, static_argnames=("scale", "block_s", "interpret"))
def decode_attention_partial(
    q: jax.Array,        # (b, hq, 1, hd)
    k: jax.Array,        # (b, hkv, S, hd)
    v: jax.Array,
    valid: jax.Array,    # (S,) bool — position mask (causal/window/emptiness)
    scale: float,
    *,
    block_s: int = 512,
    interpret: bool = True,
):
    """-> flash partials (m (b,hq,1), l (b,hq,1), acc (b,hq,1,hd)) fp32."""
    b, hq, _, hd = q.shape
    hkv, S = k.shape[1], k.shape[2]
    g = hq // hkv
    bs = min(block_s, S)
    pad_s = (-S) % bs
    if pad_s:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_s), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_s), (0, 0)))
        valid = jnp.pad(valid, (0, pad_s))
    Sp = S + pad_s
    n_s = Sp // bs
    qg = q.reshape(b, hkv, g, hd).reshape(b * hkv, g, hd)
    kg = k.reshape(b * hkv, Sp, hd)
    vg = v.reshape(b * hkv, Sp, hd)
    vmask = valid.astype(jnp.int32)
    import jax.experimental.pallas.tpu as pltpu

    m, l, acc = pl.pallas_call(
        functools.partial(_decode_kernel, scale=scale, n_s=n_s),
        grid=(b * hkv, n_s),
        in_specs=[
            pl.BlockSpec((None, g, hd), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((None, bs, hd), lambda i, j: (i, j, 0)),
            pl.BlockSpec((None, bs, hd), lambda i, j: (i, j, 0)),
            pl.BlockSpec((bs,), lambda i, j: (j,)),
        ],
        out_specs=[
            pl.BlockSpec((None, g, 1), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((None, g, 1), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((None, g, hd), lambda i, j: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b * hkv, g, 1), jnp.float32),
            jax.ShapeDtypeStruct((b * hkv, g, 1), jnp.float32),
            jax.ShapeDtypeStruct((b * hkv, g, hd), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, hd), jnp.float32),
        ],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(qg, kg, vg, vmask)
    m = m.reshape(b, hq, 1)
    l = l.reshape(b, hq, 1)
    acc = acc.reshape(b, hq, 1, hd)
    # match the jnp path's -inf convention for fully-masked shards
    m = jnp.where(m <= NEG / 2, -jnp.inf, m)
    return m, l, acc


# ---------------------------------------------------------------------------
# Paged variant: gather K/V block-by-block through the slot's block table
# ---------------------------------------------------------------------------


def _paged_decode_kernel(bt_ref, q_ref, k_ref, v_ref, valid_ref,
                         m_ref, l_ref, acc_ref, ms_ref, ls_ref, as_ref,
                         *, scale: float, n_s: int):
    """Grid (b*hkv, blocks_per_slot).  The block table is a SCALAR-PREFETCH
    operand: the index map of K/V dereferences it to DMA the j-th logical
    block's physical (block_size, hd) slab — the kernel never sees a dense
    per-slot cache, so HBM traffic is one read of the blocks that actually
    hold data.  Validity is per block: slabs whose mask is entirely dead
    (unallocated / beyond the slot's length -> null block) skip the flash
    update altogether, moving position masking to block granularity."""
    del bt_ref  # consumed by the index maps
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        ms_ref[...] = jnp.full_like(ms_ref, NEG)
        ls_ref[...] = jnp.zeros_like(ls_ref)
        as_ref[...] = jnp.zeros_like(as_ref)

    ok = valid_ref[...] != 0                             # (bs,)

    @pl.when(jnp.any(ok))
    def _update():
        q = q_ref[...].astype(jnp.float32)               # (g, hd)
        k = k_ref[...].astype(jnp.float32)               # (bs, hd)
        v = v_ref[...].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        s = jnp.where(ok[None, :], s, NEG)
        m_prev = ms_ref[...][:, 0]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.where(ok[None, :], jnp.exp(s - m_new[:, None]), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_new = ls_ref[...][:, 0] * corr + p.sum(axis=1)
        acc_new = as_ref[...] * corr[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32
        )
        ms_ref[...] = m_new[:, None]
        ls_ref[...] = l_new[:, None]
        as_ref[...] = acc_new

    @pl.when(j == n_s - 1)
    def _emit():
        m_ref[...] = ms_ref[...]
        l_ref[...] = ls_ref[...]
        acc_ref[...] = as_ref[...]


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def paged_decode_attention_partial(
    q: jax.Array,        # (b, hq, 1, hd)
    kp: jax.Array,       # (nb, hkv, block_size, hd) block pool
    vp: jax.Array,
    bt: jax.Array,       # (b, nbps) int32 block table (view index -> block)
    valid: jax.Array,    # (b, nbps*block_size) bool per-slot position mask
    scale: float,
    *,
    interpret: bool = True,
):
    """-> flash partials (m (b,hq,1), l (b,hq,1), acc (b,hq,1,hd)) fp32."""
    import jax.experimental.pallas.tpu as pltpu

    b, hq, _, hd = q.shape
    nb, hkv, bs, _ = kp.shape
    nbps = bt.shape[1]
    g = hq // hkv
    qg = q.reshape(b, hkv, g, hd).reshape(b * hkv, g, hd)
    vmask = valid.reshape(b, nbps, bs).astype(jnp.int32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b * hkv, nbps),
        in_specs=[
            pl.BlockSpec((None, g, hd), lambda i, j, bt_ref: (i, 0, 0)),
            pl.BlockSpec((None, None, bs, hd),
                         lambda i, j, bt_ref: (bt_ref[i // hkv, j],
                                               i % hkv, 0, 0)),
            pl.BlockSpec((None, None, bs, hd),
                         lambda i, j, bt_ref: (bt_ref[i // hkv, j],
                                               i % hkv, 0, 0)),
            pl.BlockSpec((None, None, bs),
                         lambda i, j, bt_ref: (i // hkv, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, g, 1), lambda i, j, bt_ref: (i, 0, 0)),
            pl.BlockSpec((None, g, 1), lambda i, j, bt_ref: (i, 0, 0)),
            pl.BlockSpec((None, g, hd), lambda i, j, bt_ref: (i, 0, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, hd), jnp.float32),
        ],
    )
    m, l, acc = pl.pallas_call(
        functools.partial(_paged_decode_kernel, scale=scale, n_s=nbps),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b * hkv, g, 1), jnp.float32),
            jax.ShapeDtypeStruct((b * hkv, g, 1), jnp.float32),
            jax.ShapeDtypeStruct((b * hkv, g, hd), jnp.float32),
        ],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(bt.astype(jnp.int32), qg, kp, vp, vmask)
    m = m.reshape(b, hq, 1)
    l = l.reshape(b, hq, 1)
    acc = acc.reshape(b, hq, 1, hd)
    m = jnp.where(m <= NEG / 2, -jnp.inf, m)
    return m, l, acc
