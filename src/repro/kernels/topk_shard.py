"""Pallas TPU kernel: running top-k over a vocab shard (paper §2.1b hot spot).

The kernel streams the local logits row through VMEM in (block_b, block_v)
tiles, maintaining a running top-k candidate set in VMEM scratch.  Per tile it
performs k argmax-extract-mask passes over the concatenated
(running ∪ tile) candidates — k is small (<=64), the tile is MXU/VPU-aligned
(block_v multiple of 128), so the pass is VPU-bound and the HBM traffic is a
single read of the logits: exactly the memory-roofline optimum for top-k.

Target: TPU (VMEM BlockSpecs); validated on CPU via interpret=True against
``ref.topk_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro import compat

NEG = -3.0e38  # sentinel below any real logit (fp32)


def _topk_kernel(x_ref, vals_ref, idx_ref, rv_ref, ri_ref, *, k: int, block_v: int,
                 n_vblocks: int, v_local: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        rv_ref[...] = jnp.full_like(rv_ref, NEG)
        ri_ref[...] = jnp.zeros_like(ri_ref)

    x = x_ref[...].astype(jnp.float32)                   # (bb, block_v)
    bb = x.shape[0]
    col0 = j * block_v
    cols = col0 + jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
    # mask out-of-range tail (vocab padded to block multiple)
    x = jnp.where(cols < v_local, x, NEG)

    # candidates = running (bb,k) ++ tile (bb,block_v)
    cand_v = jnp.concatenate([rv_ref[...], x], axis=1)
    cand_i = jnp.concatenate([ri_ref[...], cols], axis=1)

    new_v = jnp.zeros((bb, k), jnp.float32)
    new_i = jnp.zeros((bb, k), jnp.int32)
    for t in range(k):                                   # unrolled: k small
        m = jnp.max(cand_v, axis=1)                      # (bb,)
        am = jnp.argmax(cand_v, axis=1)                  # (bb,)
        picked_i = jnp.take_along_axis(cand_i, am[:, None], axis=1)[:, 0]
        new_v = new_v.at[:, t].set(m)
        new_i = new_i.at[:, t].set(picked_i)
        onehot = jax.lax.broadcasted_iota(jnp.int32, cand_v.shape, 1) == am[:, None]
        cand_v = jnp.where(onehot, NEG, cand_v)
    rv_ref[...] = new_v
    ri_ref[...] = new_i

    @pl.when(j == n_vblocks - 1)
    def _emit():
        vals_ref[...] = new_v
        idx_ref[...] = new_i


@functools.partial(jax.jit, static_argnames=("k", "block_b", "block_v", "interpret"))
def topk(x: jax.Array, k: int, *, block_b: int = 8, block_v: int = 512,
         interpret: bool = True):
    """(batch, v_local) -> (vals (batch,k) fp32, idx (batch,k) int32)."""
    b, v = x.shape
    bb = min(block_b, b)
    bv = min(block_v, max(128, v))
    pad_b = (-b) % bb
    pad_v = (-v) % bv
    xp = jnp.pad(x, ((0, pad_b), (0, pad_v)), constant_values=NEG)
    B, V = xp.shape
    n_vblocks = V // bv
    grid = (B // bb, n_vblocks)
    import jax.experimental.pallas.tpu as pltpu

    vals, idx = pl.pallas_call(
        functools.partial(_topk_kernel, k=k, block_v=bv, n_vblocks=n_vblocks,
                          v_local=v),
        grid=grid,
        in_specs=[pl.BlockSpec((bb, bv), lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec((bb, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bb, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, k), jnp.float32),
            jax.ShapeDtypeStruct((B, k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bb, k), jnp.float32),
            pltpu.VMEM((bb, k), jnp.int32),
        ],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(xp)
    return vals[:b], idx[:b]
