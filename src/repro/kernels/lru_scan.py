"""Pallas TPU kernel: RG-LRU linear-recurrence scan (Griffin's hot loop).

Computes h_t = a_t * h_{t-1} + b_t over the sequence for a (batch, seq, width)
tile, keeping the running state in VMEM registers — one HBM read of (a, b) and
one write of h, vs the log-depth associative_scan which materialises
O(log S) intermediate (b, s, w) tensors in HBM.  Width is tiled in
lane-aligned (128) blocks; the sequential loop is a kernel-internal
fori_loop (TPU scalar unit), which is exactly how the Griffin paper describes
their Pallas implementation ("linear scan", arXiv:2402.19427 §A).

Target: TPU; validated with interpret=True against ``ref.lru_scan_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro import compat


def _lru_kernel(a_ref, b_ref, h0_ref, out_ref, hT_ref, *, seq: int):
    h = h0_ref[...].astype(jnp.float32)                  # (1, bw)

    def body(t, h):
        a_t = a_ref[0, t, :].astype(jnp.float32)         # (bw,)
        b_t = b_ref[0, t, :].astype(jnp.float32)
        h = a_t[None, :] * h + b_t[None, :]
        out_ref[0, t, :] = h[0].astype(out_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, seq, body, h)
    hT_ref[...] = h.astype(hT_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_w", "interpret"))
def lru_scan(a: jax.Array, b: jax.Array, h0: jax.Array, *,
             block_w: int = 128, interpret: bool = True):
    """a, b: (batch, seq, width) fp32; h0: (batch, width) fp32
    -> (h (batch, seq, width), h_final (batch, width))."""
    bsz, s, w = a.shape
    bw = min(block_w, w)
    pad_w = (-w) % bw
    if pad_w:
        a = jnp.pad(a, ((0, 0), (0, 0), (0, pad_w)))
        b = jnp.pad(b, ((0, 0), (0, 0), (0, pad_w)))
        h0 = jnp.pad(h0, ((0, 0), (0, pad_w)))
    Wp = w + pad_w
    import jax.experimental.pallas.tpu as pltpu

    hs, hT = pl.pallas_call(
        functools.partial(_lru_kernel, seq=s),
        grid=(bsz, Wp // bw),
        in_specs=[
            pl.BlockSpec((1, s, bw), lambda i, j: (i, 0, j)),
            pl.BlockSpec((1, s, bw), lambda i, j: (i, 0, j)),
            pl.BlockSpec((1, bw), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((1, s, bw), lambda i, j: (i, 0, j)),
            pl.BlockSpec((1, bw), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, s, Wp), jnp.float32),
            jax.ShapeDtypeStruct((bsz, Wp), jnp.float32),
        ],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel"),
        ),
        interpret=interpret,
    )(a, b, h0)
    return hs[..., :w], hT[..., :w]
