"""Pallas TPU kernel: fused GQA flash-prefill over a causal query block.

The prefill counterpart of ``decode_attention``: a block of query tokens
attends causally over K/V with one fused blockwise-softmax pass instead of
the pure-JAX ``chunked_causal_attention`` scan.  arXiv 2407.07304 (the
sibling single-node paper) measures the fused flash prefill as the single
largest prefill win on CPUs; this kernel is the TPU/Pallas expression of the
same fusion, and serves the chunked-prefill serving path where a chunk of C
prompt tokens attends the slot's cache stripe [0, start + C).

Grid is (batch x kv_head, q_blocks, kv_blocks) with the kv dimension
innermost: each step loads one (block_k, head_dim) K/V slab into VMEM and
updates running (m, l, acc) flash statistics for the (block_q, g) query tile
sharing that KV head.  Causality is positional: query row i (absolute
position ``q_pos[i]``) attends kv view index j iff ``j <= q_pos[i]`` — on
the chunked path view index == absolute position, so no separate validity
mask is carried.  KV blocks that start beyond the tile's maximum query
position skip their flash update entirely (dead-by-causality blocks cost no
FLOPs; with chunked prefill that is every block past the chunk's end).

Two variants share the kernel body:

* dense stripe — K/V are (b, hkv, Sk, hd) contiguous stripes (fresh prompt
  K/V, or the slot engine's dense cache);
* paged — K/V live in (n_blocks, hkv, block_size, hd) pools addressed
  through a per-slot block table, dereferenced by scalar-prefetch index
  maps exactly like the paged decode kernel.

Target: TPU; validated with interpret=True against the
``chunked_causal_attention`` oracle (tests/test_chunked_prefill.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro import compat

NEG = -3.0e38


def _flash_update(qp_ref, q_ref, k_ref, v_ref, ms_ref, ls_ref, as_ref,
                  *, scale: float, kv0, bk: int, kv_limit: int):
    """One (block_q, g) x (block_k,) flash step against running stats."""
    qpos = qp_ref[...]                                    # (bq,)

    @pl.when(kv0 <= jnp.max(qpos))
    def _update():
        q = q_ref[...].astype(jnp.float32)                # (bq, g, hd)
        k = k_ref[...].astype(jnp.float32)                # (bk, hd)
        v = v_ref[...].astype(jnp.float32)
        bq, g, hd = q.shape
        s = jnp.dot(q.reshape(bq * g, hd), k.T,
                    preferred_element_type=jnp.float32)
        s = (s * scale).reshape(bq, g, bk)
        kvpos = kv0 + jax.lax.broadcasted_iota(jnp.int32, (1, 1, bk), 2)
        ok = (kvpos <= qpos[:, None, None]) & (kvpos < kv_limit)
        s = jnp.where(ok, s, NEG)
        m_prev = ms_ref[...]                              # (bq, g)
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        # explicit zeroing: on a fully-masked row exp(NEG - NEG) would be 1
        p = jnp.where(ok, jnp.exp(s - m_new[..., None]), 0.0)
        corr = jnp.exp(m_prev - m_new)
        ls_ref[...] = ls_ref[...] * corr + p.sum(axis=-1)
        pv = jnp.dot(p.reshape(bq * g, bk), v,
                     preferred_element_type=jnp.float32).reshape(bq, g, hd)
        as_ref[...] = as_ref[...] * corr[..., None] + pv
        ms_ref[...] = m_new


def _prefill_kernel(qp_ref, q_ref, k_ref, v_ref, o_ref,
                    ms_ref, ls_ref, as_ref,
                    *, scale: float, bk: int, n_k: int, kv_limit: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        ms_ref[...] = jnp.full_like(ms_ref, NEG)
        ls_ref[...] = jnp.zeros_like(ls_ref)
        as_ref[...] = jnp.zeros_like(as_ref)

    _flash_update(qp_ref, q_ref, k_ref, v_ref, ms_ref, ls_ref, as_ref,
                  scale=scale, kv0=j * bk, bk=bk, kv_limit=kv_limit)

    @pl.when(j == n_k - 1)
    def _emit():
        l = ls_ref[...]
        o_ref[...] = (as_ref[...]
                      / jnp.maximum(l, 1e-30)[..., None]).astype(o_ref.dtype)


def _fold_q(q: jax.Array, hkv: int):
    """(b, hq, Sq, hd) -> (b*hkv, Sq, g, hd) GQA-grouped query layout."""
    b, hq, sq, hd = q.shape
    g = hq // hkv
    return (q.reshape(b, hkv, g, sq, hd)
             .transpose(0, 1, 3, 2, 4)
             .reshape(b * hkv, sq, g, hd))


def _unfold_o(o: jax.Array, b: int, hkv: int, sq: int):
    """(b*hkv, Sq_pad, g, hd) -> (b, hq, Sq, hd)."""
    _, sqp, g, hd = o.shape
    return (o.reshape(b, hkv, sqp, g, hd)
             .transpose(0, 1, 3, 2, 4)
             .reshape(b, hkv * g, sqp, hd)[:, :, :sq])


@functools.partial(jax.jit,
                   static_argnames=("scale", "block_q", "block_k", "interpret"))
def flash_prefill(
    q: jax.Array,        # (b, hq, Sq, hd) — RoPE already applied
    k: jax.Array,        # (b, hkv, Sk, hd) stripe (fresh K/V or cache)
    v: jax.Array,
    q_pos: jax.Array,    # (b, Sq) int32 absolute/view position per query
    scale: float,
    *,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
):
    """-> normalized attention out (b, hq, Sq, hd) in q.dtype.

    Causal against the kv VIEW index (row i attends j <= q_pos[b, i]); pad
    query rows carry q_pos = -1 and emit zeros (callers discard them)."""
    import jax.experimental.pallas.tpu as pltpu

    b, hq, sq, hd = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    g = hq // hkv
    # clamp the q tile to Sq rounded UP to a whole sublane group (multiple
    # of 8) rather than raw Sq, so narrow-q callers (flash_verify) get a
    # full-sublane tile with q_pos = -1 pad rows instead of a sliver
    bq = min(block_q, -(-sq // 8) * 8)
    bk = min(block_k, sk)
    pad_q, pad_k = (-sq) % bq, (-sk) % bk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, pad_q)), constant_values=-1)
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    sqp, skp = sq + pad_q, sk + pad_k
    n_q, n_k = sqp // bq, skp // bk
    qg = _fold_q(q, hkv)
    kg = k.reshape(b * hkv, skp, hd)
    vg = v.reshape(b * hkv, skp, hd)

    o = pl.pallas_call(
        functools.partial(_prefill_kernel, scale=scale, bk=bk, n_k=n_k,
                          kv_limit=sk),
        grid=(b * hkv, n_q, n_k),
        in_specs=[
            pl.BlockSpec((None, bq), lambda i, qi, j, hkv=hkv: (i // hkv, qi)),
            pl.BlockSpec((None, bq, g, hd), lambda i, qi, j: (i, qi, 0, 0)),
            pl.BlockSpec((None, bk, hd), lambda i, qi, j: (i, j, 0)),
            pl.BlockSpec((None, bk, hd), lambda i, qi, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((None, bq, g, hd),
                               lambda i, qi, j: (i, qi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hkv, sqp, g, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, g), jnp.float32),
            pltpu.VMEM((bq, g), jnp.float32),
            pltpu.VMEM((bq, g, hd), jnp.float32),
        ],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q_pos.astype(jnp.int32), qg, kg, vg)
    return _unfold_o(o, b, hkv, sq)


@functools.partial(jax.jit, static_argnames=("scale", "block_k", "interpret"))
def flash_verify(
    q: jax.Array,        # (b, hq, Sq, hd), Sq = spec_k+1 (tiny)
    k: jax.Array,        # (b, hkv, Sk, hd) cache stripe
    v: jax.Array,
    q_pos: jax.Array,    # (b, Sq) int32 view position per query
    scale: float,
    *,
    block_k: int = 512,
    interpret: bool = True,
):
    """Verify-width specialization of :func:`flash_prefill` for the
    speculative-decode verify step (Sq = spec_k+1, typically 2..9).

    Same kernel body, different blocking: the generic path would carve an
    Sq-row q tile (a sliver of a sublane group) and stream 128-wide KV
    blocks past it — one grid step per 128 cache tokens for a near-empty
    MXU tile.  Here the single q tile is rounded UP to whole sublane
    groups (multiples of 8; pad rows ride with q_pos = -1 and emit zeros)
    and the KV block widens to ``block_k``, so the q-block grid dimension
    degenerates to 1 and the whole cache streams through 4x fewer, fuller
    slabs.  Dead-beyond-causality KV blocks still skip their update, so
    blocks past the decode frontier cost no FLOPs."""
    sq = q.shape[2]
    bq = -(-sq // 8) * 8
    return flash_prefill(q, k, v, q_pos, scale, block_q=bq,
                         block_k=block_k, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("scale", "block_q", "interpret"))
def paged_flash_prefill(
    q: jax.Array,        # (b, hq, Sq, hd)
    kp: jax.Array,       # (nb, hkv, block_size, hd) block pool
    vp: jax.Array,
    bt: jax.Array,       # (b, nbps) int32 block table (view index -> block)
    q_pos: jax.Array,    # (b, Sq) int32 view position per query
    scale: float,
    *,
    block_q: int = 128,
    interpret: bool = True,
):
    """Paged variant: K/V gathered block-by-block through the slot's block
    table via scalar-prefetch index maps (never materialises a dense view).
    -> (b, hq, Sq, hd) in q.dtype."""
    import jax.experimental.pallas.tpu as pltpu

    b, hq, sq, hd = q.shape
    nb, hkv, bs, _ = kp.shape
    nbps = bt.shape[1]
    g = hq // hkv
    bq = min(block_q, -(-sq // 8) * 8)   # same sublane-group round-up as
                                         # the dense kernel
    pad_q = (-sq) % bq
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, pad_q)), constant_values=-1)
    sqp = sq + pad_q
    n_q = sqp // bq
    qg = _fold_q(q, hkv)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b * hkv, n_q, nbps),
        in_specs=[
            pl.BlockSpec((None, bq),
                         lambda i, qi, j, bt_ref: (i // hkv, qi)),
            pl.BlockSpec((None, bq, g, hd),
                         lambda i, qi, j, bt_ref: (i, qi, 0, 0)),
            pl.BlockSpec((None, None, bs, hd),
                         lambda i, qi, j, bt_ref: (bt_ref[i // hkv, j],
                                                   i % hkv, 0, 0)),
            pl.BlockSpec((None, None, bs, hd),
                         lambda i, qi, j, bt_ref: (bt_ref[i // hkv, j],
                                                   i % hkv, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, bq, g, hd),
                               lambda i, qi, j, bt_ref: (i, qi, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((bq, g), jnp.float32),
            pltpu.VMEM((bq, g), jnp.float32),
            pltpu.VMEM((bq, g, hd), jnp.float32),
        ],
    )

    def kernel(bt_ref, *args):
        del bt_ref  # consumed by the index maps
        _prefill_kernel(*args, scale=scale, bk=bs, n_k=nbps,
                        kv_limit=nbps * bs)

    o = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b * hkv, sqp, g, hd), q.dtype),
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(bt.astype(jnp.int32), q_pos.astype(jnp.int32), qg, kp, vp)
    return _unfold_o(o, b, hkv, sq)


# NOTE: the paged kernel needs no separate verify entry point — its KV
# blocking is pinned to the pool's block size (table entries are
# non-contiguous, one grid step per block either way) and the sublane
# round-up of narrow q tiles happens in the shared clamp above, so
# spec-decode verify widths already get the right blocking through
# paged_flash_prefill.
