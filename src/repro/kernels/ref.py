"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def topk_ref(x: jax.Array, k: int):
    """(batch, v) -> (vals fp32, idx int32)."""
    vals, idx = jax.lax.top_k(x.astype(jnp.float32), k)
    return vals, idx.astype(jnp.int32)


def fused_residual_ref(a, wa, b, wb):
    """out = a@wa + b@wb with fp32 accumulation."""
    o = jnp.dot(a.astype(jnp.float32), wa.astype(jnp.float32)) + jnp.dot(
        b.astype(jnp.float32), wb.astype(jnp.float32)
    )
    return o.astype(a.dtype)


def decode_attention_ref(q, k, v, valid, scale):
    """Flash partials (m, l, acc) for one decode token; fp32.

    q (b,hq,1,hd); k,v (b,hkv,S,hd); valid (S,) bool.
    """
    b, hq, _, hd = q.shape
    hkv = k.shape[1]
    g = hq // hkv
    qg = q.reshape(b, hkv, g, hd).astype(jnp.float32)
    s = jnp.einsum("bkgd,bksd->bkgs", qg, k.astype(jnp.float32)) * scale
    s = jnp.where(valid[None, None, None, :], s, -jnp.inf)
    m = s.max(axis=-1)
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    p = jnp.exp(s - m_safe[..., None])
    l = p.sum(axis=-1)
    acc = jnp.einsum("bkgs,bksd->bkgd", p, v.astype(jnp.float32))
    return (
        m.reshape(b, hq, 1),
        l.reshape(b, hq, 1),
        acc.reshape(b, hq, 1, hd),
    )


def dequant_matmul_ref(x, q, scale, mode: str, group: int):
    """Fused dequant-matmul oracle, mirroring the kernel's math exactly:

    int8: fp32 ``x @ q`` with the per-output-channel scale applied ONCE to
    the accumulated result (scales commute with the K reduction);
    int4: per-group ``sum_g s_g * (x_g @ q_g)`` over unpacked nibbles.
    Returns fp32 (callers cast)."""
    from repro.core.wquant import unpack4

    xf = x.astype(jnp.float32)
    if mode == "int8":
        return (xf @ q.astype(jnp.float32)) * scale.astype(jnp.float32)[None, :]
    w = unpack4(q).astype(jnp.float32)               # (K, N)
    K, N = w.shape
    wg = w.reshape(K // group, group, N) * scale.astype(jnp.float32)[:, None, :]
    return xf @ wg.reshape(K, N)


def lru_scan_ref(a, b, h0):
    """Linear recurrence h_t = a_t h_{t-1} + b_t via lax.scan; fp32.

    a, b: (batch, seq, w); h0: (batch, w) -> (h (batch, seq, w), h_T)."""
    def step(h, ab):
        a_t, b_t = ab
        h = a_t * h + b_t
        return h, h

    at = jnp.moveaxis(a.astype(jnp.float32), 1, 0)
    bt = jnp.moveaxis(b.astype(jnp.float32), 1, 0)
    hT, hs = jax.lax.scan(step, h0.astype(jnp.float32), (at, bt))
    return jnp.moveaxis(hs, 0, 1), hT
