"""jit'd public wrappers for the Pallas kernels.

On this CPU container the kernels always run with interpret=True (the kernel
body executes in Python, validating the exact TPU program); on a real TPU set
``REPRO_PALLAS_INTERPRET=0`` to lower to Mosaic.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels import decode_attention as _da
from repro.kernels import fused_residual as _fr
from repro.kernels import topk_shard as _tk

INTERPRET = os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0"


def topk(x: jax.Array, k: int):
    """(batch, v_local) -> (vals (batch,k) fp32, idx (batch,k) int32)."""
    return _tk.topk(x, k, interpret=INTERPRET)


def fused_dual_matmul(a, wa, b, wb):
    """(T,Ka)@(Ka,D) + (T,Kb)@(Kb,D) accumulated in one output tile."""
    return _fr.fused_dual_matmul(a, wa, b, wb, interpret=INTERPRET)


def decode_attention_partial(q, k, v, valid, scale):
    """Flash partials (m, l, acc) for one decode token over the cache."""
    return _da.decode_attention_partial(q, k, v, valid, float(scale),
                                        interpret=INTERPRET)


def paged_decode_attention(q, kp, vp, bt, valid, scale):
    """Flash partials for one decode token per slot, K/V gathered block-by-
    block from the paged pool through the slot's block table."""
    return _da.paged_decode_attention_partial(q, kp, vp, bt, valid,
                                              float(scale),
                                              interpret=INTERPRET)


def flash_prefill(q, k, v, q_pos, scale):
    """Fused causal flash-prefill: a (b, hq, Sq, hd) query block attends a
    dense K/V stripe, row i valid against kv view index j iff
    j <= q_pos[b, i].  Normalized output, one fused blockwise pass."""
    from repro.kernels import prefill_attention as _pa

    return _pa.flash_prefill(q, k, v, q_pos, float(scale),
                             interpret=INTERPRET)


def paged_flash_prefill(q, kp, vp, bt, q_pos, scale):
    """Fused causal flash-prefill over the paged pool: K/V blocks are
    dereferenced through the slot's block table (scalar prefetch), so the
    dense per-slot view is never materialised."""
    from repro.kernels import prefill_attention as _pa

    return _pa.paged_flash_prefill(q, kp, vp, bt, q_pos, float(scale),
                                   interpret=INTERPRET)


def flash_verify(q, k, v, q_pos, scale):
    """Narrow-q (speculative-verify / small-chunk) specialization of
    ``flash_prefill``: q tile rounded up to whole sublane groups, wider KV
    slabs — same kernel body, blocking tuned for Sq = spec_k+1.  (The
    paged kernel needs no counterpart: its KV blocking is pinned to the
    pool block size and the q-tile round-up is in the shared clamp.)"""
    from repro.kernels import prefill_attention as _pa

    return _pa.flash_verify(q, k, v, q_pos, float(scale),
                            interpret=INTERPRET)


def lru_scan(a, b, h0):
    """RG-LRU linear-recurrence scan: h_t = a_t h_{t-1} + b_t."""
    from repro.kernels import lru_scan as _ls

    return _ls.lru_scan(a, b, h0, interpret=INTERPRET)


def dequant_matmul(x, q, scale, *, mode, group, out_dtype=None):
    """Fused dequantize-and-matmul over packed weight-only-quantized
    weights: (T, K) @ dequant((K, N)) with the bf16 weight never
    materialised.  GEMV blocking (sublane-rounded T tile, wide N slabs)
    engages automatically for decode-narrow T; prefill/verify widths tile
    at 128."""
    from repro.kernels import wquant_matmul as _wq

    return _wq.dequant_matmul(x, q, scale, mode=mode, group=int(group),
                              out_dtype=out_dtype, interpret=INTERPRET)
