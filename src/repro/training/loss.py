"""Vocab-parallel cross-entropy (logits sharded over the model axis).

Never materialises the gathered (b, s, V) logits: local max / sum-exp /
label-pick are psum'd — the training-side sibling of the paper's
"reduce k values, not the vocab row" principle.
"""
from __future__ import annotations

from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core import collectives as cc
from repro.models.common import Dist, ShardPlan


def vocab_parallel_xent(
    local_logits: jax.Array,      # (b, s, V_local) fp32 (or (b,s,ncb,V_local))
    labels: jax.Array,            # (b, s) or (b, s, ncb) global vocab ids
    plan: ShardPlan,
    dist: Dist,
    *,
    mask: Optional[jax.Array] = None,  # (b, s) 1.0 = count this position
) -> jax.Array:
    """Mean CE over all tokens of the GLOBAL batch (psum over data axes)."""
    if local_logits.ndim == 4:      # codebook models: fold ncb into seq
        b, s, ncb, v = local_logits.shape
        local_logits = local_logits.reshape(b, s * ncb, v)
        labels = labels.reshape(b, s * ncb)
        if mask is not None:
            mask = jnp.repeat(mask, ncb, axis=1)
    lo = (dist.model_idx() if dist.tp > 1 else jnp.int32(0)) * plan.local_vocab

    # stable LSE over the sharded vocab
    # the subtracted max is a numerical-stability constant (zero true
    # gradient); pmax has no AD rule, so stop_gradient BEFORE the collective
    local_max = jax.lax.stop_gradient(local_logits.max(axis=-1))
    if dist.tp > 1:
        gmax = jax.lax.pmax(local_max, dist.model_axis)
    else:
        gmax = local_max
    sumexp = jnp.exp(local_logits - gmax[..., None]).sum(axis=-1)
    if dist.tp > 1:
        sumexp = cc.psum(sumexp, dist.model_axis, tag="xent_sumexp")
    lse = jnp.log(sumexp) + gmax

    # label logit: only the owning shard contributes
    lid = labels - lo
    ok = (lid >= 0) & (lid < plan.local_vocab)
    lid = jnp.clip(lid, 0, plan.local_vocab - 1)
    picked = jnp.take_along_axis(local_logits, lid[..., None], axis=-1)[..., 0]
    picked = jnp.where(ok, picked, 0.0)
    if dist.tp > 1:
        picked = cc.psum(picked, dist.model_axis, tag="xent_label")

    nll = lse - picked                                    # (b, s')
    if mask is None:
        mask = jnp.ones_like(nll)
    tot = (nll * mask).sum()
    cnt = mask.sum()
    tot = cc.psum(tot, dist.data_axes, tag="xent_mean") if dist.dp * dist.pods > 1 else tot
    cnt = cc.psum(cnt, dist.data_axes, tag="xent_mean") if dist.dp * dist.pods > 1 else cnt
    return tot / jnp.maximum(cnt, 1.0)


def chunked_vocab_parallel_xent(
    hidden: jax.Array,            # (b, s, d) final-norm hidden states
    head_fn,                      # (b, c, d) -> local logits (b, c, [ncb,] V_local) fp32
    labels: jax.Array,            # (b, s[, ncb]) global vocab ids
    plan: ShardPlan,
    dist: Dist,
    *,
    chunk: int = 512,
    mask: Optional[jax.Array] = None,
) -> jax.Array:
    """Sequence-chunked vocab-parallel CE: the (b, s, V_local) fp32 logits are
    never materialised — each chunk's logits live only inside a checkpointed
    scan step (recomputed in backward).  All cross-shard collectives happen
    ONCE, after the scan, on (b, s)-sized statistics."""
    b, s, d = hidden.shape
    c = min(chunk, s)
    if s % c:
        raise ValueError(f"seq {s} not divisible by xent chunk {c}")
    nc = s // c
    lo = (dist.model_idx() if dist.tp > 1 else jnp.int32(0)) * plan.local_vocab
    if mask is None:
        mask = jnp.ones((b, s), jnp.float32)

    ncb = labels.shape[2] if labels.ndim == 3 else 1
    lab = labels.reshape(b, nc, c * ncb).transpose(1, 0, 2)        # (nc, b, c*ncb)
    hid = hidden.reshape(b, nc, c, d).transpose(1, 0, 2, 3)        # (nc, b, c, d)
    msk = jnp.repeat(mask, ncb, axis=1).reshape(b, nc, c * ncb).transpose(1, 0, 2)

    @jax.checkpoint
    def body(carry, xs):
        h_c, lab_c, _ = xs
        logits = head_fn(h_c)                                      # fp32
        if logits.ndim == 4:
            logits = logits.reshape(b, c * ncb, plan.local_vocab)
        lmax = jax.lax.stop_gradient(logits.max(axis=-1))          # (b, c*ncb)
        sexp = jnp.exp(logits - lmax[..., None]).sum(axis=-1)
        lid = lab_c - lo
        ok = (lid >= 0) & (lid < plan.local_vocab)
        lid = jnp.clip(lid, 0, plan.local_vocab - 1)
        picked = jnp.take_along_axis(logits, lid[..., None], axis=-1)[..., 0]
        picked = jnp.where(ok, picked, 0.0)
        return carry, (lmax, sexp, picked)

    from repro.models.common import maybe_scan
    _, (lmax, sexp, picked) = maybe_scan(body, (), (hid, lab, msk))
    # (nc, b, c*ncb) -> (b, s*ncb)
    tos = lambda t: t.transpose(1, 0, 2).reshape(b, s * ncb)
    lmax, sexp, picked, msk = tos(lmax), tos(sexp), tos(picked), tos(msk)

    if dist.tp > 1:
        gmax = jax.lax.pmax(lmax, dist.model_axis)
        sexp = cc.psum(sexp * jnp.exp(lmax - gmax), dist.model_axis, tag="xent_sumexp")
        picked = cc.psum(picked, dist.model_axis, tag="xent_label")
    else:
        gmax = lmax
    lse = jnp.log(sexp) + gmax
    nll = lse - picked
    tot = (nll * msk).sum()
    cnt = msk.sum()
    if dist.dp * dist.pods > 1:
        tot = cc.psum(tot, dist.data_axes, tag="xent_mean")
        cnt = cc.psum(cnt, dist.data_axes, tag="xent_mean")
    return tot / jnp.maximum(cnt, 1.0)
