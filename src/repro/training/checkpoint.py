"""Checkpointing: flat-key .npz for params/opt-state pytrees + metadata.

A multi-pod deployment would use a sharded async checkpointer (per-host
shards, barrier on step); here the same interface writes a single host file —
the save/restore round-trip (incl. exact pytree structure) is what tests
cover.  Custom pytree nodes (e.g. the packed ``QuantWeight``) round-trip
too: their children flatten under stable key paths, int8/uint8 payloads are
stored natively, and bf16 leaves go through a lossless fp32 detour.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Tuple

import jax
import numpy as np

Pytree = Any
SEP = "/"


def _flatten(tree: Pytree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.name == "bfloat16":   # npz cannot round-trip bf16
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


def save(path: str, tree: Pytree, *, step: int = 0, meta: Dict | None = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    np.savez(path, **flat)
    with open(path + ".meta.json", "w") as f:
        json.dump({"step": step, "meta": meta or {}, "n_arrays": len(flat)}, f)


def load_meta(path: str) -> Dict | None:
    """The sidecar metadata written by :func:`save` ({"step","meta",
    "n_arrays"}), or None when no checkpoint exists at ``path``.  Checks
    both the raw path and the ``.npz``-stripped stem, mirroring restore."""
    stem = path[:-4] if path.endswith(".npz") else path
    for meta_path in (path + ".meta.json", stem + ".meta.json"):
        if os.path.exists(meta_path):
            with open(meta_path) as f:
                return json.load(f)
    return None


def restore(path: str, like: Pytree) -> Tuple[Pytree, int]:
    """Restore into the structure of ``like`` (shape/dtype-checked)."""
    data = np.load(path if path.endswith(".npz") else path + ".npz")
    leaves_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    out = []
    for path_k, leaf in leaves_like:
        key = SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path_k)
        arr = data[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"checkpoint mismatch at {key}: {arr.shape} vs {leaf.shape}")
        import jax.numpy as jnp

        out.append(jnp.asarray(arr).astype(leaf.dtype))
    import json as _json

    step = 0
    for meta_path in (path + ".meta.json",
                      (path[:-4] if path.endswith(".npz") else path) + ".meta.json"):
        if os.path.exists(meta_path):
            step = _json.load(open(meta_path))["step"]
            break
    tree = jax.tree_util.tree_unflatten(jax.tree_util.tree_structure(like), out)
    return tree, step
