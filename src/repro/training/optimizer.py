"""AdamW in pure JAX, sharded identically to the params it updates."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Tuple

import jax
import jax.numpy as jnp

Pytree = Any


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    grad_clip: float = 1.0


def init_opt_state(params: Pytree) -> Pytree:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def lr_schedule(step: jax.Array, c: AdamWConfig) -> jax.Array:
    """Linear warmup -> cosine decay to min_lr_frac."""
    warm = jnp.minimum(1.0, (step + 1) / max(1, c.warmup_steps))
    t = jnp.clip((step - c.warmup_steps) / max(1, c.total_steps - c.warmup_steps), 0.0, 1.0)
    cos = c.min_lr_frac + (1 - c.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return c.lr * warm * cos


def global_norm(tree: Pytree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def adamw_update(
    params: Pytree, grads: Pytree, state: Pytree, c: AdamWConfig
) -> Tuple[Pytree, Pytree, jax.Array]:
    """-> (new_params, new_state, grad_norm). fp32 moments, bf16 params."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, c.grad_clip / jnp.maximum(gnorm, 1e-9))
    lr = lr_schedule(state["step"], c)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m_new = c.b1 * m + (1 - c.b1) * g
        v_new = c.b2 * v + (1 - c.b2) * g * g
        mh = m_new / (1 - c.b1 ** step.astype(jnp.float32))
        vh = v_new / (1 - c.b2 ** step.astype(jnp.float32))
        delta = mh / (jnp.sqrt(vh) + c.eps) + c.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m_new, v_new

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["m"])
    flat_v = tdef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, gnorm
