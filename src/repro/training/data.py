"""Synthetic data pipeline: deterministic, shardable token streams.

A real deployment would plug an input pipeline here (SSTable/ArrayRecord
readers, tokenizer, packing); the interface — ``iter_batches`` yielding
{tokens, labels[, features]} dicts keyed by step — is what the train loop
consumes.  The synthetic stream is a fixed-point LCG over the vocab with a
learnable bigram structure (so loss measurably decreases during smoke
training runs).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig


@dataclass(frozen=True)
class DataConfig:
    global_batch: int
    seq_len: int
    seed: int = 0


def _bigram_stream(rng: np.random.Generator, n: int, vocab: int) -> np.ndarray:
    """Markov-1 stream: tok[t+1] = (a*tok[t] + noise) % vocab — learnable."""
    out = np.empty(n, dtype=np.int32)
    t = int(rng.integers(vocab))
    a = 31337 % vocab or 7
    for i in range(n):
        out[i] = t
        t = (a * t + int(rng.integers(0, 17))) % vocab
    return out


def make_batch(cfg: ModelConfig, dc: DataConfig, step: int) -> Dict[str, np.ndarray]:
    """Deterministic batch for ``step`` (resumable without state)."""
    rng = np.random.default_rng(dc.seed * 1_000_003 + step)
    text_len = dc.seq_len
    if cfg.frontend is not None:
        text_len = dc.seq_len - cfg.frontend.prefix_len
    n = dc.global_batch * (text_len + 1)
    stream = _bigram_stream(rng, n, cfg.vocab_size).reshape(dc.global_batch, text_len + 1)
    if cfg.n_codebooks > 1:
        offs = rng.integers(0, cfg.vocab_size, size=(1, 1, cfg.n_codebooks))
        stream = (stream[..., None] + offs).astype(np.int32) % cfg.vocab_size
    batch = {"tokens": stream[:, :-1], "labels": stream[:, 1:]}
    if cfg.frontend is not None:
        batch["features"] = rng.standard_normal(
            (dc.global_batch, cfg.frontend.prefix_len, cfg.frontend.feature_dim),
            dtype=np.float32,
        )
    return batch


def iter_batches(cfg: ModelConfig, dc: DataConfig, start_step: int = 0) -> Iterator[Dict]:
    step = start_step
    while True:
        yield make_batch(cfg, dc, step)
        step += 1
