"""ZeRO-1 optimizer-state sharding over the data(+pod) axes.

Beyond-paper (but production-required) memory optimization: fp32 AdamW
moments for qwen2.5-32b are 256 GB — replicated over data they cannot fit a
16 GB v5e chip; sharded over the 16-way data axis they cost 1 GB/chip.

Schedule per step (collective-optimal, extends the paper's
minimize-communication principle to training):

  grads:  flatten -> **psum_scatter** over data axes (same wire bytes as the
          all-reduce it replaces, but each shard receives only its 1/dp chunk)
  update: AdamW math on the local chunk (m, v, and the param chunk)
  params: **all_gather** the updated chunks back to replicated

Optimizer state layout (global): each param leaf owns ``(n_data, [tp,] chunk)``
arrays sharded P(data_axes[, model]) — chunk = ceil(local_param_size / n_data).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import collectives as cc
from repro.models.common import Dist, ParamDef, ShardPlan
from repro.training.optimizer import AdamWConfig, lr_schedule

Pytree = Any


def _spec_axis_names(spec) -> set:
    names = set()
    for entry in spec:
        if entry is None:
            continue
        names.update(entry if isinstance(entry, (tuple, list)) else (entry,))
    return names


def _local_size(shape, spec, dist: Dist) -> int:
    n = 1
    for dim, entry in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        axes = entry if isinstance(entry, (tuple, list)) else (entry,) if entry else ()
        div = 1
        for a in axes:
            div *= dist.tp if a == dist.model_axis else 1
            # data axes never shard params (params are data-replicated)
        n *= dim // div
    return n


def _n_data(dist: Dist) -> int:
    return dist.dp * dist.pods


def zero_state_defs(param_defs: Pytree, dist: Dist) -> Pytree:
    """ParamDefs for the (m, v) moment chunks, matching the param tree."""
    from repro.models.common import is_def

    nd = _n_data(dist)

    def one(d: ParamDef) -> Dict[str, ParamDef]:
        model_sharded = dist.model_axis in _spec_axis_names(d.spec)
        local = _local_size(d.shape, d.spec, dist)
        chunk = -(-local // nd)
        if model_sharded:
            shape = (nd, dist.tp, chunk)
            spec = P(
                dist.data_axes if len(dist.data_axes) > 1 else dist.data_axes[0],
                dist.model_axis, None,
            )
        else:
            shape = (nd, chunk)
            spec = P(dist.data_axes if len(dist.data_axes) > 1 else dist.data_axes[0], None)
        return {
            "m": ParamDef(shape, spec, init="zeros", dtype=jnp.float32),
            "v": ParamDef(shape, spec, init="zeros", dtype=jnp.float32),
        }

    moments = jax.tree.map(one, param_defs, is_leaf=is_def)
    return {"moments": moments, "step": ParamDef((), P(), init="zeros", dtype=jnp.int32)}


def init_zero_state(param_defs: Pytree, dist: Dist) -> Pytree:
    from repro.models.common import materialize

    return materialize(zero_state_defs(param_defs, dist), jax.random.key(0))


def zero_update(
    params: Pytree,
    grads: Pytree,                # per-shard grads, NOT yet data-reduced
    state: Pytree,
    specs: Pytree,                # param partition specs
    c: AdamWConfig,
    dist: Dist,
) -> Tuple[Pytree, Pytree, jax.Array]:
    """-> (new_params, new_state, grad_norm)."""
    nd = _n_data(dist)
    data_ax = dist.data_axes if len(dist.data_axes) > 1 else dist.data_axes[0]
    step = state["step"] + 1

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_s = tdef.flatten_up_to(specs)
    flat_m = tdef.flatten_up_to(state["moments"])

    # ---- scatter grads: psum_scatter over data axes (1/dp arrives) --------
    # Grads are reduce-scattered in their native dtype (bf16) — Megatron
    # default; the fp32 cast happens on the 1/dp chunk only, which keeps the
    # peak temp at chunk-size instead of full-param-size fp32 copies.
    scattered = []
    for g, spec in zip(flat_g, flat_s):
        gf = g.reshape(-1)
        # replicated-over-model params need the Megatron TP grad all-reduce
        if dist.tp > 1 and dist.model_axis not in _spec_axis_names(spec):
            gf = cc.psum(gf, dist.model_axis, tag="zero_grad_tp")
        chunk = -(-gf.size // nd)
        gf = jnp.pad(gf, (0, nd * chunk - gf.size))
        if nd > 1:
            gf = cc.psum_scatter(gf, data_ax, scatter_dimension=0, tag="zero_grad_rs")
        scattered.append(gf.astype(jnp.float32))     # (chunk,) fp32

    # ---- global grad norm (for clipping), spec-aware over model -----------
    sq = jnp.zeros((), jnp.float32)
    for gf, spec in zip(scattered, flat_s):
        contrib = jnp.sum(gf * gf)
        if dist.tp > 1 and dist.model_axis not in _spec_axis_names(spec):
            contrib = contrib / dist.tp      # now replicated over model (post-psum)
        sq = sq + contrib
    if nd > 1:
        sq = cc.psum(sq, data_ax, tag="zero_gnorm")
    if dist.tp > 1:
        sq = cc.psum(sq, dist.model_axis, tag="zero_gnorm")
    gnorm = jnp.sqrt(sq)
    scale = jnp.minimum(1.0, c.grad_clip / jnp.maximum(gnorm, 1e-9))
    lr = lr_schedule(state["step"], c)
    b1c = 1 - c.b1 ** step.astype(jnp.float32)
    b2c = 1 - c.b2 ** step.astype(jnp.float32)

    new_p, new_m = [], []
    for p, gf, mm, spec in zip(flat_p, scattered, flat_m, flat_s):
        m, v = mm["m"][0, ...], mm["v"][0, ...]      # local chunk(s)
        if m.ndim == 2:                              # (1, chunk) model-sharded layout
            m, v = m[0], v[0]
        g = gf * scale
        pf = p.reshape(-1)
        chunk = g.shape[0]
        pf = jnp.pad(pf, (0, nd * chunk - pf.size))
        idx = jax.lax.axis_index(data_ax) if nd > 1 else jnp.int32(0)
        p_chunk = jax.lax.dynamic_slice(pf, (idx * chunk,), (chunk,)).astype(jnp.float32)
        m_new = c.b1 * m + (1 - c.b1) * g
        v_new = c.b2 * v + (1 - c.b2) * g * g
        delta = (m_new / b1c) / (jnp.sqrt(v_new / b2c) + c.eps) + c.weight_decay * p_chunk
        p_chunk = (p_chunk - lr * delta).astype(p.dtype)  # round, THEN gather (bf16 wire)
        if nd > 1:
            pf_new = cc.all_gather(p_chunk, data_ax, gather_axis=0, tag="zero_param_ag")
        else:
            pf_new = p_chunk
        new_p.append(pf_new[: p.size].reshape(p.shape))
        shape_back = mm["m"].shape
        new_m.append({
            "m": m_new.reshape(shape_back),
            "v": v_new.reshape(shape_back),
        })

    return (
        tdef.unflatten(new_p),
        {"moments": tdef.unflatten(new_m), "step": step},
        gnorm,
    )
