"""Per-shard train step: loss -> grads -> spec-aware grad reduction -> AdamW.

Gradient reduction rule (verified empirically in tests/test_distributed.py):
inside shard_map, AD does NOT sum cotangents over mesh axes, so each param's
gradient must be psum'd over every mesh axis NOT mentioned in its partition
spec — data(+pod) for sharded params, data+model for replicated ones
(the Megatron "all-reduce LN grads over the TP group" rule).  Loss terms that
are replicated end-to-end across the model axis (the MoE aux loss) are
wrapped in a model-axis pmean so the same rule stays exact for them.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import collectives as cc
from repro.models import model as M
from repro.models.common import Dist
from repro.training.loss import chunked_vocab_parallel_xent, vocab_parallel_xent
from repro.training.optimizer import AdamWConfig, adamw_update, init_opt_state

Pytree = Any


def _spec_axis_names(spec) -> set:
    names = set()
    for entry in spec:
        if entry is None:
            continue
        if isinstance(entry, (tuple, list)):
            names.update(entry)
        else:
            names.add(entry)
    return names


def reduce_grads(grads: Pytree, specs: Pytree, dist: Dist) -> Pytree:
    """psum each grad over the mesh axes its param spec does not mention."""
    mesh_axes = set(dist.data_axes) | ({dist.model_axis} if dist.tp > 1 else set())

    def red(g, spec):
        missing = tuple(sorted(mesh_axes - _spec_axis_names(spec)))
        if not missing:
            return g
        return cc.psum(g, missing, tag="grad_reduce")

    return jax.tree.map(red, grads, specs)


def make_train_step(ctx: M.ModelCtx, opt_cfg: AdamWConfig,
                    aux_weight: Optional[float] = None, *, zero1: bool = False,
                    grad_accum: int = 1):
    """Returns the per-shard train_step(params, opt_state, batch) function.

    zero1=True uses data-axis-sharded optimizer state (training/zero.py):
    the production path — fp32 moments cost 1/dp the memory and gradients
    move via psum_scatter instead of all-reduce.

    grad_accum=N splits the per-shard batch into N microbatches scanned
    sequentially with fp32 grad accumulation: activation transients shrink
    ~N-fold while the collective schedule stays per-STEP (one grad
    reduce-scatter) — §Perf H5."""
    cfg, plan, dist = ctx.cfg, ctx.plan, ctx.dist
    specs = M.param_specs(ctx)
    if aux_weight is None:
        aux_weight = cfg.moe.router_aux_weight if cfg.moe else 0.0
    all_axes = tuple(dist.data_axes) + ((dist.model_axis,) if dist.tp > 1 else ())

    def loss_fn(params, batch):
        hidden, _, aux = M.forward(
            params, batch["tokens"], ctx, features=batch.get("features"),
            seq_sharded=True, skip_head=True,
        )
        labels = batch["labels"]
        if cfg.frontend is not None:
            # prefix positions carry no next-token loss; hidden covers
            # [prefix + text]; predict text token t from position prefix+t-1.
            hidden = hidden[:, cfg.frontend.prefix_len:]
        s = hidden.shape[1]
        chunk = next(c for c in (512, 448, 384, 320, 256, 192, 128, 96, 64,
                                 32, 16, 8, 4, 2, 1) if s % c == 0)
        xent = chunked_vocab_parallel_xent(
            hidden, lambda h: M.lm_head_local(params, h, ctx), labels, plan, dist,
            chunk=chunk,
        )
        aux_m = jax.lax.pmean(aux, all_axes) if all_axes else aux
        return xent + aux_weight * aux_m, (xent, aux_m)

    def _grads(params, batch):
        if grad_accum <= 1:
            return jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        n = grad_accum
        micro = jax.tree.map(
            lambda x: x.reshape(n, x.shape[0] // n, *x.shape[1:]), batch)

        def body(carry, mb):
            acc, tot, xent, aux = carry
            (t, (xe, au)), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
            acc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), acc, g)
            return (acc, tot + t, xent + xe, aux + au), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (acc, tot, xent, aux), _ = jax.lax.scan(
            body, (zeros, 0.0, 0.0, jnp.zeros((), jnp.float32)), micro)
        scale = 1.0 / n
        grads = jax.tree.map(lambda g, p: (g * scale).astype(p.dtype), acc, params)
        return (tot * scale, (xent * scale, aux * scale)), grads

    def train_step(params, opt_state, batch):
        (total, (xent, aux)), grads = _grads(params, batch)
        if zero1:
            from repro.training.zero import zero_update

            new_params, new_opt, gnorm = zero_update(
                params, grads, opt_state, specs, opt_cfg, dist
            )
        else:
            grads = reduce_grads(grads, specs, dist)
            new_params, new_opt, gnorm = adamw_update(params, grads, opt_state, opt_cfg)
        metrics = {"loss": xent, "total_loss": total, "aux": aux, "grad_norm": gnorm}
        return new_params, new_opt, metrics

    return train_step


__all__ = ["AdamWConfig", "adamw_update", "init_opt_state", "make_train_step",
           "reduce_grads"]
