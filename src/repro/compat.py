"""Version-compat shims over the jax public API.

The codebase targets the modern jax surface (``jax.shard_map`` with
``check_vma=``, ``jax.make_mesh(..., axis_types=...)`` with
``jax.sharding.AxisType``).  Older installs (e.g. jax 0.4.x) only have
``jax.experimental.shard_map.shard_map`` with ``check_rep=`` and a
``jax.make_mesh`` that takes no ``axis_types``.  Every call site routes
through this module so the rest of the tree can stay written against the
new API.
"""
from __future__ import annotations

import inspect
from typing import Any, Optional

import jax

__all__ = ["shard_map", "make_mesh", "tpu_compiler_params", "cost_analysis",
           "axis_size"]


_HAS_TOP_LEVEL_SHARD_MAP = hasattr(jax, "shard_map")

if not _HAS_TOP_LEVEL_SHARD_MAP:
    from jax.experimental.shard_map import shard_map as _exp_shard_map


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` on new jax; the experimental one on old jax.

    ``check_vma`` (new name) maps onto ``check_rep`` (old name) — both turn
    off the replication/varying-manual-axes check that the per-shard code
    here does not satisfy (it returns unreduced partials on purpose).
    """
    if _HAS_TOP_LEVEL_SHARD_MAP:
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    return _exp_shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=check_vma)


def _axis_types_auto(n: int) -> Optional[tuple]:
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return None
    return (axis_type.Auto,) * n


_MAKE_MESH_HAS_AXIS_TYPES = (
    hasattr(jax, "make_mesh")
    and "axis_types" in inspect.signature(jax.make_mesh).parameters
)


def make_mesh(axis_shapes, axis_names, **kwargs: Any):
    """``jax.make_mesh`` with ``axis_types=Auto`` where supported.

    Old jax has neither the kwarg nor ``jax.sharding.AxisType``; meshes there
    are implicitly Auto, so dropping the kwarg preserves semantics.
    """
    if _MAKE_MESH_HAS_AXIS_TYPES:
        kwargs.setdefault("axis_types", _axis_types_auto(len(axis_shapes)))
        return jax.make_mesh(axis_shapes, axis_names, **kwargs)
    kwargs.pop("axis_types", None)
    if hasattr(jax, "make_mesh"):
        return jax.make_mesh(axis_shapes, axis_names, **kwargs)
    from jax.experimental import mesh_utils

    devices = mesh_utils.create_device_mesh(tuple(axis_shapes))
    return jax.sharding.Mesh(devices, tuple(axis_names))


def axis_size(axis_name):
    """``jax.lax.axis_size`` (new); psum of a unit constant folds to the
    same static size on old jax."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


def cost_analysis(compiled) -> dict:
    """Normalized ``compiled.cost_analysis()``: new jax returns a dict, old
    jax a one-entry list of dicts (per program)."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost or {}


def tpu_compiler_params(**kwargs: Any):
    """``pltpu.CompilerParams`` (new name) / ``pltpu.TPUCompilerParams`` (old)."""
    from jax.experimental.pallas import tpu as pltpu

    cls = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
    return cls(**kwargs)
