"""deepseek-moe-16b — fine-grained MoE: 2 shared + 64 routed top-6
[arXiv:2401.06066].

Layer 0 is a dense FFN (d_ff 10944 per the model card); layers 1..27 are MoE
with 64 routed experts of d_ff 1408 (assignment value) and 2 shared experts
(2 x 1408 = 2816 total shared width).
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=10944,                 # dense layer-0 FFN width (model card)
    vocab_size=102400,
    dense_ffn_layers=(0,),
    rope_theta=10000.0,
    moe=MoEConfig(
        n_experts=64,
        top_k=6,
        expert_d_ff=1408,       # assignment value (fine-grained experts)
        n_shared=2,
        shared_d_ff=2816,       # 2 shared experts x 1408
    ),
    citation="arXiv:2401.06066 (DeepSeekMoE)",
)
