"""Config registry: ``get_config("<arch-id>")`` for every assigned arch."""
from __future__ import annotations

import importlib

from repro.configs.base import (
    INPUT_SHAPES,
    FrontendStub,
    InputShape,
    MLAConfig,
    ModelConfig,
    MoEConfig,
    ParallelConfig,
    RGLRUConfig,
    SamplingConfig,
    SSMConfig,
)

# arch-id (assignment spelling) -> module name
_REGISTRY = {
    "recurrentgemma-9b": "recurrentgemma_9b",
    "qwen2.5-32b": "qwen2_5_32b",
    "musicgen-medium": "musicgen_medium",
    "minicpm3-4b": "minicpm3_4b",
    "internvl2-26b": "internvl2_26b",
    "mixtral-8x7b": "mixtral_8x7b",
    "yi-9b": "yi_9b",
    "qwen2.5-14b": "qwen2_5_14b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "mamba2-1.3b": "mamba2_1_3b",
    # extras (not part of the 10-arch assignment)
    "qwen-72b": "qwen_72b",           # the paper's own experiment model
    "gptj-parallel": "gptj_parallel",  # parallel-residual demo for §2.2
}

ASSIGNED_ARCHS = tuple(list(_REGISTRY)[:10])
ALL_ARCHS = tuple(_REGISTRY)


def get_config(name: str) -> ModelConfig:
    key = name.replace("_", "-") if name not in _REGISTRY else name
    if key not in _REGISTRY:
        # also accept module-style ids like qwen2_5_32b
        for arch_id, mod in _REGISTRY.items():
            if mod == name:
                key = arch_id
                break
        else:
            raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    module = importlib.import_module(f"repro.configs.{_REGISTRY[key]}")
    return module.CONFIG


def get_shape(name: str) -> InputShape:
    return INPUT_SHAPES[name]


__all__ = [
    "ALL_ARCHS",
    "ASSIGNED_ARCHS",
    "INPUT_SHAPES",
    "FrontendStub",
    "InputShape",
    "MLAConfig",
    "ModelConfig",
    "MoEConfig",
    "ParallelConfig",
    "RGLRUConfig",
    "SamplingConfig",
    "SSMConfig",
    "get_config",
    "get_shape",
]
