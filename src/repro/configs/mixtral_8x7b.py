"""mixtral-8x7b — MoE 8 experts top-2 with sliding-window attention
[arXiv:2401.04088]."""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,                 # == expert_d_ff; no dense FFN layers
    vocab_size=32000,
    layer_pattern=("local_attn",),
    window=4096,                # SWA per Mistral-7B/Mixtral
    rope_theta=1_000_000.0,
    moe=MoEConfig(n_experts=8, top_k=2, expert_d_ff=14336),
    citation="arXiv:2401.04088 (Mixtral of Experts)",
)
