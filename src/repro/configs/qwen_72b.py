"""qwen-72b — the paper's own experiment model (§3) [arXiv:2309.16609].

Qwen-72B: 80 layers, d_model 8192, 64 MHA heads, d_ff 24576, vocab 151936,
QKV bias. This config reproduces the paper's headline measurement target
(140 ms/token at TP=4 on 4x Xeon 8575C).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen-72b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=64,
    d_ff=24576,
    vocab_size=151936,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    citation="arXiv:2309.16609 (Qwen Technical Report)",
)
