"""recurrentgemma-9b — hybrid Griffin: RG-LRU + local attention, 2:1 pattern
[arXiv:2402.19427].

Pattern is (recurrent, recurrent, local-attn) repeating; 38 layers = 12 full
periods + 2 trailing recurrent blocks. Local attention window 2048 per the
Griffin/RecurrentGemma papers. GQA with a single KV head (MQA).
"""
from repro.configs.base import ModelConfig, RGLRUConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    d_ff=12288,
    vocab_size=256000,
    layer_pattern=("rglru", "rglru", "local_attn"),
    window=2048,
    act="gelu",                 # Gemma-family GeGLU
    rope_theta=10000.0,
    rglru=RGLRUConfig(lru_width=0, conv_width=4),
    citation="arXiv:2402.19427 (Griffin); RecurrentGemma-9B card",
)
