"""musicgen-medium — audio decoder-only over EnCodec tokens [arXiv:2306.05284].

4 codebooks (delay interleave pattern), vocab 2048 per codebook; embeddings of
the 4 streams are summed and 4 parallel LM heads predict the next frame. The
EnCodec tokenizer and text-conditioning encoder are STUBS per the assignment
carve-out — ``input_specs`` feeds conditioning frame embeddings.
MusicGen uses a plain (non-gated, GELU) transformer FFN.
"""
from repro.configs.base import FrontendStub, ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    n_codebooks=4,
    gated_mlp=False,
    act="gelu",
    rope_theta=10000.0,
    frontend=FrontendStub(kind="audio", prefix_len=64, feature_dim=768),
    citation="arXiv:2306.05284 (MusicGen); EnCodec 4x2048 codebooks",
)
