"""minicpm3-4b — dense with multi-head latent attention (MLA)
[hf:openbmb/MiniCPM3-4B].

MLA dims (q_lora_rank/kv_lora_rank/nope/rope/v) follow the MiniCPM3-4B model
card; the outer dims (62L, d_model 2560, 40H, d_ff 6400, vocab 73448) are the
assignment values.
"""
from repro.configs.base import MLAConfig, ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b",
    family="dense",
    n_layers=62,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    d_ff=6400,
    vocab_size=73448,
    rope_theta=10000.0,
    mla=MLAConfig(
        q_lora_rank=768,
        kv_lora_rank=256,
        qk_nope_head_dim=64,
        qk_rope_head_dim=32,
        v_head_dim=64,
    ),
    citation="hf:openbmb/MiniCPM3-4B (MLA dims per model card)",
)
