"""gptj-parallel — parallel-residual demo config for the paper's §2.2.

GPT-J-6B layout: attention and FFN branches read the same LayerNorm output and
their results are summed into the residual — exactly the structure for which
the paper's one-time-synchronization applies (one all-reduce per layer instead
of two). [EleutherAI/gpt-j-6B]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gptj-parallel",
    family="dense",
    n_layers=28,
    d_model=4096,
    n_heads=16,
    n_kv_heads=16,
    d_ff=16384,
    vocab_size=50400,
    parallel_residual=True,
    gated_mlp=False,
    act="gelu",
    rope_theta=10000.0,
    citation="hf:EleutherAI/gpt-j-6B (parallel attention+FFN)",
)
