"""internvl2-26b — VLM: InternViT (stub) + InternLM2 backbone [arXiv:2404.16821].

Per the assignment carve-out the ViT is a STUB: ``input_specs`` feeds
precomputed patch embeddings (256 tokens/tile after pixel-shuffle, 3200-wide
InternViT-6B features). The 2-layer MLP projector IS implemented.
"""
from repro.configs.base import FrontendStub, ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=92553,
    rope_theta=1_000_000.0,
    frontend=FrontendStub(kind="vision", prefix_len=256, feature_dim=3200),
    citation="arXiv:2404.16821 (InternVL 1.5/2); InternViT-6B features 3200-d",
)
