"""Configuration dataclasses for the repro framework.

Every assigned architecture is expressed as a :class:`ModelConfig`; the
values are exact per the assignment table and cite their source in the
per-arch module.  Configs are frozen dataclasses so they are hashable and
usable as jit static arguments.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


# ---------------------------------------------------------------------------
# Sub-configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts block config (GShard-style capacity dispatch)."""

    n_experts: int
    top_k: int
    expert_d_ff: int
    n_shared: int = 0           # DeepSeekMoE shared experts (always-on)
    shared_d_ff: int = 0        # d_ff of the shared experts (total)
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01  # load-balance loss weight (training)


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head latent attention (DeepSeek-V2 / MiniCPM3 style)."""

    q_lora_rank: int
    kv_lora_rank: int
    qk_nope_head_dim: int
    qk_rope_head_dim: int
    v_head_dim: int


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 SSD block config."""

    state_dim: int = 128        # N
    head_dim: int = 64          # P
    expand: int = 2             # d_inner = expand * d_model
    chunk: int = 256            # SSD chunk length
    conv_width: int = 4         # depthwise conv kernel size
    dt_min: float = 0.001
    dt_max: float = 0.1


@dataclass(frozen=True)
class RGLRUConfig:
    """RG-LRU recurrence config (Griffin / RecurrentGemma)."""

    lru_width: int = 0          # 0 -> d_model
    conv_width: int = 4
    c_constant: float = 8.0     # the fixed `c` in a = exp(-c * softplus(Lambda) * sigma(r))


@dataclass(frozen=True)
class FrontendStub:
    """Stub modality frontend (spec carve-out: ViT / EnCodec are NOT built).

    ``input_specs`` provides precomputed frame/patch embeddings of shape
    (batch, prefix_len, feature_dim); the (real, implemented) projector maps
    feature_dim -> d_model.
    """

    kind: str                   # "vision" | "audio"
    prefix_len: int             # number of patch/frame positions
    feature_dim: int            # raw frontend feature width


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------

# Mixer kinds. The FFN kind is orthogonal: dense unless ``moe`` is set and
# the layer is not in ``dense_ffn_layers``; ``ssd`` blocks carry no FFN.
BLOCK_KINDS = ("attn", "local_attn", "ssd", "rglru")


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0           # 0 -> d_model // n_heads
    # block pattern, cycled over layers, e.g. ("rglru","rglru","local_attn")
    layer_pattern: Tuple[str, ...] = ("attn",)
    # layer indices whose FFN is dense even in an MoE model (deepseek layer 0)
    dense_ffn_layers: Tuple[int, ...] = ()
    qkv_bias: bool = False
    parallel_residual: bool = False   # GPT-J/Falcon style (paper §2.2)
    tie_embeddings: bool = False
    rope_theta: float = 10000.0
    rms_eps: float = 1e-6
    window: int = 0             # sliding-window size for local_attn / SWA (0 = full)
    act: str = "silu"           # silu (gated) | gelu
    gated_mlp: bool = True      # SwiGLU vs plain 2-matmul MLP
    n_codebooks: int = 1        # musicgen: parallel codebook streams
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    frontend: Optional[FrontendStub] = None
    citation: str = ""
    # unroll all layer groups (no lax.scan) — used by the dry-run cost probes,
    # where XLA's cost_analysis counts while-loop bodies only once
    force_unroll: bool = False

    # -- derived ----------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def q_group(self) -> int:
        return self.n_heads // self.n_kv_heads if self.n_kv_heads else 1

    def block_kind(self, layer: int) -> str:
        return self.layer_pattern[layer % len(self.layer_pattern)]

    @property
    def is_attention_free(self) -> bool:
        return all(k in ("ssd", "rglru") for k in self.layer_pattern)

    @property
    def supports_long_context(self) -> bool:
        """True if decode state is O(window) or O(1) per token natively."""
        return all(k in ("ssd", "rglru", "local_attn") for k in self.layer_pattern) or (
            self.window > 0
        )

    def param_count(self) -> int:
        """Approximate parameter count (used for MODEL_FLOPS = 6ND)."""
        d, hd = self.d_model, self.resolved_head_dim
        n_q, n_kv = self.n_heads, self.n_kv_heads
        total = self.vocab_size * d * self.n_codebooks  # embed
        if not self.tie_embeddings:
            total += self.vocab_size * d * self.n_codebooks  # lm head(s)
        ffn_mats = 3 if self.gated_mlp else 2
        for layer in range(self.n_layers):
            kind = self.block_kind(layer)
            if kind in ("attn", "local_attn"):
                if self.mla is not None:
                    m = self.mla
                    qd = m.qk_nope_head_dim + m.qk_rope_head_dim
                    total += d * m.q_lora_rank + m.q_lora_rank * n_q * qd
                    total += d * (m.kv_lora_rank + m.qk_rope_head_dim)
                    total += m.kv_lora_rank * n_q * (m.qk_nope_head_dim + m.v_head_dim)
                    total += n_q * m.v_head_dim * d
                else:
                    total += d * (n_q + 2 * n_kv) * hd + n_q * hd * d
            elif kind == "ssd":
                s = self.ssm
                di = s.expand * d
                n_sh = di // s.head_dim
                total += d * (2 * di + 2 * s.state_dim + n_sh) + di * d
            elif kind == "rglru":
                w = self.rglru.lru_width or d
                total += d * 2 * w + 3 * w + w * d  # in-proj(x2), gates, out-proj
            # FFN (ssd blocks carry none)
            if kind != "ssd":
                if self.moe is not None and layer not in self.dense_ffn_layers:
                    m = self.moe
                    total += m.n_experts * ffn_mats * d * m.expert_d_ff
                    total += d * m.n_experts  # router
                    if m.n_shared:
                        total += ffn_mats * d * m.shared_d_ff
                else:
                    total += ffn_mats * d * self.d_ff
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top_k + shared experts)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        full = self.param_count()
        per = (3 if self.gated_mlp else 2) * self.d_model * m.expert_d_ff
        n_moe_layers = sum(
            1
            for layer in range(self.n_layers)
            if layer not in self.dense_ffn_layers
        )
        inactive = n_moe_layers * (m.n_experts - m.top_k) * per
        return full - inactive

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: 2 layers, d_model<=512, <=4 experts."""
        d = min(self.d_model, 256)
        n_heads = min(self.n_heads, 4)
        n_kv = max(1, min(self.n_kv_heads, n_heads))
        while n_heads % n_kv:
            n_kv -= 1
        pat = self.layer_pattern
        n_layers = max(2, len(pat)) if len(pat) > 1 else 2
        kw = dict(
            name=self.name + "-smoke",
            n_layers=n_layers,
            d_model=d,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            head_dim=64,
            d_ff=min(self.d_ff, 512) or 0,
            vocab_size=min(self.vocab_size, 512),
            window=min(self.window, 64) if self.window else 0,
            dense_ffn_layers=tuple(i for i in self.dense_ffn_layers if i < n_layers),
        )
        if self.moe is not None:
            kw["moe"] = dataclasses.replace(
                self.moe,
                n_experts=min(self.moe.n_experts, 4),
                top_k=min(self.moe.top_k, 2),
                expert_d_ff=min(self.moe.expert_d_ff, 128),
                shared_d_ff=min(self.moe.shared_d_ff, 128),
            )
        if self.mla is not None:
            kw["mla"] = MLAConfig(
                q_lora_rank=64, kv_lora_rank=32, qk_nope_head_dim=32,
                qk_rope_head_dim=16, v_head_dim=32,
            )
        if self.ssm is not None:
            kw["ssm"] = dataclasses.replace(self.ssm, state_dim=32, head_dim=32, chunk=32)
        if self.rglru is not None:
            kw["rglru"] = dataclasses.replace(self.rglru, lru_width=0)
        if self.frontend is not None:
            kw["frontend"] = dataclasses.replace(
                self.frontend, prefix_len=8, feature_dim=64
            )
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Input shapes (assignment table)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Parallelism / runtime configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ParallelConfig:
    """How the model is laid out on the mesh."""

    tp: int = 1                 # size of the "model" axis
    dp: int = 1                 # size of the "data" axis
    pods: int = 1               # size of the "pod" axis
    seq_parallel: bool = True   # Megatron-SP residual stream (train/prefill)
    kv_seq_shard: bool = False  # shard decode KV cache sequence over data axis
    expert_parallel: bool = True  # MoE experts over model axis (vs d_ff TP)
    remat: bool = True          # activation checkpointing per layer (train)
    # paper-technique toggles (for ablation benches; all on by default)
    topk_sync: bool = True      # §2.1b local top-k before reduction
    id_broadcast: bool = True   # §2.1a broadcast token ids not embeddings
    one_shot_sync: bool = True  # §2.2 single psum for parallel-residual
    zero_copy: bool = True      # §2.3 donation + fused epilogue
    use_pallas: bool = False    # use Pallas kernels (interpret on CPU)
    flash_prefill: bool = True  # fused Pallas flash-prefill kernel on the
                                # prefill hot path (effective with
                                # use_pallas; the pure-JAX scan remains the
                                # reference + MLA/windowed fallback)
    kv_quant: bool = False      # int8 KV cache (per-head-per-slot scales)
    # weight-only quantization (quantize-at-load transform over the param
    # tree): "int8" = per-output-channel scales, "int4" = group-wise scales
    # along the reduction dim (wq_group_size, clamped per tensor so groups
    # never straddle a TP shard).  Covers every serving projection
    # (attention q/k/v/o, MLP up/gate/down, MoE experts, lm_head); embed
    # tables, norms, biases, routers, and MLA latent projections stay bf16.
    # Routing follows use_pallas: fused dequant matmul kernels on the hot
    # 2-D projections when Pallas is on, pure-JAX dequant reference
    # otherwise (always the fallback for batched einsum sites).
    weight_quant: str = "none"  # none | int8 | int4
    wq_group_size: int = 128    # int4 group length along the reduction dim
    # chunked prefill (continuous-batching schedulers): prompts longer than
    # this many tokens are admitted chunk-by-chunk through the fused mixed
    # prefill/decode step, so a long prompt never stalls in-flight decode
    # for more than one chunk's worth of compute.  0 disables chunking
    # (whole-prompt admission only).  Eligibility is declared per arch by
    # the capability registry (core.capabilities): ineligible archs clamp
    # this config default to whole-prompt admission; an explicit scheduler
    # constructor override raises the registry error instead.
    prefill_chunk: int = 256
    # speculative decoding (continuous-batching schedulers): propose spec_k
    # draft tokens per active slot from a host-side n-gram prompt-lookup
    # drafter and score all spec_k+1 positions in ONE fused verify step (a
    # width-(k+1) chunk at the decode frontier), emitting 1..spec_k+1
    # tokens per step.  0 disables (plain one-token decode).  Greedy spec
    # decode is token-identical to plain greedy decode; eligibility comes
    # from the capability registry's "spec" path (same derivation as
    # chunked prefill — ineligible archs clamp this default to plain
    # decode, explicit constructor overrides raise).
    spec_k: int = 0
    spec_ngram: int = 3         # longest n-gram the prompt-lookup drafter
                                # matches (falls through to shorter n-grams)
    # paged KV cache (slot engine second storage backend; dense remains the
    # default and the only layout for wave mode).  PagedContinuousScheduler
    # reads these as its defaults; constructor args override.
    kv_block_size: int = 16     # tokens per KV block (paged backend)
    kv_pool_blocks: int = 0     # total pool blocks; 0 = n_slots * blocks/slot
                                # (i.e. the dense footprint — shrink to
                                # overcommit capacity vs n_slots x max_seq)
    # disaggregated prefill/decode serving (DisaggScheduler): the first
    # disagg_prefill_shards data shards form the PREFILL POOL (prompts admit
    # and chunk-prefill there), the remaining shards the DECODE POOL;
    # finished KV blocks migrate between the per-shard block namespaces via
    # a batched device-to-device copy, with refcounts handed off through
    # the allocator.  0 disables (unified serving).  Requires an arch whose
    # capability record supports "disagg" (chunked + paged with no
    # blockers) and dp * pods >= 2.
    disagg_prefill_shards: int = 0
    # overlapped host/device engine loop (continuous-batching schedulers):
    # dispatch decode step N+1 while step N's token array is still a device
    # future, running host work (drafting, admission, block allocation,
    # migration queueing) against the previous step's landed tokens and
    # materializing np.asarray one step late.  Host state advances on a
    # PREDICTION (budget decrements are deterministic; EOS is the only
    # surprise) with a one-step rollback when a landed token turns out to be
    # EOS.  Greedy token streams are bit-identical to the blocking loop —
    # overlap reorders host observation, not device math.
    overlap_decode: bool = False
    # fault tolerance (continuous-batching schedulers).  fault_plan is a
    # compact spec string (see runtime/faults.py for the grammar) injecting
    # deterministic failures — step exceptions, poisoned slot tokens,
    # allocator exhaustion, migration faults, delayed steps — at chosen
    # step indices; "" disables injection.  Kept as a str so this config
    # stays frozen/hashable.  A transient step failure is retried up to
    # max_step_retries times with exponential backoff starting at
    # retry_backoff_s (the pipeline drains to the exact pre-step state
    # before each retry); when retries exhaust, a failure attributed to one
    # slot quarantines that request (finish_reason "error") and everything
    # else keeps serving.
    fault_plan: str = ""
    max_step_retries: int = 3
    retry_backoff_s: float = 0.05
    # overload resilience (continuous-batching schedulers).  Requests carry
    # a priority class ("interactive" | "standard" | "batch"); the slo_*_s
    # fields are per-class PER-TOKEN latency targets in seconds (0 = no
    # target).  interactive_reserve_slots / _blocks hold back a quota of
    # slots (dense + paged) and free KV blocks (paged) that only
    # interactive-class admissions may consume, so a background flood can
    # never starve the latency class.  overload_degrade enables the
    # graceful-degradation controller (runtime/overload.py): it watches
    # arrived-queue depth and recently landed ITL every round and, under
    # sustained pressure, walks a ladder — shed batch at admission, disable
    # spec decode, cap the admission window — restoring in reverse as
    # pressure clears.  queue_hi/lo are the hysteresis thresholds in queued
    # requests (0 = auto from n_slots); patience/cooldown are the number of
    # consecutive pressured/clear rounds before escalating/restoring;
    # itl_hi/lo scale the interactive SLO into the ITL pressure band.
    # Every lever changes WHICH requests run and WHEN — never their tokens.
    slo_interactive_s: float = 0.0
    slo_standard_s: float = 0.0
    slo_batch_s: float = 0.0
    interactive_reserve_slots: int = 0
    interactive_reserve_blocks: int = 0
    overload_degrade: bool = False
    overload_queue_hi: int = 0
    overload_queue_lo: int = 0
    overload_patience: int = 3
    overload_cooldown: int = 6
    overload_itl_hi: float = 1.5
    overload_itl_lo: float = 1.0


@dataclass(frozen=True)
class SamplingConfig:
    top_k: int = 40
    temperature: float = 1.0
    greedy: bool = False
