"""mamba2-1.3b — attention-free SSM with SSD (state-space duality)
[arXiv:2405.21060].

48 pure-SSD layers (no FFN, as in the Mamba block layout); d_inner = 2*d_model,
ssm_state=128 (assignment value), head_dim 64.
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=1,                  # attention-free; SSD heads derive from ssm cfg
    n_kv_heads=1,
    d_ff=0,
    vocab_size=50280,
    layer_pattern=("ssd",),
    tie_embeddings=True,
    ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, chunk=256, conv_width=4),
    citation="arXiv:2405.21060 (Transformers are SSMs / Mamba-2)",
)
