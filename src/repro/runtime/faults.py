"""Deterministic fault-injection harness for the serving runtime.

A :class:`FaultPlan` is parsed from a compact spec string (kept as a plain
``str`` so it can ride on the frozen/hashable ``ParallelConfig`` and the
``--fault-plan`` CLI flag) and consulted by the schedulers at the host
boundaries where failures are actually recoverable:

* **pre-dispatch** (``on_dispatch``) — before an engine step program is
  queued.  This is the honest injection point for step failures under cache
  donation (§2.3 zero-copy): once a program holding the donated KV buffers
  has been dispatched, the host cannot replay it — the input cache is gone.
  A transient failure *before* dispatch, by contrast, leaves the exact
  pre-step state intact, which is what makes bounded retry sound.
* **token landing** (``corrupt_tokens``) — after ``np.asarray`` materializes
  a block of sampled tokens.  Tokens are ``int32`` ids, so "non-finite
  logits on slot i" is modeled as the out-of-range garbage id such logits
  sample to; the schedulers' range guard (0 <= t < vocab) is the detector
  either way.  Device math is never altered, so surviving slots' streams
  are structurally bit-identical to an uninjected run.
* **allocation** (``deny_alloc``) — the paged allocator's grow path reports
  exhaustion regardless of actual pool occupancy.
* **handoff staging** (``on_handoff``) — the disagg scheduler's final
  migration enqueue raises mid-handoff, exercising the rollback path
  (queued copies unpinned, destination blocks freed).

Spec grammar — clauses separated by ``;``, each ``kind:key=val,key=val``::

    step:at=N[,times=M][,slot=I][,p=F]   transient exception at the first
                                         engine dispatch with step >= N;
                                         fires M times (default 1) then
                                         disarms.  slot= attributes blame
                                         (escalates to quarantine when
                                         retries exhaust); p= makes each
                                         opportunity fire with probability
                                         F from the plan's seeded rng.
    poison:slot=I,at=N[,times=M]         corrupt slot I's landed token at
                                         the first step >= N where slot I
                                         is actively decoding (out-of-range
                                         id; defers while the slot is
                                         empty/frozen).
    alloc:at=N[,times=M]                 deny the next M block allocations
                                         once step >= N.
    migrate:handoff=K[,times=M]          raise MigrationFault at the K-th
                                         (0-based) final handoff staging.
    delay:at=N,s=F[,times=M]             sleep F seconds before the first
                                         dispatch with step >= N (drives
                                         the liveness watchdog).
    burst:at=N,count=K[,plen=P][,new=M]  inject K synthetic requests
         [,cls=C][,times=T][,every=E]    (prompt length P, default 8;
                                         decode budget M, default 4;
                                         priority class C, default
                                         "standard") at the first serving
                                         round with step >= N.  times=T
                                         refires the burst T times,
                                         every=E spacing refires E steps
                                         apart — a deterministic overload
                                         wave for degradation tests.
                                         Prompts come from an rng seeded
                                         by the firing step, so two runs
                                         of the same plan inject
                                         identical traffic.
    seed:n=K                             seed for probabilistic clauses
                                         (default 0; the plan is fully
                                         deterministic either way).

Example: ``step:at=12,times=2;poison:slot=1,at=20;migrate:handoff=0``.
"""
from __future__ import annotations

import random
import time
from dataclasses import dataclass
from typing import List, Optional

import numpy as np

# An id no real vocab reaches: what NaN/Inf logits "sample" to.  The
# schedulers detect any id outside [0, vocab) — injected or organic.
POISON_TOKEN = 1 << 30


class InjectedFault(Exception):
    """Base class for all faults raised by a FaultPlan."""


class TransientStepError(InjectedFault):
    """A step dispatch failed before the program consumed any state.

    ``slot`` optionally attributes the failure to one request (e.g. its
    input triggers the crash): when bounded retries exhaust, the scheduler
    quarantines that slot instead of dying."""

    def __init__(self, msg: str, slot: Optional[int] = None):
        super().__init__(msg)
        self.slot = slot


class MigrationFault(InjectedFault):
    """A KV-block handoff failed mid-staging (disagg prefill->decode)."""


@dataclass
class _Clause:
    kind: str                     # step|poison|alloc|migrate|delay|burst
    at: int = 0                   # engine-step threshold
    times: int = 1                # remaining fires (counts down to 0)
    slot: Optional[int] = None    # blamed/targeted slot
    handoff: int = 0              # migrate: 0-based handoff index
    seconds: float = 0.0          # delay: sleep duration
    p: float = 1.0                # per-opportunity fire probability
    count: int = 0                # burst: requests injected per fire
    plen: int = 8                 # burst: synthetic prompt length
    new: int = 4                  # burst: per-request decode budget
    cls: str = "standard"         # burst: priority class of injected load
    every: int = 0                # burst: step spacing between refires
    fired: int = 0                # burst: fires consumed so far


_KINDS = ("step", "poison", "alloc", "migrate", "delay", "burst", "seed")
_INT_KEYS = ("at", "times", "slot", "handoff", "n", "count", "plen",
             "new", "every")
_FLOAT_KEYS = ("s", "p")
_STR_KEYS = ("cls",)


class FaultPlan:
    """Parsed fault schedule; one instance per scheduler (stateful: clauses
    disarm as they fire, so a plan must not be shared across runs)."""

    def __init__(self, clauses: List[_Clause], seed: int = 0):
        self.clauses = clauses
        self._rng = random.Random(seed)
        self._handoffs = 0        # final handoff stagings observed

    def __bool__(self) -> bool:
        return bool(self.clauses)

    @classmethod
    def parse(cls, spec: Optional[str]) -> "FaultPlan":
        clauses: List[_Clause] = []
        seed = 0
        for part in (spec or "").split(";"):
            part = part.strip()
            if not part:
                continue
            kind, _, rest = part.partition(":")
            kind = kind.strip()
            if kind not in _KINDS:
                raise ValueError(f"unknown fault kind {kind!r} in {part!r}")
            kw = {}
            for item in rest.split(","):
                item = item.strip()
                if not item:
                    continue
                k, _, v = item.partition("=")
                k = k.strip()
                if k in _INT_KEYS:
                    kw[k] = int(v)
                elif k in _FLOAT_KEYS:
                    kw[k] = float(v)
                elif k in _STR_KEYS:
                    kw[k] = v.strip()
                else:
                    raise ValueError(f"unknown fault key {k!r} in {part!r}")
            if kind == "seed":
                seed = kw.get("n", 0)
                continue
            c = _Clause(kind=kind, at=kw.get("at", 0),
                        times=kw.get("times", 1), slot=kw.get("slot"),
                        handoff=kw.get("handoff", 0),
                        seconds=kw.get("s", 0.0), p=kw.get("p", 1.0),
                        count=kw.get("count", 0), plen=kw.get("plen", 8),
                        new=kw.get("new", 4), cls=kw.get("cls", "standard"),
                        every=kw.get("every", 0))
            if kind == "poison" and c.slot is None:
                raise ValueError(f"poison clause needs slot= in {part!r}")
            if kind == "burst" and c.count <= 0:
                raise ValueError(f"burst clause needs count= in {part!r}")
            clauses.append(c)
        return cls(clauses, seed=seed)

    # -- firing logic ------------------------------------------------------
    def _fire(self, c: _Clause) -> bool:
        if c.times <= 0:
            return False
        if c.p < 1.0 and self._rng.random() >= c.p:
            return False
        c.times -= 1
        return True

    def on_dispatch(self, step: int) -> None:
        """Consulted before every engine step dispatch.  Delay clauses
        sleep; step clauses raise :class:`TransientStepError`."""
        for c in self.clauses:
            if c.kind == "delay" and step >= c.at and self._fire(c):
                time.sleep(c.seconds)
        for c in self.clauses:
            if c.kind == "step" and step >= c.at and self._fire(c):
                raise TransientStepError(
                    f"injected step fault (at={c.at}, step={step})",
                    slot=c.slot)

    def corrupt_tokens(self, toks, base_step: int, active=None):
        """Apply poison clauses to a landed (n, B) token block covering
        engine steps [base_step, base_step + n).  Copy-on-write: the input
        (np.asarray of a device array) may be a read-only view, so the
        first firing clause takes a host-owned copy.

        ``active`` is the caller's slot-is-decoding mask at the block's
        start.  A clause whose target slot is empty/frozen there DEFERS
        (does not consume ``times``) — "poison slot I at step N" means the
        first block at/after N where slot I's stream would actually read
        the corrupted cell, not a silent no-op on whatever block happened
        to cover N while the slot sat idle."""
        out = toks
        n = toks.shape[0]
        for c in self.clauses:
            if c.kind != "poison" or c.times <= 0:
                continue
            if c.at >= base_step + n:
                continue              # this block ends before the target
            if active is not None and (c.slot >= len(active)
                                       or not active[c.slot]):
                continue              # slot not live yet: wait, don't spend
            if self._fire(c):
                if out is toks:
                    out = np.array(toks)
                out[max(0, c.at - base_step), c.slot] = POISON_TOKEN
        return out

    def burst(self, step: int) -> List[tuple]:
        """Consulted at each serving-round start; returns a list of
        ``(count, plen, max_new, cls, fire_step)`` burst specs due now.

        A clause's i-th fire (0-based) is due once ``step >= at + i *
        every``; ``times`` bounds total fires.  ``fire_step`` is the step
        the fire was *scheduled* for (not the observed step), so prompt
        synthesis seeded by it is identical run-to-run even if rounds
        land on slightly different step indices."""
        due = []
        for c in self.clauses:
            if c.kind != "burst":
                continue
            while c.times > 0 and step >= c.at + c.fired * max(0, c.every):
                due.append((c.count, max(2, c.plen), max(1, c.new),
                            c.cls, c.at + c.fired * max(0, c.every)))
                c.fired += 1
                c.times -= 1
        return due

    def deny_alloc(self, step: int) -> bool:
        """True when an allocation at ``step`` should report exhaustion."""
        for c in self.clauses:
            if c.kind == "alloc" and step >= c.at and self._fire(c):
                return True
        return False

    def on_handoff(self) -> None:
        """Consulted at each FINAL handoff staging (disagg); raises
        :class:`MigrationFault` for the matching 0-based handoff index."""
        k = self._handoffs
        self._handoffs += 1
        for c in self.clauses:
            if c.kind == "migrate" and k >= c.handoff and self._fire(c):
                raise MigrationFault(
                    f"injected migration fault (handoff #{k})")

    def on_quarantine(self, slot: int) -> None:
        """Disarm every clause attributed/targeted at ``slot`` — once the
        request is quarantined, its poisoned input is out of the system and
        the failures it caused stop."""
        for c in self.clauses:
            if c.slot == slot:
                c.times = 0
