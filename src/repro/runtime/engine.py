"""Serving engine: jitted shard_map'd prefill/decode steps + host generate loop.

The decode step is the paper's experiment unit (§3 measures ms/token of
exactly this function).  Schedule per decode round, with all paper
optimizations on:

  1 x  (token ids already replicated — §2.1a "broadcast" is free)
  L x  block reductions (1 psum per parallel-residual block, 2 per
       sequential block, or scatter/gather pairs under SP)
  1 x  k-candidate all-gather for sampling (§2.1b)

KV caches are DONATED to the decode step (§2.3): XLA aliases them in-place,
`memory_analysis().alias_size_in_bytes` is the receipt.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ParallelConfig, SamplingConfig
from repro.models import model as M
from repro.runtime import kvcache
from repro.runtime.sampling import sample_tokens

Pytree = Any


def make_prefill_step(ctx: M.ModelCtx, sampling: SamplingConfig):
    """Per-shard fn: (params, tokens, features, caches, rng) -> (tok, caches)."""

    def prefill_step(params, tokens, features, caches, rng):
        kv_axis = ctx.dist.data_axis if ctx.parallel.kv_seq_shard else None
        logits, caches, _ = M.forward(
            params, tokens, ctx, features=features, caches=caches,
            last_only=True, seq_sharded=True, kv_seq_axis=kv_axis,
        )
        tok = sample_tokens(
            logits[:, -1], rng, sampling, ctx.plan, ctx.dist,
            topk_sync_enabled=ctx.parallel.topk_sync,
            use_pallas=ctx.parallel.use_pallas,
        )
        return tok, caches

    return prefill_step


def make_decode_step(ctx: M.ModelCtx, sampling: SamplingConfig):
    """Per-shard fn: (params, tok, caches, cur_pos, rng) -> (tok', caches)."""

    def decode_step(params, tok, caches, cur_pos, rng):
        kv_axis = ctx.dist.data_axis if ctx.parallel.kv_seq_shard else None
        tokens = tok[:, None] if tok.ndim == 1 else tok[:, None, :]
        logits, caches, _ = M.forward(
            params, tokens, ctx, caches=caches, cur_pos=cur_pos,
            kv_seq_axis=kv_axis, last_only=True, seq_sharded=False,
        )
        nxt = sample_tokens(
            logits[:, -1], rng, sampling, ctx.plan, ctx.dist,
            topk_sync_enabled=ctx.parallel.topk_sync,
            use_pallas=ctx.parallel.use_pallas,
        )
        return nxt, caches

    return decode_step


@dataclass
class Engine:
    """Host-side serving engine over a local (or production) mesh."""

    cfg: ModelConfig
    parallel: ParallelConfig
    sampling: SamplingConfig
    mesh: Any
    max_len: int
    params: Pytree = None
    seed: int = 0

    def __post_init__(self):
        pod = "pod" if "pod" in self.mesh.axis_names else None
        self.ctx = M.ModelCtx.make(self.cfg, self.parallel, pod_axis=pod)
        if self.params is None:
            self.params = M.init_params(self.ctx, jax.random.key(self.seed))
        self._build()

    # -- sharding specs -----------------------------------------------------
    def _specs(self):
        dist = self.ctx.dist
        d = dist.data_axes if len(dist.data_axes) > 1 else dist.data_axes[0]
        batch_spec = P(None) if self.parallel.kv_seq_shard else P(d)
        tok2 = P(*batch_spec, None) if self.cfg.n_codebooks == 1 else P(*batch_spec, None, None)
        tok1 = P(*batch_spec) if self.cfg.n_codebooks == 1 else P(*batch_spec, None)
        feat = P(*batch_spec, None, None)
        cache = kvcache.cache_pspecs(self.ctx, kv_seq_shard=self.parallel.kv_seq_shard)
        return batch_spec, tok2, tok1, feat, cache

    def _build(self):
        pspecs = M.param_specs(self.ctx)
        batch_spec, tok2, tok1, feat, cache_spec = self._specs()
        sm = partial(jax.shard_map, mesh=self.mesh, check_vma=False)

        pre = make_prefill_step(self.ctx, self.sampling)
        if self.cfg.frontend is None:
            pre_nofeat = lambda p, t, c, r: pre(p, t, None, c, r)
            self._prefill_raw = jax.jit(
                sm(pre_nofeat, in_specs=(pspecs, tok2, cache_spec, P()),
                   out_specs=(tok1, cache_spec)),
                donate_argnums=(2,) if self.parallel.zero_copy else (),
            )
            self._prefill = lambda p, t, f, c, r: self._prefill_raw(p, t, c, r)
        else:
            self._prefill = jax.jit(
                sm(pre, in_specs=(pspecs, tok2, feat, cache_spec, P()),
                   out_specs=(tok1, cache_spec)),
                donate_argnums=(3,) if self.parallel.zero_copy else (),
            )
        dec = make_decode_step(self.ctx, self.sampling)
        self._decode = jax.jit(
            sm(dec, in_specs=(pspecs, tok1, cache_spec, P(), P()),
               out_specs=(tok1, cache_spec)),
            donate_argnums=(2,) if self.parallel.zero_copy else (),
        )

        # §Perf H4: fused multi-token decode — lax.scan over n steps inside
        # ONE jitted program removes the per-token dispatch + host-sync
        # overhead of the token loop (the paper's §3 metric IS this loop).
        def decode_n(params, tok, caches, cur_pos, rng, *, n):
            def body(carry, i):
                tok, caches = carry
                nxt, caches = dec(params, tok, caches,
                                  cur_pos + i, jax.random.fold_in(rng, i))
                return (nxt, caches), nxt

            (tok, caches), toks = jax.lax.scan(
                body, (tok, caches), jnp.arange(n, dtype=jnp.int32))
            return toks, caches

        tokn = P(None, *tuple(tok1))
        self._decode_n = {
            n: jax.jit(
                sm(partial(decode_n, n=n),
                   in_specs=(pspecs, tok1, cache_spec, P(), P()),
                   out_specs=(tokn, cache_spec)),
                donate_argnums=(2,) if self.parallel.zero_copy else (),
            )
            for n in (8, 16, 32)
        }

    # -- API ------------------------------------------------------------
    def init_caches(self, batch: int):
        """Create the cache pytree as properly-sharded global arrays: each
        shard builds its LOCAL buffers inside shard_map and the runtime
        assembles the global arrays per the cache specs."""
        dp_total = self.ctx.dist.dp * self.ctx.dist.pods
        if self.parallel.kv_seq_shard:
            b_local, kv_dp = batch, self.ctx.dist.dp
        else:
            b_local, kv_dp = batch // dp_total, 1
        cspecs = kvcache.cache_pspecs(self.ctx,
                                      kv_seq_shard=self.parallel.kv_seq_shard)
        make = jax.jit(jax.shard_map(
            lambda: M.init_caches(self.ctx, b_local, self.max_len,
                                  kv_seq_shard_dp=kv_dp),
            mesh=self.mesh, in_specs=(), out_specs=cspecs, check_vma=False,
        ))
        return make()

    def generate(self, prompts: np.ndarray, max_new: int,
                 features: Optional[np.ndarray] = None,
                 *, multi_step: bool = True) -> np.ndarray:
        """prompts (b, prompt_len [, ncb]) -> generated tokens (b, max_new [, ncb]).

        multi_step=True uses the fused n-token decode programs (§Perf H4);
        set False to force the one-jit-call-per-token baseline loop."""
        b, plen = prompts.shape[0], prompts.shape[1]
        caches = self.init_caches(b)
        if features is None and self.cfg.frontend is not None:
            f = self.cfg.frontend
            features = np.zeros((b, f.prefix_len, f.feature_dim), np.float32)
        rng = jax.random.key(self.seed + 1)
        prefix = self.cfg.frontend.prefix_len if self.cfg.frontend else 0
        tok, caches = self._prefill(self.params, jnp.asarray(prompts),
                                    features, caches, rng)
        outs = [tok[None] if tok.ndim == 1 else tok[None, ...]]
        cur = plen + prefix  # next position to write
        remaining = max_new - 1
        while remaining > 0:
            n = next((n for n in (32, 16, 8)
                      if multi_step and remaining >= n), 0)
            rng = jax.random.fold_in(rng, cur)
            if n:
                toks, caches = self._decode_n[n](self.params, tok, caches,
                                                 jnp.int32(cur), rng)
                tok = toks[-1]
                outs.append(toks)
                cur += n
                remaining -= n
            else:
                tok, caches = self._decode(self.params, tok, caches,
                                           jnp.int32(cur), rng)
                outs.append(tok[None])
                cur += 1
                remaining -= 1
        return np.asarray(jnp.concatenate(outs, axis=0)).swapaxes(0, 1)
