"""Serving engine: jitted shard_map'd prefill/decode steps + host generate loop.

The decode step is the paper's experiment unit (§3 measures ms/token of
exactly this function).  Schedule per decode round, with all paper
optimizations on:

  1 x  (token ids already replicated — §2.1a "broadcast" is free)
  L x  block reductions (1 psum per parallel-residual block, 2 per
       sequential block, or scatter/gather pairs under SP)
  1 x  k-candidate all-gather for sampling (§2.1b)

KV caches are DONATED to the decode step (§2.3): XLA aliases them in-place,
`memory_analysis().alias_size_in_bytes` is the receipt.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.configs.base import ModelConfig, ParallelConfig, SamplingConfig
from repro.core.capabilities import ArchCapabilities
from repro.models import model as M
from repro.runtime import kvcache
from repro.runtime.sampling import sample_tokens

Pytree = Any


def make_prefill_step(ctx: M.ModelCtx, sampling: SamplingConfig):
    """Per-shard fn: (params, tokens, features, caches, rng) -> (tok, caches)."""

    def prefill_step(params, tokens, features, caches, rng):
        kv_axis = ctx.dist.data_axis if ctx.parallel.kv_seq_shard else None
        logits, caches, _ = M.forward(
            params, tokens, ctx, features=features, caches=caches,
            last_only=True, seq_sharded=True, kv_seq_axis=kv_axis,
        )
        tok = sample_tokens(
            logits[:, -1], rng, sampling, ctx.plan, ctx.dist,
            topk_sync_enabled=ctx.parallel.topk_sync,
            use_pallas=ctx.parallel.use_pallas,
        )
        return tok, caches

    return prefill_step


def make_decode_step(ctx: M.ModelCtx, sampling: SamplingConfig):
    """Per-shard fn: (params, tok, caches, cur_pos, rng) -> (tok', caches)."""

    def decode_step(params, tok, caches, cur_pos, rng):
        kv_axis = ctx.dist.data_axis if ctx.parallel.kv_seq_shard else None
        tokens = tok[:, None] if tok.ndim == 1 else tok[:, None, :]
        logits, caches, _ = M.forward(
            params, tokens, ctx, caches=caches, cur_pos=cur_pos,
            kv_seq_axis=kv_axis, last_only=True, seq_sharded=False,
        )
        nxt = sample_tokens(
            logits[:, -1], rng, sampling, ctx.plan, ctx.dist,
            topk_sync_enabled=ctx.parallel.topk_sync,
            use_pallas=ctx.parallel.use_pallas,
        )
        return nxt, caches

    return decode_step


# ---------------------------------------------------------------------------
# Continuous batching (slot engine)
#
# The wave model above decodes a whole batch at one shared ``cur_pos``.  The
# slot engine instead runs a fixed-capacity batch where every row is an
# independent *slot* at its own position ``pos[b]``: finished/empty slots are
# masked inside the jitted step, and new requests are admitted in-flight by
# prefilling into free slots of the live cache — no batch restart, no
# recompile (prompt lengths are bucketed by the scheduler).
# ---------------------------------------------------------------------------


def make_slot_prefill_step(ctx: M.ModelCtx, sampling: SamplingConfig):
    """Per-shard in-flight admission step.

    (params, tokens (b,Lp), caches, admit (b,) bool, plens (b,), rng)
      -> (tok (b,), caches)

    Runs a full-width prefill over the padded token batch, then merges ONLY
    the admitted slots back into the live cache; un-admitted rows keep their
    cache/state bit-for-bit (their forward results are discarded).  Each
    admitted slot samples its first token from its own last *real* prompt
    position (padding never conditions the sample — per-request semantics are
    identical to running the request alone)."""
    from repro.models import transformer as tfm

    groups = tfm.build_groups(ctx.cfg)

    prefix = ctx.cfg.frontend.prefix_len if ctx.cfg.frontend else 0

    def prefill_slots(params, tokens, caches, admit, plens, rng):
        # fresh requests integrate recurrent state from t=0 and must not see
        # stale positions, so their slots reset before the forward
        caches_r = kvcache.reset_slots(caches, groups, admit)
        features = None
        if prefix:
            # modality-prefix archs: the stub encoder consumes zero features
            # (as in Engine.generate) and projects a fixed-length prefix in
            # front of every row's prompt, so every prefix column is real and
            # each row's valid cache extent is prefix + plen.
            features = jnp.zeros(
                (tokens.shape[0], prefix, ctx.cfg.frontend.feature_dim),
                jnp.float32)
        ext = plens + prefix
        lmask = (jnp.arange(prefix + tokens.shape[1], dtype=jnp.int32)[None, :]
                 < ext[:, None])                         # (b, prefix + Lp)
        hidden, new_caches, _ = M.forward(
            params, tokens, ctx, features=features, caches=caches_r,
            last_only=False, skip_head=True, seq_sharded=True,
            length_mask=lmask,
        )
        idx = jnp.clip(ext - 1, 0, prefix + tokens.shape[1] - 1)
        h_last = jnp.take_along_axis(hidden, idx[:, None, None], axis=1)
        logits = M.lm_head_local(params, h_last, ctx)
        tok = sample_tokens(
            logits[:, -1], rng, sampling, ctx.plan, ctx.dist,
            topk_sync_enabled=ctx.parallel.topk_sync,
            use_pallas=ctx.parallel.use_pallas,
        )
        new_caches = kvcache.mask_prompt_padding(new_caches, groups, ext)
        merged = kvcache.merge_slots(caches, new_caches, groups, admit)
        return tok, merged

    return prefill_slots


def make_slot_decode_step(ctx: M.ModelCtx, sampling: SamplingConfig):
    """Per-shard masked decode step with per-slot positions.

    (params, tok, caches, pos, done, remaining, eos, rng)
      -> (nxt, caches, pos', done', remaining')

    ``pos`` (b,) is the cache index the incoming token is written at (== its
    absolute position); done/remaining implement per-slot stopping (eos or
    budget) INSIDE the program, so a fused multi-step scan never overruns a
    slot: finished rows freeze their token/position and their (harmless,
    row-local) cache write lands at the frozen index."""

    def slot_decode(params, tok, caches, pos, done, remaining, eos, rng,
                    block_tables=None):
        tokens = tok[:, None] if tok.ndim == 1 else tok[:, None, :]
        logits, caches, _ = M.forward(
            params, tokens, ctx, caches=caches, cur_pos=pos,
            kv_seq_axis=None, last_only=True, seq_sharded=False,
            block_tables=block_tables,
        )
        nxt = sample_tokens(
            logits[:, -1], rng, sampling, ctx.plan, ctx.dist,
            topk_sync_enabled=ctx.parallel.topk_sync,
            use_pallas=ctx.parallel.use_pallas,
        )
        active = (~done) & (remaining > 0)
        amask = active if nxt.ndim == 1 else active[:, None]
        nxt = jnp.where(amask, nxt, tok)
        new_pos = jnp.where(active, pos + 1, pos)
        flat = nxt if nxt.ndim == 1 else nxt[..., 0]
        hit_eos = active & (eos >= 0) & (flat == eos)
        new_remaining = jnp.where(active, remaining - 1, remaining)
        new_done = done | hit_eos | (new_remaining <= 0)
        return nxt, caches, new_pos, new_done, new_remaining

    return slot_decode


def make_paged_prefill_step(ctx: M.ModelCtx, sampling: SamplingConfig,
                            *, with_prefix: bool):
    """Paged in-flight admission: like the dense slot prefill, but K/V lands
    in the block pool through a write block table and (with_prefix=True)
    each row's tokens are only its prompt SUFFIX — the shared-prefix blocks
    are already resident and are attended through the slot's view.

    (params, tokens (b,Lp), caches, admit, plens, starts, total_lens,
     block_tables, rng) -> (tok (b,), caches)

    ``plens`` are suffix lengths, ``starts`` the per-slot absolute offset of
    the suffix (0 without sharing), ``total_lens = starts + plens`` the full
    prompt length.  The un-admitted rows' table entries are the null block,
    which confines their scatter writes to a dead sink; merge_slots then
    row-selects only the per-slot leaves (pos, recurrent state)."""
    from repro.models import transformer as tfm

    groups = tfm.build_groups(ctx.cfg)

    def prefill_paged(params, tokens, caches, admit, plens, starts,
                      total_lens, bt, rng):
        caches_r = kvcache.reset_slots(caches, groups, admit, paged=True)
        lmask = (jnp.arange(tokens.shape[1], dtype=jnp.int32)[None, :]
                 < plens[:, None])                              # (b, Lp)
        hidden, new_caches, _ = M.forward(
            params, tokens, ctx, caches=caches_r, last_only=False,
            skip_head=True, seq_sharded=True, length_mask=lmask,
            block_tables=bt, start_pos=starts if with_prefix else None,
        )
        idx = jnp.clip(plens - 1, 0, tokens.shape[1] - 1)
        h_last = jnp.take_along_axis(hidden, idx[:, None, None], axis=1)
        logits = M.lm_head_local(params, h_last, ctx)
        tok = sample_tokens(
            logits[:, -1], rng, sampling, ctx.plan, ctx.dist,
            topk_sync_enabled=ctx.parallel.topk_sync,
            use_pallas=ctx.parallel.use_pallas,
        )
        new_caches = kvcache.set_slot_positions(new_caches, groups, total_lens)
        merged = kvcache.merge_slots(caches, new_caches, groups, admit,
                                     paged=True)
        return tok, merged

    return prefill_paged


def _make_chunk_half(ctx: M.ModelCtx, sampling: SamplingConfig, groups,
                     *, paged: bool):
    """The chunk-prefill half shared by the fused mixed step and the
    chunk-only step (disaggregated prefill pool): scatter ONE chunk of up to
    C tokens for every admitting slot and sample each row's next token from
    its last real chunk position.  ``rng`` arrives pre-folded by the caller
    so both users derive ``ptok`` from the identical key stream."""

    def half(params, ctokens, caches, admit, first, clens, starts, totals,
             bt_w, rng):
        caches_r = kvcache.reset_slots(caches, groups, admit & first,
                                       paged=paged)
        lmask = (jnp.arange(ctokens.shape[1], dtype=jnp.int32)[None, :]
                 < clens[:, None])                           # (b, C)
        hidden, new_caches, _ = M.forward(
            params, ctokens, ctx, caches=caches_r, last_only=False,
            skip_head=True, seq_sharded=True, length_mask=lmask,
            start_pos=starts, block_tables=bt_w,
        )
        idx = jnp.clip(clens - 1, 0, ctokens.shape[1] - 1)
        h_last = jnp.take_along_axis(hidden, idx[:, None, None], axis=1)
        logits = M.lm_head_local(params, h_last, ctx)
        ptok = sample_tokens(
            logits[:, -1], rng, sampling, ctx.plan, ctx.dist,
            topk_sync_enabled=ctx.parallel.topk_sync,
            use_pallas=ctx.parallel.use_pallas,
        )
        new_caches = kvcache.set_slot_positions(
            new_caches, groups, totals,
            window=0 if paged else ctx.cfg.window)
        merged = kvcache.merge_slots(caches, new_caches, groups, admit,
                                     paged=paged)
        return ptok, merged

    return half


def make_chunk_prefill_step(ctx: M.ModelCtx, sampling: SamplingConfig,
                            *, paged: bool):
    """Chunk-prefill-ONLY step — the prefill half of the mixed step with no
    decode ride-along, for the disaggregated prefill pool where decode-active
    slots live on other shards and step separately.

    (params, ctokens (b,C), caches, admit, first, clens, starts, totals,
     [bt_w,] rng) -> (ptok (b,), caches)

    Operand semantics match the mixed step's prefill half exactly (and ptok
    folds the same rng stream), so a prompt chunk-prefilled here is
    bit-identical to one admitted through the unified mixed step."""
    from repro.models import transformer as tfm

    groups = tfm.build_groups(ctx.cfg)
    half = _make_chunk_half(ctx, sampling, groups, paged=paged)

    def chunk(params, ctokens, caches, admit, first, clens, starts, totals,
              *rest):
        *bts, rng = rest
        bt_w = bts[0] if paged else None
        return half(params, ctokens, caches, admit, first, clens, starts,
                    totals, bt_w, jax.random.fold_in(rng, 0))

    return chunk


def make_mixed_step(ctx: M.ModelCtx, sampling: SamplingConfig, *, paged: bool):
    """Fused chunked-prefill + decode step — the unit of chunked admission.

    (params, ctokens (b,C), caches, admit, first, clens, starts, totals,
     tok, pos, done, remaining, eos, [bt_w, bt,] rng)
      -> (ptok (b,), nxt (b,), caches, pos', done', remaining')

    One jitted program does BOTH halves of a serving step so a long prompt
    never stalls in-flight decode for more than one chunk of compute:

    * prefill ONE chunk of up to C tokens for every admitting slot —
      ``starts`` (b,) is each row's resume offset (view position of the
      chunk's first token), ``clens`` its real token count, ``first`` marks
      a request's opening chunk (slot state resets), ``totals`` the row's
      valid cache extent after this chunk (position rows are rewritten
      whole).  ``ptok`` samples each row's next token from its last real
      chunk position — the host uses it only for rows whose chunk completed
      the prompt (their first emitted token);
    * one masked decode step for every decode-active slot (admitting slots
      ride with done=True, so the decode half freezes them).

    The chunk width C is FIXED by the scheduler, so this path compiles once
    — no pow-2 prompt buckets.  Paged variant threads two tables: ``bt_w``
    (admitting rows real, all others null — confines the chunk scatter)
    for the prefill half, ``bt`` (real) for the decode half."""
    from repro.models import transformer as tfm

    groups = tfm.build_groups(ctx.cfg)
    half = _make_chunk_half(ctx, sampling, groups, paged=paged)
    dec = make_slot_decode_step(ctx, sampling)

    def mixed(params, ctokens, caches, admit, first, clens, starts, totals,
              tok, pos, done, remaining, eos, *rest):
        *bts, rng = rest
        bt_w = bts[0] if paged else None
        bt = bts[1] if paged else None
        ptok, merged = half(params, ctokens, caches, admit, first, clens,
                            starts, totals, bt_w, jax.random.fold_in(rng, 0))
        # The decode half freezes admitting rows (done=True), but a frozen
        # row still performs its row-local cache write at its incoming
        # position — which for an admitting row is STALE and would clobber
        # the chunk just written.
        if paged:
            # Redirect those rows' write index to the last view slot: dead
            # by causality (entry value == index, never <= any earlier
            # cur_pos), confined by the nulled block table, and overwritten
            # by the real decode write before the row could ever attend it.
            sink = caches[0]["sub0"]["pos"].shape[-1] - 1
            dec_pos = jnp.where(admit, jnp.int32(sink), pos)
            nxt, merged, pos, done, remaining = dec(
                params, tok, merged, dec_pos, done, remaining, eos,
                jax.random.fold_in(rng, 1), block_tables=bt)
        else:
            # Dense caches include ring layouts, which have NO dead index to
            # redirect to (every in-window slot is live).  Let the frozen
            # write land at the stale position, then re-select the chunk
            # half's rows for admitting slots — a pure per-row merge that
            # discards the stale write entirely (and is equivalent to the
            # sink redirect for non-ring layouts).
            nxt, dec_caches, pos, done, remaining = dec(
                params, tok, merged, pos, done, remaining, eos,
                jax.random.fold_in(rng, 1), block_tables=None)
            merged = kvcache.merge_slots(dec_caches, merged, groups, admit)
        return ptok, nxt, merged, pos, done, remaining

    return mixed


def make_spec_verify_step(ctx: M.ModelCtx, sampling: SamplingConfig,
                          *, paged: bool):
    """Fused multi-token speculative-decode verify step.

    (params, vtokens (b, K+1), caches, pos, done, remaining, eos, [bt,] rng)
      -> (targets (b, K+1), n_emit (b,), nxt (b,), caches, pos', done',
          remaining')

    ``vtokens[:, 0]`` is each slot's pending token (the one plain decode
    would feed this step); columns 1..K are the host drafter's proposals.
    A verify step IS a width-(K+1) prefill chunk at the decode frontier:
    the K+1 tokens scatter into the cache at view offsets pos..pos+K via
    the batched-offset chunk writers, each row attends its stripe
    [0, pos+K] through the same flash-prefill path as chunked admission
    (view index == absolute position, causality does all the masking), and
    ALL K+1 positions sample a target token from one forward pass — one
    weight sweep scores K+1 conditionals instead of 1.

    Per slot, targets[j] is drawn from the true conditional given
    [history, vtokens[:j+1]]; draft j+1 is accepted iff it equals
    targets[j], so the emitted run targets[0..acc] (``acc`` accepted drafts
    + the bonus token at the first rejected position) is distributed
    exactly as plain autoregressive decode — and bit-identical under
    greedy.  The emit length is additionally cut at the slot's budget and
    at the first EOS among the emitted run, mirroring the masked
    slot-decode stopping rule in-program.

    KV rewind: entries pos+e..pos+K hold K/V of rejected drafts.  Dense
    slots rewind by position mask (set_slot_positions marks [0, pos+e)
    valid; the dead entries are overwritten by the next verify chunk
    before they could ever be attended, since its writes start exactly at
    pos+e).  Paged slots additionally have their block tables truncated on
    the host after the step.  Frozen rows (done / mid-admission) keep
    their cache bit-for-bit: dense rows merge from the old tree, paged
    rows write through a nulled block-table row."""
    from repro.models import transformer as tfm

    groups = tfm.build_groups(ctx.cfg)

    def verify(params, vtokens, caches, pos, done, remaining, eos, *rest):
        *bts, rng = rest
        bt = bts[0] if paged else None
        b, K1 = vtokens.shape
        active = (~done) & (remaining > 0)
        hidden, new_caches, _ = M.forward(
            params, vtokens, ctx, caches=caches, last_only=False,
            skip_head=True, seq_sharded=True, start_pos=pos,
            block_tables=bt,
        )
        logits = M.lm_head_local(params, hidden, ctx)      # (b, K+1, Vp)
        targets = sample_tokens(
            logits.reshape(b * K1, -1), rng, sampling, ctx.plan, ctx.dist,
            topk_sync_enabled=ctx.parallel.topk_sync,
            use_pallas=ctx.parallel.use_pallas,
        ).reshape(b, K1)
        # longest accepted draft prefix, then cut at EOS and budget
        match = (vtokens[:, 1:] == targets[:, :-1]).astype(jnp.int32)
        acc = jnp.cumprod(match, axis=1).sum(axis=1)       # (b,) in [0, K]
        idx = jnp.arange(K1, dtype=jnp.int32)
        is_eos = (eos[:, None] >= 0) & (targets == eos[:, None])
        j_eos = jnp.min(jnp.where(is_eos, idx[None, :], K1), axis=1)
        e = jnp.minimum(jnp.minimum(acc + 1, j_eos + 1), remaining)
        e = jnp.where(active, e, 0).astype(jnp.int32)
        new_pos = pos + e
        new_remaining = remaining - e
        hit_eos = active & (j_eos < e)
        new_done = done | hit_eos | (active & (new_remaining <= 0))
        last = jnp.clip(e - 1, 0, K1 - 1)
        nxt = jnp.where(
            active,
            jnp.take_along_axis(targets, last[:, None], axis=1)[:, 0],
            vtokens[:, 0])
        # rewind: exactly [0, pos+e) is valid for active rows; frozen rows
        # keep their old cache (and pos rows) through the per-row merge
        new_caches = kvcache.set_slot_positions(
            new_caches, groups, new_pos,
            window=0 if paged else ctx.cfg.window)
        merged = kvcache.merge_slots(caches, new_caches, groups, active,
                                     paged=paged)
        return targets, e, nxt, merged, new_pos, new_done, new_remaining

    return verify


def make_paged_decode_step(ctx: M.ModelCtx, sampling: SamplingConfig):
    """Masked per-slot decode over the paged pool: the dense slot-decode
    body with cache reads/writes routed through the block table.
    (params, tok, caches, pos, done, remaining, eos, bt, rng) ->
    (nxt, caches, pos', done', remaining')."""
    dec = make_slot_decode_step(ctx, sampling)

    def paged_decode(params, tok, caches, pos, done, remaining, eos, bt, rng):
        return dec(params, tok, caches, pos, done, remaining, eos, rng,
                   block_tables=bt)

    return paged_decode


def make_migrate_step(ctx: M.ModelCtx):
    """Batched cross-pool KV-block migration (disaggregated serving).

    (caches, src (m,), dst (m,), land (b,), totals (b,)) -> caches

    ``src``/``dst`` are GLOBAL block ids (shard * blocks_per_shard + local);
    every pool leaf copies row ``src[j]`` into row ``dst[j]`` in one gather +
    scatter over the block dim.  The program is jitted GLOBALLY (no
    shard_map): the pool's block dim is sharded over the data axis, so when
    src and dst fall on different shards GSPMD lowers the copy to the actual
    device-to-device transfer — which is precisely the migration traffic the
    scheduler accounts (migration_bytes = blocks x pool_block_bytes).

    ``land`` flags decode slots receiving a fully-migrated request this
    step; their position rows are rewritten to ``[0, totals[b])`` valid so
    the landed view is immediately decodable (all other rows, and all
    recurrent per-slot state, are untouched — bit-for-bit).

    Callers pad (src, dst) with null self-copies (0 -> 0) to a bucketed
    width: global block 0 is shard 0's reserved null block, and duplicate
    scatter writes of identical values are benign."""
    from repro.models import transformer as tfm

    groups = tfm.build_groups(ctx.cfg)

    def migrate(caches, src, dst, land, totals):
        def f(key, leaf, stacked):
            if key not in kvcache.POOL_KEYS:
                return leaf
            if stacked:                     # (layers, n_blocks, ...)
                return leaf.at[:, dst].set(leaf[:, src])
            return leaf.at[dst].set(leaf[src])

        out = kvcache._map_by_key(caches, groups, f)
        newpos = kvcache.set_slot_positions(out, groups, totals)
        return kvcache.merge_slots(out, newpos, groups, land, paged=True)

    return migrate


@dataclass
class Engine:
    """Host-side serving engine over a local (or production) mesh."""

    cfg: ModelConfig
    parallel: ParallelConfig
    sampling: SamplingConfig
    mesh: Any
    max_len: int
    params: Pytree = None
    seed: int = 0
    wq_cache: Optional[str] = None   # path for the packed QuantWeight tree:
                                     # load it if present (skipping bf16
                                     # materialization), else save after
                                     # quantize-at-load
    # pre-dispatch hook, called with no arguments at the top of every
    # retry-safe serving step (decode/mixed/chunk/verify) BEFORE the jitted
    # program is queued.  This is the engine's fault boundary: an exception
    # raised here leaves the donated cache chain untouched — the program
    # never dispatched, so the exact pre-step state survives and the caller
    # may re-dispatch (the schedulers' bounded-retry path).  Once a program
    # holding donated buffers HAS dispatched, a host-side replay is
    # impossible; that asymmetry is why fault injection and the watchdog
    # delay both live at this hook.  Installed by schedulers running a
    # FaultPlan (runtime/faults.py); None = zero overhead.
    dispatch_hook: Optional[Any] = None

    def __post_init__(self):
        pod = "pod" if "pod" in self.mesh.axis_names else None
        self.ctx = M.ModelCtx.make(self.cfg, self.parallel, pod_axis=pod)
        # the declarative capability record every scheduler/serve entry
        # consults (the single require() choke point for path eligibility)
        self.caps = ArchCapabilities.from_config(self.cfg)
        wq = self.parallel.weight_quant != "none"
        loaded = False
        if self.params is None:
            if wq and self.wq_cache and M.has_quantized(self.wq_cache):
                self.params = M.load_quantized(self.ctx, self.wq_cache)
                loaded = True
            else:
                self.params = M.init_params(self.ctx, jax.random.key(self.seed))
        if wq:
            # quantize-at-load: the serving programs only ever see packed
            # weights + scales; param_specs mirrors the transform so the
            # shard_map spec trees stay structurally identical (quantize is
            # a no-op on already-packed QuantWeight leaves, so a tree
            # restored from wq_cache passes straight through)
            self.params = M.quantize_params(self.ctx, self.params)
            if self.wq_cache and not loaded:
                M.save_quantized(self.ctx, self.params, self.wq_cache)
        self._build()

    # -- sharding specs -----------------------------------------------------
    def _specs(self):
        dist = self.ctx.dist
        d = dist.data_axes if len(dist.data_axes) > 1 else dist.data_axes[0]
        batch_spec = P(None) if self.parallel.kv_seq_shard else P(d)
        tok2 = P(*batch_spec, None) if self.cfg.n_codebooks == 1 else P(*batch_spec, None, None)
        tok1 = P(*batch_spec) if self.cfg.n_codebooks == 1 else P(*batch_spec, None)
        feat = P(*batch_spec, None, None)
        cache = kvcache.cache_pspecs(self.ctx, kv_seq_shard=self.parallel.kv_seq_shard)
        return batch_spec, tok2, tok1, feat, cache

    def _build(self):
        pspecs = M.param_specs(self.ctx)
        batch_spec, tok2, tok1, feat, cache_spec = self._specs()
        sm = partial(compat.shard_map, mesh=self.mesh, check_vma=False)

        pre = make_prefill_step(self.ctx, self.sampling)
        if self.cfg.frontend is None:
            pre_nofeat = lambda p, t, c, r: pre(p, t, None, c, r)
            self._prefill_raw = jax.jit(
                sm(pre_nofeat, in_specs=(pspecs, tok2, cache_spec, P()),
                   out_specs=(tok1, cache_spec)),
                donate_argnums=(2,) if self.parallel.zero_copy else (),
            )
            self._prefill = lambda p, t, f, c, r: self._prefill_raw(p, t, c, r)
        else:
            self._prefill = jax.jit(
                sm(pre, in_specs=(pspecs, tok2, feat, cache_spec, P()),
                   out_specs=(tok1, cache_spec)),
                donate_argnums=(3,) if self.parallel.zero_copy else (),
            )
        dec = make_decode_step(self.ctx, self.sampling)
        self._decode = jax.jit(
            sm(dec, in_specs=(pspecs, tok1, cache_spec, P(), P()),
               out_specs=(tok1, cache_spec)),
            donate_argnums=(2,) if self.parallel.zero_copy else (),
        )

        # §Perf H4: fused multi-token decode — lax.scan over n steps inside
        # ONE jitted program removes the per-token dispatch + host-sync
        # overhead of the token loop (the paper's §3 metric IS this loop).
        def decode_n(params, tok, caches, cur_pos, rng, *, n):
            def body(carry, i):
                tok, caches = carry
                nxt, caches = dec(params, tok, caches,
                                  cur_pos + i, jax.random.fold_in(rng, i))
                return (nxt, caches), nxt

            (tok, caches), toks = jax.lax.scan(
                body, (tok, caches), jnp.arange(n, dtype=jnp.int32))
            return toks, caches

        tokn = P(None, *tuple(tok1))
        self._decode_n = {
            n: jax.jit(
                sm(partial(decode_n, n=n),
                   in_specs=(pspecs, tok1, cache_spec, P(), P()),
                   out_specs=(tokn, cache_spec)),
                donate_argnums=(2,) if self.parallel.zero_copy else (),
            )
            for n in (8, 16, 32)
        }

    # -- continuous batching (slot engine) --------------------------------
    def _slot_gate(self):
        if self.parallel.kv_seq_shard:
            raise ValueError("slot engine is incompatible with kv_seq_shard")

    def _slot_decode_builder(self, dec, cspec, extra_specs=()):
        """``build_decode(n)`` factory shared by the dense and paged slot
        engines: a lax.scan of ``n`` fused masked steps.  ``extra_specs``
        appends trailing sharded operands (the paged block table) that are
        threaded into every step between ``eos`` and the rng."""
        pspecs = M.param_specs(self.ctx)
        batch_spec, _, tok1, _, _ = self._specs()
        sm = partial(compat.shard_map, mesh=self.mesh, check_vma=False)
        slot = P(*batch_spec)
        donate = (2,) if self.parallel.zero_copy else ()

        def decode_n(params, tok, caches, pos, done, remaining, eos, *rest,
                     n):
            *extra, rng = rest

            def body(carry, i):
                tok, caches, pos, done, remaining = carry
                nxt, caches, pos, done, remaining = dec(
                    params, tok, caches, pos, done, remaining, eos, *extra,
                    jax.random.fold_in(rng, i))
                return (nxt, caches, pos, done, remaining), nxt

            (tok, caches, pos, done, remaining), toks = jax.lax.scan(
                body, (tok, caches, pos, done, remaining),
                jnp.arange(n, dtype=jnp.int32))
            return toks, caches, pos, done, remaining

        tokn = P(None, *tuple(tok1))

        def build_decode(n):
            return jax.jit(
                sm(partial(decode_n, n=n),
                   in_specs=(pspecs, tok1, cspec, slot, slot, slot, slot,
                             *extra_specs, P()),
                   out_specs=(tokn, cspec, slot, slot, slot)),
                donate_argnums=donate,
            )

        return build_decode

    def _cb(self):
        """Lazily-built slot-engine programs (prefill_into_slots + fused
        masked decode).  Separate from the wave programs so wave-only users
        pay no extra compile time."""
        if getattr(self, "_cb_built", None) is None:
            self._slot_gate()
            pspecs = M.param_specs(self.ctx)
            batch_spec, tok2, tok1, _, _ = self._specs()
            cspec = kvcache.cache_pspecs(self.ctx, kv_seq_shard=False,
                                         batched_pos=True)
            sm = partial(compat.shard_map, mesh=self.mesh, check_vma=False)
            slot = P(*batch_spec)
            donate = (2,) if self.parallel.zero_copy else ()

            pre = make_slot_prefill_step(self.ctx, self.sampling)
            prefill = jax.jit(
                sm(pre, in_specs=(pspecs, tok2, cspec, slot, slot, P()),
                   out_specs=(tok1, cspec)),
                donate_argnums=donate,
            )

            dec = make_slot_decode_step(self.ctx, self.sampling)
            self._cb_built = {
                "prefill": prefill, "decode": {},
                "build_decode": self._slot_decode_builder(dec, cspec),
            }
        return self._cb_built

    def init_slot_caches(self, n_slots: int, *, ring_slack: Optional[int] = None):
        """``ring_slack`` sizes sliding-window ring caches at window + slack
        so a speculative verify chunk of K drafts never wraps onto live
        window entries; defaults to the configured spec_k."""
        dp_total = self.ctx.dist.dp * self.ctx.dist.pods
        if n_slots % dp_total:
            raise ValueError(f"n_slots {n_slots} must divide dp*pods {dp_total}")
        slack = self.parallel.spec_k if ring_slack is None else ring_slack
        return self.init_caches(n_slots, batched_pos=True, ring_slack=slack)

    def prefill_into_slots(self, caches, tokens, admit, plens, rng):
        """Admit requests in-flight: prefill ``tokens`` (B, Lp[, ncb]) into
        the slots flagged by ``admit`` (B,) of a LIVE cache; other slots are
        untouched.  Returns (first sampled token (B,[ncb]), caches).

        jit retraces per distinct Lp — callers bucket prompt lengths (the
        scheduler pads to powers of two) to bound compilation."""
        cb = self._cb()
        return cb["prefill"](
            self.params, jnp.asarray(tokens), caches,
            jnp.asarray(admit, bool), jnp.asarray(plens, jnp.int32), rng)

    @staticmethod
    def land(*arrays):
        """Materialize device futures to host numpy, blocking until the
        dispatched programs that produce them have executed.  The single
        synchronization primitive of the overlapped serving loop: every
        ``decode_slots``/``verify_slots``/``mixed_step`` output is a device
        future under JAX async dispatch, so a caller that chains outputs
        into the next dispatch and ``land``s one step late overlaps all of
        its host work with device compute.

        **Async-dispatch contract** (what makes chaining safe): jitted
        programs execute in dispatch order per device, so a program that
        consumes another's output future always reads the produced value —
        including donated cache buffers (``zero_copy``), provided the chain
        stays linear: each cache future is consumed by exactly one
        subsequent dispatch.  Host numpy arrays captured at dispatch time
        are copied by ``jnp.asarray`` during tracing/transfer, so the
        caller may mutate its host mirrors freely while blocks are in
        flight."""
        out = [np.asarray(a) for a in arrays]
        return out[0] if len(out) == 1 else out

    def _predispatch(self):
        """Run the fault/watchdog hook before a retry-safe step dispatch
        (see ``dispatch_hook``)."""
        if self.dispatch_hook is not None:
            self.dispatch_hook()

    def decode_slots(self, caches, tok, pos, done, remaining, eos, rng, *, n=1):
        """Run ``n`` fused masked decode steps over all slots.

        Outputs are device FUTURES (JAX async dispatch): callers may chain
        them into the next ``decode_slots`` call without materializing and
        ``Engine.land`` them one step late — see the overlapped scheduler
        loop.  Returns (toks (n, B[, ncb]), caches, pos, done, remaining)."""
        self._predispatch()
        cb = self._cb()
        if n not in cb["decode"]:
            cb["decode"][n] = cb["build_decode"](n)
        return cb["decode"][n](
            self.params, tok, caches, jnp.asarray(pos, jnp.int32),
            jnp.asarray(done, bool), jnp.asarray(remaining, jnp.int32),
            jnp.asarray(eos, jnp.int32), rng)

    # -- chunked prefill (fused mixed prefill/decode step) -----------------
    def _mixed(self, paged: bool):
        """Lazily-built fused mixed step (jit retraces per chunk width; the
        scheduler pins one width, so the chunked path compiles exactly one
        prefill program — no pow-2 prompt buckets)."""
        cb = self._cb_paged() if paged else self._cb()
        if "mixed" not in cb:
            pspecs = M.param_specs(self.ctx)
            batch_spec, tok2, tok1, _, _ = self._specs()
            cspec = kvcache.cache_pspecs(self.ctx, kv_seq_shard=False,
                                         batched_pos=True)
            sm = partial(compat.shard_map, mesh=self.mesh, check_vma=False)
            slot = P(*batch_spec)
            extra = (P(*batch_spec, None),) * 2 if paged else ()
            mix = make_mixed_step(self.ctx, self.sampling, paged=paged)
            cb["mixed"] = jax.jit(
                sm(mix,
                   in_specs=(pspecs, tok2, cspec, slot, slot, slot, slot,
                             slot, tok1, slot, slot, slot, slot, *extra, P()),
                   out_specs=(tok1, tok1, cspec, slot, slot, slot)),
                donate_argnums=(2,) if self.parallel.zero_copy else (),
            )
        return cb["mixed"]

    def mixed_step(self, caches, ctokens, admit, first, clens, starts, totals,
                   tok, pos, done, remaining, eos, rng):
        """One fused chunked-admission step over the dense slot engine:
        prefill one chunk into the admitting slots AND run one masked decode
        step for the decode-active slots, in the same jitted program.
        Returns (ptok (B,), nxt (B,), caches, pos, done, remaining)."""
        self._predispatch()
        return self._mixed(False)(
            self.params, jnp.asarray(ctokens), caches,
            jnp.asarray(admit, bool), jnp.asarray(first, bool),
            jnp.asarray(clens, jnp.int32), jnp.asarray(starts, jnp.int32),
            jnp.asarray(totals, jnp.int32), jnp.asarray(tok),
            jnp.asarray(pos, jnp.int32), jnp.asarray(done, bool),
            jnp.asarray(remaining, jnp.int32), jnp.asarray(eos, jnp.int32),
            rng)

    def mixed_step_paged(self, caches, ctokens, admit, first, clens, starts,
                         totals, tok, pos, done, remaining, eos, bt_w, bt,
                         rng):
        """Paged fused mixed step: ``bt_w`` routes the chunk scatter (null
        rows for every non-admitting slot), ``bt`` serves the decode half."""
        self._predispatch()
        return self._mixed(True)(
            self.params, jnp.asarray(ctokens), caches,
            jnp.asarray(admit, bool), jnp.asarray(first, bool),
            jnp.asarray(clens, jnp.int32), jnp.asarray(starts, jnp.int32),
            jnp.asarray(totals, jnp.int32), jnp.asarray(tok),
            jnp.asarray(pos, jnp.int32), jnp.asarray(done, bool),
            jnp.asarray(remaining, jnp.int32), jnp.asarray(eos, jnp.int32),
            jnp.asarray(bt_w, jnp.int32), jnp.asarray(bt, jnp.int32), rng)

    # -- disaggregated serving (chunk-only prefill + block migration) ------
    def _chunk_only(self, paged: bool):
        """Lazily-built chunk-prefill-only program (prefill-pool step of the
        disaggregated engine; same one-width compile story as _mixed)."""
        cb = self._cb_paged() if paged else self._cb()
        if "chunk" not in cb:
            pspecs = M.param_specs(self.ctx)
            batch_spec, tok2, tok1, _, _ = self._specs()
            cspec = kvcache.cache_pspecs(self.ctx, kv_seq_shard=False,
                                         batched_pos=True)
            sm = partial(compat.shard_map, mesh=self.mesh, check_vma=False)
            slot = P(*batch_spec)
            extra = (P(*batch_spec, None),) if paged else ()
            ch = make_chunk_prefill_step(self.ctx, self.sampling, paged=paged)
            cb["chunk"] = jax.jit(
                sm(ch, in_specs=(pspecs, tok2, cspec, slot, slot, slot, slot,
                                 slot, *extra, P()),
                   out_specs=(tok1, cspec)),
                donate_argnums=(2,) if self.parallel.zero_copy else (),
            )
        return cb["chunk"]

    def chunk_slots_paged(self, caches, ctokens, admit, first, clens, starts,
                          totals, bt_w, rng):
        """One chunk-prefill-only step over the paged pool (no decode half):
        ``bt_w`` routes the chunk scatter, with null rows for every
        non-admitting slot.  Returns (ptok (B,), caches)."""
        self._predispatch()
        return self._chunk_only(True)(
            self.params, jnp.asarray(ctokens), caches,
            jnp.asarray(admit, bool), jnp.asarray(first, bool),
            jnp.asarray(clens, jnp.int32), jnp.asarray(starts, jnp.int32),
            jnp.asarray(totals, jnp.int32), jnp.asarray(bt_w, jnp.int32), rng)

    def _migrate(self, m: int):
        """Lazily-built jitted migration program per padded batch width
        ``m`` (widths are pow-2 bucketed by migrate_blocks)."""
        cb = self._cb_paged()
        key = ("migrate", m)
        if key not in cb:
            from jax.sharding import NamedSharding
            cspecs = kvcache.cache_pspecs(self.ctx, kv_seq_shard=False,
                                          batched_pos=True)
            shard_of = jax.tree.map(
                lambda p: NamedSharding(self.mesh, p), cspecs,
                is_leaf=lambda x: isinstance(x, P))
            cb[key] = jax.jit(
                make_migrate_step(self.ctx),
                donate_argnums=(0,) if self.parallel.zero_copy else (),
                out_shardings=shard_of,
            )
        return cb[key]

    def migrate_blocks(self, caches, src_ids, dst_ids, land, totals):
        """Copy pool blocks ``src_ids`` -> ``dst_ids`` (GLOBAL ids; cross-
        shard pairs become device-to-device traffic) and land the slots
        flagged by ``land`` at valid extent ``totals``.  Returns caches."""
        n = len(src_ids)
        m = 1 << max(0, int(n - 1).bit_length())      # pow-2 bucket, >= 1
        src = np.zeros(m, np.int32)
        dst = np.zeros(m, np.int32)
        src[:n] = src_ids
        dst[:n] = dst_ids
        return self._migrate(m)(
            caches, jnp.asarray(src), jnp.asarray(dst),
            jnp.asarray(land, bool), jnp.asarray(totals, jnp.int32))

    # -- speculative decoding (fused multi-token verify) -------------------
    def _verify(self, paged: bool, K1: int):
        """Lazily-built jitted verify program for draft width K1-1 (jit
        retraces per distinct width; the scheduler pins one ``spec_k``, so
        spec decode compiles exactly one verify program per backend)."""
        cb = self._cb_paged() if paged else self._cb()
        key = ("verify", K1)
        if key not in cb:
            pspecs = M.param_specs(self.ctx)
            batch_spec, _, tok1, _, _ = self._specs()
            cspec = kvcache.cache_pspecs(self.ctx, kv_seq_shard=False,
                                         batched_pos=True)
            sm = partial(compat.shard_map, mesh=self.mesh, check_vma=False)
            slot = P(*batch_spec)
            tokk = P(*batch_spec, None)
            extra = (tokk,) if paged else ()
            ver = make_spec_verify_step(self.ctx, self.sampling, paged=paged)
            cb[key] = jax.jit(
                sm(ver, in_specs=(pspecs, tokk, cspec, slot, slot, slot,
                                  slot, *extra, P()),
                   out_specs=(tokk, slot, tok1, cspec, slot, slot, slot)),
                donate_argnums=(2,) if self.parallel.zero_copy else (),
            )
        return cb[key]

    def verify_slots(self, caches, vtokens, pos, done, remaining, eos, rng):
        """One fused speculative verify step over the dense slot engine:
        score ``vtokens`` (B, spec_k+1) = [pending token, drafts] at the
        decode frontier of every active slot, accept the longest matching
        draft prefix plus one bonus token, and rewind the cache past it.
        Returns (targets (B, spec_k+1), n_emit (B,), nxt (B,), caches,
        pos', done', remaining')."""
        self._predispatch()
        vtokens = jnp.asarray(vtokens, jnp.int32)
        return self._verify(False, vtokens.shape[1])(
            self.params, vtokens, caches, jnp.asarray(pos, jnp.int32),
            jnp.asarray(done, bool), jnp.asarray(remaining, jnp.int32),
            jnp.asarray(eos, jnp.int32), rng)

    def verify_slots_paged(self, caches, vtokens, pos, done, remaining, eos,
                           block_tables, rng):
        """Paged verify step: the chunk scatter and the stripe gather both
        route through ``block_tables`` (rows for frozen slots nulled by the
        caller, confining their writes to the dead sink block)."""
        self._predispatch()
        vtokens = jnp.asarray(vtokens, jnp.int32)
        return self._verify(True, vtokens.shape[1])(
            self.params, vtokens, caches, jnp.asarray(pos, jnp.int32),
            jnp.asarray(done, bool), jnp.asarray(remaining, jnp.int32),
            jnp.asarray(eos, jnp.int32), jnp.asarray(block_tables, jnp.int32),
            rng)

    # -- paged KV backend (slot engine, second storage layout) -------------
    def _cb_paged(self):
        """Lazily-built paged slot programs.  Same gating and decode
        scaffolding as the dense slot engine; the block table rides as an
        extra sharded operand."""
        if getattr(self, "_cbp_built", None) is None:
            self._slot_gate()
            pspecs = M.param_specs(self.ctx)
            batch_spec, tok2, tok1, _, _ = self._specs()
            cspec = kvcache.cache_pspecs(self.ctx, kv_seq_shard=False,
                                         batched_pos=True)
            sm = partial(compat.shard_map, mesh=self.mesh, check_vma=False)
            slot = P(*batch_spec)
            btspec = P(*batch_spec, None)
            donate = (2,) if self.parallel.zero_copy else ()

            prefill = {
                wp: jax.jit(
                    sm(make_paged_prefill_step(self.ctx, self.sampling,
                                               with_prefix=wp),
                       in_specs=(pspecs, tok2, cspec, slot, slot, slot, slot,
                                 btspec, P()),
                       out_specs=(tok1, cspec)),
                    donate_argnums=donate,
                )
                for wp in (False, True)
            }

            dec = make_paged_decode_step(self.ctx, self.sampling)
            self._cbp_built = {
                "prefill": prefill, "decode": {},
                "build_decode": self._slot_decode_builder(
                    dec, cspec, extra_specs=(btspec,)),
            }
        return self._cbp_built

    def init_paged_caches(self, n_slots: int, n_blocks: int, block_size: int):
        """Paged cache pytree: per-shard block pools assembled into global
        arrays.  The pool's block dim shards over the data axis (each shard
        owns an independent block namespace incl. its null block 0)."""
        dp_total = self.ctx.dist.dp * self.ctx.dist.pods
        if n_slots % dp_total:
            raise ValueError(f"n_slots {n_slots} must divide dp*pods {dp_total}")
        if n_blocks % dp_total:
            raise ValueError(f"n_blocks {n_blocks} must divide dp*pods {dp_total}")
        b_local, nb_local = n_slots // dp_total, n_blocks // dp_total
        cspecs = kvcache.cache_pspecs(self.ctx, kv_seq_shard=False,
                                      batched_pos=True)
        make = jax.jit(compat.shard_map(
            lambda: M.init_caches(self.ctx, b_local, self.max_len,
                                  batched_pos=True,
                                  paged=(nb_local, block_size)),
            mesh=self.mesh, in_specs=(), out_specs=cspecs, check_vma=False,
        ))
        return make()

    def prefill_into_slots_paged(self, caches, tokens, admit, plens, starts,
                                 total_lens, block_tables, rng):
        """Paged admission.  ``tokens`` (B, Lp) hold each row's prompt
        SUFFIX (the whole prompt when nothing is cached); ``starts`` (B,)
        the absolute offset of that suffix; ``block_tables`` (B, nbps) the
        write tables (null rows for un-admitted slots).  Two jitted
        variants: the no-prefix program's attention math is identical to the
        dense slot engine; the with-prefix program attends each slot's
        gathered view."""
        cb = self._cb_paged()
        starts = jnp.asarray(starts, jnp.int32)
        with_prefix = bool(np.asarray(starts).any())
        return cb["prefill"][with_prefix](
            self.params, jnp.asarray(tokens), caches,
            jnp.asarray(admit, bool), jnp.asarray(plens, jnp.int32), starts,
            jnp.asarray(total_lens, jnp.int32),
            jnp.asarray(block_tables, jnp.int32), rng)

    def decode_slots_paged(self, caches, tok, pos, done, remaining, eos,
                           block_tables, rng, *, n=1):
        """``n`` fused masked decode steps through the block tables."""
        self._predispatch()
        cb = self._cb_paged()
        if n not in cb["decode"]:
            cb["decode"][n] = cb["build_decode"](n)
        return cb["decode"][n](
            self.params, tok, caches, jnp.asarray(pos, jnp.int32),
            jnp.asarray(done, bool), jnp.asarray(remaining, jnp.int32),
            jnp.asarray(eos, jnp.int32), jnp.asarray(block_tables, jnp.int32),
            rng)

    # -- API ------------------------------------------------------------
    def init_caches(self, batch: int, *, batched_pos: bool = False,
                    ring_slack: int = 0):
        """Create the cache pytree as properly-sharded global arrays: each
        shard builds its LOCAL buffers inside shard_map and the runtime
        assembles the global arrays per the cache specs."""
        dp_total = self.ctx.dist.dp * self.ctx.dist.pods
        if self.parallel.kv_seq_shard:
            if batched_pos:
                raise ValueError("continuous batching (batched_pos) is "
                                 "incompatible with kv_seq_shard")
            b_local, kv_dp = batch, self.ctx.dist.dp
        else:
            b_local, kv_dp = batch // dp_total, 1
        cspecs = kvcache.cache_pspecs(self.ctx,
                                      kv_seq_shard=self.parallel.kv_seq_shard,
                                      batched_pos=batched_pos)
        make = jax.jit(compat.shard_map(
            lambda: M.init_caches(self.ctx, b_local, self.max_len,
                                  kv_seq_shard_dp=kv_dp,
                                  batched_pos=batched_pos,
                                  ring_slack=ring_slack),
            mesh=self.mesh, in_specs=(), out_specs=cspecs, check_vma=False,
        ))
        return make()

    def generate(self, prompts: np.ndarray, max_new: int,
                 features: Optional[np.ndarray] = None,
                 *, multi_step: bool = True) -> np.ndarray:
        """prompts (b, prompt_len [, ncb]) -> generated tokens (b, max_new [, ncb]).

        multi_step=True uses the fused n-token decode programs (§Perf H4);
        set False to force the one-jit-call-per-token baseline loop."""
        b, plen = prompts.shape[0], prompts.shape[1]
        caches = self.init_caches(b)
        if features is None and self.cfg.frontend is not None:
            f = self.cfg.frontend
            features = np.zeros((b, f.prefix_len, f.feature_dim), np.float32)
        rng = jax.random.key(self.seed + 1)
        prefix = self.cfg.frontend.prefix_len if self.cfg.frontend else 0
        tok, caches = self._prefill(self.params, jnp.asarray(prompts),
                                    features, caches, rng)
        outs = [tok[None] if tok.ndim == 1 else tok[None, ...]]
        cur = plen + prefix  # next position to write
        remaining = max_new - 1
        while remaining > 0:
            n = next((n for n in (32, 16, 8)
                      if multi_step and remaining >= n), 0)
            rng = jax.random.fold_in(rng, cur)
            if n:
                toks, caches = self._decode_n[n](self.params, tok, caches,
                                                 jnp.int32(cur), rng)
                tok = toks[-1]
                outs.append(toks)
                cur += n
                remaining -= n
            else:
                tok, caches = self._decode(self.params, tok, caches,
                                           jnp.int32(cur), rng)
                outs.append(tok[None])
                cur += 1
                remaining -= 1
        return np.asarray(jnp.concatenate(outs, axis=0)).swapaxes(0, 1)
