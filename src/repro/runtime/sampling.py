"""Sampling on vocab-sharded logits — the serving face of paper §2.1.

``sample_tokens`` consumes the model's LOCAL logits (b, [ncb,] V_local) and
returns replicated token ids; the §2.1b topk-sync path keeps the wire cost at
O(k·tp) instead of O(vocab).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import SamplingConfig
from repro.core import topk_sync
from repro.models.common import Dist, ShardPlan


def sample_tokens(
    local_logits: jax.Array,      # (b, V_local) or (b, ncb, V_local) fp32
    rng: jax.Array,
    sampling: SamplingConfig,
    plan: ShardPlan,
    dist: Dist,
    *,
    topk_sync_enabled: bool = True,
    use_pallas: bool = False,
) -> jax.Array:
    """-> (b,) or (b, ncb) int32 token ids, replicated on all shards."""
    squeeze = local_logits.ndim == 2
    if squeeze:
        local_logits = local_logits[:, None]
    b, ncb, vl = local_logits.shape
    flat = local_logits.reshape(b * ncb, vl)
    tok = topk_sync.sample(
        flat, rng, sampling, plan, dist,
        topk_sync=topk_sync_enabled, use_pallas=use_pallas,
    )
    tok = tok.reshape(b, ncb)
    return tok[:, 0] if squeeze else tok
