"""Request schedulers over the serving engine.

Two serving cores share one request/stats vocabulary:

``WaveScheduler`` (baseline) — drain-and-restart: forms *waves* of up to
``batch_size`` requests with a shared (padded) prompt length, runs prefill
once and decodes every request to the wave's max ``max_new``.  One straggler
holds the whole wave and finished rows burn full decode FLOPs.

``ContinuousScheduler`` (slot engine) — a fixed-capacity batch of *slots*
with an admit → step → retire loop: decode runs with a per-slot position
vector, finished/empty slots are masked inside the jitted step, and new
requests are admitted **in-flight** by prefilling into free slots of the
live cache — no batch restart, no recompile (prompt lengths bucket to powers
of two).  This closes the batch-utilization gap that arXiv 2407.07304 / the
LIMINAL analysis identify as the dominant decode-throughput lever once
per-token sync cost is minimized.

Arrivals are measured on a virtual clock of *decode steps* so schedules are
deterministic and testable: a request with ``arrival_step=s`` becomes
admissible once ``s`` decode steps have executed.  ``WaveScheduler`` ignores
arrivals (it drains whatever is queued) — it is the pessimistic baseline.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import jax
import numpy as np

from repro.models.common import pad_to
from repro.runtime.engine import Engine


@dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (prompt_len,) or (prompt_len, ncb)
    max_new: int
    eos_id: Optional[int] = None
    arrival_step: int = 0         # virtual-clock arrival (decode steps)
    submitted_at: float = field(default_factory=time.monotonic)
    output: Optional[np.ndarray] = None
    stats: Dict = field(default_factory=dict)


class WaveScheduler:
    def __init__(self, engine: Engine, batch_size: int, pad_id: int = 0):
        self.engine = engine
        self.batch_size = batch_size
        self.pad_id = pad_id
        self.queue: List[Request] = []
        self.done: List[Request] = []
        self._next_id = 0

    def submit(self, prompt: np.ndarray, max_new: int,
               eos_id: Optional[int] = None, arrival_step: int = 0) -> int:
        rid = self._next_id
        self._next_id += 1
        self.queue.append(Request(rid, np.asarray(prompt), max_new, eos_id,
                                  arrival_step))
        return rid

    def _form_wave(self) -> List[Request]:
        wave = self.queue[: self.batch_size]
        self.queue = self.queue[self.batch_size:]
        return wave

    def run(self) -> List[Request]:
        """Drain the queue; returns completed requests in completion order."""
        while self.queue:
            wave = self._form_wave()
            self._run_wave(wave)
        return self.done

    def _run_wave(self, wave: List[Request]) -> None:
        # honest tail sizing: a partial last wave only pays for the rows it
        # needs, padded up to data-parallel divisibility (generate shards the
        # batch over dp), not up to the full configured batch_size
        dp_total = self.engine.ctx.dist.dp * self.engine.ctx.dist.pods
        b = pad_to(max(len(wave), 1), dp_total)
        plen = max(len(r.prompt) for r in wave)
        max_new = max(r.max_new for r in wave)
        ncb = self.engine.cfg.n_codebooks
        shape = (b, plen) if ncb == 1 else (b, plen, ncb)
        prompts = np.full(shape, self.pad_id, dtype=np.int32)
        for i, r in enumerate(wave):
            # left-align; short prompts are right-padded (positions aligned)
            prompts[i, : len(r.prompt)] = r.prompt
        t0 = time.monotonic()
        out = self.engine.generate(prompts, max_new)       # (b, max_new[, ncb])
        dt = time.monotonic() - t0
        cut = []
        for i, r in enumerate(wave):
            toks = out[i, : r.max_new]
            if r.eos_id is not None:
                flat = toks if toks.ndim == 1 else toks[..., 0]
                hits = np.nonzero(flat == r.eos_id)[0]
                if hits.size:
                    toks = toks[: hits[0] + 1]
            cut.append(toks)
        # throughput from tokens actually delivered: EOS-cut, per-request
        # max_new — NOT the padded wave_b * wave_max_new the step loop ran
        emitted = sum(len(t) for t in cut)
        for r, toks in zip(wave, cut):
            r.output = toks
            r.stats = {
                "wave_batch": len(wave),
                "queue_s": t0 - r.submitted_at,
                "wave_s": dt,
                "emitted": len(toks),
                "tok_per_s": emitted / dt if dt > 0 else float("inf"),
            }
            self.done.append(r)


# ---------------------------------------------------------------------------
# Continuous batching
# ---------------------------------------------------------------------------


@dataclass
class _Slot:
    req: Optional[Request] = None
    toks: List = field(default_factory=list)
    admitted_step: int = 0


class ContinuousScheduler:
    """Slot-based continuous batching over ``Engine``'s slot programs.

    The loop per iteration: retire finished slots, admit arrived requests
    into free slots (one bucketed in-flight prefill), then run a fused block
    of up to ``block_steps`` masked decode steps.  Per-request streaming is
    available via ``on_token(rid, token)``.
    """

    def __init__(self, engine: Engine, n_slots: int, pad_id: int = 0,
                 block_steps: int = 8, min_bucket: int = 8,
                 responsive_blocks: bool = False,
                 on_token: Optional[Callable[[int, int], None]] = None):
        if engine.cfg.n_codebooks != 1:
            raise NotImplementedError(
                "ContinuousScheduler serves single-codebook archs "
                "(multi-codebook stays on WaveScheduler for now)")
        self.engine = engine
        self.B = n_slots
        self.pad_id = pad_id
        self.block_steps = block_steps
        self.min_bucket = min_bucket
        self.responsive_blocks = responsive_blocks
        self.on_token = on_token
        # Admission prefill right-pads prompts to a power-of-two bucket.  A
        # sliding-window (local_attn) ring cache keeps only the LAST S
        # tokens of that padded batch, so padding past the window would push
        # real prompt history out of the ring (and the slot-index pad mask
        # cannot repair a ring layout).  Cap prompts and buckets at the
        # window cache length so admission always takes the slot==position
        # write path.
        cfg = engine.cfg
        self.prompt_limit = engine.max_len
        if cfg.window and "local_attn" in cfg.layer_pattern:
            self.prompt_limit = min(self.prompt_limit, cfg.window)
        self.queue: List[Request] = []
        self.done: List[Request] = []
        self._next_id = 0
        self._rng = jax.random.key(engine.seed + 17)
        self._calls = 0
        self.caches = None
        self.slots = [_Slot() for _ in range(n_slots)]
        self.step_count = 0               # virtual clock: decode steps so far
        self.tok = np.zeros((n_slots,), np.int32)
        self.pos = np.zeros((n_slots,), np.int32)
        self.dones = np.ones((n_slots,), bool)
        self.remaining = np.zeros((n_slots,), np.int32)
        self.eos = np.full((n_slots,), -1, np.int32)
        self.stats = {
            "decode_steps": 0, "slot_steps": 0, "active_slot_steps": 0,
            "emitted": 0, "admission_rounds": 0, "in_flight_admissions": 0,
            "prefill_calls": 0,
        }

    # -- submission -------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new: int,
               eos_id: Optional[int] = None, arrival_step: int = 0) -> int:
        prompt = np.asarray(prompt)
        if len(prompt) + max_new > self.engine.max_len:
            raise ValueError(
                f"request needs {len(prompt)}+{max_new} positions > "
                f"max_len {self.engine.max_len}")
        if len(prompt) > self.prompt_limit:
            raise ValueError(
                f"prompt len {len(prompt)} exceeds the sliding-window cache "
                f"({self.prompt_limit}); longer-than-window prompts are not "
                f"admissible in-flight yet — use WaveScheduler")
        if len(prompt) < 2:
            raise ValueError("prompts must have >= 2 tokens")
        rid = self._next_id
        self._next_id += 1
        self.queue.append(Request(rid, prompt, max_new, eos_id, arrival_step))
        return rid

    # -- internals --------------------------------------------------------
    def _next_rng(self):
        self._calls += 1
        return jax.random.fold_in(self._rng, self._calls)

    def _retire(self) -> None:
        now = time.monotonic()
        for i, s in enumerate(self.slots):
            if s.req is not None and self.dones[i]:
                r = s.req
                r.output = np.asarray(s.toks, dtype=np.int32)
                r.stats.update({
                    "emitted": len(s.toks),
                    "finished_at": now,
                    "decode_steps_held": self.step_count - s.admitted_step,
                })
                self.done.append(r)
                self.slots[i] = _Slot()

    def _bucket(self, plen: int) -> int:
        b = self.min_bucket
        while b < plen:
            b *= 2
        return min(b, self.prompt_limit)

    def _admit(self) -> int:
        free = [i for i, s in enumerate(self.slots) if s.req is None]
        arrived = [r for r in self.queue if r.arrival_step <= self.step_count]
        if not free or not arrived:
            return 0
        chosen = arrived[: len(free)]
        for r in chosen:
            self.queue.remove(r)
        in_flight = any(s.req is not None and not self.dones[i]
                        for i, s in enumerate(self.slots))
        Lp = self._bucket(max(len(r.prompt) for r in chosen))
        tokens = np.full((self.B, Lp), self.pad_id, np.int32)
        admit = np.zeros((self.B,), bool)
        plens = np.ones((self.B,), np.int32)
        now = time.monotonic()
        for slot, r in zip(free, chosen):
            tokens[slot, : len(r.prompt)] = r.prompt
            admit[slot] = True
            plens[slot] = len(r.prompt)
            self.slots[slot] = _Slot(req=r, admitted_step=self.step_count)
            r.stats["queue_s"] = now - r.submitted_at
            r.stats["admitted_step"] = self.step_count
        new_tok, self.caches = self.engine.prefill_into_slots(
            self.caches, tokens, admit, plens, self._next_rng())
        new_tok = np.array(new_tok)
        self.tok = np.where(admit, new_tok, self.tok)
        for slot, r in zip(free, chosen):
            t = int(new_tok[slot])
            self.slots[slot].toks.append(t)
            if self.on_token is not None:
                self.on_token(r.rid, t)
            self.pos[slot] = len(r.prompt)
            self.remaining[slot] = r.max_new - 1
            self.eos[slot] = -1 if r.eos_id is None else r.eos_id
            self.dones[slot] = (r.max_new <= 1) or (
                r.eos_id is not None and t == r.eos_id)
            r.stats["ttft_s"] = time.monotonic() - r.submitted_at
            self.stats["emitted"] += 1
        self.stats["admission_rounds"] += 1
        self.stats["prefill_calls"] += 1
        if in_flight:
            self.stats["in_flight_admissions"] += len(chosen)
        return len(chosen)

    def _decode_block(self, n: int) -> None:
        toks, self.caches, pos, done, remaining = self.engine.decode_slots(
            self.caches, self.tok, self.pos, self.dones, self.remaining,
            self.eos, self._next_rng(), n=n)
        toks = np.asarray(toks)                              # (n, B)
        # replay the device's masking rule to tell real emissions from
        # frozen-slot repeats; final state must agree with the device's
        cur_done = self.dones.copy()
        cur_rem = self.remaining.copy()
        for s in range(n):
            for i, slot in enumerate(self.slots):
                if slot.req is None or cur_done[i] or cur_rem[i] <= 0:
                    continue
                t = int(toks[s, i])
                slot.toks.append(t)
                if self.on_token is not None:
                    self.on_token(slot.req.rid, t)
                cur_rem[i] -= 1
                if cur_rem[i] == 0 or (self.eos[i] >= 0 and t == self.eos[i]):
                    cur_done[i] = True
                self.stats["emitted"] += 1
                self.stats["active_slot_steps"] += 1
        self.tok = toks[-1].copy()
        self.pos = np.array(pos)
        self.dones = np.array(done)
        self.remaining = np.array(remaining)
        self.step_count += n
        self.stats["decode_steps"] += n
        self.stats["slot_steps"] += n * self.B

    def _block_size(self) -> int:
        """Fused block size in {1,2,4,...,block_steps}.

        A slot that finishes inside a fused block burns masked steps until
        the block ends: nearly free compute (the batch width is fixed), but
        the freed slot cannot be refilled until the next host turn.  Two
        policies, measured head-to-head on the straggler bench:

        * amortizing (default): stretch to the LONGEST active budget —
          fewest host dispatches; admission waits at most block_steps.
          Wins wall-clock when per-step compute is cheap relative to
          dispatch (this CPU container: 1.6x vs 1.4x over the wave
          baseline).
        * responsive (``responsive_blocks=True``): while arrived requests
          wait, bound by the SHORTEST budget (floored at block_steps/4 to
          cap dispatch thrash) so finished slots refill immediately —
          fewer total decode steps and higher slot utilization (84% vs
          77%, 149 vs 163 steps on the bench); wins when a decode step
          dominates dispatch, i.e. real model scale."""
        active = self.remaining[(~self.dones) & (self.remaining > 0)]
        if active.size == 0:
            return 0
        waiting = any(r.arrival_step <= self.step_count for r in self.queue)
        if self.responsive_blocks and waiting:
            need = max(int(active.min()), max(1, self.block_steps // 4))
        else:
            need = int(active.max())
        n = 1
        while n * 2 <= min(self.block_steps, need):
            n *= 2
        return n

    # -- main loop --------------------------------------------------------
    def run(self) -> List[Request]:
        """Serve until queue and slots drain; returns requests in completion
        order."""
        if self.caches is None:
            self.caches = self.engine.init_slot_caches(self.B)
        while True:
            self._retire()
            self._admit()
            n = self._block_size()
            if n == 0:
                pending = [r.arrival_step for r in self.queue]
                if not pending:
                    break
                # idle: jump the virtual clock to the next arrival
                self.step_count = max(self.step_count, min(pending))
                continue
            self._decode_block(n)
        self._retire()
        return self.done
