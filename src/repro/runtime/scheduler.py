"""Request schedulers over the serving engine.

Two serving cores share one request/stats vocabulary:

``WaveScheduler`` (baseline) — drain-and-restart: forms *waves* of up to
``batch_size`` requests with a shared (padded) prompt length, runs prefill
once and decodes every request to the wave's max ``max_new``.  One straggler
holds the whole wave and finished rows burn full decode FLOPs.

``ContinuousScheduler`` (slot engine) — a fixed-capacity batch of *slots*
with an admit → step → retire loop: decode runs with a per-slot position
vector, finished/empty slots are masked inside the jitted step, and new
requests are admitted **in-flight** by prefilling into free slots of the
live cache — no batch restart, no recompile (prompt lengths bucket to powers
of two).  This closes the batch-utilization gap that arXiv 2407.07304 / the
LIMINAL analysis identify as the dominant decode-throughput lever once
per-token sync cost is minimized.

**Chunked prefill** (``prefill_chunk``): EVERY prompt on an arch whose
capability record supports chunked admission streams through the engine's
fused mixed prefill/decode step — each serving step prefills one
fixed-width chunk per admitting slot AND decodes one token per active slot,
so a long prompt never stalls in-flight decode for more than one chunk of
compute (LIMINAL's point: inter-token latency, not aggregate throughput,
is the binding constraint once batching works), and a short prompt
completes in its first chunk.  The chunked path uses one fixed chunk
shape, so admission compiles exactly once; the pow-2 bucketed single-shot
prefill survives only as the fallback for families whose capability record
blocks chunked admission (recurrent state, modality-prefix frontends,
multi-codebook heads — see ``core/capabilities.py``) or when chunking is
explicitly disabled.  Greedy outputs are bit-identical either way.

Arrivals are measured on a virtual clock of *decode steps* so schedules are
deterministic and testable: a request with ``arrival_step=s`` becomes
admissible once ``s`` decode steps have executed.  ``WaveScheduler`` ignores
arrivals (it drains whatever is queued) — it is the pessimistic baseline.

**Priority classes & overload.**  Every request carries a priority class —
``interactive`` | ``standard`` | ``batch`` — with an optional per-class
per-token SLO target.  Admission orders arrivals by class (stable within a
class, so FIFO and preemption's requeue-at-head survive), a configurable
slot/block quota can be held back for ``interactive``, preemption evicts
the lowest-class-youngest victim, and an optional degradation controller
(``runtime/overload.py``) sheds ``batch`` / suspends spec decode / tightens
the admission window under sustained overload, restoring in reverse with
hysteresis.  Every lever changes *which* requests run and *when* — never
their tokens: admitted survivors' greedy streams stay bit-identical to an
unloaded run.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.models.common import pad_to
from repro.runtime import kvcache
from repro.runtime.engine import Engine
from repro.runtime.faults import (FaultPlan, MigrationFault,
                                  TransientStepError)


def percentile_summary(vals) -> Optional[Dict[str, float]]:
    """The one percentile helper every latency summary uses: linear-
    interpolated percentiles (np.percentile) so p50 is the true median —
    not the upper-median ``vals[n//2]`` shortcut, which disagrees with the
    interpolated p95 two keys later on every even-sized sample.  Returns
    None for an empty sample; a single sample is its own mean/p50/p95/max."""
    v = np.asarray(list(vals), np.float64)
    if v.size == 0:
        return None
    return {
        "mean": float(v.mean()),
        "p50": float(np.percentile(v, 50)),
        "p95": float(np.percentile(v, 95)),
        "max": float(v.max()),
    }


def _tok_scalar(tok) -> int:
    """The token id used for EOS / vocab-range checks: the token itself for
    single-codebook archs, codebook 0 of the frame for multi-codebook ones
    (codebook 0 carries the primary/EOS stream in every config here)."""
    a = np.asarray(tok)
    return int(a if a.ndim == 0 else a.reshape(-1)[0])


# Priority classes, best first.  Rank 0 (interactive) admits first, is
# never shed by the degradation ladder, and is protected by the reserve
# quotas; rank 2 (batch) is shed first and preempted first.
PRIORITY_CLASSES = ("interactive", "standard", "batch")
PRIORITY_RANK = {c: i for i, c in enumerate(PRIORITY_CLASSES)}


def _check_priority(priority: str) -> str:
    if priority not in PRIORITY_RANK:
        raise ValueError(
            f"unknown priority class {priority!r}; expected one of "
            f"{PRIORITY_CLASSES}")
    return priority


@dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (prompt_len,) or (prompt_len, ncb)
    max_new: int
    eos_id: Optional[int] = None
    arrival_step: int = 0         # virtual-clock arrival (decode steps)
    submitted_at: float = field(default_factory=time.monotonic)
    output: Optional[np.ndarray] = None
    stats: Dict = field(default_factory=dict)
    # why the request retired: "stop" (EOS) | "length" (budget) | "error"
    # (quarantined: poisoned output, persistent step failure, failed
    # handoff, pool exhaustion, livelock abort) | "timeout" (deadline)
    finish_reason: Optional[str] = None
    # wall-clock deadline in seconds from submission; the scheduler retires
    # the request with finish_reason "timeout" (keeping tokens emitted so
    # far) once it expires — queued, mid-prefill, or mid-decode alike
    deadline_s: Optional[float] = None
    # priority class (PRIORITY_CLASSES): drives admission order, the
    # interactive reserve quotas, preemption victim choice, and which
    # requests the degradation ladder sheds.  "shed" joins the
    # finish_reason vocabulary: retired at admission under overload,
    # empty output, never held a slot.
    priority: str = "standard"


class WaveScheduler:
    def __init__(self, engine: Engine, batch_size: int, pad_id: int = 0):
        self.engine = engine
        self.batch_size = batch_size
        self.pad_id = pad_id
        self.queue: List[Request] = []
        self.done: List[Request] = []
        self._next_id = 0

    def submit(self, prompt: np.ndarray, max_new: int,
               eos_id: Optional[int] = None, arrival_step: int = 0,
               priority: str = "standard") -> int:
        rid = self._next_id
        self._next_id += 1
        # wave mode records the class for reporting but schedules blind:
        # it is the pessimistic baseline on purpose
        self.queue.append(Request(rid, np.asarray(prompt), max_new, eos_id,
                                  arrival_step,
                                  priority=_check_priority(priority)))
        return rid

    def _form_wave(self) -> List[Request]:
        wave = self.queue[: self.batch_size]
        self.queue = self.queue[self.batch_size:]
        return wave

    def run(self) -> List[Request]:
        """Drain the queue; returns completed requests in completion order."""
        while self.queue:
            wave = self._form_wave()
            self._run_wave(wave)
        return self.done

    def _run_wave(self, wave: List[Request]) -> None:
        # honest tail sizing: a partial last wave only pays for the rows it
        # needs, padded up to data-parallel divisibility (generate shards the
        # batch over dp), not up to the full configured batch_size
        dp_total = self.engine.ctx.dist.dp * self.engine.ctx.dist.pods
        b = pad_to(max(len(wave), 1), dp_total)
        plen = max(len(r.prompt) for r in wave)
        max_new = max(r.max_new for r in wave)
        ncb = self.engine.cfg.n_codebooks
        shape = (b, plen) if ncb == 1 else (b, plen, ncb)
        prompts = np.full(shape, self.pad_id, dtype=np.int32)
        for i, r in enumerate(wave):
            # left-align; short prompts are right-padded (positions aligned)
            prompts[i, : len(r.prompt)] = r.prompt
        t0 = time.monotonic()
        out = self.engine.generate(prompts, max_new)       # (b, max_new[, ncb])
        dt = time.monotonic() - t0
        cut = []
        for i, r in enumerate(wave):
            toks = out[i, : r.max_new]
            if r.eos_id is not None:
                flat = toks if toks.ndim == 1 else toks[..., 0]
                hits = np.nonzero(flat == r.eos_id)[0]
                if hits.size:
                    toks = toks[: hits[0] + 1]
            cut.append(toks)
        # throughput from tokens actually delivered: EOS-cut, per-request
        # max_new — NOT the padded wave_b * wave_max_new the step loop ran
        emitted = sum(len(t) for t in cut)
        for r, toks in zip(wave, cut):
            r.output = toks
            flat = toks if toks.ndim == 1 else toks[..., 0]
            r.finish_reason = ("stop" if (r.eos_id is not None and len(flat)
                                          and flat[-1] == r.eos_id)
                               else "length")
            r.stats = {
                "wave_batch": len(wave),
                "queue_s": t0 - r.submitted_at,
                "wave_s": dt,
                "emitted": len(toks),
                "tok_per_s": emitted / dt if dt > 0 else float("inf"),
            }
            self.done.append(r)


# ---------------------------------------------------------------------------
# Continuous batching
# ---------------------------------------------------------------------------


@dataclass
class _Pending:
    """One dispatched-but-unlanded fused decode block (overlapped loop).

    The token/state outputs stay DEVICE FUTURES until ``_land_next``
    materializes them one step late; the captured host context is what the
    landing replay needs to attribute emissions exactly as the blocking
    loop would have: the slot objects live at dispatch, the per-slot eos
    ids, and the predicted-active mask (a superset of the device's true
    active set — EOS surprises freeze slots earlier than prediction)."""

    toks: object                  # device (n, B) sampled tokens
    pos: object                   # device (B,) post-block positions
    done: object                  # device (B,) post-block done mask
    remaining: object             # device (B,) post-block budgets
    n: int                        # fused steps in this block
    base_step: int                # engine step index of the block's first row
    slots: List                   # slot objects at dispatch (replay targets)
    eos: np.ndarray               # per-slot eos ids at dispatch
    active: np.ndarray            # predicted-active mask at dispatch
    adm_mark: bool                # _admission_mark consumed by this block
    itl_anchor: Optional[float]   # dispatch-time ITL anchor (disagg) or None
    dispatch_t: float = 0.0       # host clock right after dispatch returned


@dataclass
class _Slot:
    req: Optional[Request] = None
    toks: List = field(default_factory=list)
    admitted_step: int = 0
    # chunked admission in progress: absolute offset of the next prefill
    # chunk (None = not chunking), and whether the opening chunk already ran
    # (slot state resets exactly once, on the first chunk)
    chunk_next: Optional[int] = None
    chunk_started: bool = False
    # spec-decode drafting history: preallocated prompt+generated buffer so
    # every verify step appends O(new tokens) instead of re-concatenating
    # the whole history (O(len) per step = quadratic per request)
    hist: Optional[np.ndarray] = None
    hist_len: int = 0


class ContinuousScheduler:
    """Slot-based continuous batching over ``Engine``'s slot programs.

    The loop per iteration: retire finished slots, admit arrived requests
    into free slots (one bucketed in-flight prefill), then run a fused block
    of up to ``block_steps`` masked decode steps.  Per-request streaming is
    available via ``on_token(rid, token)``.
    """

    def __init__(self, engine: Engine, n_slots: int, pad_id: int = 0,
                 block_steps: int = 8, min_bucket: int = 8,
                 responsive_blocks: bool = False,
                 on_token: Optional[Callable[[int, int], None]] = None,
                 prefill_chunk: Optional[int] = None,
                 spec_k: Optional[int] = None,
                 spec_ngram: Optional[int] = None,
                 overlap: Optional[bool] = None,
                 fault_plan: Optional[str] = None,
                 max_step_retries: Optional[int] = None,
                 retry_backoff_s: Optional[float] = None,
                 slo_targets: Optional[Dict[str, float]] = None,
                 reserve_slots: Optional[int] = None,
                 reserve_blocks: Optional[int] = None,
                 overload_opts: Optional[Dict] = None):
        self.engine = engine
        self.B = n_slots
        self.pad_id = pad_id
        self.block_steps = block_steps
        self.min_bucket = min_bucket
        self.responsive_blocks = responsive_blocks
        self.on_token = on_token
        cfg = engine.cfg
        caps = engine.caps
        # multi-codebook archs decode (n_slots, ncb) token frames; codebook
        # 0 carries the EOS/primary stream (see _tok_scalar)
        self.ncb = cfg.n_codebooks
        # modality-prefix archs prepend a fixed encoder prefix: every cache
        # extent / position is offset by it (the engine's slot prefill
        # synthesizes the stub features itself)
        self._prefix = cfg.frontend.prefix_len if cfg.frontend else 0
        # The capability record's max_prompt caps prompts and buckets: a
        # sliding-window (local_attn) ring cache keeps only the LAST window
        # tokens, so padding a bucketed whole-prompt admission past the
        # window would push real prompt history out of the ring.
        self.prompt_limit = engine.max_len
        if caps.max_prompt is not None:
            self.prompt_limit = min(self.prompt_limit, caps.max_prompt)
        self.queue: List[Request] = []
        self.done: List[Request] = []
        self._next_id = 0
        self._rng = jax.random.key(engine.seed + 17)
        self._calls = 0
        self.caches = None
        self.slots = [_Slot() for _ in range(n_slots)]
        self.step_count = 0               # virtual clock: decode steps so far
        self.tok = np.zeros((n_slots,) if self.ncb == 1
                            else (n_slots, self.ncb), np.int32)
        self.pos = np.zeros((n_slots,), np.int32)
        self.dones = np.ones((n_slots,), bool)
        self.remaining = np.zeros((n_slots,), np.int32)
        self.eos = np.full((n_slots,), -1, np.int32)
        self.stats = {
            "decode_steps": 0, "slot_steps": 0, "active_slot_steps": 0,
            "emitted": 0, "admission_rounds": 0, "in_flight_admissions": 0,
            "prefill_calls": 0, "prefill_tokens": 0,
            "prefill_chunks": 0, "chunked_admissions": 0,
            # host/device timing split (both loops): host_blocked_s sums the
            # np.asarray waits (every materialization routes through
            # _materialize), host_overlap_s sums host time spent between a
            # dispatch and its landing — the work the overlapped loop takes
            # off the device critical path
            "host_blocked_s": 0.0, "host_overlap_s": 0.0, "landings": 0,
            "eos_rollbacks": 0, "dispatch_ahead_steps": 0,
            "max_dispatch_ahead": 0, "shed_requests": 0,
            # failure-isolation counters (all loud: request_summary surfaces
            # them whenever any is nonzero)
            "step_faults": 0, "step_retries": 0, "quarantined": 0,
            "timeouts": 0, "aborts_exhaustion": 0, "livelock_aborts": 0,
            "migration_faults": 0,
        }
        # fault tolerance: the injection/watchdog plan (empty spec = every
        # hook compiles to a no-op), bounded retry policy for transient
        # step failures, and the liveness clock the frontend watchdog reads
        par = engine.parallel
        self.faults = FaultPlan.parse(
            fault_plan if fault_plan is not None else par.fault_plan)
        self.max_step_retries = int(
            max_step_retries if max_step_retries is not None
            else par.max_step_retries)
        self.retry_backoff_s = float(
            retry_backoff_s if retry_backoff_s is not None
            else par.retry_backoff_s)
        self._retry_streak = 0            # consecutive failures, same step
        self.vocab = engine.cfg.vocab_size
        self._progress_t = time.monotonic()
        self._has_deadlines = False       # any live request carries one
        # slots force-retired (quarantine/timeout) that the DEVICE still
        # believes are active: landed device done-masks are OR-ed with this
        # so in-flight blocks dispatched before the retirement cannot
        # resurrect the slot; cleared when the slot is reassigned
        self._forced_done = np.zeros((n_slots,), bool)
        if self.faults:
            engine.dispatch_hook = self._fault_dispatch
        # overlapped host/device loop: dispatch block N+1 on block N's
        # device-future outputs, land (np.asarray) one block late.  Host
        # decisions between dispatch and landing run on a PREDICTED state:
        # budget decrements are deterministic, so prediction is exact except
        # when a landed token turns out to be EOS — fixed by a one-step
        # rollback at landing (_land_next).  Greedy streams are
        # bit-identical to the blocking loop: overlap reorders host
        # observation, never device math.
        self.overlap = bool(engine.parallel.overlap_decode
                            if overlap is None else overlap)
        from collections import deque as _dq
        self._pipeline: "_dq[_Pending]" = _dq()
        # exact landed frontier (rolling pre-state for the landing replay)
        self._exact_tok = self._exact_pos = None
        self._exact_dones = self._exact_rem = None
        self._stamp_itl_at_dispatch = False   # disagg overrides (see its doc)
        # frontend hook: called with each Request as it retires
        self.on_finish: Optional[Callable[[Request], None]] = None
        # chunked prefill: EVERY prompt on a chunk-capable arch streams
        # through the fused mixed prefill/decode step — long ones
        # chunk-by-chunk (admission never stalls in-flight decode for more
        # than one chunk of compute), short ones in a single chunk.  One
        # fixed chunk shape = one compiled admission program; blocked
        # families fall back to the legacy bucketed single-shot prefill.
        # Gating is the capability record's: an inherited config default
        # falls back silently, an EXPLICIT constructor request raises the
        # registry's uniform error.
        chunk = (prefill_chunk if prefill_chunk is not None
                 else engine.parallel.prefill_chunk)
        if chunk and not caps.supports("chunked"):
            if prefill_chunk is not None:
                caps.require("chunked")
            chunk = 0
        self.chunk = min(int(chunk), self.prompt_limit) if chunk else 0
        # speculative decoding: an n-gram prompt-lookup drafter proposes
        # spec_k tokens per active slot; one fused verify step (a width
        # spec_k+1 chunk at the decode frontier) scores them all and emits
        # the accepted prefix + one bonus token.  Eligibility is the
        # capability record's ``spec`` path (the verify chunk resumes
        # mid-cache over the slot stripe), gated like chunked prefill:
        # config defaults fall back silently, explicit requests raise.
        sk = spec_k if spec_k is not None else engine.parallel.spec_k
        if sk and not caps.supports("spec"):
            if spec_k is not None:
                caps.require("spec")
            sk = 0
        self.spec_k = max(0, int(sk or 0))
        self.spec_ngram = int(spec_ngram if spec_ngram is not None
                              else engine.parallel.spec_ngram)
        self.drafter = None
        if self.spec_k:
            from repro.runtime.drafter import NgramDrafter
            self.drafter = NgramDrafter(self.spec_k,
                                        ngram_max=self.spec_ngram)
            self.stats.update({
                "spec_steps": 0, "spec_slot_steps": 0, "spec_proposed": 0,
                "spec_accepted": 0, "spec_emitted": 0,
            })
        # decode inter-token latency stream: (seconds/step, during-admission);
        # bounded so a long-lived server doesn't grow host memory per step —
        # summaries cover the most recent window
        from collections import deque
        self._itl: "deque[Tuple[float, bool]]" = deque(maxlen=65536)
        self._last_step_t: Optional[float] = None
        self._admission_mark = False
        # emitted tokens per (engine step, active slot): 1 for plain masked
        # decode, 1..spec_k+1 under speculative decoding
        self._tps: "deque[int]" = deque(maxlen=65536)
        # overload resilience: per-class per-token SLO targets, the
        # interactive reserve quotas (slots here; blocks read by the paged
        # backend), and the graceful-degradation controller.  Constructor
        # args override ParallelConfig; ``overload_opts`` merges over the
        # config-derived controller knobs (and its "enabled" key can turn
        # the controller on for a single scheduler on a shared engine).
        self.slo_targets = {"interactive": par.slo_interactive_s,
                            "standard": par.slo_standard_s,
                            "batch": par.slo_batch_s}
        if slo_targets:
            self.slo_targets.update(slo_targets)
        self.reserve_slots = int(par.interactive_reserve_slots
                                 if reserve_slots is None else reserve_slots)
        self.reserve_blocks = int(par.interactive_reserve_blocks
                                  if reserve_blocks is None
                                  else reserve_blocks)
        opts = {"enabled": par.overload_degrade,
                "queue_hi": par.overload_queue_hi,
                "queue_lo": par.overload_queue_lo,
                "slo_s": float(self.slo_targets.get("interactive") or 0.0),
                "itl_hi": par.overload_itl_hi,
                "itl_lo": par.overload_itl_lo,
                "patience": par.overload_patience,
                "cooldown": par.overload_cooldown}
        opts.update(overload_opts or {})
        self.overload_ctl = None
        if opts.pop("enabled"):
            if opts["queue_hi"] <= 0:
                opts["queue_hi"] = 2 * n_slots
            if opts["queue_lo"] <= 0:
                opts["queue_lo"] = max(1, n_slots // 2)
            opts["queue_lo"] = min(opts["queue_lo"], opts["queue_hi"])
            from repro.runtime.overload import OverloadController
            self.overload_ctl = OverloadController(**opts)
        self.stats["classes"] = {c: {"served": 0, "shed": 0, "timeout": 0,
                                     "error": 0} for c in PRIORITY_CLASSES}
        self.stats.update({"burst_injected": 0, "overload_transitions": 0,
                           "spec_off_rounds": 0})
        # recent landed per-step ITL window the controller reads (wall
        # clock — advisory next to the deterministic queue-depth signal)
        self._itl_recent: "deque[float]" = deque(
            maxlen=(self.overload_ctl.window if self.overload_ctl else 32))

    # -- submission -------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new: int,
               eos_id: Optional[int] = None, arrival_step: int = 0,
               deadline_s: Optional[float] = None,
               priority: str = "standard") -> int:
        prompt = np.asarray(prompt)
        if self._prefix + len(prompt) + max_new > self.engine.max_len:
            raise ValueError(
                f"request needs {self._prefix + len(prompt)}+{max_new} "
                f"positions > max_len {self.engine.max_len}")
        if len(prompt) > self.prompt_limit:
            raise ValueError(
                f"prompt len {len(prompt)} exceeds the sliding-window cache "
                f"({self.prompt_limit}); longer-than-window prompts are not "
                f"admissible in-flight yet — use WaveScheduler")
        if len(prompt) < 2:
            raise ValueError("prompts must have >= 2 tokens")
        rid = self._next_id
        self._next_id += 1
        self.queue.append(Request(rid, prompt, max_new, eos_id, arrival_step,
                                  deadline_s=deadline_s,
                                  priority=_check_priority(priority)))
        if deadline_s is not None:
            self._has_deadlines = True
        return rid

    # -- internals --------------------------------------------------------
    def _next_rng(self):
        self._calls += 1
        return jax.random.fold_in(self._rng, self._calls)

    def _inflight_mask(self) -> Optional[np.ndarray]:
        """Slots with emissions still in flight (covered by an unlanded
        block's predicted-active mask) — they may not retire or be reused
        until their record lands."""
        if not self._pipeline:
            return None
        m = np.zeros((self.B,), bool)
        for rec in self._pipeline:
            m |= rec.active
        return m

    def _finish(self, r: Request) -> None:
        """The single retirement funnel: every path that moves a request to
        ``done`` (retire, quarantine, deadline expiry, admission shed,
        landing abort) routes here so the per-class counters and the
        frontend's ``on_finish`` hook can never drift apart.

        ``finished_step`` stamps retirement on the virtual decode-step
        clock — with arrival_step it gives a latency measure that is
        exactly reproducible run to run (the SLO bench compares scheduling
        policies on it, free of wall-clock noise)."""
        r.stats["finished_step"] = self.step_count
        buckets = self.stats["classes"].setdefault(
            r.priority, {"served": 0, "shed": 0, "timeout": 0, "error": 0})
        fr = r.finish_reason or "length"
        buckets["served" if fr in ("stop", "length")
                else fr if fr in ("shed", "timeout") else "error"] += 1
        self.done.append(r)
        if self.on_finish is not None:
            self.on_finish(r)

    def _shed_request(self, r: Request) -> None:
        """Admission-time load shed (degradation lever): the queued request
        retires immediately with finish_reason "shed" and an empty output —
        it never held a slot, so no stream or pool state is touched."""
        r.output = np.zeros((0,), np.int32)
        r.finish_reason = "shed"
        r.stats.update({"emitted": 0, "finished_at": time.monotonic()})
        self.stats["shed_requests"] += 1
        self._finish(r)

    def _retire(self) -> None:
        now = time.monotonic()
        infl = self._inflight_mask()
        for i, s in enumerate(self.slots):
            # mid-prefill slots ride with done=True (decode freezes them)
            # but are NOT finished — their chunks are still streaming in;
            # under overlap, a done slot with unlanded emissions waits for
            # its record to land (the tail tokens aren't host-visible yet)
            if (s.req is not None and self.dones[i] and s.chunk_next is None
                    and (infl is None or not infl[i])):
                r = s.req
                r.output = np.asarray(s.toks, dtype=np.int32)
                if r.finish_reason is None:
                    r.finish_reason = (
                        "stop" if (r.eos_id is not None and s.toks
                                   and _tok_scalar(s.toks[-1]) == r.eos_id)
                        else "length")
                r.stats.update({
                    "emitted": len(s.toks),
                    "finished_at": now,
                    "decode_steps_held": self.step_count - s.admitted_step,
                })
                self.slots[i] = _Slot()
                self._finish(r)

    def _bucket(self, plen: int) -> int:
        """Pow-2 prompt bucket — whole-prompt admission only (``self.chunk
        == 0``: the arch's capability record blocks chunked admission, or
        chunking is explicitly disabled).  Chunk-capable archs admit every
        prompt — short ones included — through the fixed-width mixed step,
        which compiles exactly once; each distinct bucket width here is a
        separate XLA compilation, the recompile cost this path is gated
        for."""
        assert self.chunk == 0, "bucketed admission is fallback-arch only"
        b = self.min_bucket
        while b < plen:
            b *= 2
        return min(b, self.prompt_limit)

    def _free_slots(self) -> List[int]:
        """Slots admission may fill (the disagg scheduler restricts this to
        the prefill pool; landings fill decode-pool slots directly)."""
        return [i for i, s in enumerate(self.slots) if s.req is None]

    def _admissible(self) -> List[Request]:
        """Arrived queue entries in class-aware order.  Classes the
        degradation ladder is shedding retire immediately (finish_reason
        "shed"); the rest sort STABLY by priority rank — interactive first
        — so FIFO order (and preemption's requeue-at-head) is preserved
        within each class."""
        arrived = [r for r in self.queue if r.arrival_step <= self.step_count]
        ctl = self.overload_ctl
        if ctl is not None and ctl.shed_classes and arrived:
            for r in [r for r in arrived if r.priority in ctl.shed_classes]:
                self.queue.remove(r)
                self._shed_request(r)
            arrived = [r for r in arrived
                       if r.priority not in ctl.shed_classes]
        arrived.sort(key=lambda r: PRIORITY_RANK[r.priority])
        return arrived

    def _admission_quota(self, n_free: int) -> int:
        """Admissions allowed this round: the free-slot count, tightened to
        the degradation ladder's cap on CONCURRENT admissions (counting
        slots still mid-chunk-prefill) at tight-admission."""
        ctl = self.overload_ctl
        if ctl is not None and ctl.admission_cap is not None:
            n_free = min(n_free,
                         max(0, ctl.admission_cap - len(self._prefilling())))
        return n_free

    def _admit(self) -> int:
        free = self._free_slots()
        arrived = self._admissible()
        if not free or not arrived:
            return 0
        # class-ordered selection; non-interactive requests may not eat
        # into the interactive slot reserve.  Arrivals are class-sorted, so
        # the first refusal ends the scan (everything after is the same
        # class or lower — no reordering under pressure beyond class rank).
        quota = self._admission_quota(len(free))
        chosen: List[Request] = []
        left = len(free)
        for r in arrived:
            if len(chosen) >= quota:
                break
            if r.priority != "interactive" and left <= self.reserve_slots:
                break
            chosen.append(r)
            left -= 1
        if not chosen:
            return 0
        for r in chosen:
            self.queue.remove(r)
        in_flight = any(s.req is not None
                        and (not self.dones[i] or s.chunk_next is not None)
                        for i, s in enumerate(self.slots))
        now = time.monotonic()
        short = []
        for slot, r in zip(free, chosen):
            self.slots[slot] = _Slot(req=r, admitted_step=self.step_count)
            self._forced_done[slot] = False
            r.stats["queue_s"] = now - r.submitted_at
            r.stats["admitted_step"] = self.step_count
            if self.chunk:
                # ALL chunk-eligible prompts stream through the fused mixed
                # step: long prompts chunk-by-chunk (decode never waits for
                # the whole prompt), short prompts in a single chunk — the
                # mixed program's width is fixed, so admission compiles
                # exactly once, with no pow-2 prompt buckets at all
                self.slots[slot].chunk_next = 0
                self.dones[slot] = True
                self.remaining[slot] = 0
                if len(r.prompt) > self.chunk:
                    self.stats["chunked_admissions"] += 1
            else:
                short.append((slot, r))
        self.stats["admission_rounds"] += 1
        if in_flight:
            self.stats["in_flight_admissions"] += len(chosen)
        if short:
            self._prefill_short(short)
        return len(chosen)

    def _prefill_short(self, pairs) -> None:
        """Legacy single-shot admission for prompts within the chunk budget
        (and for fallback archs): one bucketed full-width prefill."""
        Lp = self._bucket(max(len(r.prompt) for _, r in pairs))
        shape = (self.B, Lp) if self.ncb == 1 else (self.B, Lp, self.ncb)
        tokens = np.full(shape, self.pad_id, np.int32)
        admit = np.zeros((self.B,), bool)
        plens = np.ones((self.B,), np.int32)
        for slot, r in pairs:
            tokens[slot, : len(r.prompt)] = r.prompt
            admit[slot] = True
            plens[slot] = len(r.prompt)
        new_tok, self.caches = self.engine.prefill_into_slots(
            self.caches, tokens, admit, plens, self._next_rng())
        self.stats["prefill_tokens"] += int(plens[admit].sum())
        self.stats["prefill_calls"] += 1
        self._admission_mark = True
        self._finish_admission([s for s, _ in pairs], [r for _, r in pairs],
                               admit, np.array(new_tok))

    def _finish_admission(self, free, chosen, admit, new_tok) -> None:
        """Shared post-prefill host bookkeeping (dense, paged, chunked):
        record each finishing request's first emitted token and arm its
        decode state.  ``ttft_s`` is stamped HERE — under chunked admission
        that is the step whose chunk completed the prompt, so TTFT reflects
        the first token actually *emitted*, not slot assignment."""
        adm = admit if new_tok.ndim == 1 else admit[:, None]
        self.tok = np.where(adm, new_tok, self.tok)
        for slot, r in zip(free, chosen):
            t = _tok_scalar(new_tok[slot])
            if not 0 <= t < self.vocab:
                # poisoned prefill output (the int32 image of non-finite
                # logits): quarantine before the garbage id reaches the
                # stream — the first decode dispatch masks the slot out
                self._quarantine_slot(
                    slot, "error", f"poisoned prefill token {t}")
                continue
            self.slots[slot].toks.append(
                t if self.ncb == 1
                else np.asarray(new_tok[slot], np.int32).copy())
            if self.on_token is not None:
                self.on_token(r.rid, t)
            self.pos[slot] = len(r.prompt) + self._prefix
            self.remaining[slot] = r.max_new - 1
            self.eos[slot] = -1 if r.eos_id is None else r.eos_id
            self.dones[slot] = (r.max_new <= 1) or (
                r.eos_id is not None and t == r.eos_id)
            r.stats["ttft_s"] = time.monotonic() - r.submitted_at
            self.stats["emitted"] += 1

    def _decode_inputs(self):
        """Decode-state inputs for the next engine dispatch: the newest
        unlanded block's device-future outputs when the pipeline is
        non-empty (exact by construction — the device chains its own
        masking), host arrays otherwise."""
        if self._pipeline:
            rec = self._pipeline[-1]
            return rec.toks[-1], rec.pos, rec.done, rec.remaining
        return self.tok, self.pos, self.dones, self.remaining

    def _materialize(self, *arrs):
        """np.asarray with the wait accounted to ``host_blocked_s`` — the
        single choke point both loops materialize through, so the bench's
        blocked-time comparison is honest."""
        t0 = time.monotonic()
        out = [np.asarray(a) for a in arrs]
        now = time.monotonic()
        self.stats["host_blocked_s"] += now - t0
        # liveness: engine outputs just became host-visible — the watchdog
        # signal /health reports (a wedged device stops advancing this)
        self._progress_t = now
        return out[0] if len(out) == 1 else out

    def _run_decode(self, n: int):
        """Engine dispatch for one fused block (overridden by the paged
        backend to thread block tables)."""
        tok, pos, dones, remaining = self._decode_inputs()
        return self.engine.decode_slots(
            self.caches, tok, pos, dones, remaining,
            self.eos, self._next_rng(), n=n)

    def _ensure_capacity(self, n: int) -> None:
        """Pre-decode capacity hook (paged backend: block allocation)."""

    def _decode_block(self, n: int) -> None:
        self._ensure_capacity(n)
        toks, self.caches, pos, done, remaining = self._run_decode(n)
        self._apply_decode(self._materialize(toks), pos, done, remaining, n)

    # -- overlapped loop (dispatch-ahead + one-step-late landing) -----------
    def _dispatch_block(self, n: int) -> None:
        """Dispatch one fused decode block WITHOUT landing it: outputs stay
        device futures in a ``_Pending`` record, and the host state arrays
        advance on a prediction (budget decrements are exact; a landed EOS
        is the only surprise, rolled back at ``_land_next``).  The virtual
        clock advances at dispatch so arrival admissibility matches the
        blocking loop decision-for-decision."""
        self._ensure_capacity(n)
        active = (~self.dones) & (self.remaining > 0)
        if not self._pipeline:
            # pipeline was drained: the host arrays ARE the exact frontier
            self._exact_tok = self.tok.copy()
            self._exact_pos = self.pos.copy()
            self._exact_dones = self.dones.copy()
            self._exact_rem = self.remaining.copy()
        toks, self.caches, pos, done, remaining = self._run_decode(n)
        self._pipeline.append(_Pending(
            toks=toks, pos=pos, done=done, remaining=remaining, n=n,
            base_step=self.step_count,
            slots=list(self.slots), eos=self.eos.copy(), active=active,
            adm_mark=self._admission_mark,
            itl_anchor=(self._last_step_t if self._stamp_itl_at_dispatch
                        else None),
            dispatch_t=time.monotonic()))
        self._admission_mark = False
        # predicted frontier: EOS-blind replay of the device's masking
        steps = np.where(active, np.minimum(n, self.remaining), 0)
        self.pos = (self.pos + steps).astype(np.int32)
        self.remaining = (self.remaining - steps).astype(np.int32)
        self.dones = self.dones | (self.remaining <= 0)
        self.step_count += n
        self.stats["decode_steps"] += n
        self.stats["slot_steps"] += n * self.B
        depth = len(self._pipeline)
        if depth > 1:
            self.stats["dispatch_ahead_steps"] += n
        self.stats["max_dispatch_ahead"] = max(
            self.stats["max_dispatch_ahead"], depth)

    def _land_next(self) -> None:
        """Materialize the OLDEST unlanded block and run its host
        bookkeeping: replay emissions exactly as the blocking loop's
        ``_apply_decode`` (same appends, same on_token order, same stats),
        stamp ITL at host-visibility, then reconcile the predicted state —
        slots the device froze early (EOS) are rolled back in the predicted
        arrays so later admission/capacity decisions see the truth."""
        if not self._pipeline:
            return
        rec = self._pipeline.popleft()
        t0 = time.monotonic()
        self.stats["host_overlap_s"] += t0 - rec.dispatch_t
        toks, pos, done, remaining = self._materialize(
            rec.toks, rec.pos, rec.done, rec.remaining)
        self.stats["landings"] += 1
        if self.faults:
            toks = self.faults.corrupt_tokens(
                toks, rec.base_step,
                active=(np.array([s.req is not None for s in rec.slots])
                        & ~self._exact_dones & (self._exact_rem > 0)))
        # exact emission replay off the rolling landed pre-state
        cur_done = self._exact_dones.copy()
        cur_rem = self._exact_rem.copy()
        emitted_block = 0
        poisoned: Dict[int, int] = {}
        for s in range(rec.n):
            for i, slot in enumerate(rec.slots):
                if slot.req is None or cur_done[i] or cur_rem[i] <= 0:
                    continue
                t = _tok_scalar(toks[s, i])
                if not 0 <= t < self.vocab:
                    # poisoned step output: freeze the slot NOW so no later
                    # token from this block reaches its stream; quarantine
                    # below, after the exact frontier is adopted
                    poisoned[i] = t
                    cur_done[i] = True
                    continue
                slot.toks.append(
                    t if self.ncb == 1
                    else np.asarray(toks[s, i], np.int32).copy())
                if self.on_token is not None:
                    self.on_token(slot.req.rid, t)
                cur_rem[i] -= 1
                if cur_rem[i] == 0 or (rec.eos[i] >= 0 and t == rec.eos[i]):
                    cur_done[i] = True
                self.stats["emitted"] += 1
                self.stats["active_slot_steps"] += 1
                self._tps.append(1)
                emitted_block += 1
        # the landed arrays are the exact post-block frontier.  The device
        # never learns about host-forced retirements (quarantine/timeout),
        # so its done-mask is OR-ed with the forced set — otherwise a block
        # dispatched before the retirement would resurrect the dead slot.
        self._exact_tok = toks[-1].copy()
        self._exact_pos = np.array(pos)
        self._exact_dones = np.array(done) | self._forced_done
        self._exact_rem = np.where(self._forced_done, 0,
                                   np.array(remaining)).astype(np.int32)
        # one-step rollback: prediction thought these slots were still
        # decoding, but a landed token was EOS — adopt the frozen truth so
        # retire/admission/capacity decisions stop overshooting
        fix = self._exact_dones & ~self.dones
        if fix.any():
            self.stats["eos_rollbacks"] += int(fix.sum())
            self.dones = self.dones | fix
            self.remaining = np.where(fix, self._exact_rem,
                                      self.remaining).astype(np.int32)
            self.pos = np.where(fix, self._exact_pos,
                                self.pos).astype(np.int32)
        if not self._pipeline:
            # fully landed: predicted == exact (incl. the token frontier)
            self.tok = self._exact_tok.copy()
            self.pos = self._exact_pos.copy()
            self.dones = self._exact_dones.copy()
            self.remaining = self._exact_rem.copy()
        # ITL stamps at host-visibility (satellite: never at dispatch);
        # disagg anchors the sample at its own dispatch so the sample stays
        # the decode dispatch's duration (see DisaggScheduler docstring)
        if rec.itl_anchor is not None:
            self._last_step_t = rec.itl_anchor
        self._admission_mark = rec.adm_mark
        self._note_itl(rec.n, emissions=emitted_block)
        for i, t in poisoned.items():
            self._quarantine_slot(
                i, "error", f"poisoned step output (token {t})")
        # retire replays in LANDED-BLOCK order, mirroring the blocking
        # loop's after-every-block retire scan: a request whose final block
        # just landed retires here (its rows are inactive in every still-
        # unlanded record when predictions were exact), so sync and overlap
        # retire requests in the same order, not batched up at round tops
        self._retire()

    def _drain_pipeline(self) -> None:
        """Land every unlanded block (host state becomes exact).  Called
        before any host decision that must merge exact values into the
        engine state: admission, mixed/chunk steps, spec drafting,
        migrations, preemption."""
        while self._pipeline:
            self._land_next()

    def _apply_decode(self, toks, pos, done, remaining, n: int) -> None:
        """Host bookkeeping for ``n`` executed decode steps (toks (n, B)):
        replay the device's masking rule to tell real emissions from
        frozen-slot repeats; final state must agree with the device's."""
        if self.faults:
            toks = self.faults.corrupt_tokens(
                toks, self.step_count,
                active=(np.array([s.req is not None for s in self.slots])
                        & ~self.dones & (self.remaining > 0)))
        cur_done = self.dones.copy()
        cur_rem = self.remaining.copy()
        emitted_block = 0
        poisoned: Dict[int, int] = {}
        for s in range(n):
            for i, slot in enumerate(self.slots):
                if slot.req is None or cur_done[i] or cur_rem[i] <= 0:
                    continue
                t = _tok_scalar(toks[s, i])
                if not 0 <= t < self.vocab:
                    poisoned[i] = t
                    cur_done[i] = True
                    continue
                slot.toks.append(
                    t if self.ncb == 1
                    else np.asarray(toks[s, i], np.int32).copy())
                if self.on_token is not None:
                    self.on_token(slot.req.rid, t)
                cur_rem[i] -= 1
                if cur_rem[i] == 0 or (self.eos[i] >= 0 and t == self.eos[i]):
                    cur_done[i] = True
                self.stats["emitted"] += 1
                self.stats["active_slot_steps"] += 1
                self._tps.append(1)
                emitted_block += 1
        self.tok = toks[-1].copy()
        self.pos = np.array(pos)
        self.dones = np.array(done)
        self.remaining = np.array(remaining)
        self.step_count += n
        self.stats["decode_steps"] += n
        self.stats["slot_steps"] += n * self.B
        self._note_itl(n, emissions=emitted_block)
        for i, t in poisoned.items():
            self._quarantine_slot(
                i, "error", f"poisoned step output (token {t})")

    def _note_itl(self, n: int, emissions: Optional[int] = None,
                  tokens_per_slot: Optional[List[int]] = None) -> None:
        """Record decode inter-token latency: ONE sample per emitted token,
        not per engine step, so plain and speculative runs weight the
        distribution identically.  Plain masked decode: every token in a
        fused block of ``n`` steps (``emissions`` of them) experienced the
        block's uniform per-step share (host timing cannot see inside the
        block).  A speculative verify step emits a variable run per slot
        (``tokens_per_slot``): a slot that emitted e tokens in a T-second
        step experienced per-token latency T/e, so it contributes e samples
        of T/e — without this, multi-token steps would overstate ITL by the
        acceptance factor.  Samples whose interval spans admission work (a
        whole-prompt prefill call since the previous decode step, or a
        mixed chunk step) are tagged as admission-window samples — the
        population whose p95 chunked prefill exists to flatten."""
        now = time.monotonic()
        if self._last_step_t is not None:
            dt = (now - self._last_step_t) / n
            self._itl_recent.append(dt)
            if tokens_per_slot is None:
                m = n if emissions is None else emissions
                self._itl.extend([(dt, self._admission_mark)] * m)
            else:
                for e in tokens_per_slot:
                    if e > 0:
                        self._itl.extend([(dt / e, self._admission_mark)] * e)
        self._last_step_t = now
        self._admission_mark = False

    # -- failure isolation (quarantine, bounded retry, deadlines) -----------
    def liveness_age(self) -> float:
        """Seconds since engine outputs last became host-visible — the
        scheduler-watchdog signal the frontend's /health surfaces so a load
        balancer can eject a wedged node."""
        return time.monotonic() - self._progress_t

    def _release_slot(self, i: int) -> None:
        """Backend storage release for slot ``i`` (paged: blocks/refcounts;
        disagg: queued copies unpinned, destination blocks returned).  The
        dense engine owns nothing per slot."""

    def _quarantine_slot(self, i: int, finish_reason: str = "error",
                         error: Optional[str] = None) -> None:
        """Retire slot ``i``'s request IMMEDIATELY with a failure
        finish_reason, releasing everything it holds, without touching any
        other slot's stream.  Safe while blocks are still in flight: the
        forced-done mask keeps landed device state from resurrecting the
        slot, and already-dispatched programs reading its freed blocks are
        harmless — the device executes in dispatch order, so those reads
        complete before any later program could overwrite them."""
        s = self.slots[i]
        if s.req is None:
            return
        r = s.req
        self._release_slot(i)
        r.output = np.asarray(s.toks, dtype=np.int32)
        r.finish_reason = finish_reason
        if error is not None:
            r.stats["error"] = error
        r.stats.update({
            "emitted": len(s.toks),
            "finished_at": time.monotonic(),
            "decode_steps_held": self.step_count - s.admitted_step,
        })
        self.slots[i] = _Slot()
        self.tok[i] = 0
        self.dones[i] = True
        self.remaining[i] = 0
        self._forced_done[i] = True
        if self._pipeline and self._exact_dones is not None:
            self._exact_tok[i] = 0
            self._exact_dones[i] = True
            self._exact_rem[i] = 0
        self.stats["timeouts" if finish_reason == "timeout"
                   else "quarantined"] += 1
        if self.faults:
            self.faults.on_quarantine(i)
        self._finish(r)

    def _fault_dispatch(self) -> None:
        """Installed as ``Engine.dispatch_hook`` when a fault plan is
        active: consulted immediately before every retry-safe step dispatch
        (the only boundary where a raise leaves the donated cache chain
        untouched — see ``runtime/faults.py``)."""
        self.faults.on_dispatch(self.step_count)

    def _try_step(self, fn):
        """Run one engine-step thunk under the bounded-retry fault policy.

        A :class:`TransientStepError` at the dispatch boundary consumed no
        state: the step's rng draw is rolled back (``_next_rng`` evaluates
        as a call argument, before the engine method's hook runs), the
        pipeline is drained to the exact landed frontier, and the round
        simply ends — the next round re-issues the identical work, so the
        replay is bit-exact by construction.  When retries exhaust, a
        slot-attributed failure quarantines that request; an unattributed
        one propagates (honestly fatal)."""
        calls0 = self._calls
        try:
            out = fn()
        except TransientStepError as e:
            self._calls = calls0
            self._recover_step_fault(e)
            return None
        self._retry_streak = 0
        return out

    def _recover_step_fault(self, e: TransientStepError) -> None:
        self._drain_pipeline()
        self.stats["step_faults"] += 1
        self._retry_streak += 1
        if self._retry_streak <= self.max_step_retries:
            self.stats["step_retries"] += 1
            backoff = self.retry_backoff_s * (2 ** (self._retry_streak - 1))
            if backoff > 0:
                time.sleep(backoff)
            return
        self._retry_streak = 0
        slot = e.slot
        if slot is not None and self.slots[slot].req is not None:
            self._quarantine_slot(slot, "error",
                                  f"persistent step failure: {e}")
            return
        raise e

    def _expire_deadlines(self) -> None:
        """Retire every request whose wall-clock deadline passed —
        queued (never admitted: empty output) or slot-resident (keeps the
        tokens emitted so far) — with finish_reason "timeout".  No pipeline
        drain needed: the forced-done mask drops the victim's unlanded
        emissions at landing."""
        now = time.monotonic()

        def late(r: Request) -> bool:
            return (r.deadline_s is not None
                    and now - r.submitted_at >= r.deadline_s)

        for r in [r for r in self.queue if late(r)]:
            self.queue.remove(r)
            r.output = np.zeros((0,), np.int32)
            r.finish_reason = "timeout"
            r.stats.update({"emitted": 0, "finished_at": now})
            self.stats["timeouts"] += 1
            self._finish(r)
        for i, s in enumerate(self.slots):
            if s.req is not None and late(s.req):
                self._quarantine_slot(i, "timeout")

    # -- overload resilience (burst injection + degradation ladder) ---------
    def _inject_bursts(self) -> None:
        """Submit the fault plan's due ``burst:`` clauses: deterministic
        synthetic load (prompts seeded by the scheduled fire step) arriving
        NOW on the virtual clock — the reproducible overload wave the
        degradation tests ride."""
        for count, plen, max_new, cls, fire_step in \
                self.faults.burst(self.step_count):
            rng = np.random.default_rng(0xB0057 + fire_step)
            plen = min(plen, self.prompt_limit,
                       max(2, self.engine.max_len - max_new))
            for _ in range(count):
                self.submit(rng.integers(0, self.vocab, plen,
                                         dtype=np.int32),
                            max_new, arrival_step=self.step_count,
                            priority=cls)
                self.stats["burst_injected"] += 1

    def _overload_observe(self) -> None:
        """One controller observation per round: arrived-queue depth (the
        deterministic primary signal) plus the recent landed per-step ITL
        window (advisory, SLO-scaled)."""
        ctl = self.overload_ctl
        depth = sum(1 for r in self.queue
                    if r.arrival_step <= self.step_count)
        recent = (float(np.mean(self._itl_recent))
                  if self._itl_recent else None)
        before = ctl.level
        ctl.observe(depth, recent)
        if ctl.level != before:
            self.stats["overload_transitions"] += 1

    def _round_prologue(self) -> None:
        """Shared head of every serving round (unified and disagg): burst
        injection, deadline expiry, then one degradation-controller
        observation."""
        if self.faults:
            self._inject_bursts()
        if self._has_deadlines:
            self._expire_deadlines()
        if self.overload_ctl is not None:
            self._overload_observe()

    def _spec_suspended(self) -> bool:
        """True while the degradation ladder has turned spec decode off
        (level 2+).  Safe lever: greedy spec decode is token-identical to
        plain decode, so suspension changes speed, never streams."""
        ctl = self.overload_ctl
        if ctl is not None and ctl.spec_off:
            self.stats["spec_off_rounds"] += 1
            return True
        return False

    def overload_level(self) -> int:
        """Current degradation-ladder level (0 = normal / controller off);
        the frontend's ``/health`` surfaces this."""
        return 0 if self.overload_ctl is None else self.overload_ctl.level

    # -- speculative decoding (fused multi-token verify steps) -------------
    def _active_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots)
                if s.req is not None and not self.dones[i]
                and self.remaining[i] > 0]

    def _slot_history(self, i: int) -> np.ndarray:
        """The slot's prompt+generated token history for the drafter,
        maintained incrementally: the prompt copies in once, emitted tokens
        (spec runs AND mixed-step decode emissions) append as they land."""
        s = self.slots[i]
        plen = len(s.req.prompt)
        if s.hist is None:
            s.hist = np.empty(plen + s.req.max_new + 1, np.int32)
            s.hist[:plen] = np.asarray(s.req.prompt, np.int32).ravel()
            s.hist_len = plen
        total = plen + len(s.toks)
        if s.hist_len < total:               # catch up on new emissions
            s.hist[s.hist_len:total] = s.toks[s.hist_len - plen:]
            s.hist_len = total
        return s.hist[:s.hist_len]

    def _ensure_spec_capacity(self) -> None:
        """Pre-verify capacity hook (paged: blocks for spec_k+1 writes)."""

    def _run_verify(self, vtok):
        return self.engine.verify_slots(
            self.caches, vtok, self.pos, self.dones, self.remaining,
            self.eos, self._next_rng())

    def _post_verify(self, active: List[int]) -> None:
        """Post-verify hook (paged: truncate block tables past the rewound
        frontier so resident memory tracks accepted tokens, not drafts)."""

    def _spec_step(self) -> None:
        """One speculative serving step: draft spec_k tokens per active slot
        from its own history (host n-gram lookup), verify all of them plus
        the bonus position in ONE fused forward, emit each slot's accepted
        run.  Every step emits at least one token per active slot (the
        zero-acceptance floor is exactly plain decode), at most spec_k+1.

        Unlike plain decode, verify steps are not fused into multi-step
        blocks: each step's drafts depend on the tokens the previous step
        emitted, so the drafter sits on the host between steps (block_steps
        does not apply while spec decode is on)."""
        K = self.spec_k
        self._ensure_spec_capacity()       # may preempt: collect slots AFTER
        active = self._active_slots()
        if not active:
            return
        vtok = np.zeros((self.B, K + 1), np.int32)
        vtok[:, 0] = self.tok
        histories = [self._slot_history(i) for i in active]
        if hasattr(self.drafter, "propose_many"):
            vtok[active, 1:] = self.drafter.propose_many(histories)
        else:                     # per-slot drafters (the pre-batch API)
            vtok[active, 1:] = np.stack(
                [self.drafter.propose(h) for h in histories])
        targets, n_emit, nxt, self.caches, pos, done, remaining = \
            self._run_verify(vtok)
        targets, n_emit = self._materialize(targets, n_emit)
        counts = []
        for i in active:
            e = int(n_emit[i])
            slot = self.slots[i]
            for t in targets[i, :e].tolist():
                slot.toks.append(int(t))
                if self.on_token is not None:
                    self.on_token(slot.req.rid, int(t))
            counts.append(e)
            self._tps.append(e)
            # acceptance counts drafts the model VERIFIED correct (leading
            # match run), independent of EOS/budget cuts to the emitted
            # run — otherwise short-budget slots would bias the rate low
            match = vtok[i, 1:] == targets[i, :-1]
            acc = int(np.cumprod(match).sum())
            self.stats["emitted"] += e
            self.stats["active_slot_steps"] += 1
            self.stats["spec_slot_steps"] += 1
            self.stats["spec_emitted"] += e
            self.stats["spec_accepted"] += acc
        self.tok = np.asarray(nxt).copy()
        self.pos = np.array(pos)
        self.dones = np.array(done)
        self.remaining = np.array(remaining)
        self.step_count += 1
        self.stats["decode_steps"] += 1
        self.stats["slot_steps"] += self.B
        self.stats["spec_steps"] += 1
        self.stats["spec_proposed"] += K * len(active)
        self._note_itl(1, tokens_per_slot=counts)
        self._post_verify(active)

    # -- chunked admission (fused mixed prefill/decode steps) --------------
    def _prefilling(self) -> List[int]:
        return [i for i, s in enumerate(self.slots)
                if s.req is not None and s.chunk_next is not None]

    def _pre_mixed(self) -> None:
        """Pre-step capacity hook (paged: decode block coverage)."""

    def _run_mixed(self, tokens, admit, first, clens, starts, totals):
        return self.engine.mixed_step(
            self.caches, tokens, admit, first, clens, starts, totals,
            self.tok, self.pos, self.dones, self.remaining, self.eos,
            self._next_rng())

    def _post_chunks(self, slots_p: List[int]) -> None:
        """Hook after each chunk lands (paged: publish completed prefix
        blocks incrementally)."""

    def _mixed_step(self) -> None:
        """One fused chunked-admission step: every mid-prefill slot advances
        one fixed-width chunk while all decode-active slots decode one token
        — in the same jitted program, so long-prompt admission costs decode
        at most one chunk of extra latency per token."""
        C = self.chunk
        self._pre_mixed()                  # may preempt: assemble AFTER
        slots_p = self._prefilling()
        if not slots_p:
            # capacity pressure preempted every prefilling slot — nothing to
            # chunk this turn; the main loop falls through to plain decode
            return
        tokens = np.full((self.B, C), self.pad_id, np.int32)
        admit = np.zeros((self.B,), bool)
        first = np.zeros((self.B,), bool)
        clens = np.ones((self.B,), np.int32)
        starts = np.zeros((self.B,), np.int32)
        totals = np.ones((self.B,), np.int32)
        emits = []
        for i in slots_p:
            s = self.slots[i]
            off = s.chunk_next
            plen = len(s.req.prompt)
            nc = min(C, plen - off)
            tokens[i, :nc] = s.req.prompt[off:off + nc]
            admit[i] = True
            first[i] = not s.chunk_started
            clens[i] = nc
            starts[i] = off
            totals[i] = off + nc
            if off + nc == plen:
                emits.append(i)
        ptok, toks, self.caches, pos, done, remaining = self._run_mixed(
            tokens, admit, first, clens, starts, totals)
        self._admission_mark = True        # this step carried prefill work
        self._apply_decode(self._materialize(toks)[None], pos, done,
                           remaining, 1)
        for i in slots_p:
            s = self.slots[i]
            s.chunk_started = True
            s.chunk_next += int(clens[i])
            self.stats["prefill_tokens"] += int(clens[i])
            self.stats["prefill_chunks"] += 1
        self.stats["prefill_calls"] += 1
        self._post_chunks(slots_p)
        if emits:
            adm = np.zeros((self.B,), bool)
            adm[emits] = True
            self._finish_admission(emits, [self.slots[i].req for i in emits],
                                   adm, np.array(ptok))
            for i in emits:
                self.slots[i].chunk_next = None

    def _block_size(self) -> int:
        """Fused block size in {1,2,4,...,block_steps}.

        A slot that finishes inside a fused block burns masked steps until
        the block ends: nearly free compute (the batch width is fixed), but
        the freed slot cannot be refilled until the next host turn.  Two
        policies, measured head-to-head on the straggler bench:

        * amortizing (default): stretch to the LONGEST active budget —
          fewest host dispatches; admission waits at most block_steps.
          Wins wall-clock when per-step compute is cheap relative to
          dispatch (this CPU container: 1.6x vs 1.4x over the wave
          baseline).
        * responsive (``responsive_blocks=True``): while arrived requests
          wait, bound by the SHORTEST budget (floored at block_steps/4 to
          cap dispatch thrash) so finished slots refill immediately —
          fewer total decode steps and higher slot utilization (84% vs
          77%, 149 vs 163 steps on the bench); wins when a decode step
          dominates dispatch, i.e. real model scale."""
        active = self.remaining[(~self.dones) & (self.remaining > 0)]
        if active.size == 0:
            return 0
        waiting = any(r.arrival_step <= self.step_count for r in self.queue)
        if self.responsive_blocks and waiting:
            need = max(int(active.min()), max(1, self.block_steps // 4))
        else:
            need = int(active.max())
        n = 1
        while n * 2 <= min(self.block_steps, need):
            n *= 2
        return n

    def request_summary(self) -> Dict:
        """Aggregate per-request latency stats (TTFT + queue wait) over the
        completed set, plus the decode inter-token latency distribution —
        overall and restricted to admission windows (steps whose interval
        absorbed prefill work).  Per-request numbers live in
        ``Request.stats``; under chunked admission ``ttft_s`` is stamped at
        the chunk that completed the prompt (first *emitted* token).

        **``_last_step_t`` semantics.**  ITL samples are intervals between
        successive ``_note_itl`` stamps, and a stamp is ALWAYS taken when
        tokens become host-visible — after ``np.asarray`` returns, i.e. at
        ``_apply_decode`` in the blocking loop and at ``_land_next`` in the
        overlapped loop — never at dispatch, which under overlap would
        report the near-zero time to *queue* a block rather than the time
        its tokens took to exist.  ``DisaggScheduler`` additionally anchors
        the interval's start at its own decode dispatch (``itl_anchor``)
        so the sample stays the decode dispatch's duration, excluding
        same-round prefill-pool host time (see its class docstring); the
        end of the interval is still the landing.

        The ``overlap`` section reports the host/device timing split for
        either loop: ``host_blocked_s`` (total np.asarray wait),
        ``host_overlap_s`` (host work done between a dispatch and its
        landing), the derived overlap fraction and per-step blocked time,
        dispatch-ahead depth, EOS rollbacks, and frontend shed count."""
        out: Dict = {"requests": len(self.done)}
        for key in ("ttft_s", "queue_s"):
            s = percentile_summary(r.stats[key] for r in self.done
                                   if key in r.stats)
            if s is not None:
                out[key] = s
        if self._itl:
            out["decode_itl_s"] = percentile_summary(d for d, _ in self._itl)
            adm = percentile_summary(d for d, a in self._itl if a)
            if adm is not None:
                out["decode_itl_admission_s"] = adm
        if self._tps:
            out["tokens_per_step"] = percentile_summary(self._tps)
        hb = self.stats["host_blocked_s"]
        ho = self.stats["host_overlap_s"]
        out["overlap"] = {
            "enabled": self.overlap,
            "host_blocked_s": hb,
            "host_overlap_s": ho,
            "host_overlap_fraction": (ho / (ho + hb) if ho + hb > 0 else 0.0),
            "host_blocked_per_step_s": (
                hb / max(1, self.stats["decode_steps"])),
            "landings": self.stats["landings"],
            "dispatch_ahead_steps": self.stats["dispatch_ahead_steps"],
            "max_dispatch_ahead": self.stats["max_dispatch_ahead"],
            "eos_rollbacks": self.stats["eos_rollbacks"],
            "shed_requests": self.stats["shed_requests"],
        }
        if self.stats.get("spec_steps"):
            prop = self.stats["spec_proposed"]
            slot_steps = max(1, self.stats["spec_slot_steps"])
            out["spec"] = {
                "steps": self.stats["spec_steps"],
                # fraction of proposed drafts the model verified correct
                # (leading match run, independent of EOS/budget cuts)
                "acceptance_rate": (self.stats["spec_accepted"] / prop
                                    if prop else 0.0),
                # tokens emitted per (verify step, active slot): the
                # speedup factor over plain one-token decode (floor 1.0)
                "mean_tokens_per_step": (self.stats["spec_emitted"]
                                         / slot_steps),
                # drafts verified correct per (verify step, active slot)
                "mean_accepted_per_step": (self.stats["spec_accepted"]
                                           / slot_steps),
            }
        fr: Dict[str, int] = {}
        for r in self.done:
            key = r.finish_reason or "length"
            fr[key] = fr.get(key, 0) + 1
        out["finish_reasons"] = fr
        fkeys = ("step_faults", "step_retries", "quarantined", "timeouts",
                 "aborts_exhaustion", "livelock_aborts", "migration_faults")
        if any(self.stats.get(k) for k in fkeys):
            out["faults"] = {k: self.stats.get(k, 0) for k in fkeys}
        classes = self._class_summary()
        if classes:
            out["classes"] = classes
        if self.overload_ctl is not None:
            out["overload"] = self.overload_ctl.summary()
        return out

    def _class_summary(self) -> Dict:
        """Per-priority-class latency breakdown over the completed set:
        outcome counters, TTFT percentiles, per-request decode ITL
        percentiles (token cadence between first emission and completion),
        and — when the class carries an SLO target — the attainment
        fraction: completed requests (finish_reason stop/length) whose
        per-token latency ``(finished_at - submitted_at) / emitted`` met
        the target, over ALL retired requests of the class, so shed and
        timed-out requests count against attainment."""
        counters = self.stats.get("classes", {})
        classes: Dict = {}
        for cls in PRIORITY_CLASSES:
            recs = [r for r in self.done if r.priority == cls]
            counts = counters.get(cls, {})
            if not recs and not any(counts.values()):
                continue
            entry: Dict = {"requests": len(recs)}
            entry.update(counts)
            s = percentile_summary(r.stats["ttft_s"] for r in recs
                                   if "ttft_s" in r.stats)
            if s is not None:
                entry["ttft_s"] = s
            s = percentile_summary(
                (r.stats["finished_at"] - r.submitted_at - r.stats["ttft_s"])
                / (r.stats["emitted"] - 1)
                for r in recs
                if r.stats.get("emitted", 0) >= 2 and "ttft_s" in r.stats
                and "finished_at" in r.stats)
            if s is not None:
                entry["itl_s"] = s
            target = float(self.slo_targets.get(cls) or 0.0)
            if target > 0 and recs:
                ok = sum(1 for r in recs
                         if r.finish_reason in ("stop", "length")
                         and r.stats.get("emitted", 0) > 0
                         and "finished_at" in r.stats
                         and (r.stats["finished_at"] - r.submitted_at)
                         / r.stats["emitted"] <= target)
                entry["slo_target_s"] = target
                entry["slo_attainment"] = ok / len(recs)
            classes[cls] = entry
        return classes

    def _init_caches(self) -> None:
        # ring caches get spec_k slack entries so a verify chunk of K
        # drafts never wraps onto live window history
        self.caches = self.engine.init_slot_caches(
            self.B, ring_slack=self.spec_k)

    # -- main loop --------------------------------------------------------
    def _serve_round(self) -> bool:
        """One scheduler round (retire → admit → step); returns False when
        fully idle — no unlanded block, no busy slot, no queued request.

        The overlapped loop's shape: drain the pipeline only when this
        round must merge exact host values into the engine state (an
        admission could fill a slot, a chunk/spec step reads the token
        frontier); otherwise dispatch the next block on the previous
        block's device futures, THEN land the older block — np.asarray
        waits only for a block whose successor is already queued on the
        device."""
        self._round_prologue()
        if self._pipeline and any(r.arrival_step <= self.step_count
                                  for r in self.queue):
            # an arrival could admit once done slots retire: land first so
            # admission sees the same frontier the blocking loop would
            if any(s.req is None or (self.dones[i] and s.chunk_next is None)
                   for i, s in enumerate(self.slots)):
                self._drain_pipeline()
        self._retire()
        self._admit()
        if self._prefilling():
            # chunked admission in flight: fused mixed steps advance one
            # chunk per slot AND one decode token per active slot (reads
            # the host token frontier — exact state required)
            self._drain_pipeline()
            self._try_step(self._mixed_step)
            return True
        n = self._block_size()
        if n == 0:
            if self._pipeline:
                self._land_next()     # tail blocks land before going idle
                return True
            pending = [r.arrival_step for r in self.queue]
            if not pending:
                return False
            # idle: jump the virtual clock to the next arrival
            self.step_count = max(self.step_count, min(pending))
            return True
        if self.spec_k and not self._spec_suspended():
            # the drafter consumes the previous step's landed tokens, so
            # spec verify steps cannot dispatch ahead — they run blocking
            self._drain_pipeline()
            self._try_step(self._spec_step)
        elif self.overlap:
            self._try_step(lambda: self._overlap_turn(n))
        else:
            self._try_step(lambda: self._decode_block(n))
        return True

    def _overlap_turn(self, n: int) -> None:
        self._dispatch_block(n)
        while len(self._pipeline) > 1:
            self._land_next()

    def serve_step(self) -> bool:
        """One scheduler round for external drivers (the asyncio frontend):
        admits anything queued, advances the engine one round, retires, and
        returns False when there is nothing left to do.  Safe to call again
        after new ``submit``s."""
        if self.caches is None:
            self._init_caches()
        return self._serve_round()

    def run(self) -> List[Request]:
        """Serve until queue and slots drain; returns requests in completion
        order."""
        if self.caches is None:
            self._init_caches()
        while self._serve_round():
            pass
        self._drain_pipeline()
        self._retire()
        return self.done


# ---------------------------------------------------------------------------
# Paged continuous batching (block-table backend)
# ---------------------------------------------------------------------------


class PagedContinuousScheduler(ContinuousScheduler):
    """Continuous batching over the paged KV backend.

    Same admit -> step -> retire loop as the dense slot engine, plus
    host-side block management (``kvcache.BlockAllocator``):

    * **block-aware admission** — a request is admitted only when enough
      free blocks exist for its full prompt (+ matched shared-prefix blocks
      are referenced instead of re-prefilled: the suffix alone is computed,
      which is where the prefill-token saving comes from);
    * **incremental allocation** — decode claims the next block only when a
      slot's position crosses a block boundary, so resident memory tracks
      ACTUAL occupancy, not ``n_slots x max_seq``;
    * **preempt-to-requeue** — if the pool is exhausted mid-decode, the
      youngest running request is evicted (blocks freed, request requeued
      for recompute-on-readmission) instead of corrupting the pool;
    * **prefix reuse** — full prompt blocks are published to the allocator's
      hash-chained prefix cache after prefill and dropped when their last
      reference dies.  Only for attention-pure models: recurrent state is
      position-integrated and cannot be grafted from another slot's history.

    ``n_blocks`` defaults to the dense-equivalent footprint
    (n_slots x blocks/slot + nulls); size it SMALLER to overcommit capacity
    against short-request traffic (that is the point of paging).
    """

    def __init__(self, engine: Engine, n_slots: int, pad_id: int = 0,
                 block_steps: int = 8, min_bucket: int = 8,
                 responsive_blocks: bool = False,
                 on_token: Optional[Callable[[int, int], None]] = None,
                 prefill_chunk: Optional[int] = None,
                 spec_k: Optional[int] = None,
                 spec_ngram: Optional[int] = None,
                 overlap: Optional[bool] = None,
                 fault_plan: Optional[str] = None,
                 max_step_retries: Optional[int] = None,
                 retry_backoff_s: Optional[float] = None,
                 slo_targets: Optional[Dict[str, float]] = None,
                 reserve_slots: Optional[int] = None,
                 reserve_blocks: Optional[int] = None,
                 overload_opts: Optional[Dict] = None,
                 *, block_size: Optional[int] = None,
                 n_blocks: Optional[int] = None,
                 prefix_cache: bool = True,
                 on_preempt: Optional[Callable[[int], None]] = None):
        # paged is a hard backend choice — no silent fallback: the registry
        # raises its uniform error for ring/frontend/multi-codebook archs
        engine.caps.require("paged")
        super().__init__(engine, n_slots, pad_id, block_steps, min_bucket,
                         responsive_blocks, on_token, prefill_chunk,
                         spec_k, spec_ngram, overlap, fault_plan,
                         max_step_retries, retry_backoff_s,
                         slo_targets, reserve_slots, reserve_blocks,
                         overload_opts)
        cfg = engine.cfg
        self.has_attn = any(k in ("attn", "local_attn")
                            for k in cfg.layer_pattern)
        block_size = block_size or engine.parallel.kv_block_size
        self.bs = block_size
        self.view_blocks = -(-engine.max_len // block_size)
        self.n_shards = engine.ctx.dist.dp * engine.ctx.dist.pods
        if n_slots % self.n_shards:
            raise ValueError(f"n_slots {n_slots} must divide data shards "
                             f"{self.n_shards}")
        self.on_preempt = on_preempt
        if n_blocks is None:
            n_blocks = engine.parallel.kv_pool_blocks or None
        if n_blocks is None:
            n_blocks = n_slots * self.view_blocks + self.n_shards
        self.alloc = kvcache.BlockAllocator(n_blocks, block_size,
                                            n_shards=self.n_shards)
        self.n_blocks = n_blocks
        self.prefix_cache = (prefix_cache and self.has_attn
                             and all(k in ("attn", "local_attn")
                                     for k in cfg.layer_pattern))
        # per-slot block table (LOCAL ids; shard_map splits rows by shard);
        # unallocated entries point at the null block 0
        self.bt = np.zeros((n_slots, self.view_blocks), np.int32)
        self.slot_blocks: List[List[int]] = [[] for _ in range(n_slots)]
        self.stats.update({
            "prefill_tokens_saved": 0, "preemptions": 0,
            "shared_block_hits": 0, "blocks_hwm": 0, "blocks_in_use": 0,
            "deferred_admissions": 0,
        })

    # -- geometry ---------------------------------------------------------
    def _shard_of(self, slot: int) -> int:
        return slot // (self.B // self.n_shards)

    def _note_usage(self) -> None:
        used = self.alloc.total_used()
        self.stats["blocks_in_use"] = used
        self.stats["blocks_hwm"] = max(self.stats["blocks_hwm"], used)

    def submit(self, prompt: np.ndarray, max_new: int,
               eos_id: Optional[int] = None, arrival_step: int = 0,
               deadline_s: Optional[float] = None,
               priority: str = "standard") -> int:
        prompt = np.asarray(prompt)
        need = -(-(len(prompt) + max_new) // self.bs)
        usable = self.alloc.blocks_per_shard - 1
        if self.has_attn and need > usable:
            raise ValueError(
                f"request needs {need} blocks > per-shard pool {usable}")
        return super().submit(prompt, max_new, eos_id, arrival_step,
                              deadline_s, priority)

    def _init_caches(self) -> None:
        self.caches = self.engine.init_paged_caches(
            self.B, self.n_blocks, self.bs)

    # -- block management -------------------------------------------------
    def _release_slot(self, i: int) -> None:
        if self.slot_blocks[i]:
            self.alloc.free(self._shard_of(i), self.slot_blocks[i])
            self.slot_blocks[i] = []
        self.bt[i, :] = kvcache.NULL_BLOCK
        self._note_usage()

    def _retire(self) -> None:
        infl = self._inflight_mask()
        for i, s in enumerate(self.slots):
            if (s.req is not None and self.dones[i] and s.chunk_next is None
                    and (infl is None or not infl[i])):
                self._release_slot(i)
        super()._retire()

    def _preempt_youngest(self, shard: int) -> bool:
        """Evict the LOWEST-PRIORITY, most recently admitted running
        request on ``shard`` (victim key: worst class rank, then youngest
        admission, then highest rid — the method keeps its historical name;
        with a single class it degenerates to exactly the old
        youngest-first rule): free its blocks, requeue it (recompute on
        readmission) at the queue head.  Its generated-so-far tokens are
        DISCARDED (recompute restarts from the prompt): the emitted counter
        rolls back, and streaming clients are told via ``on_preempt(rid)``
        to drop what they buffered for that request — under stochastic
        sampling the regenerated stream need not match the discarded one.
        Mid-chunk-prefill slots are also candidates (they hold blocks but
        have emitted nothing); their chunk progress is simply dropped with
        the slot."""
        if self._pipeline:
            # never pick a victim under an unlanded block: its in-flight
            # emissions would replay into a cleared slot, and the evicted
            # state must merge exactly into the engine inputs
            self._drain_pipeline()
        cand = [i for i, s in enumerate(self.slots)
                if s.req is not None and self._shard_of(i) == shard
                and ((not self.dones[i] and self.remaining[i] > 0)
                     or s.chunk_next is not None)]
        if not cand:
            return False
        i = max(cand,
                key=lambda j: (PRIORITY_RANK[self.slots[j].req.priority],
                               self.slots[j].admitted_step,
                               self.slots[j].req.rid))
        req = self.slots[i].req
        self.stats["emitted"] -= len(self.slots[i].toks)
        self._release_slot(i)
        self.slots[i] = _Slot()
        self.dones[i] = True
        self.remaining[i] = 0
        req.stats["preempted"] = req.stats.get("preempted", 0) + 1
        self.queue.insert(0, req)
        self.stats["preemptions"] += 1
        if self.on_preempt is not None:
            self.on_preempt(req.rid)
        return True

    def _grow_slot(self, i: int, n_needed: int) -> bool:
        """Extend slot i's table to cover ``n_needed`` blocks; False if the
        pool cannot supply them."""
        have = len(self.slot_blocks[i])
        if n_needed <= have:
            return True
        if self.faults and self.faults.deny_alloc(self.step_count):
            return False                  # injected pool exhaustion
        fresh = self.alloc.alloc(self._shard_of(i), n_needed - have)
        if fresh is None:
            return False
        for j, b in enumerate(fresh, start=have):
            self.bt[i, j] = b
        self.slot_blocks[i].extend(fresh)
        self._note_usage()
        return True

    def _ensure_capacity(self, n: int) -> None:
        """Before a fused block of ``n`` decode steps, every active slot
        must own blocks covering its writes at pos..pos+min(n, remaining)-1
        (a slot that finishes mid-block freezes; its frozen rewrites are
        covered or harmlessly redirected to the null block).  Allocation
        failure preempts the youngest request on the starved shard and
        retries — the pool is never over-referenced."""
        if not self.has_attn:       # recurrent-only: no pools, nothing to own
            return
        i = 0
        while i < len(self.slots):
            s = self.slots[i]
            if s.req is None or self.dones[i] or self.remaining[i] <= 0:
                i += 1
                continue
            steps = min(n, int(self.remaining[i]))
            need = -(-(int(self.pos[i]) + steps) // self.bs)
            if self._grow_slot(i, need):
                i += 1
                continue
            if self._pipeline:
                # starved while blocks are tied up in unlanded requests:
                # land first (an EOS surprise may free them via retire)
                # before resorting to preemption — and never preempt a slot
                # whose emissions are still in flight
                self._drain_pipeline()
                self._retire()
                continue                   # re-check slot i after landing
            if not self._preempt_youngest(self._shard_of(i)):
                # terminal starvation: no block, nothing evictable.  Abort
                # THIS request (loud counter) instead of killing the serve
                # loop — every other stream keeps decoding
                self.stats["aborts_exhaustion"] += 1
                self._quarantine_slot(
                    i, "error", "paged pool exhausted with nothing to preempt")
                i += 1
                continue
            # re-check slot i (it may itself have been the one evicted)

    def _run_decode(self, n: int):
        tok, pos, dones, remaining = self._decode_inputs()
        return self.engine.decode_slots_paged(
            self.caches, tok, pos, dones, remaining,
            self.eos, self.bt, self._next_rng(), n=n)

    # -- admission --------------------------------------------------------
    def _admit(self) -> int:
        free = self._free_slots()
        arrived = self._admissible()
        if not free or not arrived:
            return 0
        in_flight = any(s.req is not None
                        and (not self.dones[i] or s.chunk_next is not None)
                        for i, s in enumerate(self.slots))
        # block-aware selection: class-ordered arrivals (interactive first,
        # FIFO within a class), stop at the first request whose blocks —
        # or whose claim on the interactive slot/block reserves — don't
        # fit.  Arrivals are class-sorted, so stopping never starves a
        # higher class behind a refused lower one, and the (request, slot)
        # zip pairing stays aligned for the assignment below.
        quota = self._admission_quota(len(free))
        chosen, starts_of = [], {}
        left = len(free)
        for r, slot in zip(arrived, free):
            if len(chosen) >= quota:
                break
            if r.priority != "interactive" and left <= self.reserve_slots:
                break
            if not self.has_attn:   # recurrent-only: no pools to reserve
                starts_of[r.rid] = 0
                chosen.append(r)
                left -= 1
                continue
            shard = self._shard_of(slot)
            plen = len(r.prompt)
            shared, n_cached = [], 0
            if self.prefix_cache:
                shared, n_cached = self.alloc.match_prefix(shard, r.prompt)
                while n_cached > plen - 1:   # keep >=1 suffix token to run
                    shared = shared[:-1]
                    n_cached -= self.bs
            need = -(-plen // self.bs) - len(shared)
            if (r.priority != "interactive"
                    and self.alloc.free_count(shard) - need
                    < self.reserve_blocks):
                # the blocks exist but are held for interactive admissions
                self.stats["deferred_admissions"] += 1
                break
            fresh = self.alloc.alloc(shard, need)
            if fresh is None:
                self.stats["deferred_admissions"] += 1
                break
            if shared:
                self.alloc.incref(shard, shared)
                self.stats["shared_block_hits"] += len(shared)
            blocks = shared + fresh
            self.slot_blocks[slot] = blocks
            self.bt[slot, :] = kvcache.NULL_BLOCK
            self.bt[slot, :len(blocks)] = blocks
            starts_of[r.rid] = n_cached
            chosen.append(r)
            left -= 1
        if not chosen:
            return 0
        self._note_usage()
        for r in chosen:
            self.queue.remove(r)
        now = time.monotonic()
        short = []
        for slot, r in zip(free, chosen):
            self.slots[slot] = _Slot(req=r, admitted_step=self.step_count)
            self._forced_done[slot] = False
            r.stats["queue_s"] = now - r.submitted_at
            r.stats["admitted_step"] = self.step_count
            r.stats["prefill_tokens_saved"] = starts_of[r.rid]
            self.stats["prefill_tokens_saved"] += starts_of[r.rid]
            if self.chunk:
                # every uncached suffix streams through the mixed step (the
                # first chunk resumes right after the shared prefix); short
                # suffixes complete in one chunk — one compiled admission
                # program, no pow-2 buckets
                self.slots[slot].chunk_next = starts_of[r.rid]
                self.dones[slot] = True
                self.remaining[slot] = 0
                if len(r.prompt) - starts_of[r.rid] > self.chunk:
                    self.stats["chunked_admissions"] += 1
            else:
                short.append((slot, r))
        self.stats["admission_rounds"] += 1
        if in_flight:
            self.stats["in_flight_admissions"] += len(chosen)
        if short:
            self._prefill_suffix(short, starts_of)
        return len(chosen)

    def _prefill_suffix(self, pairs, starts_of) -> None:
        """Legacy single-shot paged admission (suffix within the chunk
        budget): one bucketed full-width prefill through the write table."""
        Lp = self._bucket(max(len(r.prompt) - starts_of[r.rid]
                              for _, r in pairs))
        tokens = np.full((self.B, Lp), self.pad_id, np.int32)
        admit = np.zeros((self.B,), bool)
        plens = np.ones((self.B,), np.int32)
        starts = np.zeros((self.B,), np.int32)
        totals = np.ones((self.B,), np.int32)
        for slot, r in pairs:
            suffix = r.prompt[starts_of[r.rid]:]
            tokens[slot, : len(suffix)] = suffix
            admit[slot] = True
            plens[slot] = len(suffix)
            starts[slot] = starts_of[r.rid]
            totals[slot] = len(r.prompt)
            self.stats["prefill_tokens"] += len(suffix)
        # write table: un-admitted rows are nulled so the full-width prefill
        # scatter cannot touch a live slot's blocks (their pad-token K/V
        # sinks into the null block; their forward output is discarded)
        bt_w = np.where(admit[:, None], self.bt, kvcache.NULL_BLOCK).astype(np.int32)
        new_tok, self.caches = self.engine.prefill_into_slots_paged(
            self.caches, tokens, admit, plens, starts, totals, bt_w,
            self._next_rng())
        self.stats["prefill_calls"] += 1
        self._admission_mark = True
        # publish the freshly-prefilled full prompt blocks for reuse
        if self.prefix_cache:
            for slot, r in pairs:
                n_full = len(r.prompt) // self.bs
                self.alloc.register_prefix(self._shard_of(slot), r.prompt,
                                           self.slot_blocks[slot][:n_full])
        self._finish_admission([s for s, _ in pairs], [r for _, r in pairs],
                               admit, np.array(new_tok))

    # -- speculative decoding hooks ---------------------------------------
    def _ensure_spec_capacity(self) -> None:
        # a verify step writes up to spec_k+1 tokens per active slot
        # (accepted or not — rejected writes are rewound afterwards); every
        # slot needs block coverage for the worst case before the step
        self._ensure_capacity(self.spec_k + 1)

    def _run_verify(self, vtok):
        # one table serves both halves of verify: active rows carry their
        # real tables (the chunk scatter AND the stripe gather route through
        # it), frozen rows are nulled so their writes sink into the dead
        # block instead of touching live (possibly mid-admission) blocks
        active = (~self.dones) & (self.remaining > 0)
        bt_w = np.where(active[:, None], self.bt,
                        kvcache.NULL_BLOCK).astype(np.int32)
        return self.engine.verify_slots_paged(
            self.caches, vtok, self.pos, self.dones, self.remaining,
            self.eos, bt_w, self._next_rng())

    def _post_verify(self, active: List[int]) -> None:
        # block-table truncation = the paged half of KV rewind: blocks that
        # _ensure_spec_capacity grabbed for draft positions past the
        # accepted frontier hold only rejected-draft K/V (dead by the
        # position rewind) — return them so resident memory tracks tokens
        # actually accepted, and the freed blocks can serve other slots'
        # admissions immediately.  self.pos is already the rewound
        # frontier: entries [0, pos) are valid, the entry AT pos is written
        # by the next step (whose capacity hook re-grows the table).
        for i in active:
            keep = -(-int(self.pos[i]) // self.bs)
            blocks = self.slot_blocks[i]
            if len(blocks) > keep:
                self.alloc.free(self._shard_of(i), blocks[keep:])
                self.bt[i, keep:len(blocks)] = kvcache.NULL_BLOCK
                self.slot_blocks[i] = blocks[:keep]
        self._note_usage()

    # -- chunked admission hooks ------------------------------------------
    def _pre_mixed(self) -> None:
        # the decode half writes one token per active slot: ensure block
        # coverage first (may preempt — mixed assembly happens after, so an
        # evicted slot simply drops out of this step)
        self._ensure_capacity(1)

    def _run_mixed(self, tokens, admit, first, clens, starts, totals):
        # two tables: the chunk scatter goes through null rows for every
        # non-admitting slot (protecting live blocks), the decode half
        # through the real per-slot tables
        bt_w = np.where(admit[:, None], self.bt,
                        kvcache.NULL_BLOCK).astype(np.int32)
        return self.engine.mixed_step_paged(
            self.caches, tokens, admit, first, clens, starts, totals,
            self.tok, self.pos, self.dones, self.remaining, self.eos,
            bt_w, self.bt, self._next_rng())

    def _post_chunks(self, slots_p: List[int]) -> None:
        # publish prefix blocks INCREMENTALLY: each chunk boundary completes
        # chunk_next // block_size full blocks, reusable immediately by
        # admissions that arrive while the rest of the prompt still streams
        # (register_prefix zips the hash chain against the blocks given, so
        # a partial prefix registers exactly its completed blocks)
        if not self.prefix_cache:
            return
        for i in slots_p:
            s = self.slots[i]
            n_full = s.chunk_next // self.bs
            if n_full:
                self.alloc.register_prefix(self._shard_of(i), s.req.prompt,
                                           self.slot_blocks[i][:n_full])


# ---------------------------------------------------------------------------
# Disaggregated prefill/decode serving
# ---------------------------------------------------------------------------


class DisaggScheduler(PagedContinuousScheduler):
    """Disaggregated serving: the data axis splits into a PREFILL POOL (the
    first ``prefill_shards`` shards) and a DECODE POOL (the rest), each with
    its own per-shard block namespace from the allocator.

    Prompts admit only to prefill-pool slots and stream through the
    chunk-prefill-ONLY program (no decode ride-along — the chunked engine's
    mixed step exists precisely because admission steals decode steps there;
    here decode-active slots live on other shards and step separately).  At
    each published chunk boundary the completed full blocks are EAGERLY
    enqueued for migration; when the prompt completes, the tail block
    follows, the prefill slot is released, and the request lands in a free
    decode-pool slot with its position row rewritten — the same batched
    jitted step that executes the queued device-to-device block copies.
    Refcounts hand off through the allocator: sources are pinned by
    ``begin_migration`` until the copy lands, destinations are owned by the
    landing slot, and a decode-side prefix hit on an already-migrated block
    is referenced instead of copied (``migration_skipped_blocks``).

    Because decode reads K/V only through block-table indirection, the
    decode program never learns where a block was filled: greedy streams
    are token-identical to the unified paged engine (same chunk width, same
    per-row math — batch-row placement is invisible to row-local attention).

    **ITL accounting.**  This single-process container necessarily
    serializes the two pools' dispatches; on the disaggregated deployment
    this models, they run on disjoint shard groups concurrently.  The
    decode-pool ITL therefore measures each decode DISPATCH's own duration
    (``_last_step_t`` is stamped immediately before the decode program, so
    the sample excludes same-round chunk/migration host time) — exactly the
    quantity that stays flat under concurrent prefill load, where the
    unified chunked engine's admission-window ITL absorbs one chunk of
    prefill compute per token.  Rounds that carried prefill work still tag
    their decode samples (``decode_itl_admission_s``), so flatness is
    visible as admission-window p95 ≈ overall p95.
    """

    def __init__(self, engine: Engine, n_slots: int, pad_id: int = 0,
                 block_steps: int = 8, min_bucket: int = 8,
                 responsive_blocks: bool = False,
                 on_token: Optional[Callable[[int, int], None]] = None,
                 prefill_chunk: Optional[int] = None,
                 spec_k: Optional[int] = None,
                 spec_ngram: Optional[int] = None,
                 overlap: Optional[bool] = None,
                 fault_plan: Optional[str] = None,
                 max_step_retries: Optional[int] = None,
                 retry_backoff_s: Optional[float] = None,
                 slo_targets: Optional[Dict[str, float]] = None,
                 reserve_slots: Optional[int] = None,
                 reserve_blocks: Optional[int] = None,
                 overload_opts: Optional[Dict] = None,
                 *, block_size: Optional[int] = None,
                 n_blocks: Optional[int] = None,
                 prefix_cache: bool = True,
                 on_preempt: Optional[Callable[[int], None]] = None,
                 prefill_shards: Optional[int] = None):
        # the pool split rides on chunked prefill over the paged backend (a
        # prompt must be resumable mid-cache on the prefill shards);
        # ineligible archs would silently serve unified, so the registry
        # rejects them loudly with its uniform error
        engine.caps.require("disagg")
        super().__init__(engine, n_slots, pad_id, block_steps, min_bucket,
                         responsive_blocks, on_token, prefill_chunk,
                         spec_k, spec_ngram, overlap, fault_plan,
                         max_step_retries, retry_backoff_s,
                         slo_targets, reserve_slots, reserve_blocks,
                         overload_opts,
                         block_size=block_size,
                         n_blocks=n_blocks, prefix_cache=prefix_cache,
                         on_preempt=on_preempt)
        # ITL samples anchor at the decode DISPATCH (class docstring); the
        # overlapped landing restores this anchor per record (itl_anchor)
        self._stamp_itl_at_dispatch = True
        # livelock-breaker state (was loop-local before _serve_round)
        self._stall = 0
        self._stall_sig = None
        if not self.chunk:
            raise ValueError("disaggregated serving needs prefill_chunk > 0")
        from repro.launch.mesh import split_data_shards
        pf = (prefill_shards if prefill_shards is not None
              else engine.parallel.disagg_prefill_shards)
        try:
            self._pf_shards, self._dec_shards = split_data_shards(
                self.n_shards, pf)
        except ValueError as e:
            raise ValueError(
                "disaggregated serving splits the data axis into two pools "
                f"(got dp*pods={self.n_shards}, prefill_shards={pf}) — run "
                "with dp >= 2 and 1 <= prefill_shards < dp*pods") from e
        self._spss = self.B // self.n_shards
        self._pf_slots = tuple(range(len(self._pf_shards) * self._spss))
        # migration pipeline state:
        #   queue   (slot, src_shard, src_local, dst_shard, dst_local)
        #           copies awaiting the next batched migrate step
        #   _mig    per-slot handoff state {dst, dst_blocks, sent, ready_t}
        #   _handoff_ready  prefill-complete slots still enqueuing blocks
        #   _landing        fully-enqueued requests awaiting a decode slot
        self._mig_queue: List[Tuple[int, int, int, int, int]] = []
        self._mig: Dict[int, Dict] = {}
        self._handoff_ready: List[int] = []
        self._landing: List[Dict] = []
        from collections import deque
        self._mig_wait: "deque[float]" = deque(maxlen=65536)
        self._block_bytes: Optional[int] = None
        self.stats.update({
            "migrated_blocks": 0, "migration_bytes": 0,
            "migration_skipped_blocks": 0, "migration_deferrals": 0,
            "migration_steps": 0, "handoffs": 0,
            "prefill_steps": 0, "prefill_slot_busy": 0,
            "prefill_slot_total": 0,
        })

    # -- pool geometry ----------------------------------------------------
    def _free_slots(self) -> List[int]:
        return [i for i in self._pf_slots if self.slots[i].req is None]

    def _prefilling(self) -> List[int]:
        # chunk_next == plen is the awaiting-handoff sentinel: the slot is
        # no longer chunking but must not retire until its blocks migrate
        return [i for i in super()._prefilling()
                if self.slots[i].chunk_next < len(self.slots[i].req.prompt)]

    def _pick_decode_shard(self) -> int:
        """Least-loaded decode shard: most free blocks, then most free
        slots, then lowest id (deterministic)."""
        def free_slots(sh: int) -> int:
            lo = sh * self._spss
            return sum(1 for j in range(lo, lo + self._spss)
                       if self.slots[j].req is None)

        return max(self._dec_shards,
                   key=lambda sh: (self.alloc.free_count(sh),
                                   free_slots(sh), -sh))

    # -- slot release / preemption (migration-state cleanup) ---------------
    def _release_slot(self, i: int) -> None:
        m = self._mig.pop(i, None)
        if m is not None:
            # drop this slot's queued copies (unpinning their sources) and
            # return its destination-side blocks — a preempted request
            # recomputes from the prompt on readmission, so any half-done
            # handoff is rolled back whole
            keep = []
            for e in self._mig_queue:
                if e[0] == i:
                    self.alloc.end_migration(e[1], [e[2]])
                else:
                    keep.append(e)
            self._mig_queue[:] = keep
            if m["dst_blocks"]:
                self.alloc.free(m["dst"], m["dst_blocks"])
            if i in self._handoff_ready:
                self._handoff_ready.remove(i)
        super()._release_slot(i)

    # -- prefill-pool stepping --------------------------------------------
    def _chunk_step(self) -> bool:
        """One chunk-prefill-only step over every mid-prefill slot (the
        prefill pool's whole turn; assembly mirrors ``_mixed_step`` minus
        the decode half)."""
        C = self.chunk
        slots_p = self._prefilling()
        if not slots_p:
            return False
        tokens = np.full((self.B, C), self.pad_id, np.int32)
        admit = np.zeros((self.B,), bool)
        first = np.zeros((self.B,), bool)
        clens = np.ones((self.B,), np.int32)
        starts = np.zeros((self.B,), np.int32)
        totals = np.ones((self.B,), np.int32)
        emits = []
        for i in slots_p:
            s = self.slots[i]
            off = s.chunk_next
            plen = len(s.req.prompt)
            nc = min(C, plen - off)
            tokens[i, :nc] = s.req.prompt[off:off + nc]
            admit[i] = True
            first[i] = not s.chunk_started
            clens[i] = nc
            starts[i] = off
            totals[i] = off + nc
            if off + nc == plen:
                emits.append(i)
        bt_w = np.where(admit[:, None], self.bt,
                        kvcache.NULL_BLOCK).astype(np.int32)
        ptok, self.caches = self.engine.chunk_slots_paged(
            self.caches, tokens, admit, first, clens, starts, totals, bt_w,
            self._next_rng())
        self._admission_mark = True       # this round carried prefill work
        for i in slots_p:
            s = self.slots[i]
            s.chunk_started = True
            s.chunk_next += int(clens[i])
            self.stats["prefill_tokens"] += int(clens[i])
            self.stats["prefill_chunks"] += 1
        self.stats["prefill_calls"] += 1
        self.stats["prefill_steps"] += 1
        self.stats["prefill_slot_busy"] += len(slots_p)
        self.stats["prefill_slot_total"] += len(self._pf_slots)
        self._post_chunks(slots_p)
        ptok = self._materialize(ptok)
        for i in emits:
            self._complete_prefill(i, int(ptok[i]))
        return True

    def _post_chunks(self, slots_p: List[int]) -> None:
        super()._post_chunks(slots_p)     # prefill-shard prefix publication
        # eager migration: completed full blocks start their copy at the
        # chunk boundary they publish at, overlapping migration with the
        # remaining prefill instead of paying the whole prompt at handoff
        for i in slots_p:
            s = self.slots[i]
            if s.chunk_next < len(s.req.prompt):
                self._enqueue_migration(i)

    def _complete_prefill(self, i: int, tok: int) -> None:
        """The slot's chunk completed its prompt: record the first emitted
        token (sampled by the chunk program) and stage the handoff."""
        if not 0 <= tok < self.vocab:
            self._quarantine_slot(
                i, "error", f"poisoned prefill token {tok}")
            return
        s = self.slots[i]
        r = s.req
        s.toks.append(tok)
        if self.on_token is not None:
            self.on_token(r.rid, tok)
        r.stats["ttft_s"] = time.monotonic() - r.submitted_at
        self.stats["emitted"] += 1
        if r.max_new <= 1 or (r.eos_id is not None and tok == r.eos_id):
            # nothing left to decode: complete off the prefill pool (the
            # retire path releases blocks + any eagerly-queued migration)
            self.dones[i] = True
            self.remaining[i] = 0
            s.chunk_next = None
            return
        m = self._mig.get(i)
        if m is None:
            m = self._mig[i] = {"dst": self._pick_decode_shard(),
                                "dst_blocks": [], "sent": 0, "ready_t": None}
        m["ready_t"] = time.monotonic()
        self._handoff_ready.append(i)
        # chunk_next stays == plen: the sentinel keeping _retire and
        # _prefilling off the slot while its blocks stream out

    # -- migration pipeline ------------------------------------------------
    def _enqueue_migration(self, i: int, final: bool = False) -> None:
        """Queue copies for slot ``i``'s blocks up to its published
        frontier (all of them incl. the partial tail when ``final``).  A
        decode-side prefix hit references the resident block instead of
        copying; destination exhaustion preempts the youngest decode-pool
        request once, then defers (retried every round)."""
        s = self.slots[i]
        prompt = s.req.prompt
        plen = len(prompt)
        done_toks = plen if s.chunk_next is None else min(s.chunk_next, plen)
        target = -(-plen // self.bs) if final else done_toks // self.bs
        if target == 0:
            return
        m = self._mig.get(i)
        if m is None:
            m = self._mig[i] = {"dst": self._pick_decode_shard(),
                                "dst_blocks": [], "sent": 0, "ready_t": None}
        dshard = m["dst"]
        src_shard = self._shard_of(i)
        hits: List[int] = []
        if self.prefix_cache:
            hits, _ = self.alloc.match_prefix(dshard, prompt)
        while m["sent"] < target:
            j = m["sent"]
            if j < len(hits):
                # the chain-verified block already lives in the decode
                # pool: hand the refcount off, skip the copy entirely
                self.alloc.incref(dshard, [hits[j]])
                m["dst_blocks"].append(hits[j])
                self.stats["migration_skipped_blocks"] += 1
            else:
                got = self.alloc.alloc(dshard, 1)
                if got is None and self._preempt_youngest(dshard):
                    got = self.alloc.alloc(dshard, 1)
                if got is None:
                    self.stats["migration_deferrals"] += 1
                    return
                src_local = self.slot_blocks[i][j]
                self.alloc.begin_migration(src_shard, [src_local])
                self._mig_queue.append((i, src_shard, src_local,
                                        dshard, got[0]))
                m["dst_blocks"].append(got[0])
            m["sent"] += 1

    def _advance_handoffs(self) -> None:
        """Finish staging prefill-complete slots: once every block (incl.
        the tail) is enqueued or referenced, free the prefill slot (the
        allocator pins keep queued sources alive until the copy executes)
        and move the request to the landing list."""
        for i in list(self._handoff_ready):
            s = self.slots[i]
            try:
                if self.faults:
                    self.faults.on_handoff()
                self._enqueue_migration(i, final=True)
            except MigrationFault as e:
                # failed mid-handoff: roll the whole handoff back (queued
                # copies unpinned, dst blocks freed — _release_slot) and
                # quarantine the request; nothing reached the decode pool
                self.stats["migration_faults"] += 1
                self._quarantine_slot(i, "error", str(e))
                continue
            m = self._mig[i]
            if m["sent"] < -(-len(s.req.prompt) // self.bs):
                continue                   # starved for dst blocks; retry
            self._handoff_ready.remove(i)
            m = self._mig.pop(i)
            self._landing.append({
                "req": s.req, "shard": m["dst"], "blocks": m["dst_blocks"],
                "toks": list(s.toks), "ready_t": m["ready_t"],
            })
            self.stats["handoffs"] += 1
            self._release_slot(i)          # _mig popped -> src blocks free
            self.slots[i] = _Slot()
            self.dones[i] = True
            self.remaining[i] = 0

    def _run_migrations(self) -> None:
        """Land waiting requests into free decode slots and execute every
        queued copy in ONE batched jitted step (global block ids; cross-
        shard pairs lower to the actual device-to-device transfer)."""
        if self._pipeline and (self._landing or self._mig_queue):
            # landing a request rewrites its decode slot's host state row —
            # exact values must merge before the next overlapped dispatch
            self._drain_pipeline()
        land = np.zeros((self.B,), bool)
        totals = np.zeros((self.B,), np.int32)
        landed = []
        for rec in self._landing:
            lo = rec["shard"] * self._spss
            slot = next((j for j in range(lo, lo + self._spss)
                         if self.slots[j].req is None and not land[j]), None)
            if slot is None:
                continue                   # decode pool full; lands later
            r = rec["req"]
            plen = len(r.prompt)
            land[slot] = True
            totals[slot] = plen
            s = _Slot(req=r, admitted_step=self.step_count)
            s.toks = list(rec["toks"])
            self.slots[slot] = s
            self._forced_done[slot] = False
            self.slot_blocks[slot] = list(rec["blocks"])
            self.bt[slot, :] = kvcache.NULL_BLOCK
            self.bt[slot, :len(rec["blocks"])] = rec["blocks"]
            t = int(rec["toks"][-1])
            self.tok[slot] = t
            self.pos[slot] = plen
            self.remaining[slot] = r.max_new - 1
            self.eos[slot] = -1 if r.eos_id is None else r.eos_id
            self.dones[slot] = r.eos_id is not None and t == r.eos_id
            wait = time.monotonic() - rec["ready_t"]
            r.stats["migration_wait_s"] = wait
            self._mig_wait.append(wait)
            if self.prefix_cache:
                self.alloc.register_prefix(rec["shard"], r.prompt,
                                           rec["blocks"][:plen // self.bs])
            landed.append(rec)
        for rec in landed:
            self._landing.remove(rec)
        if not self._mig_queue and not landed:
            return
        per = self.alloc.blocks_per_shard
        src = [sh * per + b for _, sh, b, _, _ in self._mig_queue]
        dst = [sh * per + b for _, _, _, sh, b in self._mig_queue]
        self.caches = self.engine.migrate_blocks(self.caches, src, dst,
                                                 land, totals)
        for _, sh, b, _, _ in self._mig_queue:
            self.alloc.end_migration(sh, [b])
        n = len(self._mig_queue)
        self._mig_queue.clear()
        self.stats["migrated_blocks"] += n
        self.stats["migration_bytes"] += n * (self._block_bytes or 0)
        self.stats["migration_steps"] += 1
        self._note_usage()

    # -- failure isolation (migration-aware) --------------------------------
    def _finish_landing_record(self, rec: Dict, finish_reason: str,
                               error: Optional[str] = None) -> None:
        """Abort a fully-migrated request still waiting for a decode slot:
        free its destination blocks and retire it.  Callers must ensure the
        copy queue is EMPTY first — a queued batched copy still targets
        these blocks, and freeing them mid-queue would let the copy write
        into storage another request may have claimed."""
        assert not self._mig_queue, "landing abort with copies in flight"
        self._landing.remove(rec)
        self.alloc.free(rec["shard"], rec["blocks"])
        r = rec["req"]
        r.output = np.asarray(rec["toks"], np.int32)
        r.finish_reason = finish_reason
        if error is not None:
            r.stats["error"] = error
        r.stats.update({"emitted": len(rec["toks"]),
                        "finished_at": time.monotonic()})
        self.stats["timeouts" if finish_reason == "timeout"
                   else "quarantined"] += 1
        self._note_usage()
        self._finish(r)

    def _abort_stuck_entity(self) -> bool:
        """Last-resort livelock escape: abort ONE stuck request so every
        other stream keeps its slot.  Deterministic priority: a slot wedged
        mid-handoff, then a landed-but-unplaced request (only once the copy
        queue is drained — see ``_finish_landing_record``), then a
        mid-prefill slot."""
        victim = False
        if self._handoff_ready:
            self._quarantine_slot(self._handoff_ready[0], "error",
                                  "livelock: migration handoff stuck")
            victim = True
        elif self._landing and not self._mig_queue:
            self._finish_landing_record(
                self._landing[0], "error",
                "livelock: no decode slot ever freed for landing")
            victim = True
        else:
            for i, s in enumerate(self.slots):
                if s.req is not None and s.chunk_next is not None:
                    self._quarantine_slot(i, "error",
                                          "livelock: prefill stuck")
                    victim = True
                    break
        if victim:
            self.stats["livelock_aborts"] += 1
        return victim

    def _expire_deadlines(self) -> None:
        super()._expire_deadlines()
        # landed-but-unplaced requests hold destination blocks while they
        # wait for a decode slot — they time out too, but only once the
        # copy queue is empty (it drains every round via _run_migrations)
        if not self._landing or self._mig_queue:
            return
        now = time.monotonic()
        for rec in [rec for rec in self._landing
                    if rec["req"].deadline_s is not None
                    and now - rec["req"].submitted_at
                    >= rec["req"].deadline_s]:
            self._finish_landing_record(rec, "timeout")

    # -- decode-pool stepping ----------------------------------------------
    def _run_decode(self, n: int):
        # unlike the unified engine, decode here runs WHILE other slots are
        # mid-prefill: those rows carry stale positions but real tables, so
        # their frozen row-local rewrite must sink into the null block (the
        # _run_verify idiom) or it would clobber a freshly-written chunk.
        # _last_step_t stamps HERE so the ITL sample is the decode
        # dispatch's own duration (see class docstring).  Under overlap the
        # predicted-active mask is a SUPERSET of the device's true active
        # set (EOS surprises freeze rows early) — keeping a frozen row's
        # real table is safe: its row-local rewrite lands at its own valid
        # next position, which nothing reads.
        self._last_step_t = time.monotonic()
        active = (~self.dones) & (self.remaining > 0)
        bt = np.where(active[:, None], self.bt,
                      kvcache.NULL_BLOCK).astype(np.int32)
        tok, pos, dones, remaining = self._decode_inputs()
        return self.engine.decode_slots_paged(
            self.caches, tok, pos, dones, remaining,
            self.eos, bt, self._next_rng(), n=n)

    def _run_verify(self, vtok):
        self._last_step_t = time.monotonic()
        return super()._run_verify(vtok)

    # -- reporting ---------------------------------------------------------
    def request_summary(self) -> Dict:
        out = super().request_summary()
        st = self.stats
        pools: Dict = {
            "prefill_shards": len(self._pf_shards),
            "decode_shards": len(self._dec_shards),
            "prefill_steps": st["prefill_steps"],
            "decode_steps": st["decode_steps"],
            "prefill_occupancy": (
                st["prefill_slot_busy"] / st["prefill_slot_total"]
                if st["prefill_slot_total"] else 0.0),
            "migrated_blocks": st["migrated_blocks"],
            "migration_bytes": st["migration_bytes"],
            "migration_skipped_blocks": st["migration_skipped_blocks"],
            "migration_deferrals": st["migration_deferrals"],
            "handoffs": st["handoffs"],
        }
        w = percentile_summary(self._mig_wait)
        if w is not None:
            pools["migration_wait_s"] = w
        if "decode_itl_s" in out:
            pools["decode_itl_s"] = out["decode_itl_s"]
        out["pools"] = pools
        return out

    # -- main loop ---------------------------------------------------------
    def _serve_round(self) -> bool:
        """One disagg round.  Under overlap, only decode-pool blocks
        pipeline; any round that must land a migrated request or hand off
        blocks (host-exact slot arming) drains first.  Chunk-prefill steps
        do NOT force a drain — the chunk program reads only the (chained)
        cache future, and its own ``ptok`` materialization serializes after
        the in-flight decode blocks device-side anyway."""
        if self._block_bytes is None:
            from repro.models import transformer as tfm
            self._block_bytes = kvcache.pool_block_bytes(
                self.caches, tfm.build_groups(self.engine.cfg))
        self._round_prologue()
        if self._pipeline and (self._handoff_ready or self._landing
                               or self._mig_queue):
            # a migration landing rewrites a decode slot's position row on
            # the host — exact state must merge before the next dispatch
            self._drain_pipeline()
        self._retire()
        self._admit()
        did_prefill = bool(self._try_step(self._chunk_step))
        self._advance_handoffs()
        self._run_migrations()
        n = self._block_size()
        if n:
            if self.spec_k and not self._spec_suspended():
                self._drain_pipeline()
                self._try_step(self._spec_step)
            elif self.overlap:
                self._try_step(lambda: self._overlap_turn(n))
            else:
                self._try_step(lambda: self._decode_block(n))
        elif did_prefill:
            # prefill-only round: the virtual arrival clock advances so
            # arrivals keyed to decode steps stay admissible
            self.step_count += 1
        elif self._pipeline:
            self._land_next()
        busy = any(s.req is not None for s in self.slots)
        if (not busy and not self._landing and not self._mig_queue
                and not self._pipeline):
            pending = [r.arrival_step for r in self.queue]
            if not pending:
                return False
            self.step_count = max(self.step_count, min(pending))
            return True
        # livelock breaker: a full round with zero observable progress
        # (deferred migrations against a wedged decode pool) preempts
        # its way out rather than spinning forever
        sig = (len(self.done), self.stats["emitted"],
               self.stats["migrated_blocks"], self.stats["handoffs"],
               self.stats["prefill_chunks"], self.stats["decode_steps"],
               len(self.queue), len(self._landing))
        if sig == self._stall_sig:
            self._stall += 1
            if self._stall > 4 * self.B + 16:
                self._drain_pipeline()
                if not any(self._preempt_youngest(sh) for sh in
                           (*self._dec_shards, *self._pf_shards)):
                    # nothing preemptible either: abort ONE stuck request
                    # (loud counter) instead of killing the serve loop —
                    # the remaining streams get another full stall window
                    if not self._abort_stuck_entity():
                        raise RuntimeError(
                            "disagg scheduler stalled: no progress and "
                            "nothing to preempt or abort")
                self._stall = 0
        else:
            self._stall, self._stall_sig = 0, sig
        return True

    def run(self) -> List[Request]:
        """Serve until queue, slots, and migration pipeline drain."""
        if self.caches is None:
            self._init_caches()
        while self._serve_round():
            pass
        self._drain_pipeline()
        self._retire()
        return self.done
