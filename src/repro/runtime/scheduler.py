"""Batch-wave request scheduler (continuous-batching lite).

Requests queue up; the scheduler forms *waves* of up to ``batch_size``
requests with a shared (padded) prompt length, runs prefill once and decodes
until every request in the wave reaches its ``max_new`` (per-request early
stop on ``eos_id``).  Decode positions stay batch-aligned, which keeps the
decode step a single shared-``cur_pos`` program — the same simplification
real engines make per "generation group".  Slot-level stats (queue time,
tokens/s) are recorded per request.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.runtime.engine import Engine


@dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (prompt_len,) or (prompt_len, ncb)
    max_new: int
    eos_id: Optional[int] = None
    submitted_at: float = field(default_factory=time.monotonic)
    output: Optional[np.ndarray] = None
    stats: Dict = field(default_factory=dict)


class WaveScheduler:
    def __init__(self, engine: Engine, batch_size: int, pad_id: int = 0):
        self.engine = engine
        self.batch_size = batch_size
        self.pad_id = pad_id
        self.queue: List[Request] = []
        self.done: List[Request] = []
        self._next_id = 0

    def submit(self, prompt: np.ndarray, max_new: int,
               eos_id: Optional[int] = None) -> int:
        rid = self._next_id
        self._next_id += 1
        self.queue.append(Request(rid, np.asarray(prompt), max_new, eos_id))
        return rid

    def _form_wave(self) -> List[Request]:
        wave = self.queue[: self.batch_size]
        self.queue = self.queue[self.batch_size:]
        return wave

    def run(self) -> List[Request]:
        """Drain the queue; returns completed requests in completion order."""
        while self.queue:
            wave = self._form_wave()
            self._run_wave(wave)
        return self.done

    def _run_wave(self, wave: List[Request]) -> None:
        b = self.batch_size
        plen = max(len(r.prompt) for r in wave)
        max_new = max(r.max_new for r in wave)
        ncb = self.engine.cfg.n_codebooks
        shape = (b, plen) if ncb == 1 else (b, plen, ncb)
        prompts = np.full(shape, self.pad_id, dtype=np.int32)
        for i, r in enumerate(wave):
            # left-align; short prompts are right-padded (positions aligned)
            prompts[i, : len(r.prompt)] = r.prompt
        t0 = time.monotonic()
        out = self.engine.generate(prompts, max_new)       # (b, max_new[, ncb])
        dt = time.monotonic() - t0
        for i, r in enumerate(wave):
            toks = out[i, : r.max_new]
            if r.eos_id is not None:
                flat = toks if toks.ndim == 1 else toks[..., 0]
                hits = np.nonzero(flat == r.eos_id)[0]
                if hits.size:
                    toks = toks[: hits[0] + 1]
            r.output = toks
            r.stats = {
                "wave_batch": len(wave),
                "queue_s": t0 - r.submitted_at,
                "wave_s": dt,
                "tok_per_s": max_new * len(wave) / dt if dt > 0 else float("inf"),
            }
            self.done.append(r)
