"""Adaptive graceful-degradation controller for sustained overload.

The serving schedulers already *survive* short bursts: the frontend sheds
at ``max_pending``, paged pools preempt-to-requeue, deadlines expire.
What none of that handles is demand that stays above capacity for many
rounds — queues grow without bound and every class's latency collapses
together.  :class:`OverloadController` closes that gap with a small,
fully documented ladder of degradation levers, applied and released with
hysteresis so the system neither flaps nor stays degraded after the
burst passes.

Ladder (level 0 is normal operation; each level keeps the levers of the
levels below it):

=====  ================  =================================================
level  name              lever
=====  ================  =================================================
0      normal            —
1      shed-batch        ``batch``-class requests are shed at admission
                         (scheduler) and at submission (frontend) instead
                         of queueing behind latency classes.
2      spec-off          speculative decoding is suspended.  Greedy spec
                         decode is token-identical to plain decode, so
                         this trades per-request speed for a smaller
                         fused-step footprint without changing any
                         stream.
3      tight-admission   the admission window shrinks to one new request
                         per round (and at most one mid-prefill slot
                         under chunked prefill), keeping decode cadence
                         for already-admitted work instead of paying wide
                         prefill chunks at the worst moment.
=====  ================  =================================================

Signals, observed once per serving round (``observe``):

* **queue depth** — requests that have arrived (``arrival_step <=
  step_count``) but hold no slot.  Deterministic under the virtual
  decode-step clock, which is what makes degradation testable.
* **recent landed ITL** — mean of the last ``window`` per-step
  inter-token latencies, compared against the interactive-class SLO
  scaled by ``itl_hi``/``itl_lo``.  Only consulted when an interactive
  SLO target is configured (wall-clock signals are advisory; queue depth
  is the primary, reproducible signal).

Hysteresis: the controller escalates one level only after ``patience``
consecutive pressured rounds, and restores one level only after
``cooldown`` consecutive clear rounds; rounds in the dead band between
the lo and hi thresholds reset both streaks (hold the current level).
Every transition is recorded and surfaced through ``summary()`` —
wired into ``request_summary()["overload"]`` and ``GET /health``.

None of the levers ever touches device math or sampled tokens: admitted
survivors' greedy streams stay bit-identical to an unloaded run.
Degradation changes *which* requests run and *when* — never *what*.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

LADDER = ("normal", "shed-batch", "spec-off", "tight-admission")
MAX_LEVEL = len(LADDER) - 1


class OverloadController:
    """Hysteresis ladder walker; one instance per scheduler."""

    def __init__(self,
                 queue_hi: int,
                 queue_lo: int,
                 slo_s: float = 0.0,
                 itl_hi: float = 1.5,
                 itl_lo: float = 1.0,
                 patience: int = 3,
                 cooldown: int = 6,
                 window: int = 32):
        if queue_lo > queue_hi:
            raise ValueError("overload queue_lo must be <= queue_hi")
        self.queue_hi = int(queue_hi)
        self.queue_lo = int(queue_lo)
        self.slo_s = float(slo_s)
        self.itl_hi = float(itl_hi)
        self.itl_lo = float(itl_lo)
        self.patience = max(1, int(patience))
        self.cooldown = max(1, int(cooldown))
        self.window = max(1, int(window))
        self.level = 0
        self.max_level_seen = 0
        self._hot = 0             # consecutive pressured rounds
        self._cool = 0            # consecutive clear rounds
        self._round = 0
        self.escalations = 0
        self.restorations = 0
        self.rounds_at_level = [0] * len(LADDER)
        # (round, from_level, to_level) — every ladder transition, in order
        self.transitions: List[Tuple[int, int, int]] = []

    # -- signal evaluation -------------------------------------------------
    def _pressured(self, depth: int, itl: Optional[float]) -> bool:
        if depth >= self.queue_hi:
            return True
        return (self.slo_s > 0.0 and itl is not None
                and itl > self.itl_hi * self.slo_s)

    def _clear(self, depth: int, itl: Optional[float]) -> bool:
        if depth > self.queue_lo:
            return False
        return (self.slo_s <= 0.0 or itl is None
                or itl <= self.itl_lo * self.slo_s)

    def observe(self, depth: int, itl: Optional[float] = None) -> int:
        """Feed one round's signals; returns the (possibly new) level."""
        self._round += 1
        self.rounds_at_level[self.level] += 1
        if self._pressured(depth, itl):
            self._hot += 1
            self._cool = 0
            if self._hot >= self.patience and self.level < MAX_LEVEL:
                self._shift(self.level + 1)
                self.escalations += 1
                self._hot = 0
        elif self._clear(depth, itl):
            self._cool += 1
            self._hot = 0
            if self._cool >= self.cooldown and self.level > 0:
                self._shift(self.level - 1)
                self.restorations += 1
                self._cool = 0
        else:
            # dead band: hold the level, reset both streaks
            self._hot = 0
            self._cool = 0
        return self.level

    def _shift(self, to: int) -> None:
        self.transitions.append((self._round, self.level, to))
        self.level = to
        self.max_level_seen = max(self.max_level_seen, to)

    # -- levers (read by the schedulers each round) ------------------------
    @property
    def shed_classes(self) -> Tuple[str, ...]:
        return ("batch",) if self.level >= 1 else ()

    @property
    def spec_off(self) -> bool:
        return self.level >= 2

    @property
    def admission_cap(self) -> Optional[int]:
        """Max new admissions per round (None = unlimited)."""
        return 1 if self.level >= 3 else None

    # -- reporting ---------------------------------------------------------
    def summary(self) -> dict:
        return {
            "level": self.level,
            "level_name": LADDER[self.level],
            "max_level": self.max_level_seen,
            "max_level_name": LADDER[self.max_level_seen],
            "escalations": self.escalations,
            "restorations": self.restorations,
            "transitions": len(self.transitions),
            "rounds_at_level": list(self.rounds_at_level),
            "shed_classes": list(self.shed_classes),
            "spec_off": self.spec_off,
            "admission_cap": self.admission_cap or 0,
        }
