"""Cache partition specs + cache utilities + the paged-KV block allocator.

Cache pytrees are built by ``models.model.init_caches``; leaves are named
dict keys with fixed layouts, so partition specs are assigned by key:

  k/v      (b, local_kv, S, hd)   -> (data*, model, None, None)
  ckv      (b, S, rank)           -> (data*, None, None)      [MLA latent]
  krope    (b, S, rope)           -> (data*, None, None)
  pos      (S,)                   -> (None,)
  h (ssd)  (b, heads, P, N)       -> (data*, model, None, None)
  h (lru)  (b, width)             -> (data*, model)
  conv     (b, W-1, channels)     -> (data*, None, model)

With ``kv_seq_shard`` (long_500k: batch 1, cache sequence sharded over the
data axis) the attention-cache sequence dim takes "data" and batch is
replicated; recurrent state stays tiny and batch-replicated.
Scanned groups prepend a None (layer-stack) axis.

Paged layout (second storage backend, slot engine only) keeps the SAME leaf
keys but pool shapes: k/v become a global block pool
(n_blocks, local_kv, block_size, hd) (ckv/krope: (n_blocks, block_size, r)),
addressed through a per-slot block table (b, blocks_per_slot) carried
OUTSIDE the cache pytree (it is host-managed and changes per call).  Since
the pool's block dim shards over the data axis exactly like the dense batch
dim, and every leaf keeps its ndim, the dense pspecs apply verbatim —
``cache_pspecs(batched_pos=True)`` covers both layouts.  Position arrays
stay per-slot dense (b, S_view), so validity masking is identical to the
dense engine.  Blocks are handed out, refcounted, and freed by the
host-side :class:`BlockAllocator`; block 0 of every data shard is reserved
as the *null block* — a write sink for empty/out-of-range rows that is
never validly read (dead by position masking).
"""
from __future__ import annotations

from collections import deque
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import model as M
from repro.models import transformer as tfm

Pytree = Any

NULL_BLOCK = 0   # reserved per-shard write sink; never allocated, never valid

# pool-shaped leaves (paged layout): selected whole from the scatter-written
# `new` tree by merge_slots(paged=True) instead of per-row merging
POOL_KEYS = ("k", "v", "k_scale", "v_scale", "ckv", "krope")


def _leaf_spec(key: str, ndim: int, dist, kv_seq_shard: bool, stacked: bool,
               replicate_batch: bool = False):
    d = None if (kv_seq_shard or replicate_batch) else (
        dist.data_axes if len(dist.data_axes) > 1 else dist.data_axes[0]
    )
    seq = dist.data_axis if kv_seq_shard else None
    m = dist.model_axis
    if key in ("k", "v"):
        spec = (d, m, seq, None)
    elif key in ("k_scale", "v_scale"):
        spec = (d, m, seq)
    elif key in ("ckv", "krope"):
        spec = (d, seq, None)
    elif key == "pos":
        # (S,) shared positions, or (b, S) per-slot (continuous batching)
        spec = (seq,) if ndim == 1 else (d, seq)
    elif key == "h":                       # recurrent state: always batch-major
        spec = (d, m, None, None)[:ndim]
    elif key == "conv":
        spec = (d, None, m)
    else:
        raise KeyError(f"unknown cache leaf {key!r}")
    if stacked:
        spec = (None,) + tuple(spec)
    return P(*spec)


def cache_pspecs(ctx: M.ModelCtx, *, kv_seq_shard: bool = False,
                 replicate_batch: bool = False,
                 batched_pos: bool = False) -> Tuple:
    """Spec tree matching ``init_caches`` exactly (same treedef)."""
    groups = tfm.build_groups(ctx.cfg)
    # build a template (tiny batch) to mirror structure + ndims
    template = jax.eval_shape(
        lambda: M.init_caches(ctx, 1, 2, kv_seq_shard_dp=1,
                              batched_pos=batched_pos))
    out = []
    for g, gc in zip(groups, template):
        stacked = g.n > 1

        def spec_of(subtree):
            return {
                k: (
                    spec_of(v)
                    if isinstance(v, dict)
                    else _leaf_spec(k, v.ndim - (1 if stacked else 0), ctx.dist,
                                   kv_seq_shard, stacked, replicate_batch)
                )
                for k, v in subtree.items()
            }

        out.append(spec_of(gc))
    return tuple(out)


def cache_shapes(ctx: M.ModelCtx, batch_local: int, cache_len: int,
                 *, kv_seq_shard_dp: int = 1) -> Tuple:
    """ShapeDtypeStructs of the GLOBAL cache arrays (for the dry-run)."""
    local = jax.eval_shape(
        lambda: M.init_caches(ctx, batch_local, cache_len,
                              kv_seq_shard_dp=kv_seq_shard_dp)
    )
    return local


# ---------------------------------------------------------------------------
# Slot-level utilities (continuous batching)
#
# Caches built with ``batched_pos=True`` treat every batch row as an
# independent *slot*: a request occupies one row, its per-slot position
# array masks validity, and recurrent state lives in the same row.  The
# helpers below operate on whole slots inside a jitted program: reset before
# an in-flight admission, mask prompt padding out of the position arrays,
# and merge freshly-prefilled slots into a live cache.
# ---------------------------------------------------------------------------


def _map_by_key(caches: Tuple, groups, fn) -> Tuple:
    """Apply ``fn(key, leaf, stacked)`` to every leaf, keyed by cache name."""

    def walk(subtree, stacked):
        return {
            k: walk(v, stacked) if isinstance(v, dict) else fn(k, v, stacked)
            for k, v in subtree.items()
        }

    return tuple(walk(gc, g.n > 1) for g, gc in zip(groups, caches))


def _expand_over(mask, leaf, stacked):
    """Broadcast a (b,) mask against the leaf's batch axis (1 if stacked)."""
    ax = 1 if stacked else 0
    shape = (1,) * ax + (mask.shape[0],) + (1,) * (leaf.ndim - ax - 1)
    return mask.reshape(shape)


def reset_slots(caches: Tuple, groups, mask: jax.Array,
                *, paged: bool = False) -> Tuple:
    """Clear the slots selected by ``mask`` (b,) bool for a fresh request.

    Positions go to -1 (masking every stale K/V entry without touching the
    K/V bytes) and recurrent state (SSM h, LRU h, conv tails) zeroes, since
    prefill integrates state from t=0.  K/V payloads stay: they are dead by
    position masking and get overwritten as the new request progresses.
    Dense int8 scale leaves zero alongside: a dead dequantized entry then
    reads exactly 0 instead of stale-scale garbage (masked either way, but
    bounded values keep the score matmul's masked lanes tame — and a fresh
    slot starts bit-identical to a fresh wave cache).

    ``paged=True``: position/recurrent leaves are per-slot rows there too,
    but scale (and K/V) leaves are block pools — their stale blocks become
    unreachable by table surgery on the host, so they are left alone."""

    def f(key, leaf, stacked):
        if key == "pos":
            if leaf.ndim - (1 if stacked else 0) != 2:
                raise ValueError("reset_slots needs batched_pos caches")
            return jnp.where(_expand_over(mask, leaf, stacked), -1, leaf)
        if key in ("h", "conv"):
            return jnp.where(_expand_over(mask, leaf, stacked),
                             jnp.zeros((), leaf.dtype), leaf)
        if key in ("k_scale", "v_scale") and not paged:
            return jnp.where(_expand_over(mask, leaf, stacked),
                             jnp.zeros((), leaf.dtype), leaf)
        return leaf

    return _map_by_key(caches, groups, f)


def mask_prompt_padding(caches: Tuple, groups, plens: jax.Array) -> Tuple:
    """Invalidate position entries at/after each slot's true prompt length.

    Admission prefills a whole (b, Lp) padded batch; K/V written for padding
    tokens must never be attended, so their pos entries drop to -1.  Decode
    then overwrites index plen, plen+1, ... with real generated tokens."""

    def f(key, leaf, stacked):
        if key != "pos":
            return leaf
        S = leaf.shape[-1]
        idx = jnp.arange(S, dtype=jnp.int32)
        keep = idx[None, :] < plens[:, None]                 # (b, S)
        if stacked:
            keep = keep[None]
        return jnp.where(keep, leaf, -1)

    return _map_by_key(caches, groups, f)


def merge_slots(old: Tuple, new: Tuple, groups, mask: jax.Array,
                *, paged: bool = False) -> Tuple:
    """Per-slot select: rows where ``mask`` is True come from ``new``.

    ``paged=True``: pool-shaped leaves (k/v/scales/ckv/krope) have no batch
    axis to row-select — the prefill scatter already confined their writes
    to the admitted slots' blocks (un-admitted rows write through a
    null-block table), so the new pool is taken whole.  Per-slot leaves
    (pos, recurrent h/conv) merge per row exactly as in the dense layout."""

    def walk(key, o, n, stacked):
        if isinstance(o, dict):
            return {k: walk(k, o[k], n[k], stacked) for k in o}
        if paged and key in POOL_KEYS:
            return n
        return jnp.where(_expand_over(mask, o, stacked), n, o)

    return tuple(walk(None, go, gn, g.n > 1)
                 for g, go, gn in zip(groups, old, new))


def _map_by_sub(caches: Tuple, groups, fn) -> Tuple:
    """Apply ``fn(sub, key, leaf, stacked)`` to every leaf — like
    ``_map_by_key`` but with the owning :class:`SubLayer` in scope, for
    transforms that depend on the mixer kind (ring vs identity layout)."""

    def walk(sub, subtree, stacked):
        return {
            k: walk(sub, v, stacked) if isinstance(v, dict)
            else fn(sub, k, v, stacked)
            for k, v in subtree.items()
        }

    return tuple(
        {k: walk(g.subs[int(k[3:])], v, g.n > 1) for k, v in gc.items()}
        for g, gc in zip(groups, caches)
    )


def set_slot_positions(caches: Tuple, groups, total_lens: jax.Array,
                       *, window: int = 0) -> Tuple:
    """Rewrite every pos leaf row so exactly positions
    [0..total_lens[b]) read as valid, everything else -1.

    Non-ring slot layouts (view index IS absolute position — the paged pool
    after an admission prefill, the dense slot cache after a chunked-prefill
    step) get the identity row [0..total) / -1.  With ``window`` > 0,
    ``local_attn`` leaves use the RING layout instead: ring index ``i``
    holds the largest position congruent to ``i`` mod S that has been
    written, so the row is that position where it falls inside the last S
    written positions, -1 elsewhere.  This replaces the dense path's
    _write_prefill position writes + mask_prompt_padding in one shot (and,
    after a spec-decode verify, un-marks rejected draft writes); merge_slots
    then keeps the rewritten rows only for admitted slots."""

    def f(sub, key, leaf, stacked):
        if key != "pos":
            return leaf
        S = leaf.shape[-1]
        idx = jnp.arange(S, dtype=jnp.int32)
        if window and sub.kind == "local_attn":
            # largest p ≡ idx (mod S) with p < total; valid iff it is one of
            # the last S positions written (floor division keeps total=0 and
            # idx >= total rows at -1)
            p = idx[None, :] + ((total_lens[:, None] - 1 - idx[None, :]) // S) * S
            row = jnp.where((p >= 0) & (p >= total_lens[:, None] - S), p, -1)
        else:
            row = jnp.where(idx[None, :] < total_lens[:, None], idx[None, :], -1)
        return jnp.broadcast_to(row if not stacked else row[None], leaf.shape)

    return _map_by_sub(caches, groups, f)


def pool_block_bytes(caches: Tuple, groups) -> int:
    """Bytes of KV payload held by ONE global block across every pool leaf
    (all layers, all heads) — the unit of migration traffic accounting for
    disaggregated serving, mirroring how sync_policy accounts collectives."""
    import math

    total = 0

    def f(key, leaf, stacked):
        nonlocal total
        if key in POOL_KEYS:
            ax = 1 if stacked else 0          # block axis
            layers = leaf.shape[0] if stacked else 1
            total += layers * math.prod(leaf.shape[ax + 1:]) * leaf.dtype.itemsize
        return leaf

    _map_by_key(caches, groups, f)
    return total


# ---------------------------------------------------------------------------
# Host-side block allocator (paged KV)
# ---------------------------------------------------------------------------


class BlockAllocator:
    """Hands out, refcounts, and frees KV blocks; tracks reusable prefixes.

    The pool's block dim is sharded over the data axis, so the allocator
    manages one independent namespace per data shard: a slot living on
    shard ``d`` may only reference that shard's local blocks (block-table
    rows are split by shard_map and index the local pool directly).  Local
    block 0 of every shard is the reserved null block.

    Prefix reuse is vLLM-style hash chaining: full block ``i`` of a prompt
    is keyed by ``h_i = hash((h_{i-1}, tokens[i*bs:(i+1)*bs]))``, so a hit
    guarantees the whole chain matches.  Registered blocks are immutable by
    construction — decode only ever writes into a request's partial tail
    block, which is never registered — which is what makes copy-on-write
    sharing free: a block is either full-and-shared or private-and-mutable,
    never both.  A cache entry lives exactly as long as its block has a
    nonzero refcount (freeing the last reference evicts the entry), so a
    matched block can always be increfed without revalidation.
    """

    def __init__(self, n_blocks: int, block_size: int, n_shards: int = 1):
        if n_blocks % n_shards:
            raise ValueError(f"n_blocks {n_blocks} must divide shards {n_shards}")
        per = n_blocks // n_shards
        if per < 2:
            raise ValueError("need >= 2 blocks per shard (one is the null block)")
        self.block_size = block_size
        self.n_shards = n_shards
        self.blocks_per_shard = per
        self._free = [deque(range(1, per)) for _ in range(n_shards)]
        self._ref: List[Dict[int, int]] = [{} for _ in range(n_shards)]
        # (shard, chain_hash) -> (block id, the block's exact tokens);
        # (shard, block id) -> chain_hash for eviction
        self._prefix: Dict[Tuple[int, int], Tuple[int, Tuple[int, ...]]] = {}
        self._prefix_of: Dict[Tuple[int, int], int] = {}
        self._migrating = 0          # source blocks pinned by in-flight copies

    # -- accounting -------------------------------------------------------
    def free_count(self, shard: int = 0) -> int:
        return len(self._free[shard])

    def used_count(self, shard: int = 0) -> int:
        return len(self._ref[shard])

    def total_used(self) -> int:
        return sum(len(r) for r in self._ref)

    def refcount(self, shard: int, block: int) -> int:
        return self._ref[shard].get(block, 0)

    def migrating_count(self) -> int:
        return self._migrating

    def audit(self, expect_no_migration: bool = False) -> None:
        """Invariant checker for the failure-isolation paths: raises
        ``AssertionError`` naming the first violated invariant.  Called by
        the chaos tests after every quarantine/preempt/rollback so a leaked
        or double-freed block fails loudly at the fault site, not steps
        later as silent K/V corruption.

        Invariants, per shard namespace:
        * conservation — free ∪ referenced is EXACTLY local ids 1..per-1
          (every block is in precisely one place; the null block in neither)
        * no double-free — the free list holds no duplicates
        * no orphans — every referenced block has refcount >= 1, every
          prefix-cache entry points at a live (referenced) block, and the
          two prefix maps are mutually consistent
        * migration pins — the in-flight counter never goes negative, and
          (with ``expect_no_migration``) all pins have drained."""
        per = self.blocks_per_shard
        full = set(range(1, per))
        for sh in range(self.n_shards):
            free = list(self._free[sh])
            fset = set(free)
            assert len(free) == len(fset), \
                f"shard {sh}: duplicate blocks on the free list"
            refd = set(self._ref[sh])
            assert not (fset & refd), \
                f"shard {sh}: blocks both free and referenced: {fset & refd}"
            assert 0 not in fset and 0 not in refd, \
                f"shard {sh}: null block entered circulation"
            assert fset | refd == full, \
                (f"shard {sh}: conservation broken — "
                 f"leaked {full - fset - refd}, foreign {fset | refd - full}")
            for b, c in self._ref[sh].items():
                assert c >= 1, f"shard {sh}: block {b} refcount {c} < 1"
        for (sh, h), (b, _blk) in self._prefix.items():
            assert self._prefix_of.get((sh, b)) == h, \
                f"shard {sh}: prefix maps disagree for block {b}"
            assert b in self._ref[sh], \
                f"shard {sh}: prefix cache points at dead block {b}"
        for (sh, b), h in self._prefix_of.items():
            assert self._prefix.get((sh, h), (None,))[0] == b, \
                f"shard {sh}: prefix_of entry for block {b} is orphaned"
        assert self._migrating >= 0, \
            f"migration pin counter underflow: {self._migrating}"
        if expect_no_migration:
            assert self._migrating == 0, \
                f"{self._migrating} migration pins never drained"

    # -- cross-pool migration pins ---------------------------------------
    # Disaggregated serving copies blocks between shard namespaces with a
    # batched device step that executes AFTER the host has already queued
    # (and possibly released) the source slot.  begin_migration pins each
    # source block with an extra reference so releasing the source slot
    # cannot return it to the free list (and overwrite it with a new
    # prefill) before the copy lands; end_migration drops the pin once the
    # batched copy has executed.
    def begin_migration(self, shard: int, blocks: Sequence[int]) -> None:
        self.incref(shard, blocks)
        self._migrating += len(blocks)

    def end_migration(self, shard: int, blocks: Sequence[int]) -> None:
        self._migrating -= len(blocks)
        self.free(shard, blocks)

    # -- alloc / free -----------------------------------------------------
    def alloc(self, shard: int, n: int) -> Optional[List[int]]:
        """n fresh blocks (refcount 1), or None — never a partial grant."""
        if n > len(self._free[shard]):
            return None
        out = [self._free[shard].popleft() for _ in range(n)]
        for b in out:
            self._ref[shard][b] = 1
        return out

    def incref(self, shard: int, blocks: Sequence[int]) -> None:
        for b in blocks:
            self._ref[shard][b] += 1

    def free(self, shard: int, blocks: Sequence[int]) -> None:
        """Drop one reference per block; refcount 0 returns it to the free
        list and evicts its prefix-cache entry."""
        for b in blocks:
            c = self._ref[shard][b] - 1
            if c:
                self._ref[shard][b] = c
                continue
            del self._ref[shard][b]
            h = self._prefix_of.pop((shard, b), None)
            if h is not None:
                self._prefix.pop((shard, h), None)
            self._free[shard].append(b)

    # -- prefix cache -----------------------------------------------------
    @staticmethod
    def _chain(tokens, block_size: int):
        """-> (chain hash, this block's exact tokens) per full block."""
        h = 0
        for i in range(len(tokens) // block_size):
            blk = tuple(int(t) for t in
                        tokens[i * block_size:(i + 1) * block_size])
            h = hash((h, blk))
            yield h, blk

    def match_prefix(self, shard: int, tokens) -> Tuple[List[int], int]:
        """Longest chain of already-resident full blocks covering a prefix
        of ``tokens`` -> (block ids, tokens covered).  Does NOT incref.
        Hash hits are verified against the stored block tokens — a hash()
        collision must never silently serve another prompt's K/V."""
        blocks: List[int] = []
        for h, blk in self._chain(tokens, self.block_size):
            hit = self._prefix.get((shard, h))
            if hit is None or hit[1] != blk:
                break
            blocks.append(hit[0])
        return blocks, len(blocks) * self.block_size

    def register_prefix(self, shard: int, tokens, blocks: Sequence[int]) -> None:
        """Publish ``blocks`` (the prompt's full blocks, freshly prefilled
        or matched) under the token chain; existing entries win (the chain
        prefix property means they hold identical K/V)."""
        for (h, blk), b in zip(self._chain(tokens, self.block_size), blocks):
            if (shard, h) in self._prefix:
                continue
            self._prefix[(shard, h)] = (b, blk)
            self._prefix_of[(shard, b)] = h
