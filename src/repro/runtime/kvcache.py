"""Cache partition specs + cache utilities.

Cache pytrees are built by ``models.model.init_caches``; leaves are named
dict keys with fixed layouts, so partition specs are assigned by key:

  k/v      (b, local_kv, S, hd)   -> (data*, model, None, None)
  ckv      (b, S, rank)           -> (data*, None, None)      [MLA latent]
  krope    (b, S, rope)           -> (data*, None, None)
  pos      (S,)                   -> (None,)
  h (ssd)  (b, heads, P, N)       -> (data*, model, None, None)
  h (lru)  (b, width)             -> (data*, model)
  conv     (b, W-1, channels)     -> (data*, None, model)

With ``kv_seq_shard`` (long_500k: batch 1, cache sequence sharded over the
data axis) the attention-cache sequence dim takes "data" and batch is
replicated; recurrent state stays tiny and batch-replicated.
Scanned groups prepend a None (layer-stack) axis.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
from jax.sharding import PartitionSpec as P

from repro.models import model as M
from repro.models import transformer as tfm

Pytree = Any


def _leaf_spec(key: str, ndim: int, dist, kv_seq_shard: bool, stacked: bool,
               replicate_batch: bool = False):
    d = None if (kv_seq_shard or replicate_batch) else (
        dist.data_axes if len(dist.data_axes) > 1 else dist.data_axes[0]
    )
    seq = dist.data_axis if kv_seq_shard else None
    m = dist.model_axis
    if key in ("k", "v"):
        spec = (d, m, seq, None)
    elif key in ("k_scale", "v_scale"):
        spec = (d, m, seq)
    elif key in ("ckv", "krope"):
        spec = (d, seq, None)
    elif key == "pos":
        spec = (seq,)
    elif key == "h":                       # recurrent state: always batch-major
        spec = (d, m, None, None)[:ndim]
    elif key == "conv":
        spec = (d, None, m)
    else:
        raise KeyError(f"unknown cache leaf {key!r}")
    if stacked:
        spec = (None,) + tuple(spec)
    return P(*spec)


def cache_pspecs(ctx: M.ModelCtx, *, kv_seq_shard: bool = False,
                 replicate_batch: bool = False) -> Tuple:
    """Spec tree matching ``init_caches`` exactly (same treedef)."""
    groups = tfm.build_groups(ctx.cfg)
    # build a template (tiny batch) to mirror structure + ndims
    template = jax.eval_shape(lambda: M.init_caches(ctx, 1, 2, kv_seq_shard_dp=1))
    out = []
    for g, gc in zip(groups, template):
        stacked = g.n > 1

        def spec_of(subtree):
            return {
                k: (
                    spec_of(v)
                    if isinstance(v, dict)
                    else _leaf_spec(k, v.ndim - (1 if stacked else 0), ctx.dist,
                                   kv_seq_shard, stacked, replicate_batch)
                )
                for k, v in subtree.items()
            }

        out.append(spec_of(gc))
    return tuple(out)


def cache_shapes(ctx: M.ModelCtx, batch_local: int, cache_len: int,
                 *, kv_seq_shard_dp: int = 1) -> Tuple:
    """ShapeDtypeStructs of the GLOBAL cache arrays (for the dry-run)."""
    local = jax.eval_shape(
        lambda: M.init_caches(ctx, batch_local, cache_len,
                              kv_seq_shard_dp=kv_seq_shard_dp)
    )
    return local
