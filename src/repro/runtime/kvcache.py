"""Cache partition specs + cache utilities.

Cache pytrees are built by ``models.model.init_caches``; leaves are named
dict keys with fixed layouts, so partition specs are assigned by key:

  k/v      (b, local_kv, S, hd)   -> (data*, model, None, None)
  ckv      (b, S, rank)           -> (data*, None, None)      [MLA latent]
  krope    (b, S, rope)           -> (data*, None, None)
  pos      (S,)                   -> (None,)
  h (ssd)  (b, heads, P, N)       -> (data*, model, None, None)
  h (lru)  (b, width)             -> (data*, model)
  conv     (b, W-1, channels)     -> (data*, None, model)

With ``kv_seq_shard`` (long_500k: batch 1, cache sequence sharded over the
data axis) the attention-cache sequence dim takes "data" and batch is
replicated; recurrent state stays tiny and batch-replicated.
Scanned groups prepend a None (layer-stack) axis.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import model as M
from repro.models import transformer as tfm

Pytree = Any


def _leaf_spec(key: str, ndim: int, dist, kv_seq_shard: bool, stacked: bool,
               replicate_batch: bool = False):
    d = None if (kv_seq_shard or replicate_batch) else (
        dist.data_axes if len(dist.data_axes) > 1 else dist.data_axes[0]
    )
    seq = dist.data_axis if kv_seq_shard else None
    m = dist.model_axis
    if key in ("k", "v"):
        spec = (d, m, seq, None)
    elif key in ("k_scale", "v_scale"):
        spec = (d, m, seq)
    elif key in ("ckv", "krope"):
        spec = (d, seq, None)
    elif key == "pos":
        # (S,) shared positions, or (b, S) per-slot (continuous batching)
        spec = (seq,) if ndim == 1 else (d, seq)
    elif key == "h":                       # recurrent state: always batch-major
        spec = (d, m, None, None)[:ndim]
    elif key == "conv":
        spec = (d, None, m)
    else:
        raise KeyError(f"unknown cache leaf {key!r}")
    if stacked:
        spec = (None,) + tuple(spec)
    return P(*spec)


def cache_pspecs(ctx: M.ModelCtx, *, kv_seq_shard: bool = False,
                 replicate_batch: bool = False,
                 batched_pos: bool = False) -> Tuple:
    """Spec tree matching ``init_caches`` exactly (same treedef)."""
    groups = tfm.build_groups(ctx.cfg)
    # build a template (tiny batch) to mirror structure + ndims
    template = jax.eval_shape(
        lambda: M.init_caches(ctx, 1, 2, kv_seq_shard_dp=1,
                              batched_pos=batched_pos))
    out = []
    for g, gc in zip(groups, template):
        stacked = g.n > 1

        def spec_of(subtree):
            return {
                k: (
                    spec_of(v)
                    if isinstance(v, dict)
                    else _leaf_spec(k, v.ndim - (1 if stacked else 0), ctx.dist,
                                   kv_seq_shard, stacked, replicate_batch)
                )
                for k, v in subtree.items()
            }

        out.append(spec_of(gc))
    return tuple(out)


def cache_shapes(ctx: M.ModelCtx, batch_local: int, cache_len: int,
                 *, kv_seq_shard_dp: int = 1) -> Tuple:
    """ShapeDtypeStructs of the GLOBAL cache arrays (for the dry-run)."""
    local = jax.eval_shape(
        lambda: M.init_caches(ctx, batch_local, cache_len,
                              kv_seq_shard_dp=kv_seq_shard_dp)
    )
    return local


# ---------------------------------------------------------------------------
# Slot-level utilities (continuous batching)
#
# Caches built with ``batched_pos=True`` treat every batch row as an
# independent *slot*: a request occupies one row, its per-slot position
# array masks validity, and recurrent state lives in the same row.  The
# helpers below operate on whole slots inside a jitted program: reset before
# an in-flight admission, mask prompt padding out of the position arrays,
# and merge freshly-prefilled slots into a live cache.
# ---------------------------------------------------------------------------


def _map_by_key(caches: Tuple, groups, fn) -> Tuple:
    """Apply ``fn(key, leaf, stacked)`` to every leaf, keyed by cache name."""

    def walk(subtree, stacked):
        return {
            k: walk(v, stacked) if isinstance(v, dict) else fn(k, v, stacked)
            for k, v in subtree.items()
        }

    return tuple(walk(gc, g.n > 1) for g, gc in zip(groups, caches))


def _expand_over(mask, leaf, stacked):
    """Broadcast a (b,) mask against the leaf's batch axis (1 if stacked)."""
    ax = 1 if stacked else 0
    shape = (1,) * ax + (mask.shape[0],) + (1,) * (leaf.ndim - ax - 1)
    return mask.reshape(shape)


def reset_slots(caches: Tuple, groups, mask: jax.Array) -> Tuple:
    """Clear the slots selected by ``mask`` (b,) bool for a fresh request.

    Positions go to -1 (masking every stale K/V entry without touching the
    K/V bytes) and recurrent state (SSM h, LRU h, conv tails) zeroes, since
    prefill integrates state from t=0.  K/V payloads stay: they are dead by
    position masking and get overwritten as the new request progresses."""

    def f(key, leaf, stacked):
        if key == "pos":
            if leaf.ndim - (1 if stacked else 0) != 2:
                raise ValueError("reset_slots needs batched_pos caches")
            return jnp.where(_expand_over(mask, leaf, stacked), -1, leaf)
        if key in ("h", "conv"):
            return jnp.where(_expand_over(mask, leaf, stacked),
                             jnp.zeros((), leaf.dtype), leaf)
        return leaf

    return _map_by_key(caches, groups, f)


def mask_prompt_padding(caches: Tuple, groups, plens: jax.Array) -> Tuple:
    """Invalidate position entries at/after each slot's true prompt length.

    Admission prefills a whole (b, Lp) padded batch; K/V written for padding
    tokens must never be attended, so their pos entries drop to -1.  Decode
    then overwrites index plen, plen+1, ... with real generated tokens."""

    def f(key, leaf, stacked):
        if key != "pos":
            return leaf
        S = leaf.shape[-1]
        idx = jnp.arange(S, dtype=jnp.int32)
        keep = idx[None, :] < plens[:, None]                 # (b, S)
        if stacked:
            keep = keep[None]
        return jnp.where(keep, leaf, -1)

    return _map_by_key(caches, groups, f)


def merge_slots(old: Tuple, new: Tuple, groups, mask: jax.Array) -> Tuple:
    """Per-slot select: rows where ``mask`` is True come from ``new``."""

    def walk(o, n, stacked):
        if isinstance(o, dict):
            return {k: walk(o[k], n[k], stacked) for k in o}
        return jnp.where(_expand_over(mask, o, stacked), n, o)

    return tuple(walk(go, gn, g.n > 1) for g, go, gn in zip(groups, old, new))
