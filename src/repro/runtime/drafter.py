"""Model-free speculative drafting: prompt-lookup / n-gram continuation.

Autoregressive decode is bandwidth-bound — one weight sweep buys exactly one
token per sequence — so the remaining big lever after sync minimization
(arXiv 2407.00029) and batching is amortizing the sweep across several
tokens.  Draft-model speculation needs a second model resident in memory (on
CPUs, exactly the resource the paper is rationing); *prompt lookup* instead
proposes the continuation of the most recent occurrence of the sequence's
trailing n-gram in its own history (prompt + generated tokens).  That is
free on the host, needs no extra memory, and wins precisely on the
workloads where decode output overlaps its context (summarization,
code edit, RAG, extraction) or where generation is locally repetitive.

The drafter is pure host-side numpy; the engine's fused verify step scores
all ``k`` proposals plus the bonus position in one forward pass and accepts
the longest matching prefix, so a wrong draft costs compute but never
correctness: greedy speculative decode is token-identical to plain greedy
decode by construction (targets are argmaxes of the same conditionals).
"""
from __future__ import annotations

from typing import Optional

import numpy as np


class NgramDrafter:
    """Propose ``k`` draft tokens per call by n-gram prompt lookup.

    For ``n = ngram_max .. ngram_min``: find the most recent earlier
    occurrence of the history's trailing n-gram; if found, propose the ``k``
    tokens that followed it (padded by repeating the continuation's tail
    when the match sits near the end of history).  Longer n-grams are tried
    first — they are rarer and their continuations more reliable.  With no
    match at any n, the last token is repeated: a guaranteed-shape fallback
    that costs nothing when rejected (the verify step still emits its one
    bonus token, so the zero-acceptance floor is exactly plain decode).
    """

    def __init__(self, k: int, ngram_max: int = 3, ngram_min: int = 1):
        if k < 1:
            raise ValueError("drafter needs k >= 1")
        if not (1 <= ngram_min <= ngram_max):
            raise ValueError("need 1 <= ngram_min <= ngram_max")
        self.k = k
        self.ngram_max = ngram_max
        self.ngram_min = ngram_min

    @staticmethod
    def _last_match(hist: np.ndarray, n: int) -> Optional[int]:
        """Start index of the most recent occurrence of ``hist[-n:]`` that
        ends strictly before the final position (so a continuation exists),
        or None."""
        if len(hist) <= n:
            return None
        pat = hist[-n:]
        # windows start at 0..len-n; the last one IS the pattern — exclude it
        win = np.lib.stride_tricks.sliding_window_view(hist, n)[:-1]
        matches = np.nonzero((win == pat).all(axis=1))[0]
        return int(matches[-1]) if matches.size else None

    def propose(self, history: np.ndarray) -> np.ndarray:
        """history (prompt + generated so far, most recent last) -> (k,)
        int32 draft tokens continuing it."""
        hist = np.asarray(history, dtype=np.int64).ravel()
        if len(hist) == 0:
            raise ValueError("cannot draft from an empty history")
        for n in range(min(self.ngram_max, len(hist) - 1),
                       self.ngram_min - 1, -1):
            i = self._last_match(hist, n)
            if i is None:
                continue
            cont = hist[i + n: i + n + self.k]
            if len(cont) < self.k:           # match near the end: pad by
                cont = np.concatenate(       # repeating the continuation tail
                    [cont, np.full(self.k - len(cont), cont[-1])])
            return cont.astype(np.int32)
        return np.full(self.k, hist[-1], np.int32)

    def propose_many(self, histories) -> np.ndarray:
        """Draft for a batch of histories -> (len(histories), k) int32.

        The serving loop's shape: one call per scheduler step with every
        active slot's history, so the host drafting cost sits in one place
        — under the overlapped engine loop this is exactly the work that
        runs while the previous verify step is still in flight on the
        device."""
        if not len(histories):
            return np.zeros((0, self.k), np.int32)
        return np.stack([self.propose(h) for h in histories])
