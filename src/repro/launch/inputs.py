"""ShapeDtypeStruct input builders shared by the dry-run and launchers.

``input_specs(arch, shape, ...)`` returns weak-type-correct, shardable
stand-ins for every model input — no device allocation (deliverable (e).2).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig, ParallelConfig
from repro.models import model as M
from repro.runtime import kvcache

Pytree = Any


def needs_kv_seq_shard(cfg: ModelConfig, shape: InputShape) -> bool:
    """long_500k decode with any FULL-attention layer -> shard the cache
    sequence over the data axis (window/SSM/RG-LRU caches stay O(window))."""
    return (
        shape.kind == "decode"
        and shape.seq_len >= 262_144
        and any(cfg.block_kind(i) == "attn" for i in range(len(cfg.layer_pattern)))
    )


def parallel_for(cfg: ModelConfig, shape: InputShape, *, tp: int, dp: int,
                 pods: int = 1, use_pallas: bool = False) -> ParallelConfig:
    return ParallelConfig(
        tp=tp, dp=dp, pods=pods,
        seq_parallel=True,
        kv_seq_shard=needs_kv_seq_shard(cfg, shape),
        remat=shape.kind == "train",
        use_pallas=use_pallas,
    )


def _sds(shape, dtype, mesh, spec):
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def _globalize(local_tree: Pytree, spec_tree: Pytree, mesh) -> Pytree:
    """Local (per-shard) ShapeDtypeStructs -> global, by multiplying each dim
    by the total size of the mesh axes its spec entry names."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def one(local, spec):
        dims = list(local.shape)
        for i, entry in enumerate(tuple(spec)):
            if entry is None:
                continue
            axes = entry if isinstance(entry, (tuple, list)) else (entry,)
            for a in axes:
                dims[i] *= sizes[a]
        return _sds(tuple(dims), local.dtype, mesh, spec)

    return jax.tree.map(one, local_tree, spec_tree,
                        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def batch_axes(ctx: M.ModelCtx):
    d = ctx.dist.data_axes
    return d if len(d) > 1 else d[0]


def token_specs(ctx: M.ModelCtx, mesh, global_batch: int, text_len: int,
                *, replicate_batch: bool = False) -> jax.ShapeDtypeStruct:
    cfg = ctx.cfg
    b_ax = None if replicate_batch else batch_axes(ctx)
    shp = (global_batch, text_len) if cfg.n_codebooks == 1 else (
        global_batch, text_len, cfg.n_codebooks)
    spec = P(b_ax, None) if cfg.n_codebooks == 1 else P(b_ax, None, None)
    return _sds(shp, jnp.int32, mesh, spec)


def feature_specs(ctx: M.ModelCtx, mesh, global_batch: int,
                  *, replicate_batch: bool = False):
    f = ctx.cfg.frontend
    if f is None:
        return None
    b_ax = None if replicate_batch else batch_axes(ctx)
    return _sds((global_batch, f.prefix_len, f.feature_dim), jnp.float32, mesh,
                P(b_ax, None, None))


def param_input_specs(ctx: M.ModelCtx, mesh) -> Pytree:
    shapes = M.param_shapes(ctx)
    specs = M.param_specs(ctx)
    return jax.tree.map(
        lambda s, sp: _sds(s.shape, s.dtype, mesh, sp), shapes, specs,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
    )


def replicate_batch_for(ctx: M.ModelCtx, shape: InputShape) -> bool:
    return shape.global_batch < ctx.dist.dp * ctx.dist.pods


def cache_input_specs(ctx: M.ModelCtx, mesh, shape: InputShape) -> Tuple[Pytree, Pytree]:
    """-> (global cache ShapeDtypeStructs, cache specs)."""
    kv_seq = ctx.parallel.kv_seq_shard
    rep_b = replicate_batch_for(ctx, shape)
    dp_total = ctx.dist.dp * ctx.dist.pods
    if kv_seq or rep_b:
        b_local, kv_dp = shape.global_batch, (ctx.dist.dp if kv_seq else 1)
    else:
        b_local, kv_dp = shape.global_batch // dp_total, 1
    local = jax.eval_shape(
        lambda: M.init_caches(ctx, b_local, shape.seq_len, kv_seq_shard_dp=kv_dp)
    )
    specs = kvcache.cache_pspecs(ctx, kv_seq_shard=kv_seq, replicate_batch=rep_b)
    return _globalize(local, specs, mesh), specs


def rng_spec(mesh):
    k = jax.eval_shape(lambda: jax.random.key(0))
    return _sds(k.shape, k.dtype, mesh, P())


def text_len_for(cfg: ModelConfig, shape: InputShape) -> int:
    """seq_len is the TOTAL sequence; multimodal prefix comes out of it."""
    if cfg.frontend is not None and shape.kind != "decode":
        return shape.seq_len - cfg.frontend.prefix_len
    return shape.seq_len
