"""Mesh construction. Functions only — importing this module never touches
jax device state."""
from __future__ import annotations

from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """Production target: one v5e pod 16x16 = 256 chips, or 2 pods = 512.

    The dry-run launcher sets XLA_FLAGS=--xla_force_host_platform_device_count
    before any jax import so these shapes materialise on CPU."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_local_mesh(dp: int = 1, tp: int = 1):
    """Test/example mesh over however many (virtual) devices exist."""
    return make_mesh((dp, tp), ("data", "model"))


def split_data_shards(n_shards: int, prefill_shards: int):
    """Role assignment for disaggregated serving: data shards
    ``[0, prefill_shards)`` form the prefill pool, the rest the decode pool.
    Contiguous ranges, so each pool's slots and block namespaces stay
    shard-local and the split is pure host bookkeeping — the mesh itself is
    unchanged (one shard_map program still spans both pools)."""
    if not 0 < prefill_shards < n_shards:
        raise ValueError(
            f"need 1 <= prefill_shards < data shards; got "
            f"prefill_shards={prefill_shards} with {n_shards} shard(s)")
    return (tuple(range(prefill_shards)),
            tuple(range(prefill_shards, n_shards)))
