import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any other import (jax locks the device count on first init).

"""Multi-pod dry-run (deliverable (e)).

For every (architecture x input-shape x mesh) combination, lower + compile
the real step function (train_step / prefill_step / decode_step) on the
production mesh — 16x16 = 256 chips single-pod, 2x16x16 = 512 multi-pod —
with ShapeDtypeStruct stand-ins (no allocation), and record:

  * memory_analysis()  — per-device bytes: proves the configuration fits;
  * cost_analysis()    — HLO FLOPs / bytes for the roofline (§g);
  * the collective schedule parsed from the optimized HLO — op counts,
    payload bytes, and estimated per-device wire bytes per collective kind.

Usage:
  python -m repro.launch.dryrun                      # full 10x4x2 sweep
  python -m repro.launch.dryrun --arch yi-9b --shape decode_32k --multi-pod
  python -m repro.launch.dryrun --out experiments/dryrun
"""
import argparse
import json
import re
import time
import traceback
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.configs import ASSIGNED_ARCHS, get_config, get_shape
from repro.configs.base import INPUT_SHAPES, SamplingConfig
from repro.launch import inputs as I
from repro.launch.mesh import make_production_mesh
from repro.models import model as M
from repro.models.common import specs_of
from repro.runtime.engine import make_decode_step, make_prefill_step
from repro.training.train_loop import AdamWConfig, make_train_step
from repro.training.zero import zero_state_defs

DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "bf16": 2, "f16": 2, "s16": 2,
               "u16": 2, "f32": 4, "s32": 4, "u32": 4, "f64": 8, "s64": 8,
               "u64": 8, "c64": 8}

COLL_RE = re.compile(
    r"=\s*(?P<res>\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s*"
    r"(?P<kind>all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\("
)
SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
GROUPS_RE = re.compile(r"replica_groups=\{(\{[^}]*\})")
GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _result_bytes(res: str) -> int:
    total = 0
    for dt, dims in SHAPE_RE.findall(res):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = GROUPS_RE.search(line)
    if m:
        return len(m.group(1).strip("{}").split(","))
    return 1


def wire_bytes(kind: str, result_bytes: int, n: int) -> int:
    """Per-device bytes crossing links for ring implementations."""
    if n <= 1:
        return 0
    if kind == "all-gather":
        return result_bytes * (n - 1) // n
    if kind == "all-reduce":
        return 2 * result_bytes * (n - 1) // n
    if kind == "reduce-scatter":
        return result_bytes * (n - 1)          # result is the 1/n shard
    if kind == "all-to-all":
        return result_bytes * (n - 1) // n
    return result_bytes                         # collective-permute


def parse_collectives(hlo: str) -> dict:
    per_kind = {}
    seen_done = set()
    for line in hlo.splitlines():
        m = COLL_RE.search(line)
        if not m:
            continue
        if "-done(" in line:
            continue                            # count start ops only
        kind = m.group("kind")
        rb = _result_bytes(m.group("res"))
        n = _group_size(line)
        d = per_kind.setdefault(kind, {"count": 0, "result_bytes": 0, "wire_bytes": 0})
        d["count"] += 1
        d["result_bytes"] += rb
        d["wire_bytes"] += wire_bytes(kind, rb, n)
    return per_kind


def _opt_input_specs(ctx, mesh):
    defs = zero_state_defs(M.model_defs(ctx), ctx.dist)
    from repro.models.common import is_def

    return (
        jax.tree.map(
            lambda d: jax.ShapeDtypeStruct(
                d.shape, d.dtype, sharding=NamedSharding(mesh, d.spec)
            ),
            defs, is_leaf=is_def,
        ),
        specs_of(defs),
    )


def build_lowered(arch: str, shape_name: str, *, multi_pod: bool,
                  use_pallas: bool = False, overrides=None,
                  n_layers_override: int = 0):
    cfg = get_config(arch)
    if n_layers_override:
        import dataclasses as _dc

        cfg = _dc.replace(cfg, n_layers=n_layers_override, force_unroll=True)
    shape = get_shape(shape_name)
    overrides = dict(overrides or {})
    tp = overrides.pop("tp", 16)
    dp = overrides.pop("dp", 16)
    grad_accum = overrides.pop("grad_accum", 1)
    if (tp, dp) == (16, 16):
        mesh = make_production_mesh(multi_pod=multi_pod)
    else:  # same chip count, different geometry (perf experiments)
        shp = (2, dp, tp) if multi_pod else (dp, tp)
        axes = ("pod", "data", "model") if multi_pod else ("data", "model")
        mesh = compat.make_mesh(shp, axes)
    pods = 2 if multi_pod else 1
    par = I.parallel_for(cfg, shape, tp=tp, dp=dp, pods=pods, use_pallas=use_pallas)
    if overrides:
        import dataclasses

        par = dataclasses.replace(par, **overrides)
    ctx = M.ModelCtx.make(cfg, par, pod_axis="pod" if multi_pod else None)
    pspecs = M.param_specs(ctx)
    p_in = I.param_input_specs(ctx, mesh)
    sm = partial(compat.shard_map, mesh=mesh, check_vma=False)
    rep_b = I.replicate_batch_for(ctx, shape)
    b_ax = None if rep_b else I.batch_axes(ctx)
    text_len = I.text_len_for(cfg, shape)

    if shape.kind == "train":
        step = make_train_step(ctx, AdamWConfig(), zero1=True,
                               grad_accum=grad_accum)
        opt_in, ospecs = _opt_input_specs(ctx, mesh)
        tok = I.token_specs(ctx, mesh, shape.global_batch, text_len,
                            replicate_batch=rep_b)
        batch_in = {"tokens": tok, "labels": tok}
        bspecs = {"tokens": tok.sharding.spec, "labels": tok.sharding.spec}
        feat = I.feature_specs(ctx, mesh, shape.global_batch, replicate_batch=rep_b)
        if feat is not None:
            batch_in["features"] = feat
            bspecs["features"] = feat.sharding.spec
        fn = sm(step, in_specs=(pspecs, ospecs, bspecs),
                out_specs=(pspecs, ospecs, P()))
        lowered = jax.jit(fn, donate_argnums=(0, 1)).lower(p_in, opt_in, batch_in)

    elif shape.kind == "prefill":
        step = make_prefill_step(ctx, SamplingConfig())
        caches_in, cspecs = I.cache_input_specs(ctx, mesh, shape)
        tok = I.token_specs(ctx, mesh, shape.global_batch, text_len,
                            replicate_batch=rep_b)
        feat = I.feature_specs(ctx, mesh, shape.global_batch, replicate_batch=rep_b)
        tok_out = P(b_ax) if cfg.n_codebooks == 1 else P(b_ax, None)
        if feat is None:
            fn = sm(lambda p, t, c, r: step(p, t, None, c, r),
                    in_specs=(pspecs, tok.sharding.spec, cspecs, P()),
                    out_specs=(tok_out, cspecs))
            lowered = jax.jit(fn, donate_argnums=(2,)).lower(
                p_in, tok, caches_in, I.rng_spec(mesh))
        else:
            fn = sm(step,
                    in_specs=(pspecs, tok.sharding.spec, feat.sharding.spec,
                              cspecs, P()),
                    out_specs=(tok_out, cspecs))
            lowered = jax.jit(fn, donate_argnums=(3,)).lower(
                p_in, tok, feat, caches_in, I.rng_spec(mesh))

    else:  # decode
        step = make_decode_step(ctx, SamplingConfig())
        caches_in, cspecs = I.cache_input_specs(ctx, mesh, shape)
        tok_spec = P(b_ax) if cfg.n_codebooks == 1 else P(b_ax, None)
        tshape = (shape.global_batch,) if cfg.n_codebooks == 1 else (
            shape.global_batch, cfg.n_codebooks)
        tok = jax.ShapeDtypeStruct(tshape, jnp.int32,
                                   sharding=NamedSharding(mesh, tok_spec))
        cur = jax.ShapeDtypeStruct((), jnp.int32,
                                   sharding=NamedSharding(mesh, P()))
        fn = sm(step, in_specs=(pspecs, tok_spec, cspecs, P(), P()),
                out_specs=(tok_spec, cspecs))
        lowered = jax.jit(fn, donate_argnums=(2,)).lower(
            p_in, tok, caches_in, cur, I.rng_spec(mesh))

    return lowered, ctx, mesh, shape


def analyze(lowered, compiled, ctx, shape, *, t_compile: float) -> dict:
    mem = compiled.memory_analysis()
    cost = compat.cost_analysis(compiled)
    hlo = compiled.as_text()
    colls = parse_collectives(hlo)
    from repro.core.zero_copy import count_copies

    cfg = ctx.cfg
    n_chips = ctx.dist.tp * ctx.dist.dp * ctx.dist.pods
    rec = {
        "arch": cfg.name,
        "shape": shape.name,
        "kind": shape.kind,
        "chips": n_chips,
        "compile_s": round(t_compile, 1),
        "flops": cost.get("flops", 0.0),
        "bytes_accessed": cost.get("bytes accessed", 0.0),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "code_bytes": mem.generated_code_size_in_bytes,
        },
        "collectives": colls,
        "copies": count_copies(hlo),
        "model_params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
    }
    return rec


def _cost_probe(arch, shape_name, multi_pod, n_layers, overrides):
    """flops / bytes / collectives of a depth-reduced, FULLY-UNROLLED compile
    (inner chunk scans unrolled too — cost_analysis counts loop bodies once)."""
    from repro.models.common import UNROLL_SCANS

    token = UNROLL_SCANS.set(True)
    try:
        lowered, ctx, mesh, shape = build_lowered(
            arch, shape_name, multi_pod=multi_pod, overrides=overrides,
            n_layers_override=n_layers)
        compiled = lowered.compile()
    finally:
        UNROLL_SCANS.reset(token)
    cost = compat.cost_analysis(compiled)
    colls = parse_collectives(compiled.as_text())
    return {
        "flops": cost.get("flops", 0.0),
        "bytes_accessed": cost.get("bytes accessed", 0.0),
        "collectives": colls,
    }


def _layer_bases(arch: str) -> tuple:
    """(base1, base2, n_full_periods): two shallow depths whose difference is
    exactly one pattern period, plus how many periods the full config has.
    XLA's cost_analysis counts while-loop bodies ONCE, so per-layer costs are
    recovered by the two-point delta and scaled to full depth."""
    cfg = get_config(arch)
    p = len(cfg.layer_pattern)
    extra = len(cfg.dense_ffn_layers)
    n_regular = cfg.n_layers - extra
    n_periods = n_regular // p
    rem = n_regular % p
    base1 = extra + rem + p
    base2 = extra + rem + 2 * p
    return base1, base2, n_periods


def _merge_coll(c1, c2, scale):
    """c1 + scale * (c2 - c1), per collective kind/field."""
    out = {}
    for kind in set(c1) | set(c2):
        a = c1.get(kind, {"count": 0, "result_bytes": 0, "wire_bytes": 0})
        b = c2.get(kind, {"count": 0, "result_bytes": 0, "wire_bytes": 0})
        out[kind] = {
            f: round(a[f] + scale * (b[f] - a[f]))
            for f in ("count", "result_bytes", "wire_bytes")
        }
    return out


def run_one(arch: str, shape_name: str, *, multi_pod: bool, out_dir: str,
            force: bool = False, overrides=None) -> dict:
    mesh_tag = "pod2x16x16" if multi_pod else "pod16x16"
    path = os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_tag}.json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)
    t0 = time.time()
    lowered, ctx, mesh, shape = build_lowered(arch, shape_name, multi_pod=multi_pod,
                                              overrides=overrides)
    compiled = lowered.compile()
    t_compile = time.time() - t0
    print(compiled.memory_analysis())
    rec = analyze(lowered, compiled, ctx, shape, t_compile=t_compile)
    # --- loop-aware cost extrapolation (see _layer_bases) -------------------
    base1, base2, n_periods = _layer_bases(arch)
    if n_periods > 1:
        c1 = _cost_probe(arch, shape_name, multi_pod, base1, overrides)
        c2 = _cost_probe(arch, shape_name, multi_pod, base2, overrides)
        scale = n_periods - 1
        rec["flops"] = c1["flops"] + scale * (c2["flops"] - c1["flops"])
        rec["bytes_accessed"] = c1["bytes_accessed"] + scale * (
            c2["bytes_accessed"] - c1["bytes_accessed"])
        rec["collectives"] = _merge_coll(c1["collectives"], c2["collectives"], scale)
        rec["cost_extrapolated"] = {"base1": base1, "base2": base2,
                                    "n_periods": n_periods}
    os.makedirs(out_dir, exist_ok=True)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(ASSIGNED_ARCHS)
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
    meshes = [args.multi_pod] if (args.arch or args.multi_pod) else [False, True]
    if args.single_pod_only:
        meshes = [False]

    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch} x {shape} x {'2x16x16' if mp else '16x16'}"
                try:
                    t0 = time.time()
                    rec = run_one(arch, shape, multi_pod=mp, out_dir=args.out,
                                  force=args.force)
                    coll_wire = sum(v["wire_bytes"] for v in rec["collectives"].values())
                    print(f"OK   {tag}: {rec['flops']:.3e} flops, "
                          f"temp {rec['memory']['temp_bytes']/2**30:.2f} GiB/dev, "
                          f"wire {coll_wire/2**20:.1f} MiB/dev "
                          f"({time.time()-t0:.0f}s)", flush=True)
                except Exception as e:  # noqa: BLE001 - report and continue
                    failures.append((tag, repr(e)))
                    print(f"FAIL {tag}: {e}", flush=True)
                    traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for t, e in failures:
            print(" ", t, e)
        raise SystemExit(1)
    print("\nAll dry-run combinations compiled successfully.")


if __name__ == "__main__":
    main()
