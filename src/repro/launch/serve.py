"""Serving driver: ``python -m repro.launch.serve --arch yi-9b --requests 8``.

Runs the batched-request serving example on a local mesh with the paper's
optimizations on; reports per-token latency (the paper's §3 metric) and
per-request stats from the wave scheduler.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ParallelConfig, SamplingConfig, get_config
from repro.launch.mesh import make_local_mesh
from repro.runtime.engine import Engine
from repro.runtime.scheduler import WaveScheduler


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-9b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--dp", type=int, default=1)
    ap.add_argument("--tp", type=int, default=1)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--top-k", type=int, default=40)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--no-topk-sync", action="store_true",
                    help="disable paper §2.1b (baseline full-vocab gather)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    mesh = make_local_mesh(args.dp, args.tp)
    par = ParallelConfig(tp=args.tp, dp=args.dp, remat=False,
                         topk_sync=not args.no_topk_sync)
    eng = Engine(cfg=cfg, parallel=par,
                 sampling=SamplingConfig(top_k=args.top_k),
                 mesh=mesh, max_len=args.max_len)

    rng = np.random.default_rng(0)
    sched = WaveScheduler(eng, batch_size=args.batch)
    for _ in range(args.requests):
        plen = int(rng.integers(4, args.prompt_len + 1))
        shape = (plen,) if cfg.n_codebooks == 1 else (plen, cfg.n_codebooks)
        sched.submit(rng.integers(0, cfg.vocab_size, shape).astype(np.int32),
                     max_new=args.max_new)
    t0 = time.monotonic()
    done = sched.run()
    dt = time.monotonic() - t0
    total_tokens = sum(len(r.output) for r in done)
    print(f"served {len(done)} requests, {total_tokens} tokens "
          f"in {dt:.2f}s -> {1000*dt/max(total_tokens,1):.1f} ms/token "
          f"(batched; arch={cfg.name}, tp={args.tp})")
    for r in done[:4]:
        out = r.output if r.output.ndim == 1 else r.output[..., 0]
        print(f"  req {r.rid}: {len(r.output)} tokens, first 8: {out[:8].tolist()}")
    return done


if __name__ == "__main__":
    main()
