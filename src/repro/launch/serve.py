"""Serving driver: ``python -m repro.launch.serve --arch yi-9b --requests 8``.

Runs the batched-request serving example on a local mesh with the paper's
optimizations on; reports per-token latency (the paper's §3 metric) and
per-request stats from the selected scheduler.

Schedulers (``--scheduler``):

  wave        drain-and-restart baseline: waves of ``--batch`` requests pad
              to the longest prompt and decode to the wave's max ``--max-new``.
  continuous  slot engine: ``--slots`` fixed slots, per-slot positions,
              finished slots masked in-program, arrivals admitted in-flight
              by prefilling into free slots (no batch restart).  Extra knobs:
              ``--block-steps`` fused masked decode steps per host round
              trip, ``--arrival-every`` staggers request arrivals on the
              virtual decode-step clock, ``--max-new-spread`` draws each
              request's budget from [max_new/spread, max_new] to create the
              straggler-heavy mix continuous batching wins on.
  paged       slot engine over the paged KV pool: ``--kv-block-size`` tokens
              per block, ``--kv-pool-blocks`` total pool blocks (0 = the
              dense n_slots x max_len footprint; shrink to overcommit —
              allocator exhaustion preempts the youngest request instead of
              failing), ``--no-prefix-cache`` disables shared-prefix block
              reuse, ``--shared-prefix N`` prepends one common N-token
              system prompt to every request so the reuse path is visible.

  disagg      paged slot engine with the data axis split into a prefill
              pool and a decode pool (``--prefill-shards`` of ``--dp``):
              prompts chunk-prefill on the prefill shards only, finished KV
              blocks migrate to the decode shards in batched jitted copy
              steps, and decode never shares a dispatch with admission.
              Reports per-pool stats: occupancy, migrated blocks/bytes,
              decode-side prefix hits that skipped the copy, and
              migration-wait percentiles.  Needs ``--dp >= 2`` and an arch
              whose capability record supports the disaggregated path
              (``--list-archs`` prints the matrix).

All continuous schedulers also take ``--spec-k N`` (speculative decoding:
n-gram prompt-lookup drafts + fused multi-token verify, emitting 1..N+1
tokens per step; ``--spec-ngram`` caps the lookup n-gram length and
``--no-spec-decode`` forces plain decode) — the stats block then reports
acceptance rate and tokens/step.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs import ParallelConfig, SamplingConfig, get_config
from repro.launch.mesh import make_local_mesh
from repro.runtime.engine import Engine
from repro.runtime.scheduler import (ContinuousScheduler, DisaggScheduler,
                                     PagedContinuousScheduler, WaveScheduler)


def build_engine(args):
    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    mesh = make_local_mesh(args.dp, args.tp)
    par = ParallelConfig(tp=args.tp, dp=args.dp, remat=False,
                         topk_sync=not args.no_topk_sync,
                         kv_block_size=args.kv_block_size,
                         kv_pool_blocks=args.kv_pool_blocks,
                         prefill_chunk=args.prefill_chunk,
                         flash_prefill=not args.no_flash_prefill,
                         spec_k=0 if args.no_spec_decode else args.spec_k,
                         spec_ngram=args.spec_ngram,
                         weight_quant=args.weight_quant,
                         wq_group_size=args.wq_group_size,
                         overlap_decode=args.overlap,
                         fault_plan=args.fault_plan,
                         max_step_retries=args.max_step_retries,
                         retry_backoff_s=args.retry_backoff_s,
                         slo_interactive_s=args.slo_interactive,
                         slo_standard_s=args.slo_standard,
                         slo_batch_s=args.slo_batch,
                         interactive_reserve_slots=args.interactive_reserve_slots,
                         interactive_reserve_blocks=args.interactive_reserve_blocks,
                         overload_degrade=args.overload_degrade,
                         overload_queue_hi=args.overload_queue_hi,
                         overload_queue_lo=args.overload_queue_lo,
                         overload_patience=args.overload_patience,
                         overload_cooldown=args.overload_cooldown,
                         disagg_prefill_shards=(args.prefill_shards
                                                if args.scheduler == "disagg"
                                                else 0))
    return Engine(cfg=cfg, parallel=par,
                  sampling=SamplingConfig(top_k=args.top_k),
                  mesh=mesh, max_len=args.max_len,
                  wq_cache=args.wq_cache)


def make_scheduler(eng, args):
    if args.scheduler == "disagg":
        return DisaggScheduler(
            eng, n_slots=args.slots, block_steps=args.block_steps,
            responsive_blocks=args.responsive_blocks,
            prefix_cache=not args.no_prefix_cache)
    if args.scheduler == "paged":
        # block-size / pool-size ride on ParallelConfig (build_engine); the
        # scheduler reads them as its defaults
        return PagedContinuousScheduler(
            eng, n_slots=args.slots, block_steps=args.block_steps,
            responsive_blocks=args.responsive_blocks,
            prefix_cache=not args.no_prefix_cache)
    if args.scheduler == "continuous":
        return ContinuousScheduler(eng, n_slots=args.slots,
                                   block_steps=args.block_steps,
                                   responsive_blocks=args.responsive_blocks)
    return WaveScheduler(eng, batch_size=args.batch)


def parse_class_mix(spec):
    """Parse ``interactive=0.25,standard=0.5,batch=0.25`` into
    ``(classes, probabilities)``; None for an empty spec.  Weights are
    normalized, so integer ratios (``interactive=1,batch=3``) work too."""
    if not spec:
        return None
    classes, weights = [], []
    for item in spec.split(","):
        k, _, v = item.strip().partition("=")
        if k not in ("interactive", "standard", "batch"):
            raise ValueError(f"unknown priority class {k!r} in class mix")
        classes.append(k)
        weights.append(float(v) if v else 1.0)
    total = sum(weights)
    if total <= 0:
        raise ValueError("class mix weights must sum to > 0")
    return classes, [w / total for w in weights]


def submit_workload(sched, cfg, args):
    rng = np.random.default_rng(0)
    shared = rng.integers(0, cfg.vocab_size, args.shared_prefix).astype(np.int32)
    mix = parse_class_mix(getattr(args, "class_mix", ""))
    # the class draw uses its own rng stream so --class-mix never perturbs
    # the prompt/budget sequence of an existing workload
    cls_rng = np.random.default_rng(0xC1A55) if mix else None
    for i in range(args.requests):
        plen = int(rng.integers(4, args.prompt_len + 1))
        shape = (plen,) if cfg.n_codebooks == 1 else (plen, cfg.n_codebooks)
        max_new = args.max_new
        if args.max_new_spread > 1:
            max_new = int(rng.integers(max(1, args.max_new // args.max_new_spread),
                                       args.max_new + 1))
        prompt = rng.integers(0, cfg.vocab_size, shape).astype(np.int32)
        if args.shared_prefix and cfg.n_codebooks == 1:
            prompt = np.concatenate([shared, prompt])
        priority = (mix[0][int(cls_rng.choice(len(mix[0]), p=mix[1]))]
                    if mix else "standard")
        sched.submit(prompt, max_new=max_new,
                     arrival_step=i * args.arrival_every,
                     priority=priority)


def build_parser(ap=None):
    """Engine/scheduler argument set, shared with the async frontend
    (``repro.launch.frontend`` adds its server flags on top)."""
    if ap is None:
        ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-9b")
    ap.add_argument("--list-archs", action="store_true",
                    help="print the architecture capability matrix (which "
                         "serving paths each registered arch supports, and "
                         "what blocks the rest) and exit")
    ap.add_argument("--scheduler",
                    choices=("wave", "continuous", "paged", "disagg"),
                    default="wave")
    ap.add_argument("--prefill-shards", type=int, default=1,
                    help="disagg scheduler: the first N data shards form "
                         "the prefill pool (prompts admit and chunk-prefill "
                         "there; finished KV blocks migrate to the decode "
                         "pool); needs dp >= 2")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4,
                    help="wave scheduler: requests per wave")
    ap.add_argument("--slots", type=int, default=4,
                    help="continuous scheduler: fixed slot count")
    ap.add_argument("--block-steps", type=int, default=8,
                    help="continuous scheduler: fused decode steps per round trip")
    ap.add_argument("--responsive-blocks", action="store_true",
                    help="end fused blocks at the shortest active budget while "
                         "requests wait (fewer total steps, more dispatches)")
    ap.add_argument("--kv-block-size", type=int, default=16,
                    help="paged scheduler: tokens per KV block")
    ap.add_argument("--kv-pool-blocks", type=int, default=0,
                    help="paged scheduler: total pool blocks "
                         "(0 = dense-equivalent footprint)")
    ap.add_argument("--no-prefix-cache", action="store_true",
                    help="paged scheduler: disable shared-prefix block reuse")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="prepend one common N-token system prompt to every "
                         "request (makes prefix reuse visible)")
    ap.add_argument("--prefill-chunk", type=int, default=256,
                    help="continuous/paged: prompts longer than this many "
                         "tokens are admitted chunk-by-chunk through the "
                         "fused mixed prefill/decode step (decode advances "
                         "every step during admission); 0 = whole-prompt "
                         "admission only.  Gated by the capability registry "
                         "(--list-archs): recurrent and modality-prefix "
                         "archs clamp to whole-prompt admission")
    ap.add_argument("--no-flash-prefill", action="store_true",
                    help="keep prefill attention on the pure-JAX scan even "
                         "when Pallas kernels are enabled")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="continuous/paged: speculative decoding — propose "
                         "N draft tokens per active slot from the n-gram "
                         "prompt-lookup drafter and verify all of them in "
                         "one fused multi-token step (emits 1..N+1 tokens "
                         "per step); 0 = plain one-token decode.  Gated by "
                         "the capability registry (--list-archs): recurrent "
                         "and modality-prefix archs clamp to plain decode")
    ap.add_argument("--no-spec-decode", action="store_true",
                    help="force plain one-token decode even when --spec-k "
                         "is set")
    ap.add_argument("--spec-ngram", type=int, default=3,
                    help="longest n-gram the prompt-lookup drafter matches "
                         "against each request's history")
    ap.add_argument("--weight-quant", choices=("none", "int8", "int4"),
                    default="none",
                    help="weight-only quantization (quantize-at-load): "
                         "int8 = per-output-channel scales, int4 = "
                         "group-wise scales — shrinks the per-token weight "
                         "sweep, the dominant decode bandwidth on CPUs")
    ap.add_argument("--wq-group-size", type=int, default=128,
                    help="int4 group length along the reduction dim "
                         "(clamped per tensor so groups stay TP-shard-local)")
    ap.add_argument("--wq-cache", default=None,
                    help="path for the packed QuantWeight checkpoint: load "
                         "it when present (72B-scale starts skip bf16 "
                         "materialization), else save after quantize-at-load")
    ap.add_argument("--arrival-every", type=int, default=0,
                    help="stagger arrivals by N decode steps per request")
    ap.add_argument("--max-new-spread", type=int, default=1,
                    help=">1 draws per-request max_new from [max_new/spread, max_new]")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--dp", type=int, default=1)
    ap.add_argument("--tp", type=int, default=1)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--top-k", type=int, default=40)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--no-topk-sync", action="store_true",
                    help="disable paper §2.1b (baseline full-vocab gather)")
    ap.add_argument("--overlap", action="store_true",
                    help="continuous schedulers: overlapped host/device "
                         "engine loop — dispatch decode block N+1 against "
                         "block N's device futures while N's tokens land on "
                         "the host (greedy streams stay bit-identical to "
                         "the blocking loop)")
    ap.add_argument("--fault-plan", default="", metavar="SPEC",
                    help="deterministic fault injection for chaos runs "
                         "(continuous schedulers): ';'-separated clauses, "
                         "e.g. 'step:at=12;poison:slot=1,at=20;"
                         "migrate:handoff=0;alloc:at=8;delay:at=4,s=0.5' — "
                         "see repro.runtime.faults for the grammar.  "
                         "Injured requests are quarantined "
                         "(finish_reason=error); survivors' greedy streams "
                         "stay bit-identical to a clean run")
    ap.add_argument("--max-step-retries", type=int, default=3,
                    help="transient step failures are retried this many "
                         "times (exponential backoff) from the exact "
                         "pre-dispatch state before the blamed request is "
                         "quarantined")
    ap.add_argument("--retry-backoff-s", type=float, default=0.05,
                    help="base backoff before a step retry; doubles per "
                         "consecutive failure")
    ap.add_argument("--class-mix", default="", metavar="SPEC",
                    help="per-request priority classes for the synthetic "
                         "workload, drawn from a weighted mix, e.g. "
                         "'interactive=0.25,standard=0.5,batch=0.25' "
                         "(empty = everything 'standard')")
    ap.add_argument("--slo-interactive", type=float, default=0.0,
                    metavar="S", help="interactive-class per-token SLO "
                    "target in seconds (0 = unset); reported as "
                    "slo_attainment per class and consulted by the "
                    "overload controller's latency signal")
    ap.add_argument("--slo-standard", type=float, default=0.0, metavar="S",
                    help="standard-class per-token SLO target (seconds)")
    ap.add_argument("--slo-batch", type=float, default=0.0, metavar="S",
                    help="batch-class per-token SLO target (seconds)")
    ap.add_argument("--interactive-reserve-slots", type=int, default=0,
                    help="decode slots held back for interactive requests: "
                         "non-interactive admission stops once free slots "
                         "drop to this reserve")
    ap.add_argument("--interactive-reserve-blocks", type=int, default=0,
                    help="paged/disagg: KV pool blocks held back for "
                         "interactive admissions")
    ap.add_argument("--overload-degrade", action="store_true",
                    help="enable the adaptive degradation ladder (shed "
                         "batch -> suspend spec decode -> tighten "
                         "admission), walked with hysteresis from queue "
                         "depth + landed inter-token latency; see "
                         "repro.runtime.overload")
    ap.add_argument("--overload-queue-hi", type=int, default=0,
                    help="queue depth that counts as pressure "
                         "(0 = auto: 2x slots)")
    ap.add_argument("--overload-queue-lo", type=int, default=0,
                    help="queue depth that counts as clear "
                         "(0 = auto: slots/2)")
    ap.add_argument("--overload-patience", type=int, default=3,
                    help="consecutive pressured rounds before escalating "
                         "one ladder level")
    ap.add_argument("--overload-cooldown", type=int, default=6,
                    help="consecutive clear rounds before restoring one "
                         "ladder level")
    ap.add_argument("--stats-json", default=None, metavar="PATH",
                    help="dump the scheduler's full request_summary() and "
                         "raw stats counters (incl. overlap metrics: "
                         "host-overlap fraction, dispatch-ahead depth, shed "
                         "count) as JSON to PATH")
    return ap


def _jsonable(obj):
    """Recursively coerce numpy scalars/arrays so json.dump accepts the
    stats dicts."""
    if isinstance(obj, dict):
        return {str(k): _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if isinstance(obj, np.ndarray):
        return obj.tolist()
    if isinstance(obj, np.generic):
        return obj.item()
    return obj


def dump_stats_json(sched, path, extra=None):
    """Write request_summary() + raw stats counters (the full serving
    telemetry, overlap metrics included) to ``path``."""
    payload = {"request_summary": _jsonable(sched.request_summary()),
               "stats": _jsonable(sched.stats)}
    if extra:
        payload.update(_jsonable(extra))
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    return payload


def main(argv=None):
    ap = build_parser()
    args = ap.parse_args(argv)
    if args.list_archs:
        from repro.core.capabilities import render_text
        print(render_text())
        return []

    eng = build_engine(args)
    cfg = eng.cfg
    if args.weight_quant != "none":
        from repro.models import model as M
        wb = M.decode_weight_bytes(eng.ctx)
        bb = M.decode_weight_bytes(M.ModelCtx.make(
            cfg, ParallelConfig(tp=args.tp, dp=args.dp, remat=False)))
        print(f"weight quant {args.weight_quant}"
              f"{f'-g{args.wq_group_size}' if args.weight_quant == 'int4' else ''}: "
              f"{wb['swept']/2**20:.1f} MiB swept/token vs "
              f"{bb['swept']/2**20:.1f} MiB bf16 "
              f"({bb['swept']/max(wb['swept'],1):.2f}x less)")
    sched = make_scheduler(eng, args)
    submit_workload(sched, cfg, args)
    t0 = time.monotonic()
    try:
        sched.run()
    finally:
        # the report (and --stats-json) flushes even when the run raised or
        # was interrupted: sched.done holds everything retired so far, so a
        # crashed chaos run still leaves its counters on disk
        _report(sched, cfg, args, time.monotonic() - t0)
    return sched.done


def _report(sched, cfg, args, dt):
    done = sched.done
    total_tokens = sum(len(r.output) for r in done if r.output is not None)
    print(f"served {len(done)} requests, {total_tokens} tokens "
          f"in {dt:.2f}s -> {1000*dt/max(total_tokens,1):.1f} ms/token "
          f"({args.scheduler}; arch={cfg.name}, tp={args.tp})")
    if args.scheduler in ("continuous", "paged", "disagg"):
        s = sched.stats
        util = s["active_slot_steps"] / max(1, s["slot_steps"])
        print(f"  decode steps {s['decode_steps']}, slot util {util:.0%}, "
              f"admission rounds {s['admission_rounds']} "
              f"({s['in_flight_admissions']} requests admitted in-flight)")
        lat = sched.request_summary()
        if "ttft_s" in lat:
            print(f"  ttft mean {lat['ttft_s']['mean']*1e3:.0f} ms "
                  f"(p50 {lat['ttft_s']['p50']*1e3:.0f}, "
                  f"max {lat['ttft_s']['max']*1e3:.0f}); queue mean "
                  f"{lat['queue_s']['mean']*1e3:.0f} ms")
        if s.get("chunked_admissions"):
            print(f"  chunked prefill: {s['chunked_admissions']} requests in "
                  f"{s['prefill_chunks']} chunks of <= {sched.chunk} tokens")
        if "spec" in lat:
            sp, tps = lat["spec"], lat.get("tokens_per_step", {})
            print(f"  spec decode (k={sched.spec_k}, "
                  f"ngram<={sched.spec_ngram}): acceptance "
                  f"{sp['acceptance_rate']:.0%}, mean accepted "
                  f"{sp['mean_accepted_per_step']:.2f} tokens/step "
                  f"({sp['mean_tokens_per_step']:.2f} emitted; "
                  f"tokens/step p50 {tps.get('p50', 1):.0f} "
                  f"p95 {tps.get('p95', 1):.0f})")
        if "decode_itl_admission_s" in lat:
            adm, itl = lat["decode_itl_admission_s"], lat["decode_itl_s"]
            print(f"  decode inter-token p50/p95 {itl['p50']*1e3:.1f}/"
                  f"{itl['p95']*1e3:.1f} ms (admission windows "
                  f"{adm['p50']*1e3:.1f}/{adm['p95']*1e3:.1f} ms)")
        if "faults" in lat:
            fc = lat["faults"]
            print(f"  faults: {fc['step_faults']} step faults "
                  f"({fc['step_retries']} retried), "
                  f"{fc['quarantined']} quarantined, "
                  f"{fc['timeouts']} timeouts, "
                  f"{fc['migration_faults']} migration faults, "
                  f"{fc['aborts_exhaustion']} exhaustion aborts, "
                  f"{fc['livelock_aborts']} livelock aborts; "
                  f"finish_reasons {lat['finish_reasons']}")
        if "classes" in lat:
            for name, c in lat["classes"].items():
                line = (f"  class {name}: {c.get('served', 0)} served, "
                        f"{c.get('shed', 0)} shed, "
                        f"{c.get('timeout', 0)} timed out")
                if "itl_s" in c:
                    line += (f"; itl p50/p95 {c['itl_s']['p50']*1e3:.1f}/"
                             f"{c['itl_s']['p95']*1e3:.1f} ms")
                if "slo_attainment" in c:
                    line += (f"; SLO {c['slo_attainment']:.0%} "
                             f"@ {c['slo_target_s']*1e3:.0f} ms/token")
                print(line)
        if "overload" in lat:
            ov = lat["overload"]
            print(f"  overload ladder: level {ov['level']} "
                  f"({ov['level_name']}), peak {ov['max_level_name']}, "
                  f"{ov['escalations']} escalations / "
                  f"{ov['restorations']} restorations")
        if lat.get("overlap", {}).get("enabled"):
            ov = lat["overlap"]
            print(f"  overlap: host-overlap {ov['host_overlap_fraction']:.0%} "
                  f"({ov['host_overlap_s']:.2f}s hidden, "
                  f"{ov['host_blocked_s']:.2f}s blocked, "
                  f"{ov['host_blocked_per_step_s']*1e3:.1f} ms/step); "
                  f"dispatch-ahead max {ov['max_dispatch_ahead']}, "
                  f"eos rollbacks {ov['eos_rollbacks']}")
    if args.scheduler in ("paged", "disagg"):
        s = sched.stats
        print(f"  pool {sched.n_blocks} x {sched.bs}-token blocks, "
              f"high-water {s['blocks_hwm']} blocks; prefill tokens "
              f"{s['prefill_tokens']} (+{s['prefill_tokens_saved']} reused), "
              f"preemptions {s['preemptions']}")
    if args.scheduler == "disagg":
        p = sched.request_summary()["pools"]
        print(f"  pools: {p['prefill_shards']} prefill + "
              f"{p['decode_shards']} decode shards; prefill occupancy "
              f"{p['prefill_occupancy']:.0%} over {p['prefill_steps']} "
              f"chunk steps")
        print(f"  migration: {p['migrated_blocks']} blocks copied "
              f"({p['migration_bytes']/2**20:.1f} MiB), "
              f"{p['migration_skipped_blocks']} skipped via decode-side "
              f"prefix hits, {p['handoffs']} handoffs, "
              f"{p['migration_deferrals']} deferrals")
        if "migration_wait_s" in p:
            w = p["migration_wait_s"]
            print(f"  migration wait p50/p95 {w['p50']*1e3:.1f}/"
                  f"{w['p95']*1e3:.1f} ms")
    for r in done[:4]:
        if r.output is None:
            continue
        out = r.output if r.output.ndim == 1 else r.output[..., 0]
        print(f"  req {r.rid}: {len(r.output)} tokens, first 8: {out[:8].tolist()}")
    if args.stats_json:
        if args.scheduler == "wave":
            print("  --stats-json needs a continuous scheduler; skipping")
        else:
            dump_stats_json(sched, args.stats_json,
                            extra={"wall_s": dt, "total_tokens": total_tokens,
                                   "scheduler": args.scheduler,
                                   "arch": cfg.name})
            print(f"  stats -> {args.stats_json}")


if __name__ == "__main__":
    main()
