"""Async serving frontend: an OpenAI-compatible HTTP server over the slot
engine — ``python -m repro.launch.frontend --arch yi-9b --port 8080``.

Two pieces, both stdlib-only:

``EngineService`` — bridges concurrent clients to the single-threaded
scheduler.  The scheduler loop runs on ONE worker thread (JAX dispatch is
not thread-safe, and the engine wants exactly one dispatcher); clients
enqueue requests through a bounded inbox and receive tokens through
per-request asyncio queues fed by the scheduler's ``on_token`` callback
(``call_soon_threadsafe`` hops them onto the event loop).  The worker
drives ``scheduler.serve_step()`` — one admit → step → retire round per
iteration — so new requests are admitted in-flight between engine rounds,
and with ``--overlap`` each round dispatches decode block N+1 while block
N's tokens are still device futures.

**Overload shedding**: when inbox + live requests reach ``max_pending``,
new submissions are rejected up front with HTTP 429 + ``Retry-After``
(counted in ``scheduler.stats["shed_requests"]``) instead of growing an
unbounded queue — a shed request never touches the scheduler, so it can
never corrupt slot state.  Shedding is CLASS-AWARE: requests carry a
``priority`` (``interactive`` | ``standard`` | ``batch``, default
standard); at capacity a newcomer displaces a strictly lower-class entry
still waiting in the inbox (the victim gets the 429) before the newcomer
itself is shed, ``--pending-reserve`` holds back inbox headroom only
interactive may use, the 429 ``Retry-After`` hint scales per class
(batch backs off longest), and while the scheduler's degradation ladder
is shedding batch (level 1+), batch submissions are rejected at the door.
**Graceful drain**: shutdown stops accepting (503), serves every admitted
request to completion, then exits.

The API accepts token-id prompts (this repo has no tokenizer):

    POST /v1/completions
    {"prompt": [1, 2, 3], "max_tokens": 16, "stream": true,
     "stop_token_id": 5, "priority": "interactive"}

Responses follow the completions shape with ``token_ids`` in each choice;
streaming uses SSE (``data: {...}\\n\\n`` chunks, then ``data: [DONE]``).
"""
from __future__ import annotations

import argparse
import asyncio
import json
import threading
import time
from typing import List, Optional

import numpy as np

from repro.runtime.scheduler import PRIORITY_CLASSES, PRIORITY_RANK

_DONE = object()

_CAP_MATRIX = None


def _capability_matrix():
    """JSON capability matrix for /health (computed once: the registry
    derives from static configs, it cannot change while serving)."""
    global _CAP_MATRIX
    if _CAP_MATRIX is None:
        from repro.core.capabilities import as_dict
        _CAP_MATRIX = as_dict()
    return _CAP_MATRIX

# Retry-After scale per class: latency classes retry soonest, batch backs
# off longest (it is also the first class the degradation ladder sheds).
# standard stays at 1x so the default-class backoff hint is unchanged.
CLASS_RETRY_SCALE = {"interactive": 1, "standard": 1, "batch": 4}


class TokenStream:
    """Per-request token channel from the scheduler thread to one client
    coroutine.  Created on the event loop; pushed from the worker thread."""

    def __init__(self, loop: asyncio.AbstractEventLoop):
        self._loop = loop
        self._q: "asyncio.Queue" = asyncio.Queue()
        self.request = None          # set at finish (the retired Request)
        self.error: Optional[str] = None
        self.error_status = "400 Bad Request"
        self.error_type = "invalid_request_error"
        self.priority = "standard"

    # -- worker-thread side ------------------------------------------------
    def push(self, tok: int) -> None:
        self._loop.call_soon_threadsafe(self._q.put_nowait, tok)

    def finish(self, request) -> None:
        self.request = request
        self._loop.call_soon_threadsafe(self._q.put_nowait, _DONE)

    def fail(self, msg: str, status: str = "400 Bad Request",
             err_type: str = "invalid_request_error") -> None:
        self.error = msg
        self.error_status = status
        self.error_type = err_type
        self._loop.call_soon_threadsafe(self._q.put_nowait, _DONE)

    # -- client-coroutine side ---------------------------------------------
    async def next_token(self):
        """The next token id, or None when the request finished/failed."""
        item = await self._q.get()
        return None if item is _DONE else item


class EngineService:
    """Owns the scheduler worker thread and the client-facing submit path."""

    def __init__(self, scheduler, max_pending: int = 64,
                 idle_wait_s: float = 0.02, watchdog_s: float = 0.0,
                 pending_reserve: int = 0):
        self.sched = scheduler
        self.max_pending = max_pending
        # inbox headroom only interactive-class submissions may use: the
        # effective bound for standard/batch is max_pending - reserve
        self.pending_reserve = max(0, int(pending_reserve))
        self.idle_wait_s = idle_wait_s
        # scheduler watchdog: with live work in the engine and no host-
        # visible output for > watchdog_s, the node reports itself wedged —
        # /health flips to 503 and new submissions are rejected, so a load
        # balancer ejects the node instead of hanging connections on it.
        # 0 disables.
        self.watchdog_s = watchdog_s
        self._lock = threading.Lock()
        self._inbox: List = []
        self._streams = {}
        self._live = 0               # submitted (inbox or in-engine), unfinished
        self._draining = False
        self._wake = threading.Event()
        self._thread: Optional[threading.Thread] = None
        scheduler.stats.setdefault("shed_requests", 0)
        prev_tok, prev_fin = scheduler.on_token, scheduler.on_finish

        def on_token(rid: int, tok: int) -> None:
            if prev_tok is not None:
                prev_tok(rid, tok)
            s = self._streams.get(rid)
            if s is not None:
                s.push(int(tok))

        def on_finish(req) -> None:
            if prev_fin is not None:
                prev_fin(req)
            s = self._streams.pop(req.rid, None)
            with self._lock:
                self._live -= 1
            if s is not None:
                s.finish(req)

        scheduler.on_token = on_token
        scheduler.on_finish = on_finish

    # -- client side (event loop) ------------------------------------------
    def pending(self) -> int:
        with self._lock:
            return self._live + len(self._inbox)

    def wedged(self) -> bool:
        """Watchdog verdict: live work in the engine, but no host-visible
        engine output for longer than ``watchdog_s`` (idle engines never
        trip — the liveness clock only matters while work is in flight)."""
        if not self.watchdog_s:
            return False
        with self._lock:
            live = self._live
        return live > 0 and self.sched.liveness_age() > self.watchdog_s

    def _count_shed(self, priority: str) -> None:
        """Shed accounting (lock held): the global counter plus the
        per-class bucket the scheduler's ``request_summary`` reads."""
        self.sched.stats["shed_requests"] += 1
        buckets = self.sched.stats.setdefault("classes", {}).setdefault(
            priority, {"served": 0, "shed": 0, "timeout": 0, "error": 0})
        buckets["shed"] += 1

    def try_submit(self, prompt, max_new: int, eos_id: Optional[int],
                   stream: TokenStream,
                   deadline_s: Optional[float] = None,
                   priority: str = "standard") -> str:
        """Returns "ok", "shed" (bounded-queue overload), "draining", or
        "wedged" (watchdog tripped — the engine stopped making progress).

        Class-aware shedding, lowest class first: while the scheduler's
        degradation ladder sheds batch, batch is rejected at the door; a
        non-interactive submission is shed once the inbox reserve is
        reached; and at full capacity a newcomer displaces a strictly
        LOWER-class entry still waiting in the inbox (the latest-submitted
        entry of the worst class — its stream fails with a 429) before the
        newcomer itself is shed."""
        if self.wedged():
            return "wedged"
        rank = PRIORITY_RANK[priority]
        with self._lock:
            if self._draining:
                return "draining"
            if (priority == "batch" and self.sched.overload_level() >= 1):
                self._count_shed(priority)
                return "shed"
            cap = (self.max_pending if priority == "interactive"
                   else self.max_pending - self.pending_reserve)
            if self._live >= cap:
                victim = None
                if self._live >= self.max_pending:
                    worst = max((PRIORITY_RANK[e[5]] for e in self._inbox),
                                default=-1)
                    if worst > rank:
                        victim = next(e for e in reversed(self._inbox)
                                      if PRIORITY_RANK[e[5]] == worst)
                if victim is None:
                    self._count_shed(priority)
                    return "shed"
                self._inbox.remove(victim)
                self._live -= 1
                self._count_shed(victim[5])
                victim[4].fail(
                    "server overloaded: displaced by a higher-priority "
                    "request", status="429 Too Many Requests",
                    err_type="overloaded_error")
            self._inbox.append((prompt, max_new, eos_id, deadline_s, stream,
                                priority))
            self._live += 1
        self._wake.set()
        return "ok"

    # -- worker side --------------------------------------------------------
    def _worker(self) -> None:
        while True:
            with self._lock:
                batch, self._inbox = self._inbox, []
            for prompt, max_new, eos_id, deadline_s, stream, priority \
                    in batch:
                try:
                    # arrival_step = now on the virtual clock: immediately
                    # admissible, ordering decided by the scheduler
                    rid = self.sched.submit(
                        np.asarray(prompt, np.int32), max_new, eos_id=eos_id,
                        arrival_step=self.sched.step_count,
                        deadline_s=deadline_s, priority=priority)
                except ValueError as e:
                    with self._lock:
                        self._live -= 1
                    stream.fail(str(e))
                    continue
                self._streams[rid] = stream
            progressed = self.sched.serve_step()
            if progressed:
                continue
            with self._lock:
                idle = not self._inbox
                stop = self._draining and idle and self._live == 0
            if stop:
                return
            if idle:
                self._wake.wait(self.idle_wait_s)
                self._wake.clear()

    def start(self) -> None:
        if self._thread is not None:
            return
        self._thread = threading.Thread(target=self._worker, daemon=True,
                                        name="engine-service")
        self._thread.start()

    def drain(self, timeout: Optional[float] = None) -> bool:
        """Graceful shutdown: reject new work, serve everything admitted,
        join the worker.  Returns True if the worker exited in time."""
        with self._lock:
            self._draining = True
        self._wake.set()
        if self._thread is None:
            return True
        self._thread.join(timeout)
        return not self._thread.is_alive()


class HttpFrontend:
    """Minimal asyncio HTTP/1.1 server exposing the service.  One route
    family, no dependencies: POST /v1/completions (+ GET /health)."""

    MAX_BODY = 8 << 20

    def __init__(self, service: EngineService, host: str = "127.0.0.1",
                 port: int = 8080, retry_after_s: int = 1):
        self.service = service
        self.host = host
        self.port = port
        self.retry_after_s = retry_after_s
        self._server: Optional[asyncio.AbstractServer] = None
        self._next_id = 0
        self._conns = 0

    # -- lifecycle ----------------------------------------------------------
    async def start(self) -> None:
        self.service.start()
        self._server = await asyncio.start_server(
            self._handle, self.host, self.port)
        self.port = self._server.sockets[0].getsockname()[1]

    async def serve_forever(self) -> None:
        async with self._server:
            await self._server.serve_forever()

    async def stop(self) -> None:
        """Graceful drain: stop accepting, serve every admitted request to
        completion, and let in-flight responses finish writing."""
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
        await asyncio.get_running_loop().run_in_executor(
            None, self.service.drain)
        while self._conns:
            await asyncio.sleep(0.01)

    # -- plumbing -----------------------------------------------------------
    @staticmethod
    async def _read_request(reader):
        head = await reader.readuntil(b"\r\n\r\n")
        lines = head.decode("latin-1").split("\r\n")
        method, path, _ = lines[0].split(" ", 2)
        headers = {}
        for ln in lines[1:]:
            if ":" in ln:
                k, v = ln.split(":", 1)
                headers[k.strip().lower()] = v.strip()
        clen = int(headers.get("content-length", "0"))
        if clen > HttpFrontend.MAX_BODY:
            raise ValueError("body too large")
        body = await reader.readexactly(clen) if clen else b""
        return method, path, headers, body

    @staticmethod
    def _respond(writer, status: str, payload: dict,
                 extra_headers: str = "") -> None:
        body = json.dumps(payload).encode()
        writer.write(
            f"HTTP/1.1 {status}\r\nContent-Type: application/json\r\n"
            f"Content-Length: {len(body)}\r\nConnection: close\r\n"
            f"{extra_headers}\r\n".encode() + body)

    async def _handle(self, reader, writer) -> None:
        self._conns += 1
        try:
            try:
                method, path, _, body = await self._read_request(reader)
            except (asyncio.IncompleteReadError, ValueError,
                    asyncio.LimitOverrunError):
                return
            if method == "GET" and path in ("/health", "/v1/health"):
                # liveness-aware health: a load balancer ejects on 503.
                # last_step_age_s is seconds since engine outputs last
                # became host-visible — the scheduler watchdog signal
                svc = self.service
                wedged = svc.wedged()
                with svc._lock:
                    inbox_depth = len(svc._inbox)
                    draining = svc._draining
                payload = {
                    "status": ("wedged" if wedged
                               else "draining" if draining else "ok"),
                    "pending": svc.pending(),
                    "inbox_depth": inbox_depth,
                    "draining": draining,
                    "last_step_age_s": round(svc.sched.liveness_age(), 3),
                    "watchdog_s": svc.watchdog_s,
                    "shed_requests": svc.sched.stats["shed_requests"],
                    "quarantined": svc.sched.stats.get("quarantined", 0),
                    "timeouts": svc.sched.stats.get("timeouts", 0),
                    # per-class served/shed/timeout/error counters and the
                    # degradation-ladder state (level 0 = normal)
                    "classes": svc.sched.stats.get("classes", {}),
                    "overload": (
                        svc.sched.overload_ctl.summary()
                        if getattr(svc.sched, "overload_ctl", None)
                        is not None
                        else {"level": svc.sched.overload_level(),
                              "level_name": "normal"}),
                    # the registered capability matrix (same table as
                    # serve.py --list-archs), plus which arch this server
                    # is actually running
                    "arch": svc.sched.engine.cfg.name,
                    "capabilities": _capability_matrix(),
                }
                self._respond(writer,
                              "503 Service Unavailable" if wedged
                              else "200 OK", payload)
            elif method == "POST" and path == "/v1/completions":
                await self._completions(writer, body)
            else:
                self._respond(writer, "404 Not Found",
                              {"error": {"message": f"no route {path}"}})
            await writer.drain()
        finally:
            self._conns -= 1
            writer.close()

    # -- the route ----------------------------------------------------------
    async def _completions(self, writer, body: bytes) -> None:
        try:
            req = json.loads(body or b"{}")
            prompt = req["prompt"]
            if not (isinstance(prompt, list) and len(prompt) >= 2
                    and all(isinstance(t, int) for t in prompt)):
                raise ValueError(
                    "prompt must be a list of >= 2 token ids "
                    "(this engine serves token ids; there is no tokenizer)")
            max_new = int(req.get("max_tokens", 16))
            if max_new < 1:
                raise ValueError("max_tokens must be >= 1")
            eos_id = req.get("stop_token_id")
            eos_id = None if eos_id is None else int(eos_id)
            # per-request deadline: the scheduler retires the request with
            # finish_reason "timeout" once max_time seconds elapse
            max_time = req.get("max_time")
            max_time = None if max_time is None else float(max_time)
            if max_time is not None and max_time <= 0:
                raise ValueError("max_time must be > 0 seconds")
            do_stream = bool(req.get("stream", False))
            priority = str(req.get("priority", "standard"))
            if priority not in PRIORITY_RANK:
                raise ValueError(
                    f"unknown priority class {priority!r}; expected one "
                    f"of {PRIORITY_CLASSES}")
        except (KeyError, TypeError, ValueError) as e:
            self._respond(writer, "400 Bad Request",
                          {"error": {"message": str(e),
                                     "type": "invalid_request_error"}})
            return
        stream = TokenStream(asyncio.get_running_loop())
        stream.priority = priority
        verdict = self.service.try_submit(prompt, max_new, eos_id, stream,
                                          deadline_s=max_time,
                                          priority=priority)
        if verdict == "wedged":
            # scheduler watchdog tripped: the engine stopped producing
            # output with work in flight — fail fast so the load balancer
            # routes around this node instead of hanging the connection
            self._respond(writer, "503 Service Unavailable",
                          {"error": {"message": "engine is not making "
                                                "progress (watchdog)",
                                     "type": "unavailable_error"}})
            return
        if verdict == "shed":
            # bounded-queue overload shedding: reject BEFORE the scheduler
            # ever sees the request, with a per-class client backoff hint
            # (batch clients are told to back off longest)
            retry = self.retry_after_s * CLASS_RETRY_SCALE[priority]
            self._respond(
                writer, "429 Too Many Requests",
                {"error": {"message": "server overloaded, retry later",
                           "type": "overloaded_error"}},
                extra_headers=f"Retry-After: {retry}\r\n")
            return
        if verdict == "draining":
            self._respond(writer, "503 Service Unavailable",
                          {"error": {"message": "server is draining",
                                     "type": "unavailable_error"}})
            return
        self._next_id += 1
        cid = f"cmpl-{self._next_id}"
        if do_stream:
            await self._stream_response(writer, cid, eos_id, stream)
        else:
            await self._unary_response(writer, cid, eos_id, stream)

    @staticmethod
    def _finish_reason(toks: List[int], eos_id: Optional[int],
                       stream: Optional[TokenStream] = None) -> str:
        # the retired Request's own verdict wins (it distinguishes "error"
        # and "timeout" retirements from natural stop/length); the token
        # heuristic is the fallback for failed submissions
        if (stream is not None and stream.request is not None
                and stream.request.finish_reason):
            return stream.request.finish_reason
        return ("stop" if eos_id is not None and toks and toks[-1] == eos_id
                else "length")

    async def _unary_response(self, writer, cid, eos_id, stream) -> None:
        toks: List[int] = []
        while True:
            t = await stream.next_token()
            if t is None:
                break
            toks.append(t)
        if stream.error is not None:
            # the stream carries its own verdict: validation failures stay
            # 400, priority displacement surfaces as 429 with the same
            # per-class Retry-After hint the door-shed path uses
            extra = ""
            if stream.error_status.startswith("429"):
                retry = (self.retry_after_s
                         * CLASS_RETRY_SCALE.get(stream.priority, 1))
                extra = f"Retry-After: {retry}\r\n"
            self._respond(writer, stream.error_status,
                          {"error": {"message": stream.error,
                                     "type": stream.error_type}},
                          extra_headers=extra)
            return
        self._respond(writer, "200 OK", {
            "id": cid, "object": "text_completion", "model": "repro",
            "created": int(time.time()),
            "choices": [{"index": 0, "token_ids": toks, "text": "",
                         "finish_reason":
                             self._finish_reason(toks, eos_id, stream)}],
            "usage": {"completion_tokens": len(toks)}})

    async def _stream_response(self, writer, cid, eos_id, stream) -> None:
        writer.write(b"HTTP/1.1 200 OK\r\nContent-Type: text/event-stream\r\n"
                     b"Cache-Control: no-cache\r\nConnection: close\r\n\r\n")
        toks: List[int] = []
        while True:
            t = await stream.next_token()
            if t is None:
                break
            toks.append(t)
            chunk = {"id": cid, "object": "text_completion.chunk",
                     "choices": [{"index": 0, "token_ids": [t], "text": ""}]}
            writer.write(f"data: {json.dumps(chunk)}\n\n".encode())
            try:
                await writer.drain()
            except ConnectionError:
                return                # client went away; engine finishes solo
        if stream.error is not None:
            writer.write(
                f"data: {json.dumps({'error': stream.error})}\n\n".encode())
        else:
            final = {"id": cid, "object": "text_completion.chunk",
                     "choices": [{"index": 0, "token_ids": [], "text": "",
                                  "finish_reason":
                                      self._finish_reason(toks, eos_id,
                                                          stream)}]}
            writer.write(f"data: {json.dumps(final)}\n\n".encode())
        writer.write(b"data: [DONE]\n\n")


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def main(argv=None):
    from repro.launch import serve as serve_mod
    ap = serve_mod.build_parser(argparse.ArgumentParser(
        description="OpenAI-compatible async serving frontend"))
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8080,
                    help="0 picks an ephemeral port (printed at startup)")
    ap.add_argument("--max-pending", type=int, default=64,
                    help="bounded request queue: submissions beyond this "
                         "many live requests are shed with HTTP 429")
    ap.add_argument("--watchdog-s", type=float, default=30.0,
                    help="scheduler watchdog: with live work and no engine "
                         "output for this many seconds, /health turns 503 "
                         "and new submissions are rejected (0 disables)")
    ap.add_argument("--pending-reserve", type=int, default=0,
                    help="slots of the pending queue held back for "
                         "interactive-class requests (non-interactive "
                         "submissions shed this much earlier)")
    args = ap.parse_args(argv)
    if args.scheduler == "wave":
        ap.error("the frontend needs a continuous scheduler "
                 "(--scheduler continuous|paged|disagg)")
    eng = serve_mod.build_engine(args)
    sched = serve_mod.make_scheduler(eng, args)
    service = EngineService(sched, max_pending=args.max_pending,
                            watchdog_s=args.watchdog_s,
                            pending_reserve=args.pending_reserve)
    frontend = HttpFrontend(service, host=args.host, port=args.port)

    async def amain():
        await frontend.start()
        print(f"serving {eng.cfg.name} ({args.scheduler}"
              f"{', overlapped' if sched.overlap else ''}) on "
              f"http://{frontend.host}:{frontend.port}/v1/completions",
              flush=True)
        try:
            await frontend.serve_forever()
        except asyncio.CancelledError:
            pass

    try:
        asyncio.run(amain())
    except KeyboardInterrupt:
        print("draining...", flush=True)
        service.drain()
    return frontend


if __name__ == "__main__":
    main()
