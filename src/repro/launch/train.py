"""Training driver: ``python -m repro.launch.train --arch yi-9b --steps 200``.

On this CPU container it runs REDUCED configs on a local mesh (the end-to-end
example deliverable: ~100M-class model for a few hundred steps); on real
hardware the same driver takes --full and the production mesh geometry.
"""
from __future__ import annotations

import argparse
import json
import time
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.configs import ParallelConfig, get_config
from repro.launch.mesh import make_local_mesh
from repro.models import model as M
from repro.models.common import specs_of
from repro.training import checkpoint, data
from repro.training.train_loop import AdamWConfig, init_opt_state, make_train_step
from repro.training.zero import init_zero_state, zero_state_defs


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-9b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--dp", type=int, default=1)
    ap.add_argument("--tp", type=int, default=1)
    ap.add_argument("--zero1", action="store_true")
    ap.add_argument("--full", action="store_true",
                    help="use the full (not reduced) architecture config")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    mesh = make_local_mesh(args.dp, args.tp)
    par = ParallelConfig(tp=args.tp, dp=args.dp, remat=True)
    ctx = M.ModelCtx.make(cfg, par)
    params = M.init_params(ctx, jax.random.key(0))
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M mesh=({args.dp},{args.tp})")

    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=max(5, args.steps // 20),
                          total_steps=args.steps)
    pspecs = M.param_specs(ctx)
    if args.zero1:
        opt = init_zero_state(M.model_defs(ctx), ctx.dist)
        ospecs = specs_of(zero_state_defs(M.model_defs(ctx), ctx.dist))
    else:
        opt = init_opt_state(params)
        ospecs = {"m": pspecs, "v": pspecs, "step": P()}

    dc = data.DataConfig(global_batch=args.global_batch, seq_len=args.seq_len)
    b0 = data.make_batch(cfg, dc, 0)
    bspecs = {k: P("data", *(None,) * (v.ndim - 1)) for k, v in b0.items()}

    step_fn = make_train_step(ctx, opt_cfg, zero1=args.zero1)
    jstep = jax.jit(
        shard_map(step_fn, mesh=mesh, in_specs=(pspecs, ospecs, bspecs),
                  out_specs=(pspecs, ospecs, P()), check_vma=False),
        donate_argnums=(0, 1),
    )

    t0 = time.time()
    history = []
    for step, batch in enumerate(data.iter_batches(cfg, dc)):
        if step >= args.steps:
            break
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt, metrics = jstep(params, opt, batch)
        if step % args.log_every == 0 or step == args.steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            history.append({"step": step, **m})
            print(f"step {step:5d} loss {m['loss']:.4f} aux {m['aux']:.3f} "
                  f"gnorm {m['grad_norm']:.2f} ({time.time()-t0:.0f}s)", flush=True)
    if args.ckpt:
        checkpoint.save(args.ckpt, params, step=args.steps,
                        meta={"arch": cfg.name, "history": history[-5:]})
        print("saved", args.ckpt)
    return history


if __name__ == "__main__":
    main()
