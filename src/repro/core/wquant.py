"""Weight-only quantization: packed low-precision weights + scales.

Decode on CPUs (and the memory-bound regime generally) is a per-token sweep
of every weight byte; arXiv 2407.07304 makes low-precision weights the
headline lever and the LIMINAL limit study (arXiv 2507.14397) confirms the
weight stream, not FLOPs, binds decode.  This module is the storage +
numerics layer of that lever:

* ``int8`` — per-output-channel symmetric scales: ``W ≈ q * s[n]`` with
  ``q`` int8 in [-127, 127] and one bf16 scale per output column.
* ``int4`` — group-wise symmetric scales: the reduction dim is cut into
  ``group``-length segments, each with its own scale (``q`` in [-7, 7],
  two values packed per byte).  Group boundaries are clamped per tensor so
  they never straddle a TP shard of the reduction dim.

Layout convention: every quantizable weight is stored exactly as the model
declares it, ``(*B, K, N)`` — leading batch dims (scan stack, MoE experts,
attention heads for w_o, codebooks), reduction dim at axis -2, output dim
last.  This is what makes the transform TP-exact with ZERO schedule change:

* output-channel (int8) scales commute with the row-parallel reduction —
  each shard computes ``s[n] * (x @ q_shard[:, n])`` and the existing psum
  adds exact partials, so :mod:`sync_policy`'s one-psum-per-layer count and
  the :mod:`collectives` byte accounting are untouched;
* group (int4) scales are segments of the reduction dim; because the
  effective group divides the PER-SHARD reduction length, every group is
  shard-local and each shard's partial ``sum_g s_g (x_g @ q_g)`` is exact.

Scale/packed-q arrays shard exactly like the weight they describe (see
``spec_for``): batch/output axes keep the weight's spec entries, the int4
group axis inherits the reduction axis's spec.

:class:`QuantWeight` is a registered pytree whose static aux (mode, group,
true K, backend) rides through jit/shard_map/scan unchanged — leading-axis
indexing of stacked (scanned) layer groups works because K is pinned to
axis -2, invariant under losing the stack axis.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

MODES = ("int8", "int4")


@jax.tree_util.register_pytree_node_class
@dataclass
class QuantWeight:
    """Quantized weight leaf: values + scales, static quantization aux.

    ``q``: int8 ``(*B, K, N)`` (int8 mode) or uint8 ``(*B, K//2, N)`` with
    two 4-bit values per byte (int4 mode; k even ↦ low nibble).
    ``scale``: bf16 ``(*B, N)`` (int8) or ``(*B, K//group, N)`` (int4).
    Holds either arrays (a parameter) or PartitionSpecs (its spec tree) —
    the two flatten to matching pytrees, which is what shard_map needs.
    """

    q: Any
    scale: Any
    mode: str = "int8"
    group: int = 0          # effective int4 group length (0 for int8)
    k: int = 0              # true reduction length (axis -2, unpacked)
    backend: str = "ref"    # "ref" (pure-JAX dequant) | "pallas" (fused)

    def tree_flatten(self):
        return (self.q, self.scale), (self.mode, self.group, self.k,
                                      self.backend)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], *aux)


# ---------------------------------------------------------------------------
# Quantize / dequantize
# ---------------------------------------------------------------------------


def effective_group(k: int, group_size: int, k_shards: int = 1) -> int:
    """Largest group <= group_size that divides the PER-SHARD reduction
    length (so int4 groups never straddle a TP shard) and stays even (two
    values pack per byte).  Returns 0 if no valid grouping exists."""
    k_local = k // max(1, k_shards)
    for cand in range(min(group_size, k_local), 1, -1):
        if cand % 2 == 0 and k_local % cand == 0:
            return cand
    return 0


def quantizable(shape, mode: str, group_size: int, k_shards: int = 1) -> bool:
    """A weight can be quantized if it has a (K, N) tail and, for int4, an
    even shard-local grouping of K exists."""
    if len(shape) < 2:
        return False
    k = shape[-2]
    if k < 2 or (k_shards > 1 and k % k_shards):
        return False
    if mode == "int4":
        return k % 2 == 0 and effective_group(k, group_size, k_shards) > 0
    return mode == "int8"


def pack4(q4: jax.Array) -> jax.Array:
    """int8 values in [-8, 7], shape (*B, K, N) -> uint8 (*B, K//2, N);
    even k in the low nibble, odd k in the high nibble."""
    lo = q4[..., 0::2, :].astype(jnp.uint8) & 0xF
    hi = q4[..., 1::2, :].astype(jnp.uint8) & 0xF
    return lo | (hi << 4)


def unpack4(packed: jax.Array) -> jax.Array:
    """uint8 (*B, K//2, N) -> int8 (*B, K, N) (two's-complement nibbles)."""
    p = packed.astype(jnp.int32)
    lo = p & 0xF
    hi = (p >> 4) & 0xF
    lo = jnp.where(lo >= 8, lo - 16, lo)
    hi = jnp.where(hi >= 8, hi - 16, hi)
    pair = jnp.stack([lo, hi], axis=-2)                  # (*B, K//2, 2, N)
    shape = packed.shape[:-2] + (2 * packed.shape[-2], packed.shape[-1])
    return pair.reshape(shape).astype(jnp.int8)


def quantize(w: jax.Array, mode: str, group_size: int, *,
             k_shards: int = 1, backend: str = "ref") -> QuantWeight:
    """Symmetric weight quantization of ``w`` (*B, K, N) at load time."""
    if mode not in MODES:
        raise ValueError(f"weight_quant mode {mode!r} not in {MODES}")
    k = w.shape[-2]
    wf = w.astype(jnp.float32)

    def stored(amax, levels):
        # round AGAINST the scale dequantization will actually use: the
        # bf16-stored value, not the fp32 intermediate — otherwise every
        # weight picks up the scale's own bf16 rounding on top of its
        # 0.5-LSB quantization error
        s = jnp.maximum(amax, 1e-8) / levels
        return s.astype(jnp.bfloat16).astype(jnp.float32)

    if mode == "int8":
        scale = stored(jnp.max(jnp.abs(wf), axis=-2), 127.0)   # (*B, N)
        q = jnp.clip(jnp.round(wf / scale[..., None, :]),
                     -127, 127).astype(jnp.int8)
        return QuantWeight(q, scale.astype(jnp.bfloat16), "int8", 0, k,
                           backend)
    g = effective_group(k, group_size, k_shards)
    if not g:
        raise ValueError(f"no even shard-local int4 group for K={k}, "
                         f"group_size={group_size}, k_shards={k_shards}")
    lead = w.shape[:-2]
    wg = wf.reshape(*lead, k // g, g, w.shape[-1])
    scale = stored(jnp.max(jnp.abs(wg), axis=-2), 7.0)         # (*B, K/g, N)
    q4 = jnp.clip(jnp.round(wg / scale[..., None, :]), -7, 7)
    q4 = q4.reshape(*lead, k, w.shape[-1]).astype(jnp.int8)
    return QuantWeight(pack4(q4), scale.astype(jnp.bfloat16), "int4", g, k,
                       backend)


def dequantize(w: QuantWeight, dtype=jnp.bfloat16) -> jax.Array:
    """QuantWeight -> dense (*B, K, N) weight (the pure-JAX reference).

    K comes from the ARRAY, not the static aux: inside shard_map the leaf
    is this shard's slice of a possibly K-sharded weight, and the group
    clamp guarantees the local K is still a whole number of groups."""
    if w.mode == "int8":
        out = w.q.astype(jnp.float32) * w.scale.astype(jnp.float32)[..., None, :]
        return out.astype(dtype)
    q = unpack4(w.q).astype(jnp.float32)                     # (*B, K_local, N)
    lead = q.shape[:-2]
    qg = q.reshape(*lead, q.shape[-2] // w.group, w.group, q.shape[-1])
    out = qg * w.scale.astype(jnp.float32)[..., None, :]
    return out.reshape(q.shape).astype(dtype)


def to_dense(w, dtype=jnp.bfloat16):
    """Array passthrough / QuantWeight dequant — for batched einsum sites
    (MoE expert blocks, the zero-copy out-projection) that stay on the
    reference path."""
    return dequantize(w, dtype) if isinstance(w, QuantWeight) else w


# ---------------------------------------------------------------------------
# Matmul routing (2-D weights: the attention/MLP projection hot path)
# ---------------------------------------------------------------------------


def matmul(x: jax.Array, w, out_dtype=None) -> jax.Array:
    """``x (..., K) @ w (K, N)`` with ``w`` a plain array or QuantWeight.

    QuantWeight + backend "pallas" routes through the fused dequant matmul
    kernel (GEMV blocking for decode-narrow x, GEMM blocking for prefill/
    verify); backend "ref" dequantizes and uses the stock matmul — the
    oracle path, numerically the closest thing to the bf16 baseline."""
    if not isinstance(w, QuantWeight):
        y = x @ w
        return y if out_dtype is None else y.astype(out_dtype)
    if w.q.ndim != 2:
        raise ValueError("wquant.matmul serves 2-D weights; use to_dense "
                         "for batched einsum sites")
    if w.backend == "pallas":
        from repro.kernels import ops as kops

        lead = x.shape[:-1]
        y = kops.dequant_matmul(x.reshape(-1, x.shape[-1]), w.q, w.scale,
                                mode=w.mode, group=w.group,
                                out_dtype=out_dtype or x.dtype)
        return y.reshape(*lead, y.shape[-1])
    y = x @ dequantize(w)
    return y if out_dtype is None else y.astype(out_dtype)


def slice_cols(w: QuantWeight, start, size: int) -> QuantWeight:
    """Slice the output-column dim (axis -1) of q AND scale — the
    replicated-KV-weight per-shard slice (`_slice_kv_weight`)."""
    q = jax.lax.dynamic_slice_in_dim(w.q, start, size, axis=w.q.ndim - 1)
    s = jax.lax.dynamic_slice_in_dim(w.scale, start, size,
                                     axis=w.scale.ndim - 1)
    return QuantWeight(q, s, w.mode, w.group, w.k, w.backend)


def index_batch(w: QuantWeight, i: int) -> QuantWeight:
    """Drop one leading batch dim (e.g. the codebook axis of lm_head)."""
    return QuantWeight(w.q[i], w.scale[i], w.mode, w.group, w.k, w.backend)


# ---------------------------------------------------------------------------
# Spec + byte accounting (mirrors quantize() without materializing)
# ---------------------------------------------------------------------------


def shapes_for(shape, mode: str, group_size: int, *,
               k_shards: int = 1, backend: str = "ref") -> QuantWeight:
    """ShapeDtypeStruct tree for the quantized form of a weight — keeps
    ``param_shapes`` structurally matched to ``param_specs``/params when
    weight_quant is on (the contract every tree_map over the three
    relies on)."""
    k, n = shape[-2], shape[-1]
    if mode == "int8":
        return QuantWeight(jax.ShapeDtypeStruct(shape, jnp.int8),
                           jax.ShapeDtypeStruct(shape[:-2] + (n,),
                                                jnp.bfloat16),
                           "int8", 0, k, backend)
    g = effective_group(k, group_size, k_shards)
    return QuantWeight(
        jax.ShapeDtypeStruct(shape[:-2] + (k // 2, n), jnp.uint8),
        jax.ShapeDtypeStruct(shape[:-2] + (k // g, n), jnp.bfloat16),
        "int4", g, k, backend)


def spec_for(shape, spec: P, mode: str, group_size: int, *,
             k_shards: int = 1, backend: str = "ref") -> QuantWeight:
    """PartitionSpec tree for the quantized form of a weight whose dense
    spec is ``spec`` (full-length, one entry per dim).  q keeps the dense
    spec (packing halves K, divisibility preserved); the int8 scale drops
    the reduction entry, the int4 scale keeps all entries (its group axis
    shards exactly like the reduction axis it segments)."""
    entries = tuple(spec)
    if len(entries) != len(shape):
        entries = entries + (None,) * (len(shape) - len(entries))
    if mode == "int8":
        scale_spec = P(*entries[:-2], entries[-1])
        return QuantWeight(P(*entries), scale_spec, "int8", 0, shape[-2],
                           backend)
    g = effective_group(shape[-2], group_size, k_shards)
    return QuantWeight(P(*entries), P(*entries), "int4", g, shape[-2],
                       backend)


def quant_bytes(shape, mode: str, group_size: int, k_shards: int = 1) -> int:
    """Stored bytes of the quantized form (values + bf16 scales)."""
    import math

    n_el = math.prod(shape)
    lead_n = n_el // shape[-2]                       # (*B, N) element count
    if mode == "int8":
        return n_el + 2 * lead_n
    g = effective_group(shape[-2], group_size, k_shards)
    return n_el // 2 + 2 * lead_n * (shape[-2] // g)
