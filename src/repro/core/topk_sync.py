"""Paper §2.1b — each worker computes top-k over its local vocab shard
*before* the reduction; only (k values, k global indices) cross the wire.

Baseline (``topk_sync=False``): all-gather the full vocab row, then top-k.
Optimized: local top-k (optionally the Pallas kernel) + all-gather of
(tp * k) candidates + global re-top-k.  Bytes drop from O(vocab) to O(k·tp).

Sampling happens on the merged candidates with identical RNG on every shard,
so the sampled token ID is replicated — which is exactly what makes the
§2.1a "broadcast token IDs" free in SPMD.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import SamplingConfig
from repro.core import collectives as cc
from repro.models.common import Dist, ShardPlan


def local_topk(logits: jax.Array, k: int, *, use_pallas: bool = False):
    """Top-k over the last dim of the local logits shard."""
    if use_pallas:
        from repro.kernels import ops as kops

        return kops.topk(logits, k)
    return jax.lax.top_k(logits, k)


def distributed_topk(
    local_logits: jax.Array,      # (batch, local_vocab) this shard's slice
    k: int,
    plan: ShardPlan,
    dist: Dist,
    *,
    use_pallas: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Global (values, indices) top-k over the vocab-sharded logits.

    Returns replicated (batch, k) values and global vocab indices.
    """
    shard = dist.model_idx()
    vals, idx = local_topk(local_logits, k, use_pallas=use_pallas)
    gidx = idx + shard * plan.local_vocab
    # all-gather k candidates per shard -> (tp*k) candidates, then re-top-k.
    vals_g = cc.all_gather(vals, dist.model_axis, gather_axis=1, tag="topk_vals")
    gidx_g = cc.all_gather(gidx, dist.model_axis, gather_axis=1, tag="topk_idx")
    top_vals, pos = jax.lax.top_k(vals_g, k)
    top_idx = jnp.take_along_axis(gidx_g, pos, axis=1)
    return top_vals, top_idx


def full_gather_topk(
    local_logits: jax.Array,
    k: int,
    plan: ShardPlan,
    dist: Dist,
) -> Tuple[jax.Array, jax.Array]:
    """Baseline: all-gather the full vocab row, then top-k (O(vocab) bytes)."""
    full = cc.all_gather(
        local_logits, dist.model_axis, gather_axis=1, tag="full_logits"
    )
    return jax.lax.top_k(full, k)


def sample(
    local_logits: jax.Array,      # (batch, local_vocab)
    rng: jax.Array,               # replicated PRNG key
    sampling: SamplingConfig,
    plan: ShardPlan,
    dist: Dist,
    *,
    topk_sync: bool = True,
    use_pallas: bool = False,
) -> jax.Array:
    """Sample next token IDs (batch,) — replicated across all shards."""
    k = max(1, sampling.top_k)
    if topk_sync:
        vals, idx = distributed_topk(local_logits, k, plan, dist, use_pallas=use_pallas)
    else:
        vals, idx = full_gather_topk(local_logits, k, plan, dist)
    if sampling.greedy:
        return idx[:, 0]
    logits = vals.astype(jnp.float32) / jnp.maximum(sampling.temperature, 1e-6)
    # identical key on every shard -> identical draw -> replicated token id
    choice = jax.random.categorical(rng, logits, axis=-1)  # (batch,)
    return jnp.take_along_axis(idx, choice[:, None], axis=1)[:, 0]
