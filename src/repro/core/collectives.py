"""Explicit collectives with static byte accounting.

The paper's whole contribution is *which collectives run per decode round and
how many bytes they move*.  Every collective in this codebase goes through
these wrappers so that tracing a step function under
:func:`comm_stats` yields the exact schedule — the quantity benchmarked in
``benchmarks/bench_sync_minimization.py`` and friends.
"""
from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp

_local = threading.local()


@dataclass
class CommRecord:
    kind: str
    axis: str
    bytes: int
    shape: tuple
    tag: str = ""


@dataclass
class CommStats:
    records: List[CommRecord] = field(default_factory=list)

    def count(self, kind: Optional[str] = None) -> int:
        return sum(1 for r in self.records if kind is None or r.kind == kind)

    def total_bytes(self, kind: Optional[str] = None, axis: Optional[str] = None) -> int:
        return sum(
            r.bytes
            for r in self.records
            if (kind is None or r.kind == kind) and (axis is None or r.axis == axis)
        )

    def by_kind(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for r in self.records:
            out[r.kind] = out.get(r.kind, 0) + r.bytes
        return out


@contextlib.contextmanager
def comm_stats():
    """Record every wrapped collective issued while tracing under this ctx."""
    stats = CommStats()
    prev = getattr(_local, "stats", None)
    _local.stats = stats
    try:
        yield stats
    finally:
        _local.stats = prev


def _record(kind: str, axis: str, x, tag: str, wire_factor: float = 1.0) -> None:
    stats: Optional[CommStats] = getattr(_local, "stats", None)
    if stats is None:
        return
    for leaf in jax.tree.leaves(x):
        nbytes = int(leaf.size) * jnp.dtype(leaf.dtype).itemsize
        stats.records.append(
            CommRecord(kind, axis, int(nbytes * wire_factor), tuple(leaf.shape), tag)
        )


# -- wrapped collectives -----------------------------------------------------
# wire_factor approximates bytes crossing links per device for ring algos:
# all_reduce moves ~2x the payload (reduce-scatter + all-gather), the others 1x.


def psum(x, axis: str, tag: str = ""):
    _record("all_reduce", axis, x, tag, wire_factor=2.0)
    return jax.lax.psum(x, axis)


def psum_scatter(x, axis: str, *, scatter_dimension: int, tiled: bool = True, tag: str = ""):
    _record("reduce_scatter", axis, x, tag)
    return jax.lax.psum_scatter(
        x, axis, scatter_dimension=scatter_dimension, tiled=tiled
    )


def all_gather(x, axis: str, *, gather_axis: int, tiled: bool = True, tag: str = ""):
    _record("all_gather", axis, x, tag)
    return jax.lax.all_gather(x, axis, axis=gather_axis, tiled=tiled)


def all_to_all(x, axis: str, *, split_axis: int, concat_axis: int, tag: str = ""):
    _record("all_to_all", axis, x, tag)
    return jax.lax.all_to_all(x, axis, split_axis=split_axis, concat_axis=concat_axis, tiled=True)


def ppermute(x, axis: str, perm, tag: str = ""):
    _record("collective_permute", axis, x, tag)
    return jax.lax.ppermute(x, axis, perm)


def pbroadcast_from0(x, axis: str, tag: str = ""):
    """Broadcast shard 0's value to all shards of ``axis``.

    This is the explicit analogue of the paper's baseline "rank 0 broadcasts
    the embedding activations" — implemented as a masked psum so the wire cost
    is the payload size, like a real broadcast.
    """
    _record("broadcast", axis, x, tag)
    idx = jax.lax.axis_index(axis)
    masked = jax.tree.map(lambda v: jnp.where(idx == 0, v, jnp.zeros_like(v)), x)
    return jax.lax.psum(masked, axis)
