"""Declarative per-architecture serving capabilities.

One :class:`ArchCapabilities` record per architecture, derived from its
:class:`~repro.configs.base.ModelConfig` at engine construction.  Every
scheduler / engine / serve entry point consults the record through a single
``require(path)`` choke point instead of scattering per-family ``isinstance``
checks and ad-hoc clamps: an ineligible (arch, path) combination raises ONE
uniformly worded error naming the blocking capability and the fallback.

Serving paths
-------------
``chunked``   chunked prefill through the fused mixed prefill/decode step
``spec``      speculative decoding (n-gram draft + fused multi-token verify)
``paged``     paged KV backend (block pool + block tables + prefix cache)
``disagg``    disaggregated prefill/decode pools with KV-block migration
``overlap``   overlapped host/device engine loop

Derivation rules (all structural, no per-arch tables):

* ``chunked`` / ``spec`` need a resumable token-position cache: every mixer
  is attention (``attn``/``local_attn`` — dense, MLA latent, and
  sliding-window ring layouts all replay positions), no modality-prefix
  frontend, and a single-codebook head.  Recurrent mixers (``ssd``/``rglru``)
  carry state across the chunk boundary that the fused step does not
  checkpoint, so they fall back to whole-prompt admission.
* ``paged`` needs every attention cache to be block-addressable: the
  sliding-window ring layout is not pageable (a ring index is not a block
  offset), and the frontend / multi-codebook admission paths only exist on
  the dense slot engine.  Recurrent state is per-slot and constant-size, so
  SSM archs page fine.
* ``disagg`` = ``chunked`` AND ``paged`` (prefill resumes mid-cache on a
  separate pool, then blocks migrate).
* ``overlap`` reorders host observation, not device math — every arch.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from repro.configs.base import ModelConfig

# Canonical serving-path ids, in display order.
PATHS: Tuple[str, ...] = ("chunked", "spec", "paged", "disagg", "overlap")

PATH_NAMES: Dict[str, str] = {
    "chunked": "chunked prefill",
    "spec": "speculative decoding",
    "paged": "paged KV",
    "disagg": "disaggregated prefill/decode",
    "overlap": "overlapped decode",
}

# What an ineligible arch gets instead of the path.
FALLBACKS: Dict[str, str] = {
    "chunked": "whole-prompt admission",
    "spec": "plain one-token decode",
    "paged": "the dense slot engine",
    "disagg": "the unified paged engine",
    "overlap": "the blocking engine loop",
}

# Blocking-capability tags -> full phrases (tags double as matrix-cell
# annotations; phrases appear in the uniform ``require()`` error).
BLOCKERS: Dict[str, str] = {
    "ring": "the sliding-window ring cache layout",
    "recurrent": "the recurrent-state cache layout (no chunk-boundary carry)",
    "frontend": "the modality-prefix frontend",
    "codebooks": "per-codebook sampling (multi-codebook head)",
}


@dataclass(frozen=True)
class ArchCapabilities:
    """Declarative serving-capability record for one architecture."""

    arch: str
    # cache layouts this arch's caches use, e.g. ("dense", "ring")
    cache_layouts: Tuple[str, ...]
    # "single" | "per-codebook"
    sampling: str
    # in-flight admission prompt clamp (sliding-window archs: the window);
    # None = no structural clamp beyond max_len
    max_prompt: Optional[int]
    # path id -> blocking-capability tag (absent = supported)
    blockers: Dict[str, str]

    # -- derivation -------------------------------------------------------
    @classmethod
    def from_config(cls, cfg: ModelConfig) -> "ArchCapabilities":
        kinds = set(cfg.layer_pattern)
        ring = cfg.window > 0 and "local_attn" in kinds
        recurrent = bool(kinds & {"ssd", "rglru"})
        multi_cb = cfg.n_codebooks > 1
        has_frontend = cfg.frontend is not None

        blockers: Dict[str, str] = {}

        def first_blocker(*conds) -> Optional[str]:
            for tag, hit in conds:
                if hit:
                    return tag
            return None

        chunk_block = first_blocker(
            ("frontend", has_frontend),
            ("codebooks", multi_cb),
            ("recurrent", recurrent),
        )
        paged_block = first_blocker(
            ("ring", ring),
            ("frontend", has_frontend),
            ("codebooks", multi_cb),
        )
        if chunk_block:
            blockers["chunked"] = chunk_block
            blockers["spec"] = chunk_block
        if paged_block:
            blockers["paged"] = paged_block
        disagg_block = chunk_block or paged_block
        if disagg_block:
            blockers["disagg"] = disagg_block
        # "overlap" reorders host observation only — never blocked.

        layouts: List[str] = ["dense"]
        if cfg.mla is not None:
            layouts.append("latent")
        if ring:
            layouts.append("ring")
        if recurrent:
            layouts.append("recurrent-state")
        if "paged" not in blockers:
            layouts.append("paged")

        return cls(
            arch=cfg.name,
            cache_layouts=tuple(layouts),
            sampling="per-codebook" if multi_cb else "single",
            max_prompt=cfg.window if ring else None,
            blockers=blockers,
        )

    # -- queries ----------------------------------------------------------
    def supports(self, path: str) -> bool:
        if path not in PATHS:
            raise KeyError(f"unknown serving path {path!r}; known: {PATHS}")
        return path not in self.blockers

    def blocker(self, path: str) -> Optional[str]:
        """Blocking-capability tag for ``path`` (None if supported)."""
        if path not in PATHS:
            raise KeyError(f"unknown serving path {path!r}; known: {PATHS}")
        return self.blockers.get(path)

    def require(self, path: str) -> None:
        """The single eligibility choke point: raise the uniformly worded
        capability error if ``path`` is not supported by this arch."""
        tag = self.blocker(path)
        if tag is None:
            return
        raise ValueError(
            f"arch {self.arch!r} does not support {PATH_NAMES[path]}: "
            f"blocked by {BLOCKERS[tag]} — use {FALLBACKS[path]} instead"
        )


# ---------------------------------------------------------------------------
# Registry over the config registry
# ---------------------------------------------------------------------------


def registry() -> Dict[str, ArchCapabilities]:
    """arch-id -> capability record, for every registered architecture."""
    from repro import configs  # local import: configs never imports core

    return {
        arch: ArchCapabilities.from_config(configs.get_config(arch))
        for arch in configs.ALL_ARCHS
    }


def _cell(caps: ArchCapabilities, path: str) -> str:
    tag = caps.blocker(path)
    return "✓" if tag is None else f"✗ {tag}"


def matrix_rows() -> List[Tuple[str, ArchCapabilities]]:
    return sorted(registry().items())


def render_text() -> str:
    """Plain-text capability matrix (``serve.py --list-archs``)."""
    header = ["arch", *PATHS, "sampling", "max-prompt"]
    rows = [header]
    for arch, caps in matrix_rows():
        rows.append(
            [arch, *(_cell(caps, p) for p in PATHS), caps.sampling,
             str(caps.max_prompt) if caps.max_prompt else "-"]
        )
    widths = [max(len(r[i]) for r in rows) for i in range(len(header))]
    lines = ["  ".join(c.ljust(w) for c, w in zip(r, widths)).rstrip()
             for r in rows]
    lines.insert(1, "  ".join("-" * w for w in widths))
    legend = [""]
    legend.append("blocking capabilities:")
    for tag, phrase in sorted(BLOCKERS.items()):
        legend.append(f"  {tag:<10} {phrase}")
    return "\n".join(lines + legend)


def render_markdown() -> str:
    """Markdown capability matrix (the README support-matrix section)."""
    out = ["| arch | " + " | ".join(PATHS) + " | sampling | max prompt |",
           "|" + "---|" * (len(PATHS) + 3)]
    for arch, caps in matrix_rows():
        cells = [f"`{arch}`", *(_cell(caps, p) for p in PATHS),
                 caps.sampling,
                 str(caps.max_prompt) if caps.max_prompt else "—"]
        out.append("| " + " | ".join(cells) + " |")
    return "\n".join(out)


def as_dict() -> Dict[str, dict]:
    """JSON-ready capability matrix (``GET /health`` ``capabilities``)."""
    out: Dict[str, dict] = {}
    for arch, caps in matrix_rows():
        out[arch] = {
            "paths": {
                p: {"supported": caps.supports(p), "blocker": caps.blocker(p)}
                for p in PATHS
            },
            "cache_layouts": list(caps.cache_layouts),
            "sampling": caps.sampling,
            "max_prompt": caps.max_prompt,
        }
    return out
