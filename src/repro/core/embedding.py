"""Paper §2.1a — broadcast token IDs, not embedding activations.

Three modes, all explicit:

* ``id_broadcast + replicated table`` (paper-faithful): token IDs are the
  replicated value (their "broadcast" costs 4 bytes/token); every shard looks
  up the full table locally — **zero** collective bytes on the embedding path.
* ``id_broadcast + vocab-sharded table`` (memory-constrained TPU variant):
  masked local lookup over the shard's vocab slice + one psum of the
  activations; table memory is /tp.
* ``embed_broadcast`` (the paper's baseline, for the ablation bench): shard 0
  owns the lookup and broadcasts the dense (batch, seq, d_model) activations.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core import collectives as cc
from repro.models.common import Dist, ParamDef, ShardPlan

# tables at or below this many bytes (bf16) are replicated, paper-style
REPLICATE_BYTES_LIMIT = 512 * 2**20


def table_replicated(cfg: ModelConfig) -> bool:
    return (
        not cfg.tie_embeddings
        and cfg.vocab_size * cfg.d_model * 2 * cfg.n_codebooks <= REPLICATE_BYTES_LIMIT
    )


def embed_defs(cfg: ModelConfig, plan: ShardPlan, dist: Dist) -> Dict[str, ParamDef]:
    if table_replicated(cfg):
        shape = (cfg.n_codebooks, cfg.vocab_size, cfg.d_model)
        spec = P(None, None, None)
    else:
        shape = (cfg.n_codebooks, plan.vocab_p, cfg.d_model)
        spec = P(None, dist.model_axis, None)
    return {"table": ParamDef(shape, spec, init="normal")}


def embed_lookup(
    params: Dict[str, jax.Array],
    tokens: jax.Array,            # (batch, seq) or (batch, seq, n_codebooks) int32
    cfg: ModelConfig,
    plan: ShardPlan,
    dist: Dist,
    *,
    id_broadcast: bool = True,
) -> jax.Array:
    """Returns (batch, seq, d_model) activations, replicated over model axis."""
    table = params["table"]
    if tokens.ndim == 2:
        tokens = tokens[..., None]
    n_cb = tokens.shape[-1]

    if table_replicated(cfg):
        # Paper-faithful: IDs replicated, local full-table lookup, 0 comm bytes.
        out = 0.0
        for cb in range(n_cb):
            out = out + jnp.take(table[cb], tokens[..., cb], axis=0)
        if not id_broadcast:
            # baseline for the bench: rank-0 lookup + activation broadcast
            out = cc.pbroadcast_from0(out, dist.model_axis, tag="embed_bcast")
        return out

    # vocab-sharded table: masked local lookup + psum
    shard = dist.model_idx()
    lo = shard * plan.local_vocab
    out = 0.0
    for cb in range(n_cb):
        ids = tokens[..., cb]
        local = ids - lo
        ok = (local >= 0) & (local < plan.local_vocab)
        local = jnp.clip(local, 0, plan.local_vocab - 1)
        e = jnp.take(table[cb], local, axis=0)
        out = out + jnp.where(ok[..., None], e, 0.0).astype(table.dtype)
    if id_broadcast:
        return cc.psum(out, dist.model_axis, tag="embed_shard_merge")
    # baseline: merge on shard 0 then broadcast the dense activations
    # (models the paper's rank-0-computes-then-broadcasts schedule: the
    # activation row crosses the wire twice).
    merged = cc.psum(out, dist.model_axis, tag="embed_shard_merge")
    return cc.pbroadcast_from0(merged, dist.model_axis, tag="embed_bcast")
