"""Paper §2.2 — one-time synchronization per decoder layer, generalized.

The paper's observation: a TP decoder layer ordinarily ends each of its two
row-parallel matmuls (attention out-proj, FFN down-proj) with an all-reduce —
2 syncs/layer.  For parallel-residual models the two partial sums can be added
*locally* and reduced **once**.

This module centralizes the residual-stream synchronization policy so every
block uses the same, countable schedule:

* ``replicated`` (decode default): residual is replicated over the model axis;
  ``reduce_partial`` = one psum.  Parallel-residual blocks sum both branch
  partials first -> exactly the paper's 1 psum/layer.
* ``seq_sharded`` (train/prefill default; beyond-paper Megatron-SP):
  the residual is sequence-sharded over the model axis; entering a branch
  all-gathers the sequence, leaving reduce-scatters it.  Same bytes on the
  wire as one all-reduce but half the latency-exposed hops and 1/tp the
  residual memory — the TPU-idiomatic version of "cheaper syncs per layer".
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.core import collectives as cc
from repro.models.common import Dist

SEQ_AXIS = 1  # residual stream layout (batch, seq, d_model)


@dataclass(frozen=True)
class SyncPolicy:
    dist: Dist
    seq_sharded: bool = False     # Megatron-SP residual stream
    one_shot: bool = True         # paper §2.2 for parallel-residual blocks

    # -- entering a mixer/FFN branch: need the full sequence, replicated ----
    def gather_in(self, x: jax.Array, tag: str = "sp_gather") -> jax.Array:
        if self.seq_sharded and self.dist.tp > 1:
            return cc.all_gather(x, self.dist.model_axis, gather_axis=SEQ_AXIS, tag=tag)
        return x

    # -- leaving a branch: partial sums must be reduced ---------------------
    def reduce_out(self, partial: jax.Array, tag: str = "branch_reduce") -> jax.Array:
        if self.dist.tp == 1:
            return partial
        if self.seq_sharded:
            return cc.psum_scatter(
                partial, self.dist.model_axis, scatter_dimension=SEQ_AXIS, tag=tag
            )
        return cc.psum(partial, self.dist.model_axis, tag=tag)

    def shard_residual(self, x: jax.Array) -> jax.Array:
        """Slice a replicated residual down to this shard's sequence chunk."""
        if not (self.seq_sharded and self.dist.tp > 1):
            return x
        idx = self.dist.model_idx()
        chunk = x.shape[SEQ_AXIS] // self.dist.tp
        return jax.lax.dynamic_slice_in_dim(x, idx * chunk, chunk, axis=SEQ_AXIS)

    def unshard_residual(self, x: jax.Array, tag: str = "final_gather") -> jax.Array:
        if not (self.seq_sharded and self.dist.tp > 1):
            return x
        return cc.all_gather(x, self.dist.model_axis, gather_axis=SEQ_AXIS, tag=tag)
