"""Paper §2.3 — zero-copy compute→communication handoff, on TPU terms.

On CPU+oneCCL the saving is a literal memcpy into the comm buffer.  Under
XLA the same waste appears as (a) ``copy``/``transpose`` ops materialised
between the last matmul and the collective and (b) un-donated buffers that
force the runtime to keep two copies of large state alive.  This module
provides the three mechanisms we use and the measurement hook:

1. ``fused_out_projection`` — the attention output is contracted straight
   from its (b, h, s, hd) layout into the residual layout with a single
   einsum, so no reshape/transpose op sits between the matmul and the psum
   that follows it.
2. ``donate`` / jit wrappers — KV caches, recurrent state and optimizer state
   are donated, which XLA turns into true in-place aliases
   (``memory_analysis().alias_size_in_bytes`` is the receipt).
3. ``count_copies`` — counts ``copy``/``transpose`` HLO ops in a lowered
   step; the §2.3 bench reports this before/after.
"""
from __future__ import annotations

import re
from typing import Any, Callable

import jax
import jax.numpy as jnp


def fused_out_projection(attn_heads: jax.Array, w_o) -> jax.Array:
    """(b, h, s, hd) x (h, hd, d) -> (b, s, d) in one contraction.

    The naive path reshapes (b, h, s, hd) -> (b, s, h*hd) (a materialised
    transpose+copy) before a 2-D matmul.  Contracting h and hd together keeps
    the producer's layout and writes the partial sum directly into the buffer
    the following psum reads — the XLA analogue of the paper's zero-copy.

    Weight-only-quantized w_o (per-head K=hd group scales, all TP-local)
    dequantizes in place and keeps this einsum: flattening to the 2-D fused
    kernel would reintroduce exactly the (b,s,h*hd) transpose this function
    exists to avoid, so the out-projection stays on the reference dequant
    (the fused-tile dequant of a 3-D contraction is real-TPU future work).
    """
    from repro.core import wquant

    return jnp.einsum("bhsd,hde->bse", attn_heads, wquant.to_dense(w_o))


def count_copies(lowered_text: str) -> dict:
    """Count copy-like HLO ops in ``lowered.as_text()`` output."""
    counts = {"copy": 0, "transpose": 0, "reshape": 0}
    for line in lowered_text.splitlines():
        line = line.strip()
        for op in counts:
            # HLO: '%copy.3 = ...' or ' copy(' ; MLIR: 'stablehlo.transpose'
            if re.search(rf"(^%?{op}[.\d]*\s*=|stablehlo\.{op}\b|\s{op}\()", line):
                counts[op] += 1
    return counts


def donating_jit(fn: Callable, donate_argnums, **jit_kwargs):
    """jit with donated state buffers (KV cache / optimizer state)."""
    return jax.jit(fn, donate_argnums=donate_argnums, **jit_kwargs)


def alias_bytes(compiled) -> int:
    """Bytes the compiled executable aliases in-place (donation receipt)."""
    return int(compiled.memory_analysis().alias_size_in_bytes)
