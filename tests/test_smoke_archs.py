"""Per-arch REDUCED smoke tests (deliverable (f)): one forward + one train
step on CPU, asserting output shapes and no NaNs, for every assigned arch."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import compat

from repro.configs import ALL_ARCHS, ASSIGNED_ARCHS, ParallelConfig, get_config
from repro.launch.mesh import make_local_mesh
from repro.models import model as M
from repro.training import data as D
from repro.training.train_loop import AdamWConfig, init_opt_state, make_train_step


def _forward_once(arch, seq=16, batch=2):
    cfg = get_config(arch).reduced()
    ctx = M.ModelCtx.make(cfg, ParallelConfig(tp=1, dp=1, remat=False))
    params = M.init_params(ctx, jax.random.key(0))
    mesh = make_local_mesh(1, 1)
    tok_shape = (batch, seq) if cfg.n_codebooks == 1 else (batch, seq, cfg.n_codebooks)
    tokens = jax.random.randint(jax.random.key(1), tok_shape, 0, cfg.vocab_size)
    feats = None
    if cfg.frontend is not None:
        feats = jax.random.normal(
            jax.random.key(2),
            (batch, cfg.frontend.prefix_len, cfg.frontend.feature_dim), jnp.float32)

    def step(params, tokens, feats):
        logits, _, aux = M.forward(params, tokens, ctx, features=feats,
                                   seq_sharded=True)
        return logits, aux

    in_specs = (M.param_specs(ctx), P("data", *(None,) * (len(tok_shape) - 1)),
                P("data") if feats is not None else P())
    out_spec = (P("data", None, "model") if cfg.n_codebooks == 1
                else P("data", None, None, "model"))
    f = jax.jit(compat.shard_map(step, mesh=mesh, in_specs=in_specs,
                              out_specs=(out_spec, P()), check_vma=False))
    logits, aux = f(params, tokens, feats)
    return cfg, logits, aux


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_shapes_no_nan(arch):
    cfg, logits, aux = _forward_once(arch)
    prefix = cfg.frontend.prefix_len if cfg.frontend else 0
    expect_s = 16 + prefix
    from repro.models.common import ShardPlan

    vp = ShardPlan.make(cfg, 1).vocab_p
    if cfg.n_codebooks == 1:
        assert logits.shape == (2, expect_s, vp)
    else:
        assert logits.shape == (2, expect_s, cfg.n_codebooks, vp)
    assert not bool(jnp.isnan(logits).any())
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_one_train_step(arch):
    cfg = get_config(arch).reduced()
    ctx = M.ModelCtx.make(cfg, ParallelConfig(tp=1, dp=1, remat=True))
    params = M.init_params(ctx, jax.random.key(0))
    mesh = make_local_mesh(1, 1)
    opt = init_opt_state(params)
    step_fn = make_train_step(ctx, AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=10))
    dc = D.DataConfig(global_batch=2, seq_len=32)
    b = D.make_batch(cfg, dc, 0)
    bspecs = {k: P("data", *(None,) * (v.ndim - 1)) for k, v in b.items()}
    pspecs = M.param_specs(ctx)
    ospecs = {"m": pspecs, "v": pspecs, "step": P()}
    f = jax.jit(compat.shard_map(step_fn, mesh=mesh,
                              in_specs=(pspecs, ospecs, bspecs),
                              out_specs=(pspecs, ospecs, P()), check_vma=False))
    new_p, new_o, metrics = f(params, opt, {k: jnp.asarray(v) for k, v in b.items()})
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0
    # params actually moved
    delta = max(float(jnp.abs(a.astype(jnp.float32) - b_.astype(jnp.float32)).max())
                for a, b_ in zip(jax.tree.leaves(params), jax.tree.leaves(new_p)))
    assert delta > 0
    assert not any(bool(jnp.isnan(x).any()) for x in jax.tree.leaves(new_p))


@pytest.mark.parametrize("arch", ["yi-9b", "minicpm3-4b", "mamba2-1.3b",
                                  "recurrentgemma-9b", "musicgen-medium"])
def test_decode_matches_full_forward(arch):
    """Prefill+decode with cache == full forward on the concatenated tokens."""
    cfg = get_config(arch).reduced()
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=100.0))
    ctx = M.ModelCtx.make(cfg, ParallelConfig(tp=1, dp=1, remat=False))
    params = M.init_params(ctx, jax.random.key(0))
    mesh = make_local_mesh(1, 1)
    S = 40  # must cover prefix + 17 prompt tokens + 1 decode slot
    tshape = (2, 17) if cfg.n_codebooks == 1 else (2, 17, cfg.n_codebooks)
    tokens = jax.random.randint(jax.random.key(1), tshape, 0, cfg.vocab_size)
    prefix = cfg.frontend.prefix_len if cfg.frontend else 0
    feats = None
    if cfg.frontend is not None:
        feats = jax.random.normal(
            jax.random.key(2), (2, prefix, cfg.frontend.feature_dim), jnp.float32)

    def full(params, tokens, feats):
        logits, _, _ = M.forward(params, tokens, ctx, features=feats)
        return logits[:, -1]

    def cached(params, tokens, feats):
        caches = M.init_caches(ctx, 2, S)
        _, caches, _ = M.forward(params, tokens[:, :16], ctx, features=feats,
                                 caches=caches, last_only=True)
        lg, _, _ = M.forward(params, tokens[:, 16:17], ctx, caches=caches,
                             cur_pos=jnp.int32(16 + prefix))
        return lg[:, -1]

    in_specs = (M.param_specs(ctx), P("data", *(None,) * (tokens.ndim - 1)),
                P("data") if feats is not None else P())
    out_spec = (P("data", "model") if cfg.n_codebooks == 1
                else P("data", None, "model"))
    run = lambda f: np.asarray(jax.jit(compat.shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_spec, check_vma=False))(
        params, tokens, feats), dtype=np.float32)
    a, b = run(full), run(cached)
    np.testing.assert_allclose(a, b, atol=0.08, rtol=0.05)


@pytest.mark.parametrize("arch", ["yi-9b", "qwen2.5-14b"])
def test_int8_kv_cache_close_to_bf16(arch):
    """int8 KV cache (per-head-per-slot scales) stays within ~2% of bf16 on
    dense archs (MoE archs are router-flip sensitive; documented).

    NOTE: the seed-state failure of this test was NOT a quantization bug —
    it was the jax-API skew (``jax.shard_map`` missing on jax 0.4.x), fixed
    by routing through ``repro.compat``.  The scale path (absmax/127 per
    (batch, head, slot), fp32 round-trip) verifies within the 5% bound on
    both archs with no tolerance change."""
    cfg = get_config(arch).reduced()
    mesh = make_local_mesh(1, 1)
    tokens = jax.random.randint(jax.random.key(1), (2, 17), 0, cfg.vocab_size)
    outs = {}
    for quant in (False, True):
        ctx = M.ModelCtx.make(cfg, ParallelConfig(tp=1, dp=1, remat=False,
                                                  kv_quant=quant))
        params = M.init_params(ctx, jax.random.key(0))

        def pd(params, tokens, ctx=ctx):
            caches = M.init_caches(ctx, 2, 40)
            _, caches, _ = M.forward(params, tokens[:, :16], ctx, caches=caches,
                                     last_only=True)
            lg, _, _ = M.forward(params, tokens[:, 16:17], ctx, caches=caches,
                                 cur_pos=jnp.int32(16))
            return lg[:, -1]

        f = jax.jit(compat.shard_map(pd, mesh=mesh,
                                  in_specs=(M.param_specs(ctx), P("data", None)),
                                  out_specs=P("data", "model"), check_vma=False))
        outs[quant] = np.asarray(f(params, tokens), np.float32)
    rel = np.abs(outs[True] - outs[False]).max() / np.abs(outs[False]).max()
    assert rel < 0.05, rel
