"""Disaggregated prefill/decode serving: pool-split gating, KV-block
migration invariants (refcount handoff, preempt-during-migration rollback,
decode-side prefix hits that skip the copy), token identity against the
unified paged engine across the certification mix, and the packed
QuantWeight checkpoint (wq_cache) round-trip.

Single-device tests cover gating + the weight cache; everything touching an
actual pool split needs >= 2 virtual devices (JAX_NUM_CPU_DEVICES=4 in the
CI serving job — same idiom as test_paged's multi-shard section)."""
import jax
import numpy as np
import pytest

from repro.configs import ParallelConfig, SamplingConfig, get_config
from repro.launch.mesh import make_local_mesh, split_data_shards
from repro.runtime.engine import Engine
from repro.runtime.scheduler import DisaggScheduler, PagedContinuousScheduler

needs2 = pytest.mark.skipif(
    jax.device_count() < 2,
    reason="needs 2 devices (JAX_NUM_CPU_DEVICES/XLA_FLAGS)")
needs4 = pytest.mark.skipif(
    jax.device_count() < 4,
    reason="needs 4 devices (JAX_NUM_CPU_DEVICES/XLA_FLAGS)")


def greedy_engine(arch: str, max_len: int = 64, parallel=None,
                  mesh=None, **kw) -> Engine:
    cfg = get_config(arch).reduced()
    return Engine(cfg=cfg,
                  parallel=parallel or ParallelConfig(tp=1, dp=1, remat=False),
                  sampling=SamplingConfig(greedy=True, top_k=1),
                  mesh=mesh or make_local_mesh(1, 1), max_len=max_len, **kw)


def dp2_engine(**par_kw) -> Engine:
    return greedy_engine("yi-9b",
                         parallel=ParallelConfig(tp=1, dp=2, remat=False,
                                                 **par_kw),
                         mesh=make_local_mesh(2, 1))


def disagg_requests(cfg, n=6, seed=0, shared_prefix=0):
    """Long-ish multi-chunk prompts with staggered arrivals; every third
    request gets an EOS id so early stopping crosses the handoff."""
    rng = np.random.default_rng(seed)
    pre = rng.integers(0, cfg.vocab_size, shared_prefix).astype(np.int32)
    lo, hi = (8, 24) if shared_prefix else (12, 40)   # keep under max_len=64
    reqs = []
    for i in range(n):
        p = rng.integers(0, cfg.vocab_size,
                         int(rng.integers(lo, hi))).astype(np.int32)
        if shared_prefix:
            p = np.concatenate([pre, p])
        reqs.append((p, int(rng.integers(4, 10)), None if i % 3 else 5,
                     3 * i))
    return reqs


def run_disagg_vs_unified(eng, reqs, n_slots=4, block_size=8, chunk=8,
                          prefill_shards=1, **kw):
    uni = PagedContinuousScheduler(eng, n_slots=n_slots, block_steps=2,
                                   block_size=block_size,
                                   prefill_chunk=chunk, **kw)
    dis = DisaggScheduler(eng, n_slots=n_slots, block_steps=2,
                          block_size=block_size, prefill_chunk=chunk,
                          prefill_shards=prefill_shards, **kw)
    for sched in (uni, dis):
        for p, mn, eos, arr in reqs:
            sched.submit(p, mn, eos_id=eos, arrival_step=arr)
    u = {r.rid: r for r in uni.run()}
    d = {r.rid: r for r in dis.run()}
    assert sorted(u) == sorted(d)
    for rid in u:
        np.testing.assert_array_equal(u[rid].output, d[rid].output)
    return uni, dis


# ---------------------------------------------------------------------------
# Gating (single device)
# ---------------------------------------------------------------------------


def test_split_data_shards():
    assert split_data_shards(4, 1) == ((0,), (1, 2, 3))
    assert split_data_shards(4, 2) == ((0, 1), (2, 3))
    for bad in (0, 4, 5):
        with pytest.raises(ValueError):
            split_data_shards(4, bad)


@pytest.mark.parametrize("arch", ["mamba2-1.3b", "recurrentgemma-9b"])
def test_disagg_rejects_fallback_archs(arch):
    """Recurrent-state families cannot resume prefill mid-cache on a
    separate pool; the capability registry must refuse loudly (not
    silently serve unified) — the same uniform error every gated path
    raises."""
    eng = greedy_engine(arch)
    with pytest.raises(ValueError, match="does not support disaggregated"):
        DisaggScheduler(eng, n_slots=2, block_size=8, prefill_shards=1)


def test_disagg_needs_two_shards():
    eng = greedy_engine("yi-9b")
    with pytest.raises(ValueError, match="dp >= 2"):
        DisaggScheduler(eng, n_slots=2, block_size=8, prefill_chunk=8,
                        prefill_shards=1)


def test_disagg_needs_chunking():
    eng = greedy_engine("yi-9b")
    with pytest.raises(ValueError, match="prefill_chunk"):
        DisaggScheduler(eng, n_slots=2, block_size=8, prefill_chunk=0,
                        prefill_shards=1)


# ---------------------------------------------------------------------------
# Packed QuantWeight checkpoint (wq_cache)
# ---------------------------------------------------------------------------


def test_wq_cache_roundtrip(tmp_path, monkeypatch):
    from repro.models import model as M

    path = str(tmp_path / "wq")
    par = ParallelConfig(tp=1, dp=1, remat=False, weight_quant="int8")
    e1 = greedy_engine("yi-9b", parallel=par, wq_cache=path)
    assert M.has_quantized(path)
    # the restored engine must never materialize the bf16 tree
    monkeypatch.setattr(M, "init_params", lambda *a, **k: (_ for _ in ()).throw(
        AssertionError("bf16 init ran despite wq cache")))
    e2 = greedy_engine("yi-9b", parallel=par, wq_cache=path)
    l1, l2 = jax.tree.leaves(e1.params), jax.tree.leaves(e2.params)
    assert len(l1) == len(l2)
    for a, b in zip(l1, l2):
        assert a.dtype == b.dtype and a.shape == b.shape
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    prompts = np.arange(12, dtype=np.int32).reshape(2, 6) % e1.cfg.vocab_size
    np.testing.assert_array_equal(np.asarray(e1.generate(prompts, 4)),
                                  np.asarray(e2.generate(prompts, 4)))


def test_wq_cache_rejects_layout_mismatch(tmp_path):
    path = str(tmp_path / "wq")
    greedy_engine("yi-9b", wq_cache=path,
                  parallel=ParallelConfig(tp=1, dp=1, remat=False,
                                          weight_quant="int8"))
    with pytest.raises(ValueError, match="packed for"):
        greedy_engine("yi-9b", wq_cache=path,
                      parallel=ParallelConfig(tp=1, dp=1, remat=False,
                                              weight_quant="int4"))


# ---------------------------------------------------------------------------
# Token identity vs the unified paged engine (>= 2 shards)
# ---------------------------------------------------------------------------


@needs2
def test_disagg_matches_unified_gqa():
    eng = dp2_engine()
    _, dis = run_disagg_vs_unified(eng, disagg_requests(eng.cfg))
    assert dis.stats["handoffs"] > 0
    assert dis.stats["migrated_blocks"] > 0
    assert dis.stats["migration_bytes"] > 0


@needs2
def test_disagg_matches_unified_int8_kv():
    eng = dp2_engine(kv_quant=True)
    _, dis = run_disagg_vs_unified(eng, disagg_requests(eng.cfg, seed=2))
    assert dis.stats["migrated_blocks"] > 0
    # migration accounting covers the quantized pool leaves (scales too)
    assert dis._block_bytes > 0


@needs2
def test_disagg_matches_unified_wquant():
    eng = dp2_engine(weight_quant="int8")
    run_disagg_vs_unified(eng, disagg_requests(eng.cfg, seed=3))


@needs2
def test_disagg_certification_mix_prefix_hit_skips_copy():
    """The acceptance mix: GQA + int8 KV + wquant + prefix sharing.  With a
    shared system prompt and overlapping arrivals, later requests' shared
    blocks are already resident in the decode pool (registered when the
    first request landed) — migration must reference them instead of
    copying, and streams must stay token-identical to unified serving."""
    eng = dp2_engine(kv_quant=True, weight_quant="int8")
    reqs = disagg_requests(eng.cfg, n=6, seed=4, shared_prefix=24)
    _, dis = run_disagg_vs_unified(eng, reqs)
    assert dis.stats["migration_skipped_blocks"] > 0
    assert dis.stats["migrated_blocks"] > 0


@needs4
def test_disagg_2p2d_pools():
    """The CI serving-job shape: 4 data shards split 2 prefill + 2 decode."""
    eng = greedy_engine("yi-9b",
                        parallel=ParallelConfig(tp=1, dp=4, remat=False),
                        mesh=make_local_mesh(4, 1))
    _, dis = run_disagg_vs_unified(eng, disagg_requests(eng.cfg, n=8, seed=5),
                                   n_slots=8, prefill_shards=2)
    p = dis.request_summary()["pools"]
    assert p["prefill_shards"] == 2 and p["decode_shards"] == 2
    assert p["handoffs"] == dis.stats["handoffs"] > 0


# ---------------------------------------------------------------------------
# Migration invariants (>= 2 shards)
# ---------------------------------------------------------------------------


@needs2
def test_disagg_refcounts_conserved():
    """Every block allocated across admission, eager migration, handoff,
    decode growth, and landing is returned by the end of the run — on both
    pools, with no migration pins left dangling."""
    eng = dp2_engine()
    dis = DisaggScheduler(eng, n_slots=4, block_steps=2, block_size=8,
                          prefill_chunk=8, prefill_shards=1, n_blocks=20)
    for p, mn, eos, arr in disagg_requests(eng.cfg, n=8, seed=6):
        dis.submit(p, mn, eos_id=eos, arrival_step=arr)
    done = dis.run()
    assert len(done) == 8
    assert dis.stats["migrated_blocks"] > 0
    assert dis.alloc.total_used() == 0
    assert dis.alloc.migrating_count() == 0
    for sh in range(dis.n_shards):
        assert dis.alloc.free_count(sh) == dis.alloc.blocks_per_shard - 1


@needs2
def test_disagg_preempt_during_migration_requeues_cleanly():
    """Preempting a slot whose blocks are mid-migration must roll the whole
    handoff back: queued copies dropped (source pins released), destination
    blocks returned, request requeued — and the rerun completes."""
    eng = dp2_engine()
    dis = DisaggScheduler(eng, n_slots=4, block_steps=2, block_size=8,
                          prefill_chunk=8, prefill_shards=1)
    prompt = np.random.default_rng(7).integers(
        0, eng.cfg.vocab_size, 24).astype(np.int32)
    rid = dis.submit(prompt, 6)
    dis._init_caches()
    dis._retire()
    dis._admit()
    dis._chunk_step()           # publishes block 0, eagerly enqueues its copy
    assert dis._mig_queue and dis.alloc.migrating_count() > 0
    assert dis._preempt_youngest(0)
    assert not dis._mig_queue and not dis._mig
    assert dis.alloc.migrating_count() == 0
    assert dis.alloc.total_used() == 0        # src blocks AND dst blocks
    assert dis.queue and dis.queue[0].rid == rid
    done = dis.run()
    assert {r.rid for r in done} == {rid}
    assert len(done[0].output) == 6
    assert dis.stats["preemptions"] == 1
    assert dis.alloc.total_used() == 0


@needs2
def test_disagg_decode_flat_under_prefill_load():
    """The per-pool summary exists and decode ITL samples taken during
    concurrent prefill rounds are recorded (the bench quantifies flatness;
    here we assert the accounting surface)."""
    eng = dp2_engine()
    dis = DisaggScheduler(eng, n_slots=4, block_steps=2, block_size=8,
                          prefill_chunk=8, prefill_shards=1)
    for p, mn, eos, arr in disagg_requests(eng.cfg, n=6, seed=8):
        dis.submit(p, mn, eos_id=eos, arrival_step=arr)
    dis.run()
    summ = dis.request_summary()
    pools = summ["pools"]
    assert pools["migration_bytes"] == (dis.stats["migrated_blocks"]
                                        * dis._block_bytes)
    assert pools["migration_wait_s"]["p95"] >= pools["migration_wait_s"]["p50"]
    assert 0 < pools["prefill_occupancy"] <= 1
    assert "decode_itl_s" in pools
