"""Module-level correctness: RoPE, attention caches, SSD/RG-LRU vs naive
recurrence oracles, MoE dispatch, group building."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import compat

from repro.configs import get_config
from repro.configs.base import ModelConfig, RGLRUConfig, SSMConfig
from repro.models import rglru as rglru_mod
from repro.models import ssm as ssm_mod
from repro.models import transformer as tfm
from repro.models.common import Dist, apply_rope, materialize, rms_norm


def test_rope_rotation_preserves_norm():
    x = jax.random.normal(jax.random.key(0), (2, 4, 16, 64))
    pos = jnp.arange(16)[None, None, :]
    y = apply_rope(x, pos, 10000.0)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1), rtol=1e-5,
    )


def test_rope_relative_property():
    """<rope(q,i), rope(k,j)> depends only on i-j."""
    q = jax.random.normal(jax.random.key(1), (1, 1, 1, 64))
    k = jax.random.normal(jax.random.key(2), (1, 1, 1, 64))
    def dot_at(i, j):
        qi = apply_rope(q, jnp.array([[[i]]]), 10000.0)
        kj = apply_rope(k, jnp.array([[[j]]]), 10000.0)
        return float(jnp.sum(qi * kj))
    assert abs(dot_at(5, 3) - dot_at(105, 103)) < 1e-3
    assert abs(dot_at(5, 3) - dot_at(6, 3)) > 1e-5


def test_rms_norm_scale_invariant_direction():
    x = jax.random.normal(jax.random.key(0), (4, 32))
    g = jnp.zeros((32,))
    y1 = rms_norm(x, g, 1e-6)
    y2 = rms_norm(3.0 * x, g, 1e-6)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-2)


# ---------------------------------------------------------------------------
# SSD vs naive recurrence oracle
# ---------------------------------------------------------------------------


def _naive_ssd(x, log_a, B, C, D, h0):
    """x (b,s,h,P), log_a (b,s,h), B/C (b,s,N) -> per-definition recurrence."""
    b, s, h, Pd = x.shape
    N = B.shape[-1]
    H = h0.copy()
    ys = []
    for t in range(s):
        a = np.exp(log_a[:, t])                        # (b,h)
        H = H * a[..., None, None] + np.einsum("bhp,bn->bhpn", x[:, t], B[:, t])
        ys.append(np.einsum("bhpn,bn->bhp", H, C[:, t]) + D[None, :, None] * 0.0)
    return np.stack(ys, 1), H


@pytest.mark.parametrize("chunk", [4, 8, 16])
def test_ssd_chunked_matches_naive_recurrence(chunk):
    cfg = dataclasses.replace(
        get_config("mamba2-1.3b").reduced(),
        ssm=SSMConfig(state_dim=16, head_dim=8, expand=2, chunk=chunk, conv_width=4),
        d_model=32,
    )
    dist = Dist(tp=1, dp=1)
    mesh = compat.make_mesh((1, 1), ("data", "model"))
    from repro.models.common import specs_of

    defs = ssm_mod.ssd_defs(cfg, dist)
    params = materialize(defs, jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (2, 32, cfg.d_model), jnp.float32)

    def f(params, x):
        out, _ = ssm_mod.ssd_forward(params, x, cfg, dist)
        return out

    outs = {}
    for c in [chunk, 32]:
        cfg_c = dataclasses.replace(cfg, ssm=dataclasses.replace(cfg.ssm, chunk=c))

        def fc(params, x, cfg_c=cfg_c):
            out, _ = ssm_mod.ssd_forward(params, x, cfg_c, dist)
            return out

        outs[c] = np.asarray(
            jax.jit(compat.shard_map(fc, mesh=mesh, in_specs=(specs_of(defs), P()),
                                  out_specs=P(), check_vma=False))(params, x)
        )
    # chunk-size invariance == the chunked algebra matches the recurrence
    np.testing.assert_allclose(outs[chunk], outs[32], atol=2e-3, rtol=1e-3)


def test_ssd_decode_matches_prefill():
    cfg = dataclasses.replace(
        get_config("mamba2-1.3b").reduced(), d_model=32,
        ssm=SSMConfig(state_dim=16, head_dim=8, expand=2, chunk=8, conv_width=4),
    )
    dist = Dist(tp=1, dp=1)
    mesh = compat.make_mesh((1, 1), ("data", "model"))
    from repro.models.common import specs_of

    defs = ssm_mod.ssd_defs(cfg, dist)
    params = materialize(defs, jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (2, 17, cfg.d_model), jnp.float32)

    def full(params, x):
        out, _ = ssm_mod.ssd_forward(params, x[:, :16], cfg, dist)
        return out

    def stepwise(params, x):
        st = ssm_mod.init_ssd_state(cfg, dist, 2)
        ys = []
        for t in range(16):
            y, st = ssm_mod.ssd_forward(params, x[:, t : t + 1], cfg, dist, state=st)
            ys.append(y)
        return jnp.concatenate(ys, 1)

    run = lambda f: np.asarray(
        jax.jit(compat.shard_map(f, mesh=mesh, in_specs=(specs_of(defs), P()),
                              out_specs=P(), check_vma=False))(params, x)
    )
    np.testing.assert_allclose(run(full), run(stepwise), atol=2e-3, rtol=1e-3)


# ---------------------------------------------------------------------------
# RG-LRU vs sequential loop
# ---------------------------------------------------------------------------


def test_rglru_scan_matches_stepwise():
    cfg = dataclasses.replace(
        get_config("recurrentgemma-9b").reduced(), d_model=64, n_heads=4,
        rglru=RGLRUConfig(lru_width=0, conv_width=4),
    )
    dist = Dist(tp=1, dp=1)
    mesh = compat.make_mesh((1, 1), ("data", "model"))
    from repro.models.common import specs_of

    defs = rglru_mod.rglru_defs(cfg, dist)
    params = materialize(defs, jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (2, 12, cfg.d_model), jnp.float32)

    def full(params, x):
        st = rglru_mod.init_rglru_state(cfg, dist, 2)
        out, _ = rglru_mod.rglru_forward(params, x, cfg, dist, state=st)
        return out

    def stepwise(params, x):
        st = rglru_mod.init_rglru_state(cfg, dist, 2)
        ys = []
        for t in range(12):
            y, st = rglru_mod.rglru_forward(params, x[:, t : t + 1], cfg, dist, state=st)
            ys.append(y)
        return jnp.concatenate(ys, 1)

    run = lambda f: np.asarray(
        jax.jit(compat.shard_map(f, mesh=mesh, in_specs=(specs_of(defs), P()),
                              out_specs=P(), check_vma=False))(params, x)
    )
    np.testing.assert_allclose(run(full), run(stepwise), atol=2e-3, rtol=1e-3)


# ---------------------------------------------------------------------------
# Group building
# ---------------------------------------------------------------------------


def test_build_groups_recurrentgemma():
    cfg = get_config("recurrentgemma-9b")
    groups = tfm.build_groups(cfg)
    assert groups[0].n == 12 and len(groups[0].subs) == 3
    kinds = [s.kind for s in groups[0].subs]
    assert kinds == ["rglru", "rglru", "local_attn"]
    # 38 = 12*3 + 2 trailing rglru singles
    assert sum(g.n * len(g.subs) for g in groups) == 38


def test_build_groups_deepseek():
    cfg = get_config("deepseek-moe-16b")
    groups = tfm.build_groups(cfg)
    assert groups[0].n == 1 and not groups[0].subs[0].is_moe  # dense layer 0
    assert groups[1].n == 27 and groups[1].subs[0].is_moe
    assert sum(g.n * len(g.subs) for g in groups) == 28


@pytest.mark.parametrize("arch", ["qwen2.5-32b", "mixtral-8x7b", "mamba2-1.3b"])
def test_build_groups_homogeneous(arch):
    cfg = get_config(arch)
    groups = tfm.build_groups(cfg)
    assert len(groups) == 1 and groups[0].n == cfg.n_layers


# ---------------------------------------------------------------------------
# Banded sliding-window prefill (§Perf H6) and maybe_scan
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("S,W,cq", [(2048, 256, 512), (4096, 512, 1024),
                                    (2048, 700, 512)])
def test_banded_attention_matches_masked_full(S, W, cq):
    from repro.models.attention import (banded_causal_attention,
                                        chunked_causal_attention)

    ks = jax.random.split(jax.random.key(S + W), 3)
    q = jax.random.normal(ks[0], (1, 4, S, 32), jnp.float32)
    k = jax.random.normal(ks[1], (1, 2, S, 32), jnp.float32)
    v = jax.random.normal(ks[2], (1, 2, S, 32), jnp.float32)
    pos = jnp.arange(S)
    a = banded_causal_attention(q, k, v, pos, W, 0.18, q_chunk=cq)
    b = chunked_causal_attention(q, k, v, pos, pos, W, 0.18)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5, rtol=1e-5)


def test_maybe_scan_unrolled_equals_scan():
    from repro.models.common import UNROLL_SCANS, maybe_scan

    xs = jnp.arange(12.0).reshape(6, 2)

    def body(c, x):
        return c + x.sum(), c * 2

    a = maybe_scan(body, 1.0, xs)
    token = UNROLL_SCANS.set(True)
    try:
        b = maybe_scan(body, 1.0, xs)
    finally:
        UNROLL_SCANS.reset(token)
    assert jnp.allclose(a[0], b[0]) and jnp.allclose(a[1], b[1])
