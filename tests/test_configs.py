"""Config registry + parameter accounting tests."""
import pytest

from repro.configs import ALL_ARCHS, ASSIGNED_ARCHS, get_config, get_shape
from repro.configs.base import INPUT_SHAPES
from repro.models.common import ShardPlan

ADVERTISED_B = {
    "recurrentgemma-9b": 9.0,
    "qwen2.5-32b": 32.5,
    "musicgen-medium": 1.5,
    "minicpm3-4b": 4.0,
    "mixtral-8x7b": 46.7,
    "yi-9b": 8.8,
    "qwen2.5-14b": 14.7,
    "deepseek-moe-16b": 16.4,
    "mamba2-1.3b": 1.3,
    "qwen-72b": 72.0,
}


def test_registry_complete():
    assert len(ASSIGNED_ARCHS) == 10
    assert len(INPUT_SHAPES) == 4
    for a in ALL_ARCHS:
        cfg = get_config(a)
        assert cfg.name == a


@pytest.mark.parametrize("arch,b", sorted(ADVERTISED_B.items()))
def test_param_counts_near_advertised(arch, b):
    n = get_config(arch).param_count() / 1e9
    assert abs(n - b) / b < 0.35, f"{arch}: {n:.2f}B vs advertised {b}B"


def test_moe_active_params():
    mix = get_config("mixtral-8x7b")
    assert mix.active_param_count() < 0.35 * mix.param_count()
    ds = get_config("deepseek-moe-16b")
    assert ds.active_param_count() < 0.25 * ds.param_count()


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_reduced_constraints(arch):
    r = get_config(arch).reduced()
    assert r.n_layers <= 4 and r.d_model <= 512
    if r.moe:
        assert r.moe.n_experts <= 4


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_shard_plan_tp16(arch):
    """Every assigned arch must lay out on the production TP=16 axis."""
    cfg = get_config(arch)
    plan = ShardPlan.make(cfg, 16)
    assert plan.n_heads_p % 16 == 0
    assert plan.vocab_p % 16 == 0
    assert plan.local_q >= 1
    # padding never drops real heads
    assert plan.n_heads_p >= cfg.n_heads
    assert plan.n_kv_p >= min(cfg.n_kv_heads, plan.tp) or cfg.mla


def test_shapes_table():
    assert get_shape("train_4k").global_batch == 256
    assert get_shape("long_500k").seq_len == 524288
    assert get_shape("decode_32k").kind == "decode"
