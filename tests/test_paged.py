"""Paged KV cache: block allocator, block-table addressing, prefix reuse,
block-aware admission, and preemption — all against the dense slot engine
(which itself bit-matches wave/solo generation, see test_continuous)."""
import jax
import numpy as np
import pytest

from repro.configs import ParallelConfig, SamplingConfig, get_config
from repro.launch.mesh import make_local_mesh
from repro.runtime.engine import Engine
from repro.runtime.kvcache import NULL_BLOCK, BlockAllocator
from repro.runtime.scheduler import ContinuousScheduler, PagedContinuousScheduler


def greedy_engine(arch: str, max_len: int = 64, parallel=None,
                  mesh=None) -> Engine:
    cfg = get_config(arch).reduced()
    return Engine(cfg=cfg,
                  parallel=parallel or ParallelConfig(tp=1, dp=1, remat=False),
                  sampling=SamplingConfig(greedy=True, top_k=1),
                  mesh=mesh or make_local_mesh(1, 1), max_len=max_len)


@pytest.fixture(scope="module")
def yi_engine():
    return greedy_engine("yi-9b")


def straggler_requests(cfg, n=6, seed=0):
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        p = rng.integers(0, cfg.vocab_size, int(rng.integers(4, 12))).astype(np.int32)
        reqs.append((p, int(rng.integers(2, 9)), None if i % 3 else 5,
                     (i // 2) * 2))
    return reqs


def run_both(eng, reqs, n_slots=3, block_size=8, **paged_kw):
    dense = ContinuousScheduler(eng, n_slots=n_slots, block_steps=4)
    paged = PagedContinuousScheduler(eng, n_slots=n_slots, block_steps=4,
                                     block_size=block_size, **paged_kw)
    for sched in (dense, paged):
        for p, mn, eos, arr in reqs:
            sched.submit(p, mn, eos_id=eos, arrival_step=arr)
    d = {r.rid: r for r in dense.run()}
    pg = {r.rid: r for r in paged.run()}
    assert sorted(d) == sorted(pg)
    for rid in d:
        np.testing.assert_array_equal(d[rid].output, pg[rid].output)
    return dense, paged


# ---------------------------------------------------------------------------
# Paged greedy decode is token-identical to the dense slot engine
# ---------------------------------------------------------------------------


def test_paged_matches_dense_gqa(yi_engine):
    _, paged = run_both(yi_engine, straggler_requests(yi_engine.cfg))
    assert paged.stats["in_flight_admissions"] > 0
    # incremental allocation really tracked occupancy, not worst case
    assert 0 < paged.stats["blocks_hwm"] < paged.n_blocks


def test_paged_matches_dense_mla():
    eng = greedy_engine("minicpm3-4b")
    run_both(eng, straggler_requests(eng.cfg, seed=1))


def test_paged_matches_dense_int8_kv():
    eng = greedy_engine(
        "yi-9b", parallel=ParallelConfig(tp=1, dp=1, remat=False, kv_quant=True))
    _, paged = run_both(eng, straggler_requests(eng.cfg, seed=2))
    # the pool really carries quantized leaves
    g0 = paged.caches[0]
    leaves = jax.tree.leaves(g0)
    assert any(l.dtype == np.int8 for l in leaves)


def test_paged_matches_dense_attention_free():
    """Pure-SSM archs keep constant-size per-slot state; the paged backend
    must pass them through untouched (config plumbing only) — and must not
    reserve pool blocks their layers cannot use."""
    eng = greedy_engine("mamba2-1.3b")
    _, paged = run_both(eng, straggler_requests(eng.cfg, seed=3), n_slots=2)
    assert paged.stats["blocks_hwm"] == 0


def test_paged_pallas_engine_path():
    """The Pallas paged-decode kernel (block-table gather via scalar
    prefetch, interpret mode on CPU) wired into the engine: the full serve
    loop completes, and its per-step decode logits agree with the jnp view
    path to bf16 flash tolerance.  (Token-exact e2e equality is NOT
    expected across kernels — the jnp path rounds attention probabilities
    to bf16 before p@v, the kernel keeps fp32; the kernel itself is
    validated against the dense kernel in test_kernels.)"""
    import jax.numpy as jnp

    import repro.models.model as M

    outs = {}
    for up in (False, True):
        eng = greedy_engine("yi-9b", parallel=ParallelConfig(
            tp=1, dp=1, remat=False, use_pallas=up))
        rng = np.random.default_rng(11)
        reqs = [(rng.integers(0, eng.cfg.vocab_size, 7).astype(np.int32), 5)
                for _ in range(3)]
        sched = PagedContinuousScheduler(eng, n_slots=2, block_steps=2,
                                         block_size=8)
        for p, mn in reqs:
            sched.submit(p, mn)
        done = {r.rid: r for r in sched.run()}
        assert sorted(done) == [0, 1, 2]
        assert all(len(done[rid].output) == 5 for rid in done)
        # logits comparison on IDENTICAL state: admission prefill does not
        # route through the decode kernel, so right after _admit both
        # engines hold the same cache — replay one decode step over it
        sched2 = PagedContinuousScheduler(eng, n_slots=2, block_steps=2,
                                          block_size=8)
        for p, mn in reqs:
            sched2.submit(p, mn)
        sched2._init_caches()
        sched2._admit()
        logits, _, _ = M.forward(
            eng.params, jnp.asarray(sched2.tok)[:, None], eng.ctx,
            caches=sched2.caches, cur_pos=jnp.asarray(sched2.pos, jnp.int32),
            last_only=True, seq_sharded=False,
            block_tables=jnp.asarray(sched2.bt))
        outs[up] = np.asarray(logits[:, -1], np.float32)
    np.testing.assert_allclose(outs[False], outs[True], atol=0.08, rtol=0.08)


def test_paged_rejects_windowed_ring():
    eng = greedy_engine("recurrentgemma-9b", max_len=96)
    with pytest.raises(ValueError, match="sliding-window"):
        PagedContinuousScheduler(eng, n_slots=2)


# ---------------------------------------------------------------------------
# Prefix reuse (copy-on-write sharing)
# ---------------------------------------------------------------------------


def test_prefix_reuse_shares_blocks(yi_engine):
    """Two requests with a 2-block shared system prompt: the second admits
    while the first is live, references the resident blocks (refcount 2),
    prefills only its suffix, and still reproduces solo generation."""
    eng = yi_engine
    rng = np.random.default_rng(5)
    sys_prompt = rng.integers(0, eng.cfg.vocab_size, 16).astype(np.int32)
    p1 = np.concatenate([sys_prompt,
                         rng.integers(0, eng.cfg.vocab_size, 5).astype(np.int32)])
    p2 = np.concatenate([sys_prompt,
                         rng.integers(0, eng.cfg.vocab_size, 3).astype(np.int32)])
    sched = PagedContinuousScheduler(eng, n_slots=2, block_steps=2, block_size=8)
    r1 = sched.submit(p1, 8)
    r2 = sched.submit(p2, 8, arrival_step=2)
    refs = {}

    def on_tok(rid, t):
        if rid == r2 and r2 not in refs:
            slot = next(i for i, s in enumerate(sched.slots)
                        if s.req is not None and s.req.rid == r2)
            refs[r2] = [sched.alloc.refcount(0, b)
                        for b in sched.slot_blocks[slot][:2]]

    sched.on_token = on_tok
    done = {r.rid: r for r in sched.run()}
    assert refs[r2] == [2, 2]                      # shared, not copied
    assert sched.stats["prefill_tokens_saved"] == 16
    assert sched.stats["shared_block_hits"] == 2
    # prefill-token counter shows the saving: r2 computed only its suffix
    assert done[r2].stats["prefill_tokens_saved"] == 16
    for rid, p in ((r1, p1), (r2, p2)):
        solo = eng.generate(p[None], 8)[0]
        np.testing.assert_array_equal(solo, done[rid].output)


def test_prefix_reuse_with_int8_kv():
    """Prefix sharing composes with the quantized pool: shared blocks carry
    int8 payloads, refcounts still track, and the outputs reproduce solo
    generation.  (Under int8 the cached-prefix suffix prefill attends
    dequantized values, so this path is a second approximation of the same
    cache rather than bit-equal to a from-scratch prefill — deterministic
    per seed, which is what this regression pins.)"""
    eng = greedy_engine(
        "yi-9b", parallel=ParallelConfig(tp=1, dp=1, remat=False, kv_quant=True))
    rng = np.random.default_rng(12)
    sys_prompt = rng.integers(0, eng.cfg.vocab_size, 16).astype(np.int32)
    p1 = np.concatenate([sys_prompt,
                         rng.integers(0, eng.cfg.vocab_size, 4).astype(np.int32)])
    p2 = np.concatenate([sys_prompt,
                         rng.integers(0, eng.cfg.vocab_size, 6).astype(np.int32)])
    sched = PagedContinuousScheduler(eng, n_slots=2, block_steps=2, block_size=8)
    r1 = sched.submit(p1, 6)
    r2 = sched.submit(p2, 6, arrival_step=2)
    done = {r.rid: r for r in sched.run()}
    assert sched.stats["prefill_tokens_saved"] == 16
    assert sched.stats["shared_block_hits"] == 2
    for rid, p in ((r1, p1), (r2, p2)):
        solo = eng.generate(p[None], 6)[0]
        np.testing.assert_array_equal(solo, done[rid].output)


def test_prefix_fully_covering_prompt_recomputes_last_token(yi_engine):
    """A prompt that is ENTIRELY resident still needs >= 1 forward token:
    the matcher drops the last block so the suffix is non-empty."""
    eng = yi_engine
    rng = np.random.default_rng(6)
    p = rng.integers(0, eng.cfg.vocab_size, 16).astype(np.int32)  # 2 blocks
    sched = PagedContinuousScheduler(eng, n_slots=2, block_steps=2, block_size=8)
    r1 = sched.submit(p, 6)
    r2 = sched.submit(p.copy(), 6, arrival_step=2)  # identical prompt
    done = {r.rid: r for r in sched.run()}
    assert sched.stats["prefill_tokens_saved"] == 8  # 1 of 2 blocks reused
    solo = eng.generate(p[None], 6)[0]
    for rid in (r1, r2):
        np.testing.assert_array_equal(solo, done[rid].output)


# ---------------------------------------------------------------------------
# Block-aware admission + preemption
# ---------------------------------------------------------------------------


def test_pool_overcommit_beats_dense_budget(yi_engine):
    """A pool holding HALF the dense footprint (n_slots x max_len) still
    serves the full slot count concurrently — paging admits by actual
    occupancy, the whole point of the refactor."""
    eng = yi_engine                                   # max_len 64
    n_slots, bs = 4, 8
    n_blocks = 17                                     # 16 usable = 128 < 4*64
    assert (n_blocks - 1) * bs < n_slots * eng.max_len
    sched = PagedContinuousScheduler(eng, n_slots=n_slots, block_steps=2,
                                     block_size=bs, n_blocks=n_blocks)
    rng = np.random.default_rng(7)
    reqs = [(rng.integers(0, eng.cfg.vocab_size, 6).astype(np.int32), 8)
            for _ in range(n_slots)]
    for p, mn in reqs:
        sched.submit(p, mn)
    live = []
    sched.on_token = lambda rid, t: live.append(
        sum(1 for i, s in enumerate(sched.slots) if s.req is not None))
    done = {r.rid: r for r in sched.run()}
    assert max(live) == n_slots                       # truly concurrent
    assert sched.stats["preemptions"] == 0            # fits by occupancy
    for rid, (p, mn) in enumerate(reqs):
        solo = eng.generate(p[None], mn)[0]
        np.testing.assert_array_equal(solo, done[rid].output)


def test_exhaustion_preempts_and_requeues(yi_engine):
    """Allocator exhaustion mid-decode evicts the youngest request and
    requeues it (recompute on readmission) — every request still completes
    with exactly its solo output; nothing errors, nothing corrupts."""
    eng = yi_engine
    sched = PagedContinuousScheduler(eng, n_slots=2, block_steps=4,
                                     block_size=8, n_blocks=7,
                                     prefix_cache=False)   # 6 usable blocks
    rng = np.random.default_rng(8)
    pa = rng.integers(0, eng.cfg.vocab_size, 9).astype(np.int32)
    pb = rng.integers(0, eng.cfg.vocab_size, 8).astype(np.int32)
    ra = sched.submit(pa, 20)
    rb = sched.submit(pb, 16)
    preempted_rids = []
    sched.on_preempt = preempted_rids.append
    done = {r.rid: r for r in sched.run()}
    assert sched.stats["preemptions"] >= 1
    preempted = [r for r in done.values() if r.stats.get("preempted")]
    assert preempted
    # streaming clients were told which request restarted, and the emitted
    # counter rolled back the discarded tokens (counts only delivered output)
    assert {r.rid for r in preempted} == set(preempted_rids)
    assert sched.stats["emitted"] == sum(len(r.output) for r in done.values())
    for rid, p, mn in ((ra, pa, 20), (rb, pb, 16)):
        solo = eng.generate(p[None], mn)[0]
        np.testing.assert_array_equal(solo, done[rid].output)


def test_oversized_request_rejected(yi_engine):
    sched = PagedContinuousScheduler(yi_engine, n_slots=2,
                                     block_size=8, n_blocks=5)  # 4 usable
    with pytest.raises(ValueError, match="blocks"):
        sched.submit(np.arange(30, dtype=np.int32), max_new=10)  # needs 5


# ---------------------------------------------------------------------------
# TTFT / queue-wait stats (satellite)
# ---------------------------------------------------------------------------


def test_request_latency_summary(yi_engine):
    sched = PagedContinuousScheduler(yi_engine, n_slots=2, block_steps=2,
                                     block_size=8)
    rng = np.random.default_rng(9)
    for _ in range(3):
        sched.submit(rng.integers(0, yi_engine.cfg.vocab_size, 6).astype(np.int32), 4)
    done = sched.run()
    for r in done:
        assert "ttft_s" in r.stats and "queue_s" in r.stats
        assert r.stats["ttft_s"] >= r.stats["queue_s"] >= 0
    summ = sched.request_summary()
    assert summ["requests"] == 3
    for key in ("ttft_s", "queue_s"):
        assert summ[key]["max"] >= summ[key]["p50"] >= 0


# ---------------------------------------------------------------------------
# BlockAllocator unit behaviour
# ---------------------------------------------------------------------------


def test_allocator_refcount_and_exhaustion():
    a = BlockAllocator(9, block_size=4, n_shards=1)    # 8 usable
    got = a.alloc(0, 5)
    assert got is not None and len(set(got)) == 5 and NULL_BLOCK not in got
    assert a.alloc(0, 4) is None                       # all-or-nothing
    assert a.free_count(0) == 3
    a.incref(0, got[:2])
    a.free(0, got)                                     # first release
    assert a.free_count(0) == 6                        # 2 still referenced
    a.free(0, got[:2])
    assert a.free_count(0) == 8
    assert a.total_used() == 0


def test_allocator_prefix_chain_and_eviction():
    a = BlockAllocator(9, block_size=4, n_shards=1)
    toks = np.arange(10)                               # 2 full blocks + tail
    blocks = a.alloc(0, 3)
    a.register_prefix(0, toks, blocks[:2])
    hit, n = a.match_prefix(0, toks)
    assert hit == blocks[:2] and n == 8
    # a different suffix shares only the matching chain
    other = np.concatenate([toks[:4], np.full(6, 99)])
    hit2, n2 = a.match_prefix(0, other)
    assert hit2 == blocks[:1] and n2 == 4
    # freeing the last reference evicts the cache entries
    a.free(0, blocks)
    assert a.match_prefix(0, toks) == ([], 0)


def test_allocator_shards_are_independent():
    a = BlockAllocator(8, block_size=4, n_shards=2)    # 3 usable per shard
    assert a.alloc(0, 3) is not None
    assert a.alloc(0, 1) is None
    assert a.alloc(1, 3) is not None                   # shard 1 unaffected


# ---------------------------------------------------------------------------
# Property: block-table gather == dense layout, bit-exactly
# (hypothesis-optional: falls back to fixed seeds without the package)
# ---------------------------------------------------------------------------


def _gather_roundtrip(seed: int, b: int, nbps: int, bs: int, share_prefix: int):
    """Scatter a dense (b, h, S, hd) cache through random fragmented block
    tables, gather it back, compare bit-exactly.  ``share_prefix`` > 0 makes
    every slot's first blocks ALIAS slot 0's (the copy-on-write layout): the
    gathered prefix must equal slot 0's dense rows, also bit-exactly."""
    from repro.models.attention import _paged_view, _paged_write_prefill

    import jax.numpy as jnp

    rng = np.random.default_rng(seed)
    h, hd = 2, 4
    S = nbps * bs
    dense = rng.normal(size=(b, h, S, hd)).astype(np.float32)
    nb = 1 + b * nbps
    # fragmentation: blocks land anywhere in the pool, any order
    perm = rng.permutation(np.arange(1, nb))[: b * nbps].reshape(b, nbps)
    if share_prefix:
        perm[:, :share_prefix] = perm[0, :share_prefix]
        dense[:, :, : share_prefix * bs] = dense[0, :, : share_prefix * bs]
    bt = jnp.asarray(perm.astype(np.int32))
    pool = jnp.zeros((nb, h, bs, hd), jnp.float32)
    pool = _paged_write_prefill(pool, jnp.asarray(dense), bt,
                                jnp.zeros((b,), jnp.int32))
    view = np.asarray(_paged_view(pool, bt))
    np.testing.assert_array_equal(view, dense)


try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @settings(max_examples=25, deadline=None)
    @given(st.integers(0, 10_000), st.integers(1, 4), st.integers(1, 6),
           st.sampled_from([1, 2, 8, 16]), st.integers(0, 3))
    def test_block_gather_matches_dense_property(seed, b, nbps, bs, share):
        _gather_roundtrip(seed, b, nbps, bs, min(share, nbps))
except ImportError:  # hypothesis is optional (requirements-dev.txt)

    @pytest.mark.parametrize("seed,b,nbps,bs,share", [
        (0, 1, 1, 8, 0), (1, 3, 4, 8, 0), (2, 4, 3, 16, 2),
        (3, 2, 6, 2, 3), (4, 4, 2, 1, 1),
    ])
    def test_block_gather_matches_dense_property(seed, b, nbps, bs, share):
        _gather_roundtrip(seed, b, nbps, bs, share)


# ---------------------------------------------------------------------------
# Multi-device sharding of the block pool
# ---------------------------------------------------------------------------


@pytest.mark.skipif(jax.device_count() < 4,
                    reason="needs 4 devices (JAX_NUM_CPU_DEVICES/XLA_FLAGS)")
def test_paged_pool_sharded_over_data_axis():
    """dp=2 x tp=2: each data shard owns an independent block namespace;
    paged must still match the dense slot engine token-for-token."""
    eng = greedy_engine("yi-9b",
                        parallel=ParallelConfig(tp=2, dp=2, remat=False),
                        mesh=make_local_mesh(2, 2))
    rng = np.random.default_rng(10)
    reqs = [(rng.integers(0, eng.cfg.vocab_size, int(l)).astype(np.int32),
             mn, None, 0)
            for l, mn in ((5, 6), (9, 3), (4, 8), (7, 5))]
    run_both(eng, reqs, n_slots=4, block_size=8)
