"""Fault-injection harness and failure isolation: FaultPlan grammar/firing,
poisoned-slot quarantine with survivor bit-identity (dense, paged, overlapped),
bounded retry of transient step faults, per-request deadlines, allocator
exhaustion aborts, disagg migration-fault rollback, and the livelock breaker.

The load-bearing invariant everywhere: a fault on one request NEVER perturbs
another request's greedy stream — survivors are compared token-for-token
against an uninjected run of the same workload.  Allocator audits run after
every quarantine/preempt path (satellite: refcount conservation)."""
import json
import time

import jax
import numpy as np
import pytest

from repro.configs import ParallelConfig, SamplingConfig, get_config
from repro.launch.mesh import make_local_mesh
from repro.runtime.engine import Engine
from repro.runtime.faults import (POISON_TOKEN, FaultPlan, MigrationFault,
                                  TransientStepError)
from repro.runtime.scheduler import (ContinuousScheduler, DisaggScheduler,
                                     PagedContinuousScheduler, Request)

needs2 = pytest.mark.skipif(
    jax.device_count() < 2,
    reason="needs 2 devices (JAX_NUM_CPU_DEVICES/XLA_FLAGS)")
needs4 = pytest.mark.skipif(
    jax.device_count() < 4,
    reason="needs 4 devices (JAX_NUM_CPU_DEVICES/XLA_FLAGS)")


def greedy_engine(arch: str, max_len: int = 64, parallel=None,
                  mesh=None) -> Engine:
    cfg = get_config(arch).reduced()
    return Engine(cfg=cfg,
                  parallel=parallel or ParallelConfig(tp=1, dp=1, remat=False),
                  sampling=SamplingConfig(greedy=True, top_k=1),
                  mesh=mesh or make_local_mesh(1, 1), max_len=max_len)


@pytest.fixture(scope="module")
def yi_engine():
    return greedy_engine("yi-9b")


@pytest.fixture(autouse=True)
def _clear_hook(request):
    """Fault-planned schedulers install Engine.dispatch_hook; drop it after
    each test so the module-scoped engine stays clean."""
    yield
    if "yi_engine" in request.fixturenames:
        request.getfixturevalue("yi_engine").dispatch_hook = None


def fault_requests(cfg, n=5):
    """EOS-free requests with max_new >= 8 so every admitted slot is still
    emitting through the early engine steps fault clauses target."""
    rng = np.random.default_rng(3)
    reqs = []
    for i in range(n):
        p = rng.integers(0, cfg.vocab_size,
                         int(rng.integers(4, 12))).astype(np.int32)
        reqs.append((p, 8 + i % 3, None, 2 * (i // 3)))
    return reqs


def run_sched(sched, reqs):
    for p, mn, eos, arr in reqs:
        sched.submit(p, mn, eos_id=eos, arrival_step=arr)
    return {r.rid: r for r in sched.run()}


def audited(sched):
    """Satellite hook: run the allocator invariant checker after EVERY
    quarantine and preemption the scheduler performs."""
    orig_q = sched._quarantine_slot

    def q(i, finish_reason="error", error=None):
        orig_q(i, finish_reason, error)
        sched.alloc.audit()

    sched._quarantine_slot = q
    sched.on_preempt = lambda rid: sched.alloc.audit()
    return sched


@pytest.fixture(scope="module")
def clean_ref(yi_engine):
    """Uninjected dense outputs for the shared workload (the bit-identity
    reference: dense == paged == disagg is covered by the other suites)."""
    sched = ContinuousScheduler(yi_engine, n_slots=3, block_steps=4)
    return run_sched(sched, fault_requests(yi_engine.cfg))


def check_survivors(done, clean, n_bad=1, reason="error"):
    bad = [r for r in done.values() if r.finish_reason == reason]
    assert len(bad) == n_bad
    for rid, r in done.items():
        if r.finish_reason == reason:
            continue
        assert r.finish_reason in ("stop", "length")
        np.testing.assert_array_equal(r.output, clean[rid].output)
    return bad


# ---------------------------------------------------------------------------
# FaultPlan: grammar and firing (no engine)
# ---------------------------------------------------------------------------


def test_plan_parse_grammar():
    plan = FaultPlan.parse("step:at=12,times=2,slot=1; poison:slot=0,at=20;"
                           "alloc:at=5;migrate:handoff=1;delay:at=3,s=0.5;"
                           "seed:n=7")
    assert bool(plan) and len(plan.clauses) == 5
    st = plan.clauses[0]
    assert (st.kind, st.at, st.times, st.slot) == ("step", 12, 2, 1)
    assert plan.clauses[3].handoff == 1
    assert plan.clauses[4].seconds == 0.5
    assert not FaultPlan.parse("")
    assert not FaultPlan.parse(None)
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultPlan.parse("fry:at=1")
    with pytest.raises(ValueError, match="unknown fault key"):
        FaultPlan.parse("step:when=1")
    with pytest.raises(ValueError, match="needs slot"):
        FaultPlan.parse("poison:at=4")


def test_plan_step_firing_and_disarm():
    plan = FaultPlan.parse("step:at=5,times=2,slot=1")
    plan.on_dispatch(4)                     # below threshold: no fire
    for _ in range(2):
        with pytest.raises(TransientStepError) as ei:
            plan.on_dispatch(7)
        assert ei.value.slot == 1
    plan.on_dispatch(7)                     # times exhausted: disarmed
    plan2 = FaultPlan.parse("step:at=0,times=9,slot=2;poison:slot=2,at=50")
    plan2.on_quarantine(2)                  # victim gone -> clauses disarm
    plan2.on_dispatch(10)
    assert all(c.times == 0 for c in plan2.clauses)


def test_plan_corrupt_tokens_copy_on_write():
    plan = FaultPlan.parse("poison:slot=1,at=6")
    toks = np.arange(12, dtype=np.int32).reshape(4, 3)
    toks.setflags(write=False)              # np.asarray(device_array) idiom
    same = plan.corrupt_tokens(toks, base_step=0)
    assert same is toks                     # block ends before target: no-op
    idle = plan.corrupt_tokens(toks, base_step=4,
                               active=np.array([True, False, True]))
    assert idle is toks                     # target slot idle: DEFER
    assert plan.clauses[0].times == 1       # ...without consuming the clause
    out = plan.corrupt_tokens(toks, base_step=4)
    assert out is not toks and out[2, 1] == POISON_TOKEN
    mask = np.ones((4, 3), bool)
    mask[2, 1] = False
    np.testing.assert_array_equal(out[mask], toks[mask])
    assert plan.corrupt_tokens(toks, base_step=4) is toks   # spent


def test_plan_alloc_delay_handoff():
    plan = FaultPlan.parse("alloc:at=3,times=2;delay:at=0,s=0.05;"
                           "migrate:handoff=1")
    t0 = time.monotonic()
    plan.on_dispatch(0)                     # delay clause sleeps once
    assert time.monotonic() - t0 >= 0.05
    assert not plan.deny_alloc(2)
    assert plan.deny_alloc(3) and plan.deny_alloc(9)
    assert not plan.deny_alloc(9)           # times exhausted
    plan.on_handoff()                       # handoff #0 < target: clean
    with pytest.raises(MigrationFault):
        plan.on_handoff()                   # handoff #1


# ---------------------------------------------------------------------------
# Poisoned slot: quarantine + survivor bit-identity
# ---------------------------------------------------------------------------


def test_poison_quarantine_dense(yi_engine, clean_ref):
    sched = ContinuousScheduler(yi_engine, n_slots=3, block_steps=4,
                                fault_plan="poison:slot=1,at=2")
    done = run_sched(sched, fault_requests(yi_engine.cfg))
    bad = check_survivors(done, clean_ref)
    assert "poisoned" in bad[0].stats["error"]
    assert bad[0].output is not None        # partial stream preserved
    assert sched.stats["quarantined"] == 1
    summ = sched.request_summary()
    assert summ["faults"]["quarantined"] == 1
    assert summ["finish_reasons"]["error"] == 1


def test_poison_quarantine_overlap(yi_engine, clean_ref):
    sched = ContinuousScheduler(yi_engine, n_slots=3, block_steps=4,
                                overlap=True, fault_plan="poison:slot=0,at=4")
    done = run_sched(sched, fault_requests(yi_engine.cfg))
    check_survivors(done, clean_ref)
    assert sched.stats["quarantined"] == 1


def test_poison_quarantine_paged_audited(yi_engine, clean_ref):
    sched = audited(PagedContinuousScheduler(
        yi_engine, n_slots=3, block_steps=4, block_size=8,
        prefix_cache=False, fault_plan="poison:slot=0,at=3"))
    done = run_sched(sched, fault_requests(yi_engine.cfg))
    check_survivors(done, clean_ref)
    assert sched.stats["quarantined"] == 1
    sched.alloc.audit(expect_no_migration=True)
    # every request retired -> the quarantined slot's blocks came back too
    for shard in range(sched.alloc.n_shards):
        assert sched.alloc.used_count(shard) == 0


# ---------------------------------------------------------------------------
# Transient step faults: bounded retry, then slot-blamed quarantine
# ---------------------------------------------------------------------------


def test_transient_retry_bit_identical(yi_engine, clean_ref):
    sched = ContinuousScheduler(yi_engine, n_slots=3, block_steps=4,
                                fault_plan="step:at=3,times=2",
                                max_step_retries=3, retry_backoff_s=0.0)
    done = run_sched(sched, fault_requests(yi_engine.cfg))
    check_survivors(done, clean_ref, n_bad=0)     # NOTHING failed
    assert sched.stats["step_faults"] == 2
    assert sched.stats["step_retries"] == 2
    assert sched.stats["quarantined"] == 0


def test_retry_exhaustion_quarantines_blamed_slot(yi_engine, clean_ref):
    sched = ContinuousScheduler(yi_engine, n_slots=3, block_steps=4,
                                fault_plan="step:at=3,times=99,slot=0",
                                max_step_retries=2, retry_backoff_s=0.0)
    done = run_sched(sched, fault_requests(yi_engine.cfg))
    bad = check_survivors(done, clean_ref)
    assert "persistent step failure" in bad[0].stats["error"]
    assert sched.stats["step_faults"] == 3        # 2 retries + the last straw
    assert sched.stats["step_retries"] == 2
    assert sched.stats["quarantined"] == 1
    # quarantine disarmed the clause blamed on the evicted slot
    assert all(c.times == 0 for c in sched.faults.clauses)


def test_retry_exhaustion_unattributed_is_fatal(yi_engine):
    sched = ContinuousScheduler(yi_engine, n_slots=2, block_steps=4,
                                fault_plan="step:at=0,times=99",
                                max_step_retries=1, retry_backoff_s=0.0)
    sched.submit(np.arange(2, 8, dtype=np.int32), 4)
    with pytest.raises(TransientStepError):
        sched.run()
    assert sched.stats["step_faults"] == 2


# ---------------------------------------------------------------------------
# Deadlines: queued and slot-resident timeouts
# ---------------------------------------------------------------------------


def test_deadline_expires_queued_request(yi_engine):
    sched = ContinuousScheduler(yi_engine, n_slots=2, block_steps=4)
    ra = sched.submit(np.arange(2, 8, dtype=np.int32), 4)
    rb = sched.submit(np.arange(3, 9, dtype=np.int32), 4, deadline_s=0.0)
    done = {r.rid: r for r in sched.run()}
    assert done[rb].finish_reason == "timeout"
    assert done[rb].output.size == 0              # never admitted
    assert done[ra].finish_reason in ("stop", "length")
    assert len(done[ra].output) == 4
    assert sched.stats["timeouts"] == 1
    assert sched.request_summary()["finish_reasons"]["timeout"] == 1


def test_deadline_expires_slot_resident_request(yi_engine):
    sched = ContinuousScheduler(yi_engine, n_slots=2, block_steps=2)
    rid = sched.submit(np.arange(2, 8, dtype=np.int32), 24, deadline_s=60.0)
    while not sched.done:
        sched.serve_step()
        slot = next((s for s in sched.slots if s.req is not None), None)
        if slot is not None and len(slot.toks) >= 2:
            slot.req.deadline_s = 0.0             # deadline passes mid-decode
    done = {r.rid: r for r in sched.run()}
    r = done[rid]
    assert r.finish_reason == "timeout"
    assert 2 <= len(r.output) < 24                # partial stream kept
    assert sched.stats["timeouts"] == 1


def test_liveness_age_and_watchdog(yi_engine):
    from repro.launch.frontend import EngineService
    sched = ContinuousScheduler(yi_engine, n_slots=2, block_steps=2)
    sched._progress_t = time.monotonic() - 5.0
    assert sched.liveness_age() >= 5.0
    svc = EngineService(sched, watchdog_s=1.0)
    assert not svc.wedged()                       # idle engines never wedge
    svc._live = 1
    assert svc.wedged()                           # live work, stale progress
    svc_off = EngineService(sched, watchdog_s=0.0)
    svc_off._live = 1
    assert not svc_off.wedged()                   # watchdog disabled


# ---------------------------------------------------------------------------
# Allocator exhaustion: injected denial -> preempt; terminal -> loud abort
# ---------------------------------------------------------------------------


def test_injected_alloc_denial_recovers(yi_engine, clean_ref):
    sched = audited(PagedContinuousScheduler(
        yi_engine, n_slots=3, block_steps=4, block_size=8,
        prefix_cache=False, fault_plan="alloc:at=2,times=1"))
    done = run_sched(sched, fault_requests(yi_engine.cfg))
    check_survivors(done, clean_ref, n_bad=0)     # denial absorbed
    assert all(c.times == 0 for c in sched.faults.clauses)
    assert sched.stats["aborts_exhaustion"] == 0
    sched.alloc.audit(expect_no_migration=True)


def test_terminal_exhaustion_aborts_request(yi_engine, clean_ref):
    sched = audited(PagedContinuousScheduler(
        yi_engine, n_slots=3, block_steps=4, block_size=8,
        prefix_cache=False, fault_plan="alloc:at=2,times=1"))
    sched._preempt_youngest = lambda shard: False  # nothing evictable
    done = run_sched(sched, fault_requests(yi_engine.cfg))
    bad = check_survivors(done, clean_ref)
    assert "exhausted" in bad[0].stats["error"]
    assert sched.stats["aborts_exhaustion"] == 1
    assert sched.stats["quarantined"] == 1
    sched.alloc.audit(expect_no_migration=True)
    for shard in range(sched.alloc.n_shards):
        assert sched.alloc.used_count(shard) == 0


def test_preempt_requeue_cycles_conserve_pool(yi_engine):
    """Satellite: repeated preempt -> requeue -> re-admit churn under a tiny
    pool keeps refcounts conserved (audited at every preemption) and outputs
    identical to an unconstrained-pool run."""
    roomy = PagedContinuousScheduler(yi_engine, n_slots=2, block_steps=4,
                                     block_size=8, prefix_cache=False)
    tiny = audited(PagedContinuousScheduler(yi_engine, n_slots=2,
                                            block_steps=4, block_size=8,
                                            n_blocks=7, prefix_cache=False))
    rng = np.random.default_rng(8)
    reqs = [(rng.integers(0, yi_engine.cfg.vocab_size, 9).astype(np.int32),
             20, None, 0),
            (rng.integers(0, yi_engine.cfg.vocab_size, 8).astype(np.int32),
             16, None, 0)]
    ref = run_sched(roomy, reqs)
    done = run_sched(tiny, reqs)
    assert tiny.stats["preemptions"] >= 1
    assert tiny.stats["quarantined"] == 0
    for rid in ref:
        assert done[rid].finish_reason in ("stop", "length")
        np.testing.assert_array_equal(done[rid].output, ref[rid].output)
    tiny.alloc.audit(expect_no_migration=True)
    for shard in range(tiny.alloc.n_shards):
        assert tiny.alloc.used_count(shard) == 0


# ---------------------------------------------------------------------------
# Disagg: migration faults mid-handoff, livelock breaker (>= 2 devices)
# ---------------------------------------------------------------------------


def _disagg_requests(cfg, n=5):
    rng = np.random.default_rng(11)
    return [(rng.integers(0, cfg.vocab_size,
                          int(rng.integers(10, 22))).astype(np.int32),
             6 + i % 3, None, 2 * i) for i in range(n)]


def _run_disagg_fault(dp, prefill_shards):
    eng = greedy_engine("yi-9b",
                        parallel=ParallelConfig(tp=1, dp=dp, remat=False),
                        mesh=make_local_mesh(dp, 1))
    reqs = _disagg_requests(eng.cfg)
    kw = dict(n_slots=2 * dp, block_steps=2, block_size=8, prefill_chunk=8,
              prefill_shards=prefill_shards, prefix_cache=False)
    clean = run_sched(DisaggScheduler(eng, **kw), reqs)
    sched = audited(DisaggScheduler(eng, fault_plan="migrate:handoff=0",
                                    **kw))
    done = run_sched(sched, reqs)
    eng.dispatch_hook = None
    bad = check_survivors(done, clean)
    assert "migration" in bad[0].stats["error"]
    assert sched.stats["migration_faults"] == 1
    assert sched.stats["quarantined"] == 1
    sched.alloc.audit(expect_no_migration=True)
    for shard in range(sched.alloc.n_shards):
        assert sched.alloc.used_count(shard) == 0


@needs2
def test_disagg_migration_fault_rollback():
    _run_disagg_fault(dp=2, prefill_shards=1)


@needs4
def test_disagg_migration_fault_2p2():
    _run_disagg_fault(dp=4, prefill_shards=2)


@needs2
def test_disagg_livelock_abort_frees_landing_blocks():
    eng = greedy_engine("yi-9b",
                        parallel=ParallelConfig(tp=1, dp=2, remat=False),
                        mesh=make_local_mesh(2, 1))
    sched = DisaggScheduler(eng, n_slots=4, block_steps=2, block_size=8,
                            prefill_chunk=8, prefill_shards=1,
                            prefix_cache=False)
    assert not sched._abort_stuck_entity()        # nothing stuck: no victim
    # synthesize a landed-but-unplaceable request holding decode-pool blocks
    shard = 1
    blocks = sched.alloc.alloc(shard, 2)
    req = Request(rid=0, prompt=np.arange(2, 12, dtype=np.int32), max_new=8)
    sched._landing.append({"req": req, "shard": shard, "blocks": blocks,
                           "toks": [7], "ready_t": time.monotonic()})
    assert sched._abort_stuck_entity()
    assert req.finish_reason == "error"
    assert "livelock" in req.stats["error"]
    assert sched.stats["livelock_aborts"] == 1
    assert not sched._landing and sched.alloc.used_count(shard) == 0
    sched.alloc.audit(expect_no_migration=True)


# ---------------------------------------------------------------------------
# Crash-path reporting: stats flush even when the serve loop dies
# ---------------------------------------------------------------------------


def test_stats_json_flushes_on_fatal_fault(tmp_path):
    from repro.launch import serve
    path = tmp_path / "stats.json"
    argv = ["--arch", "yi-9b", "--scheduler", "continuous", "--requests", "2",
            "--slots", "2", "--prompt-len", "6", "--max-new", "4",
            "--max-len", "64", "--block-steps", "2",
            "--fault-plan", "step:at=0,times=99", "--max-step-retries", "0",
            "--retry-backoff-s", "0", "--stats-json", str(path)]
    with pytest.raises(TransientStepError):
        serve.main(argv)
    payload = json.loads(path.read_text())        # flushed from finally
    assert payload["stats"]["step_faults"] >= 1
