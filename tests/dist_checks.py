"""Multi-device collective-schedule checks, run in a SUBPROCESS with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (the main pytest process
must keep seeing 1 device).  Each check prints PASS <name> or raises.

Run directly:  XLA_FLAGS=... python tests/dist_checks.py <check> [...]
"""
import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat

from repro.configs import ParallelConfig, SamplingConfig, get_config
from repro.models import model as M
from repro.models.common import Dist, ShardPlan, specs_of


def _mesh(dp, tp):
    return compat.make_mesh((dp, tp), ("data", "model"))


def _fp32(tree):
    return jax.tree.map(
        lambda x: x.astype(jnp.float32) if x.dtype == jnp.bfloat16 else x, tree)


def _forward_logits(cfg, dp, tp, tokens, seq_sharded=True):
    ctx = M.ModelCtx.make(cfg, ParallelConfig(tp=tp, dp=dp, remat=False))
    params = _fp32(M.init_params(ctx, jax.random.key(0)))
    mesh = _mesh(dp, tp)

    def step(params, tokens):
        lg, _, _ = M.forward(params, tokens, ctx, seq_sharded=seq_sharded)
        return lg

    f = jax.jit(compat.shard_map(step, mesh=mesh,
                              in_specs=(M.param_specs(ctx), P("data", None)),
                              out_specs=P("data", None, "model"), check_vma=False))
    return np.asarray(f(params, tokens), np.float32)


def check_tp_equiv():
    for arch in ["yi-9b", "minicpm3-4b", "deepseek-moe-16b", "mamba2-1.3b",
                 "recurrentgemma-9b"]:
        cfg = get_config(arch).reduced()
        if cfg.moe:
            cfg = dataclasses.replace(
                cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=100.0))
        tokens = jax.random.randint(jax.random.key(1), (4, 16), 0, cfg.vocab_size)
        a = _forward_logits(cfg, 1, 1, tokens)
        b = _forward_logits(cfg, 2, 4, tokens)
        err = np.abs(a - b).max()
        assert err < 1e-3, f"{arch}: {err}"
    print("PASS tp_equiv")


def check_train_grads():
    """dp2/tp2 training step must produce (nearly) the same params as dp1/tp1:
    validates the spec-aware grad-psum rule through shard_map AD."""
    from repro.training import data as D
    from repro.training.train_loop import AdamWConfig, init_opt_state, make_train_step

    cfg = get_config("qwen2.5-14b").reduced()
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    dc = D.DataConfig(global_batch=4, seq_len=32)
    outs = {}
    for dp, tp in [(1, 1), (2, 2)]:
        ctx = M.ModelCtx.make(cfg, ParallelConfig(tp=tp, dp=dp, remat=True))
        params = _fp32(M.init_params(ctx, jax.random.key(0)))
        opt = init_opt_state(params)
        pspecs = M.param_specs(ctx)
        ospecs = {"m": pspecs, "v": pspecs, "step": P()}
        step_fn = make_train_step(ctx, opt_cfg)
        jstep = jax.jit(compat.shard_map(
            step_fn, mesh=_mesh(dp, tp),
            in_specs=(pspecs, ospecs,
                      {"tokens": P("data", None), "labels": P("data", None)}),
            out_specs=(pspecs, ospecs, P()), check_vma=False))
        for i in range(2):
            b = D.make_batch(cfg, dc, i)
            params, opt, m = jstep(params, opt,
                                   {k: jnp.asarray(v) for k, v in b.items()})
        outs[(dp, tp)] = (params, float(m["loss"]))
    la, lb = outs[(1, 1)][1], outs[(2, 2)][1]
    assert abs(la - lb) < 1e-3, (la, lb)
    for a, b in zip(jax.tree.leaves(outs[(1, 1)][0]),
                    jax.tree.leaves(outs[(2, 2)][0])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=5e-4, rtol=5e-4)
    print("PASS train_grads")


def check_zero1_multidev():
    from repro.training import data as D
    from repro.training.train_loop import AdamWConfig, init_opt_state, make_train_step
    from repro.training.zero import init_zero_state, zero_state_defs

    cfg = get_config("yi-9b").reduced()
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    dc = D.DataConfig(global_batch=8, seq_len=16)
    outs = {}
    for zero1, dp, tp in [(False, 1, 1), (True, 4, 2)]:
        ctx = M.ModelCtx.make(cfg, ParallelConfig(tp=tp, dp=dp, remat=False))
        params = _fp32(M.init_params(ctx, jax.random.key(0)))
        pspecs = M.param_specs(ctx)
        if zero1:
            opt = init_zero_state(M.model_defs(ctx), ctx.dist)
            ospecs = specs_of(zero_state_defs(M.model_defs(ctx), ctx.dist))
        else:
            opt = init_opt_state(params)
            ospecs = {"m": pspecs, "v": pspecs, "step": P()}
        step_fn = make_train_step(ctx, opt_cfg, zero1=zero1)
        jstep = jax.jit(compat.shard_map(
            step_fn, mesh=_mesh(dp, tp),
            in_specs=(pspecs, ospecs,
                      {"tokens": P("data", None), "labels": P("data", None)}),
            out_specs=(pspecs, ospecs, P()), check_vma=False))
        for i in range(2):
            b = D.make_batch(cfg, dc, i)
            params, opt, m = jstep(params, opt,
                                   {k: jnp.asarray(v) for k, v in b.items()})
        outs[zero1] = params
    for a, b in zip(jax.tree.leaves(outs[False]), jax.tree.leaves(outs[True])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=1e-3, rtol=1e-3)
    print("PASS zero1_multidev")


def check_topk_sync():
    """§2.1b: distributed local-topk sampling == full-gather sampling, and the
    wire bytes drop from O(vocab) to O(k·tp)."""
    from repro.core import collectives as cc
    from repro.core.topk_sync import sample
    from repro.configs.base import SamplingConfig

    cfg = dataclasses.replace(get_config("yi-9b").reduced(), vocab_size=4096)
    tp = 8
    plan = ShardPlan.make(cfg, tp)
    dist = Dist(tp=tp, dp=1)
    mesh = compat.make_mesh((1, 8), ("data", "model"))
    logits = jax.random.normal(jax.random.key(0), (4, 4096))
    rng = jax.random.key(7)
    sc = SamplingConfig(top_k=16, greedy=False)

    toks, bytes_ = {}, {}
    for mode in (True, False):
        def f(lg, rng):
            return sample(lg, rng, sc, plan, dist, topk_sync=mode)

        with cc.comm_stats() as stats:
            jf = jax.jit(compat.shard_map(f, mesh=mesh, in_specs=(P(None, "model"), P()),
                                       out_specs=P(), check_vma=False))
            t = jf(logits, rng)
        toks[mode] = np.asarray(t)
        bytes_[mode] = stats.total_bytes()
    np.testing.assert_array_equal(toks[True], toks[False])
    assert bytes_[True] < bytes_[False] / 10, bytes_
    print("PASS topk_sync", bytes_)


def check_one_shot_sync():
    """§2.2: one psum per parallel-residual layer vs two — identical outputs,
    half the layer all-reduces."""
    from repro.core import collectives as cc

    cfg = get_config("gptj-parallel").reduced()
    tokens = jax.random.randint(jax.random.key(1), (4, 16), 0, cfg.vocab_size)
    outs, n_ar = {}, {}
    for one_shot in (True, False):
        ctx = M.ModelCtx.make(
            cfg, ParallelConfig(tp=4, dp=2, remat=False, one_shot_sync=one_shot,
                                seq_parallel=False))
        params = _fp32(M.init_params(ctx, jax.random.key(0)))
        mesh = _mesh(2, 4)

        def step(params, tokens):
            lg, _, _ = M.forward(params, tokens, ctx, seq_sharded=False)
            return lg

        with cc.comm_stats() as stats:
            f = jax.jit(compat.shard_map(
                step, mesh=mesh, in_specs=(M.param_specs(ctx), P("data", None)),
                out_specs=P("data", None, "model"), check_vma=False))
            outs[one_shot] = np.asarray(f(params, tokens), np.float32)
        n_ar[one_shot] = sum(1 for r in stats.records
                             if r.tag in ("one_shot", "attn_reduce", "ffn_reduce"))
    np.testing.assert_allclose(outs[True], outs[False], atol=1e-3, rtol=1e-3)
    # comm_stats records the scanned layer body ONCE — the per-layer schedule
    # is 1 all-reduce (one-shot) vs 2 (baseline), exactly the paper's §2.2.
    assert n_ar[True] == 1 and n_ar[False] == 2, n_ar
    print("PASS one_shot_sync", n_ar)


def check_kv_seq_shard():
    """long-context path: decode over a data-axis-sharded cache == unsharded."""
    cfg = get_config("yi-9b").reduced()
    tokens = jax.random.randint(jax.random.key(1), (2, 16), 0, cfg.vocab_size)
    outs = {}
    for kv_shard, dp, tp in [(False, 1, 1), (True, 4, 2)]:
        par = ParallelConfig(tp=tp, dp=dp, remat=False, kv_seq_shard=kv_shard)
        ctx = M.ModelCtx.make(cfg, par)
        params = _fp32(M.init_params(ctx, jax.random.key(0)))
        mesh = _mesh(dp, tp)
        S, kv_dp = (20, 4) if kv_shard else (20, 1)   # 4 shards x 5 slots

        def step(params, tokens):
            caches = M.init_caches(ctx, 2, S, kv_seq_shard_dp=kv_dp)
            kv_ax = "data" if kv_shard else None
            _, caches, _ = M.forward(params, tokens[:, :16], ctx, caches=caches,
                                     last_only=True, kv_seq_axis=kv_ax)
            lg, _, _ = M.forward(params, tokens[:, 15:16], ctx, caches=caches,
                                 cur_pos=jnp.int32(16), kv_seq_axis=kv_ax)
            return lg[:, -1]

        f = jax.jit(compat.shard_map(step, mesh=mesh,
                                  in_specs=(M.param_specs(ctx), P(None, None)),
                                  out_specs=P(None, "model"), check_vma=False))
        outs[kv_shard] = np.asarray(f(params, tokens), np.float32)
    # bf16 softmax weights (mixed-precision attend) differ slightly between
    # the LSE-merged shards and the single-pass path; real sharding bugs show
    # O(0.1+) diffs (seen during bring-up), mixed-precision noise is O(5e-3).
    np.testing.assert_allclose(outs[True], outs[False], atol=2e-2, rtol=2e-2)
    print("PASS kv_seq_shard")


def check_embed_modes():
    """§2.1a: id-broadcast lookup == rank-0-embedding-broadcast baseline,
    with zero vs nonzero wire bytes (replicated table)."""
    from repro.core import collectives as cc
    from repro.core import embedding as E

    cfg = get_config("mixtral-8x7b").reduced()   # small vocab -> replicated
    tp = 8
    plan = ShardPlan.make(cfg, tp)
    dist = Dist(tp=tp, dp=1)
    mesh = compat.make_mesh((1, 8), ("data", "model"))
    from repro.models.common import materialize

    defs = E.embed_defs(cfg, plan, dist)
    params = materialize(defs, jax.random.key(0))
    tokens = jax.random.randint(jax.random.key(1), (2, 8), 0, cfg.vocab_size)
    outs, bytes_ = {}, {}
    for idb in (True, False):
        def f(params, tokens):
            return E.embed_lookup(params, tokens, cfg, plan, dist, id_broadcast=idb)

        with cc.comm_stats() as stats:
            jf = jax.jit(compat.shard_map(f, mesh=mesh,
                                       in_specs=(specs_of(defs), P()),
                                       out_specs=P(), check_vma=False))
            outs[idb] = np.asarray(jf(params, tokens), np.float32)
        bytes_[idb] = stats.total_bytes()
    np.testing.assert_allclose(outs[True], outs[False], atol=1e-2, rtol=1e-2)
    assert bytes_[True] == 0 and bytes_[False] > 0, bytes_
    print("PASS embed_modes", bytes_)


def check_engine_tp():
    """Engine produces identical greedy generations at tp=1 and tp=4."""
    from repro.launch.mesh import make_local_mesh
    from repro.runtime.engine import Engine

    cfg = get_config("qwen2.5-14b").reduced()
    prompts = np.random.default_rng(0).integers(0, cfg.vocab_size, (2, 8)).astype(np.int32)
    outs = {}
    for dp, tp in [(1, 1), (2, 4)]:
        eng = Engine(cfg=cfg,
                     parallel=ParallelConfig(tp=tp, dp=dp, remat=False),
                     sampling=SamplingConfig(greedy=True, top_k=1),
                     mesh=make_local_mesh(dp, tp), max_len=32)
        outs[(dp, tp)] = eng.generate(prompts, max_new=5)
    np.testing.assert_array_equal(outs[(1, 1)], outs[(2, 4)])
    print("PASS engine_tp")


CHECKS = {k[6:]: v for k, v in list(globals().items()) if k.startswith("check_")}

if __name__ == "__main__":
    names = sys.argv[1:] or list(CHECKS)
    for n in names:
        CHECKS[n]()
