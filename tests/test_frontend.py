"""Async HTTP frontend e2e: the asyncio server from
``repro.launch.frontend`` running in-process over a real (reduced) engine
with the overlapped loop on — OpenAI-compatible /v1/completions in unary
and SSE-streaming form, concurrent clients, bounded-queue overload
shedding (429 + Retry-After), and graceful drain.

Clients are plain ``http.client`` calls from worker threads (the server
runs its own event loop thread), so the test exercises the exact
cross-thread handoff path production traffic takes.
"""
import asyncio
import http.client
import json
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from contextlib import contextmanager

import numpy as np
import pytest

from repro.configs import ParallelConfig, SamplingConfig, get_config
from repro.launch.frontend import EngineService, HttpFrontend
from repro.launch.mesh import make_local_mesh
from repro.runtime.engine import Engine
from repro.runtime.scheduler import ContinuousScheduler


@pytest.fixture(scope="module")
def engine():
    cfg = get_config("yi-9b").reduced()
    return Engine(cfg=cfg,
                  parallel=ParallelConfig(tp=1, dp=1, remat=False,
                                          overlap_decode=True),
                  sampling=SamplingConfig(greedy=True, top_k=1),
                  mesh=make_local_mesh(1, 1), max_len=64)


@contextmanager
def serving(engine, n_slots=2, max_pending=8):
    sched = ContinuousScheduler(engine, n_slots=n_slots, block_steps=2)
    service = EngineService(sched, max_pending=max_pending,
                            idle_wait_s=0.002)
    frontend = HttpFrontend(service, port=0)
    loop = asyncio.new_event_loop()

    def run():
        asyncio.set_event_loop(loop)
        loop.run_until_complete(frontend.start())
        loop.run_forever()

    thread = threading.Thread(target=run, daemon=True)
    thread.start()
    deadline = time.monotonic() + 30
    while frontend._server is None:
        assert time.monotonic() < deadline, "server failed to start"
        time.sleep(0.01)
    try:
        yield frontend, sched
    finally:
        asyncio.run_coroutine_threadsafe(frontend.stop(),
                                         loop).result(timeout=120)
        loop.call_soon_threadsafe(loop.stop)
        thread.join(timeout=30)
        loop.close()


def post(port, body, timeout=120):
    c = http.client.HTTPConnection("127.0.0.1", port, timeout=timeout)
    c.request("POST", "/v1/completions", json.dumps(body),
              {"Content-Type": "application/json"})
    r = c.getresponse()
    return r.status, dict(r.getheaders()), r.read()


def sse_tokens(raw: bytes):
    toks, finish, done = [], None, False
    for ev in raw.decode().split("\n\n"):
        if not ev.startswith("data: "):
            continue
        payload = ev[len("data: "):]
        if payload == "[DONE]":
            done = True
            continue
        choice = json.loads(payload)["choices"][0]
        toks += choice["token_ids"]
        finish = choice.get("finish_reason", finish)
    return toks, finish, done


def prompt_for(cfg, seed, n=8):
    return np.random.default_rng(seed).integers(
        0, cfg.vocab_size, n).tolist()


def test_unary_and_stream_identical(engine):
    with serving(engine) as (fe, sched):
        body = {"prompt": prompt_for(engine.cfg, 0), "max_tokens": 6}
        st, _, data = post(fe.port, body)
        assert st == 200
        resp = json.loads(data)
        choice = resp["choices"][0]
        assert len(choice["token_ids"]) == 6
        assert choice["finish_reason"] == "length"
        assert resp["usage"]["completion_tokens"] == 6
        st, _, raw = post(fe.port, dict(body, stream=True))
        assert st == 200
        toks, finish, done = sse_tokens(raw)
        assert toks == choice["token_ids"]
        assert finish == "length" and done
        assert sched.stats["landings"] > 0    # the overlapped loop served it


def test_stop_token_finish_reason(engine):
    with serving(engine) as (fe, _):
        body = {"prompt": prompt_for(engine.cfg, 1), "max_tokens": 12}
        st, _, data = post(fe.port, body)
        toks = json.loads(data)["choices"][0]["token_ids"]
        # re-run with an EOS pinned to a token the stream actually emits
        body["stop_token_id"] = toks[2]
        st, _, data = post(fe.port, body)
        assert st == 200
        choice = json.loads(data)["choices"][0]
        assert choice["finish_reason"] == "stop"
        assert choice["token_ids"] == toks[:choice["token_ids"].__len__()]
        assert choice["token_ids"][-1] == toks[2]


def test_concurrent_streaming_clients(engine):
    with serving(engine, n_slots=2, max_pending=8) as (fe, sched):
        bodies = [{"prompt": prompt_for(engine.cfg, 10 + i),
                   "max_tokens": 5, "stream": True} for i in range(4)]
        with ThreadPoolExecutor(4) as pool:
            results = list(pool.map(lambda b: post(fe.port, b), bodies))
        for st, _, raw in results:
            assert st == 200
            toks, finish, done = sse_tokens(raw)
            assert len(toks) == 5 and finish == "length" and done
        assert len(sched.done) == 4
        # unary replay of each prompt must reproduce the streamed tokens
        for body, (_, _, raw) in zip(bodies, results):
            st, _, data = post(fe.port, {"prompt": body["prompt"],
                                         "max_tokens": 5})
            assert (json.loads(data)["choices"][0]["token_ids"]
                    == sse_tokens(raw)[0])


def test_validation_errors(engine):
    with serving(engine) as (fe, _):
        st, _, data = post(fe.port, {"prompt": [1], "max_tokens": 4})
        assert st == 400
        assert json.loads(data)["error"]["type"] == "invalid_request_error"
        st, _, _ = post(fe.port, {"max_tokens": 4})
        assert st == 400
        st, _, _ = post(fe.port, {"prompt": ["a", "b"], "max_tokens": 4})
        assert st == 400
        c = http.client.HTTPConnection("127.0.0.1", fe.port, timeout=30)
        c.request("GET", "/nope")
        assert c.getresponse().status == 404


def test_health(engine):
    with serving(engine) as (fe, _):
        c = http.client.HTTPConnection("127.0.0.1", fe.port, timeout=30)
        c.request("GET", "/health")
        r = c.getresponse()
        assert r.status == 200
        h = json.loads(r.read())
        assert h["status"] == "ok" and h["shed_requests"] == 0


def test_overload_sheds_with_429(engine):
    with serving(engine, n_slots=2, max_pending=2) as (fe, sched):
        bodies = [{"prompt": prompt_for(engine.cfg, 20 + i),
                   "max_tokens": 8} for i in range(6)]
        with ThreadPoolExecutor(6) as pool:
            results = list(pool.map(lambda b: post(fe.port, b), bodies))
        statuses = sorted(st for st, _, _ in results)
        shed = statuses.count(429)
        assert shed >= 1, "6 concurrent requests vs 2 pending: must shed"
        for st, headers, data in results:
            if st == 429:
                assert headers.get("Retry-After") == "1"
                assert json.loads(data)["error"]["type"] == "overloaded_error"
            else:
                assert st == 200
                # admitted requests are untouched by the shedding: full
                # budget, clean stream
                assert len(json.loads(data)["choices"][0]["token_ids"]) == 8
        assert sched.stats["shed_requests"] == shed
        assert len(sched.done) == 6 - shed


def test_graceful_drain(engine):
    pool = ThreadPoolExecutor(1)
    body = {"prompt": prompt_for(engine.cfg, 30), "max_tokens": 8,
            "stream": True}
    with serving(engine) as (fe, _):
        port = fe.port
        fut = pool.submit(post, port, body)
        time.sleep(0.3)           # request in flight when drain begins
    # exiting the context ran frontend.stop() while the request streamed:
    # graceful drain must have served it to completion first
    st, _, raw = fut.result(timeout=120)
    pool.shutdown()
    assert st == 200
    toks, finish, done = sse_tokens(raw)
    assert len(toks) == 8 and finish == "length" and done
    with pytest.raises(OSError):
        post(port, {"prompt": [1, 2], "max_tokens": 1}, timeout=5)
