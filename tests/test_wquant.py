"""Weight-only quantization: pack/quantize numerics, the fused dequant
matmul kernel vs its pure-JAX oracle, quant-error bounds vs bf16, the
quantize-at-load transform + TP-aware spec tree, and end-to-end greedy
bit-identity across every scheduling mode (wave / slot / chunked / spec) on
both storage backends (dense / paged) under int8 and int4 weights.

The identity property is the serving-stack invariant the whole harness
certifies: quantization changes WHICH model is served (dequantized weights
are different bf16 values), but all scheduling modes must serve that model
identically — the same argument that held for bf16 weights, since every
mode reads the same packed params through the same dequant routing."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ParallelConfig, SamplingConfig, get_config
from repro.core import wquant
from repro.kernels import ops as kops
from repro.kernels import ref as kref

try:
    import hypothesis
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:                                     # pragma: no cover
    hypothesis = None

BITWISE = jax.device_count() == 1


def greedy_engine(arch="yi-9b", max_len=96, parallel=None):
    from repro.launch.mesh import make_local_mesh
    from repro.runtime.engine import Engine

    cfg = get_config(arch).reduced()
    return Engine(cfg=cfg,
                  parallel=parallel or ParallelConfig(tp=1, dp=1, remat=False),
                  sampling=SamplingConfig(greedy=True, top_k=1),
                  mesh=make_local_mesh(1, 1), max_len=max_len)


def assert_tokens_match(actual, desired):
    actual, desired = np.asarray(actual), np.asarray(desired)
    if BITWISE:
        np.testing.assert_array_equal(actual, desired)
        return
    assert actual.shape == desired.shape
    if len(actual):
        assert actual[0] == desired[0]


# ---------------------------------------------------------------------------
# Packing + quantization numerics
# ---------------------------------------------------------------------------


def test_pack4_roundtrip():
    rng = np.random.default_rng(0)
    q4 = jnp.asarray(rng.integers(-7, 8, (6, 16, 10)), jnp.int8)
    np.testing.assert_array_equal(wquant.unpack4(wquant.pack4(q4)), q4)


def test_effective_group_shard_local():
    # group divides the PER-SHARD reduction length, never straddling TP
    assert wquant.effective_group(512, 128, 1) == 128
    assert wquant.effective_group(512, 128, 4) == 128   # 128 | 512/4
    assert wquant.effective_group(512, 128, 2) == 128
    assert wquant.effective_group(192, 128, 2) == 96    # 96 | 192/2
    assert wquant.effective_group(64, 128, 1) == 64     # clamped to K
    assert wquant.effective_group(2, 128, 2) == 0       # nothing fits


@pytest.mark.parametrize("mode", ["int8", "int4"])
def test_quant_error_bounded_vs_bf16(mode):
    """Symmetric quantization error bound: per element, |dq - w| is at most
    half an LSB of the covering scale (plus one bf16 rounding of the scale
    itself) — int8 per-output-channel, int4 per group."""
    rng = np.random.default_rng(1)
    w = jnp.asarray(rng.normal(0, 0.05, (256, 96)), jnp.bfloat16)
    qw = wquant.quantize(w, mode, 64)
    dq = np.asarray(wquant.dequantize(qw), np.float32)
    wf = np.asarray(w, np.float32)
    scale = np.asarray(qw.scale, np.float32)
    if mode == "int8":
        lsb = np.broadcast_to(scale[None, :], wf.shape)
    else:
        g = qw.group
        lsb = np.repeat(scale, g, axis=0)
    # 0.5 LSB round-off + bf16 storage of scale (2^-8 rel) + bf16 dq round
    bound = 0.5 * lsb + (np.abs(wf) + lsb) * 2 ** -7
    assert (np.abs(dq - wf) <= bound + 1e-8).all()
    # int8 must be ~16x tighter than int4 on the same tensor
    if mode == "int8":
        assert np.abs(dq - wf).max() < 0.002


@pytest.mark.parametrize("mode", ["int8", "int4"])
def test_dequant_matmul_kernel_exact_single_block(mode):
    """One K-block grid: the kernel body performs the oracle's exact jnp
    ops on the same operands — bitwise equality, not allclose."""
    rng = np.random.default_rng(2)
    w = jnp.asarray(rng.normal(0, 0.05, (128, 160)), jnp.bfloat16)
    qw = wquant.quantize(w, mode, 128)          # int4: one group per block
    x = jnp.asarray(rng.normal(0, 1, (5, 128)), jnp.bfloat16)
    ref = kref.dequant_matmul_ref(x, qw.q, qw.scale, qw.mode, qw.group or 1)
    out = kops.dequant_matmul(x, qw.q, qw.scale, mode=qw.mode,
                              group=qw.group, out_dtype=jnp.float32)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@pytest.mark.parametrize("mode", ["int8", "int4"])
@pytest.mark.parametrize("shape", [(3, 256, 384), (40, 512, 256),
                                   (9, 64, 512), (130, 320, 96)])
def test_dequant_matmul_kernel_matches_ref(mode, shape):
    """GEMV (T<=16) and GEMM blockings against the oracle across uneven
    T/N/K: multi-block accumulation reorders fp32 sums, so the tolerance is
    summation-order-only (products are exact in fp32)."""
    T, K, N = shape
    rng = np.random.default_rng(T + K)
    w = jnp.asarray(rng.normal(0, 0.05, (K, N)), jnp.bfloat16)
    qw = wquant.quantize(w, mode, 64)
    x = jnp.asarray(rng.normal(0, 1, (T, K)), jnp.bfloat16)
    ref = np.asarray(kref.dequant_matmul_ref(x, qw.q, qw.scale, qw.mode,
                                             qw.group or 1))
    out = np.asarray(kops.dequant_matmul(x, qw.q, qw.scale, mode=qw.mode,
                                         group=qw.group,
                                         out_dtype=jnp.float32))
    np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)


if hypothesis is not None:

    @settings(max_examples=25, deadline=None)
    @given(st.integers(1, 20), st.sampled_from([32, 64, 96, 128]),
           st.integers(1, 40), st.sampled_from(["int8", "int4"]),
           st.integers(0, 2 ** 31 - 1))
    def test_dequant_matmul_property(T, K, N, mode, seed):
        """Fused kernel == pure-JAX dequant reference over random shapes
        and values (the satellite property test)."""
        rng = np.random.default_rng(seed)
        w = jnp.asarray(rng.normal(0, 0.1, (K, N)), jnp.bfloat16)
        qw = wquant.quantize(w, mode, 32)
        x = jnp.asarray(rng.normal(0, 1, (T, K)), jnp.bfloat16)
        ref = np.asarray(kref.dequant_matmul_ref(
            x, qw.q, qw.scale, qw.mode, qw.group or 1))
        out = np.asarray(kops.dequant_matmul(
            x, qw.q, qw.scale, mode=qw.mode, group=qw.group,
            out_dtype=jnp.float32))
        np.testing.assert_allclose(out, ref, rtol=2e-5,
                                   atol=2e-5 * max(1.0, np.abs(ref).max()))


# ---------------------------------------------------------------------------
# Quantize-at-load transform + spec tree
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["yi-9b", "mixtral-8x7b", "minicpm3-4b"])
@pytest.mark.parametrize("mode", ["int8", "int4"])
def test_transform_covers_projections_and_specs_match(arch, mode):
    """Every serving projection quantizes (attention q/k/v/o for non-MLA,
    MLP up/gate/down, MoE expert blocks + shared experts, lm_head); embed
    tables / norms / routers stay bf16; and the spec tree rebuilt by
    param_specs is structurally identical to the quantized param tree —
    the property shard_map needs."""
    from repro.models import model as M

    cfg = get_config(arch).reduced()
    ctx = M.ModelCtx.make(cfg, ParallelConfig(
        tp=1, dp=1, remat=False, weight_quant=mode))
    params = M.quantize_params(ctx, M.init_params(ctx, jax.random.key(0)))
    assert (jax.tree_util.tree_structure(params)
            == jax.tree_util.tree_structure(M.param_specs(ctx)))
    # idempotent: a second pass is a no-op
    again = M.quantize_params(ctx, params)
    assert (jax.tree_util.tree_structure(again)
            == jax.tree_util.tree_structure(params))
    sub0 = params["groups"][0]["sub0"]
    if cfg.mla is None:
        for k in ("w_q", "w_k", "w_v", "w_o"):
            assert isinstance(sub0["mixer"][k], wquant.QuantWeight)
    else:
        assert not any(isinstance(v, wquant.QuantWeight)
                       for v in jax.tree.leaves(
                           sub0["mixer"],
                           is_leaf=lambda x: isinstance(x, wquant.QuantWeight)))
    ffn_keys = [k for k in ("w_up", "w_down") if k in sub0.get("ffn", {})]
    for k in ffn_keys:
        assert isinstance(sub0["ffn"][k], wquant.QuantWeight)
    assert not isinstance(params["embed"]["table"], wquant.QuantWeight)
    if "lm_head" in params:
        assert isinstance(params["lm_head"], wquant.QuantWeight)


def test_int4_groups_stay_shard_local():
    """Under TP, the int4 group clamp keeps every group inside one shard of
    a row-parallel (K-sharded) weight, and the scale's group axis carries
    the model-axis spec so scales shard with the weight."""
    from repro.models import model as M

    cfg = get_config("yi-9b").reduced()
    ctx = M.ModelCtx.make(cfg, ParallelConfig(
        tp=2, dp=1, remat=False, weight_quant="int4"))
    params = M.quantize_params(ctx, M.init_params(ctx, jax.random.key(0)))
    specs = M.param_specs(ctx)
    w_down = params["groups"][0]["sub0"]["ffn"]["w_down"]
    s_down = specs["groups"][0]["sub0"]["ffn"]["w_down"]
    k_local = w_down.k // 2                        # K sharded over tp=2
    assert k_local % w_down.group == 0
    assert tuple(s_down.scale)[-2] == "model"      # group axis shards
    assert tuple(s_down.q)[-2] == "model"


def test_decode_weight_bytes_ratio():
    """The memory math behind the bench: int4-g128 sweeps >= 3.5x fewer
    weight bytes per decode token than bf16 (int8 ~2x), on the reduced
    config and on the full-size qwen-72b shapes."""
    from repro.models import model as M

    for cfg in (get_config("yi-9b").reduced(), get_config("qwen-72b")):
        swept = {}
        for mode in ("none", "int8", "int4"):
            ctx = M.ModelCtx.make(cfg, ParallelConfig(
                tp=1, dp=1, remat=False, weight_quant=mode))
            swept[mode] = M.decode_weight_bytes(ctx)["swept"]
        assert swept["none"] / swept["int4"] >= 3.5
        assert swept["none"] / swept["int8"] >= 1.9


# ---------------------------------------------------------------------------
# End-to-end greedy identity: wave == slot == chunked == spec, dense + paged
# ---------------------------------------------------------------------------


def requests_mix(cfg, n=4, seed=0, equal_len=False):
    """Motif-repeating prompts (so the spec drafter accepts some drafts).

    ``equal_len=True`` pins every prompt to one length: the wave baseline
    right-pads shorter rows and CONDITIONS on the padding, so only
    equal-length mixes isolate the scheduling change when wave is in the
    comparison set (same caveat the continuous-batching suite documents)."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        motif = rng.integers(0, cfg.vocab_size, 4).astype(np.int32)
        plen = 16 if equal_len else int(rng.integers(8, 21))
        prompt = np.tile(motif, -(-plen // 4))[:plen]
        reqs.append((prompt, int(rng.integers(6, 13)), i * 2))
    return reqs


def _serve(sched, reqs):
    for p, mn, arr in reqs:
        sched.submit(p, mn, arrival_step=arr)
    return {r.rid: r.output for r in sched.run()}


@pytest.fixture(scope="module", params=["int8", "int4"])
def wq_engine(request):
    return greedy_engine(parallel=ParallelConfig(
        tp=1, dp=1, remat=False, weight_quant=request.param,
        wq_group_size=128))


def test_greedy_identity_across_modes_dense(wq_engine):
    """The acceptance invariant, dense backend: the same quantized weights
    serve bit-identical greedy streams through the wave scheduler, the
    plain slot engine, chunked admission, and speculative decoding."""
    from repro.runtime.scheduler import ContinuousScheduler, WaveScheduler

    eng = wq_engine
    reqs = requests_mix(eng.cfg, seed=3, equal_len=True)
    outs = {
        "wave": _serve(WaveScheduler(eng, batch_size=2), reqs),
        "slot": _serve(ContinuousScheduler(eng, n_slots=2, block_steps=4,
                                           prefill_chunk=0), reqs),
        "chunked": _serve(ContinuousScheduler(eng, n_slots=2, block_steps=4,
                                              prefill_chunk=8), reqs),
        "spec": _serve(ContinuousScheduler(eng, n_slots=2, block_steps=4,
                                           prefill_chunk=0, spec_k=4), reqs),
    }
    for name in ("slot", "chunked", "spec"):
        for rid in outs["wave"]:
            assert_tokens_match(outs[name][rid], outs["wave"][rid])
    assert any(l.dtype == np.int8 or l.dtype == np.uint8
               for l in jax.tree.leaves(eng.params))


def test_greedy_identity_across_modes_paged(wq_engine):
    """Same invariant on the paged backend: paged plain / chunked / spec
    streams equal the dense slot engine's."""
    from repro.runtime.scheduler import (ContinuousScheduler,
                                         PagedContinuousScheduler)

    eng = wq_engine
    reqs = requests_mix(eng.cfg, seed=4)
    ref = _serve(ContinuousScheduler(eng, n_slots=2, block_steps=4,
                                     prefill_chunk=0), reqs)
    outs = {
        "paged": _serve(PagedContinuousScheduler(
            eng, n_slots=2, block_steps=4, prefill_chunk=0, block_size=8),
            reqs),
        "paged_chunked": _serve(PagedContinuousScheduler(
            eng, n_slots=2, block_steps=4, prefill_chunk=8, block_size=8),
            reqs),
        "paged_spec": _serve(PagedContinuousScheduler(
            eng, n_slots=2, block_steps=4, prefill_chunk=0, spec_k=4,
            block_size=8), reqs),
    }
    for name, got in outs.items():
        for rid in ref:
            assert_tokens_match(got[rid], ref[rid])


def test_wq_solo_matches_slot_int8_kv():
    """Weight quant composes with the int8 KV cache: slot-engine streams
    equal solo generation with both quantizations on."""
    eng = greedy_engine(parallel=ParallelConfig(
        tp=1, dp=1, remat=False, weight_quant="int8", kv_quant=True))
    from repro.runtime.scheduler import ContinuousScheduler

    reqs = requests_mix(eng.cfg, n=3, seed=5)
    done = _serve(ContinuousScheduler(eng, n_slots=2, block_steps=4,
                                      prefill_chunk=0), reqs)
    for rid, (p, mn, _) in enumerate(reqs):
        solo = eng.generate(p[None], mn)[0]
        assert_tokens_match(done[rid], solo)


def test_wq_pallas_engine_smoke():
    """The fused dequant kernels wired through the serving engine
    (interpret mode): a short greedy generate runs through kernel-routed
    projections + lm_head for both modes."""
    for mode in ("int8", "int4"):
        eng = greedy_engine(max_len=24, parallel=ParallelConfig(
            tp=1, dp=1, remat=False, weight_quant=mode, use_pallas=True,
            flash_prefill=False))
        p = np.random.default_rng(6).integers(
            0, eng.cfg.vocab_size, (1, 6)).astype(np.int32)
        out = eng.generate(p, 3, multi_step=False)
        assert out.shape == (1, 3)
        head = eng.params["lm_head"]
        assert isinstance(head, wquant.QuantWeight)
        assert head.backend == "pallas"


@pytest.mark.skipif(jax.device_count() < 2, reason="needs >= 2 devices")
def test_wq_tp2_scale_sharding_serves():
    """TP-aware scale sharding end-to-end: a tp=2 engine with int4 weights
    (row-parallel w_down K-sharded, group scales sharded alongside) serves
    the slot engine and the wave baseline identically — wrong scale specs
    would desync the psum partials, not just perturb them."""
    from repro.launch.mesh import make_local_mesh
    from repro.runtime.engine import Engine
    from repro.runtime.scheduler import ContinuousScheduler, WaveScheduler

    cfg = get_config("yi-9b").reduced()
    eng = Engine(cfg=cfg,
                 parallel=ParallelConfig(tp=2, dp=1, remat=False,
                                         weight_quant="int4"),
                 sampling=SamplingConfig(greedy=True, top_k=1),
                 mesh=make_local_mesh(1, 2), max_len=96)
    # equal (even) prompt lengths: the seq-parallel wave prefill shards the
    # sequence over tp, so the padded wave length must divide tp — and wave
    # conditions on right-padding for shorter rows either way
    reqs = requests_mix(cfg, n=3, seed=7, equal_len=True)
    wave = _serve(WaveScheduler(eng, batch_size=2), reqs)
    slot = _serve(ContinuousScheduler(eng, n_slots=2, block_steps=4,
                                      prefill_chunk=0), reqs)
    for rid in wave:
        np.testing.assert_array_equal(slot[rid], wave[rid])
