"""Chunked prefill: the fused Pallas flash-prefill kernel against the
pure-JAX ``chunked_causal_attention`` oracle, and chunked admission
(fused mixed prefill/decode steps) against whole-prompt admission —
bit-identical greedy outputs across the dense and paged schedulers,
GQA and int8 KV."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ParallelConfig, SamplingConfig, get_config
from repro.kernels import ops
from repro.launch.mesh import make_local_mesh
from repro.models.attention import chunked_causal_attention
from repro.runtime.engine import Engine
from repro.runtime.scheduler import ContinuousScheduler, PagedContinuousScheduler


# ---------------------------------------------------------------------------
# Kernel vs scan oracle (interpret mode)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("b,hq,hkv,Sq,Sk,hd,bq,bk", [
    (1, 4, 4, 16, 16, 64, 16, 16),      # MHA, one tile
    (2, 8, 2, 37, 64, 64, 16, 16),      # GQA g=4, uneven q tail
    (2, 4, 1, 24, 50, 32, 8, 16),       # MQA, uneven kv tail
    (1, 16, 4, 128, 128, 128, 128, 128),  # TPU-aligned tile
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_prefill_matches_scan(b, hq, hkv, Sq, Sk, hd, bq, bk, dtype):
    """Property: the fused kernel equals the streaming-softmax oracle for
    every GQA group size, uneven chunk tails, and per-row resume offsets
    (the chunked-prefill case: queries start mid-cache)."""
    from repro.kernels import prefill_attention as pa

    ks = jax.random.split(jax.random.key(b * Sq + Sk), 3)
    q = jax.random.normal(ks[0], (b, hq, Sq, hd)).astype(dtype)
    k = jax.random.normal(ks[1], (b, hkv, Sk, hd)).astype(dtype)
    v = jax.random.normal(ks[2], (b, hkv, Sk, hd)).astype(dtype)
    starts = np.arange(b, dtype=np.int32) * max(1, (Sk - Sq) // max(1, b))
    qpos = (jnp.asarray(starts)[:, None]
            + jnp.arange(Sq, dtype=jnp.int32)[None, :])
    scale = 1.0 / np.sqrt(hd)
    out = pa.flash_prefill(q, k, v, qpos, float(scale), block_q=bq, block_k=bk)
    ref = chunked_causal_attention(q, k, v, qpos,
                                   jnp.arange(Sk, dtype=jnp.int32), 0, scale)
    tol = 2e-5 if dtype == jnp.float32 else 0.03
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("b,hq,hkv,bs,nbps,Sq,hd", [
    (1, 4, 4, 16, 4, 16, 64), (2, 8, 2, 8, 6, 24, 64), (3, 4, 1, 32, 2, 9, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_flash_prefill_matches_dense_kernel(b, hq, hkv, bs, nbps, Sq,
                                                  hd, dtype):
    """Pool + block-table gather (scalar-prefetch index maps) must agree
    with the dense kernel on the gathered view."""
    from repro.kernels import prefill_attention as pa

    S = nbps * bs
    ks = jax.random.split(jax.random.key(b * S + hd), 3)
    nb = 1 + b * nbps
    kp = jax.random.normal(ks[0], (nb, hkv, bs, hd)).astype(dtype)
    vp = jax.random.normal(ks[1], (nb, hkv, bs, hd)).astype(dtype)
    rng = np.random.default_rng(S)
    bt = jnp.asarray(rng.permutation(np.arange(1, nb))[: b * nbps]
                     .reshape(b, nbps).astype(np.int32))
    q = jax.random.normal(ks[2], (b, hq, Sq, hd)).astype(dtype)
    starts = rng.integers(0, S - Sq + 1, size=b).astype(np.int32)
    qpos = (jnp.asarray(starts)[:, None]
            + jnp.arange(Sq, dtype=jnp.int32)[None, :])
    scale = 1.0 / np.sqrt(hd)
    out = pa.paged_flash_prefill(q, kp, vp, bt, qpos, float(scale), block_q=8)
    view = jnp.take(kp, bt, axis=0).transpose(0, 2, 1, 3, 4).reshape(b, hkv, S, hd)
    vview = jnp.take(vp, bt, axis=0).transpose(0, 2, 1, 3, 4).reshape(b, hkv, S, hd)
    ref = pa.flash_prefill(q, view, vview, qpos, float(scale),
                           block_q=8, block_k=bs)
    tol = 2e-5 if dtype == jnp.float32 else 0.03
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


def test_flash_prefill_padded_rows_emit_zero():
    """Pad query rows (q_pos = -1, the uneven-tail case) are fully masked
    and must emit exact zeros, not NaNs from an empty softmax."""
    from repro.kernels import prefill_attention as pa

    q = jnp.ones((1, 2, 4, 64))
    k = jnp.ones((1, 2, 8, 64))
    v = jnp.ones((1, 2, 8, 64))
    qpos = jnp.asarray([[0, 1, -1, -1]], jnp.int32)
    out = np.asarray(pa.flash_prefill(q, k, v, qpos, 0.125, block_q=4,
                                      block_k=8))
    assert np.isfinite(out).all()
    np.testing.assert_allclose(out[0, :, 2:], 0.0)
    assert np.abs(out[0, :, :2]).sum() > 0


# ---------------------------------------------------------------------------
# Chunked admission == whole-prompt admission (serving level)
# ---------------------------------------------------------------------------


# Bit-exact token equality between serving modes is guaranteed against ONE
# backend compilation regime: chunked and whole-prompt admission do the same
# math, but they are different XLA programs, and a multi-device host platform
# compiles them with different tiling — ±1-ulp logit reassociation that can
# flip a greedy near-tie mid-stream (same caveat the paged suite documents
# for kernel-vs-jnp paths).  The single-device tier-1 job enforces bitwise
# equality; under forced multi-device CPU we require identical shape and
# agreement through the first emitted token (the admission path under test),
# tolerating only mid-stream near-tie flips.
BITWISE = jax.device_count() == 1


def assert_tokens_match(actual, desired):
    if BITWISE:
        np.testing.assert_array_equal(actual, desired)
        return
    actual, desired = np.asarray(actual), np.asarray(desired)
    assert actual.shape == desired.shape
    if len(actual):
        assert actual[0] == desired[0]


def greedy_engine(arch: str, max_len: int = 96,
                  parallel: ParallelConfig = None) -> Engine:
    cfg = get_config(arch).reduced()
    return Engine(cfg=cfg,
                  parallel=parallel or ParallelConfig(tp=1, dp=1, remat=False),
                  sampling=SamplingConfig(greedy=True, top_k=1),
                  mesh=make_local_mesh(1, 1), max_len=max_len)


@pytest.fixture(scope="module")
def yi_engine():
    return greedy_engine("yi-9b")


def long_requests(cfg, n=6, seed=0, pmin=20, pmax=48):
    rng = np.random.default_rng(seed)
    return [(rng.integers(0, cfg.vocab_size, int(rng.integers(pmin, pmax + 1)))
             .astype(np.int32), int(rng.integers(3, 9)), i * 2)
            for i in range(n)]


def run_chunked_vs_whole(eng, reqs, make_sched, chunk=8):
    done = {}
    scheds = {}
    for C in (0, chunk):
        sched = make_sched(eng, C)
        for p, mn, arr in reqs:
            sched.submit(p, mn, arrival_step=arr)
        done[C] = {r.rid: r for r in sched.run()}
        scheds[C] = sched
    assert sorted(done[0]) == sorted(done[chunk])
    for rid in done[0]:
        assert_tokens_match(done[chunk][rid].output, done[0][rid].output)
    return scheds[chunk], done[chunk]


def test_chunked_matches_whole_prompt_dense(yi_engine):
    """Greedy outputs must be bit-identical between chunked (C=8, prompts
    20-48 tokens -> 3-6 chunks each) and whole-prompt admission, and match
    solo generation exactly."""
    eng = yi_engine
    reqs = long_requests(eng.cfg)
    sched, done = run_chunked_vs_whole(
        eng, reqs,
        lambda e, C: ContinuousScheduler(e, n_slots=3, block_steps=4,
                                         prefill_chunk=C))
    assert sched.stats["chunked_admissions"] == len(reqs)
    assert sched.stats["prefill_chunks"] > len(reqs)   # real multi-chunk
    assert sched.stats["in_flight_admissions"] > 0     # decode was live
    for rid, (p, mn, _) in enumerate(reqs):
        solo = eng.generate(p[None], mn)[0]
        assert_tokens_match(done[rid].output, solo)
    # the chunked path compiled exactly one prefill width: no pow-2 buckets
    summ = sched.request_summary()
    assert "decode_itl_admission_s" in summ and "decode_itl_s" in summ


def test_chunked_matches_whole_prompt_paged(yi_engine):
    sched, _ = run_chunked_vs_whole(
        yi_engine, long_requests(yi_engine.cfg, seed=1),
        lambda e, C: PagedContinuousScheduler(e, n_slots=3, block_steps=4,
                                              prefill_chunk=C, block_size=8))
    assert sched.stats["chunked_admissions"] > 0
    assert sched.stats["preemptions"] == 0


@pytest.mark.parametrize("paged", [False, True])
def test_chunked_int8_kv(paged):
    """Quantized-cache chunk writes (scatter of int8 values + scales at
    per-row offsets) must reproduce the whole-prompt admission exactly."""
    eng = greedy_engine("yi-9b", parallel=ParallelConfig(
        tp=1, dp=1, remat=False, kv_quant=True))
    if paged:
        make = lambda e, C: PagedContinuousScheduler(
            e, n_slots=2, block_steps=4, prefill_chunk=C, block_size=8)
    else:
        make = lambda e, C: ContinuousScheduler(e, n_slots=2, block_steps=4,
                                                prefill_chunk=C)
    sched, _ = run_chunked_vs_whole(eng, long_requests(eng.cfg, n=4, seed=2),
                                    make)
    assert sched.stats["chunked_admissions"] > 0
    import jax as _jax
    assert any(l.dtype == np.int8 for l in _jax.tree.leaves(sched.caches))


def test_chunked_prefix_reuse_paged(yi_engine):
    """Chunked admission composes with the hash-chained prefix cache: the
    first chunk resumes right AFTER the matched prefix, prefix blocks
    publish only once the final chunk lands, and outputs stay identical to
    whole-prompt admission and solo generation."""
    eng = yi_engine
    rng = np.random.default_rng(5)
    shared = rng.integers(0, eng.cfg.vocab_size, 24).astype(np.int32)
    reqs = []
    for i in range(3):
        suffix = rng.integers(0, eng.cfg.vocab_size, 20).astype(np.int32)
        # r0 decodes long enough to keep its blocks (and prefix entries)
        # alive while r1/r2 admit -> they match the 24-token shared prefix
        reqs.append((np.concatenate([shared, suffix]),
                     16 if i == 0 else 4, i * 2))
    done = {}
    for C in (0, 8):
        sched = PagedContinuousScheduler(eng, n_slots=3, block_steps=2,
                                         prefill_chunk=C, block_size=8)
        for p, mn, arr in reqs:
            sched.submit(p, mn, arrival_step=arr)
        done[C] = {r.rid: r for r in sched.run()}
        # whole-prompt publishes the full prefix at admission (both later
        # requests reuse all 24 tokens); chunked publishes INCREMENTALLY,
        # so a request admitted mid-stream reuses the blocks completed so
        # far (r1 gets a partial prefix, r2 the full one)
        assert sched.stats["prefill_tokens_saved"] >= (48 if C == 0 else 32), C
    for rid, (p, mn, _) in enumerate(reqs):
        assert_tokens_match(done[8][rid].output, done[0][rid].output)
        solo = eng.generate(p[None], mn)[0]
        assert_tokens_match(done[8][rid].output, solo)


def test_chunked_capability_gating_recurrent():
    """Recurrent-state archs stay chunk-ineligible under the capability
    registry: an EXPLICIT per-scheduler prefill_chunk raises the uniform
    registry error, while the config-default path (engine-level
    prefill_chunk, no constructor override) silently clamps to whole-prompt
    admission and still matches solo generation."""
    with pytest.raises(ValueError, match="does not support chunked prefill"):
        ContinuousScheduler(greedy_engine("mamba2-1.3b", max_len=64),
                            n_slots=2, block_steps=4, prefill_chunk=8)
    eng = greedy_engine("mamba2-1.3b", max_len=64,
                        parallel=ParallelConfig(tp=1, dp=1, remat=False,
                                                prefill_chunk=8))
    sched = ContinuousScheduler(eng, n_slots=2, block_steps=4)
    assert sched.chunk == 0
    rng = np.random.default_rng(7)
    reqs = [(rng.integers(0, eng.cfg.vocab_size, 20).astype(np.int32), 4)
            for _ in range(2)]
    for p, mn in reqs:
        sched.submit(p, mn)
    done = {r.rid: r for r in sched.run()}
    assert sched.stats["chunked_admissions"] == 0
    for rid, (p, mn) in enumerate(reqs):
        solo = eng.generate(p[None], mn)[0]
        assert_tokens_match(done[rid].output, solo)


@pytest.mark.parametrize("arch", ["minicpm3-4b", "mixtral-8x7b"])
def test_chunked_matches_whole_prompt_newly_eligible(arch):
    """MLA latent caches and sliding-window ring caches stream chunks now
    (latent scatter-resume and pre-write ring stripe attention): chunked
    admission is bit-identical to whole-prompt admission and to solo
    generation."""
    eng = greedy_engine(arch)
    reqs = long_requests(eng.cfg, n=4, seed=3)
    sched, done = run_chunked_vs_whole(
        eng, reqs,
        lambda e, C: ContinuousScheduler(e, n_slots=2, block_steps=4,
                                         prefill_chunk=C))
    assert sched.stats["chunked_admissions"] == len(reqs)
    for rid, (p, mn, _) in enumerate(reqs):
        solo = eng.generate(p[None], mn)[0]
        assert_tokens_match(done[rid].output, solo)


def test_decode_advances_during_chunked_admission(yi_engine):
    """The point of the mixed step: while a long prompt streams in, the
    already-running request keeps emitting one token per step (it never
    waits for the whole prompt)."""
    eng = yi_engine
    rng = np.random.default_rng(9)
    sched = ContinuousScheduler(eng, n_slots=2, block_steps=4,
                                prefill_chunk=8)
    p0 = rng.integers(0, eng.cfg.vocab_size, 6).astype(np.int32)
    p1 = rng.integers(0, eng.cfg.vocab_size, 40).astype(np.int32)  # 5 chunks
    r0 = sched.submit(p0, max_new=16)
    r1 = sched.submit(p1, max_new=4, arrival_step=1)
    order = []
    sched.on_token = lambda rid, t: order.append(rid)
    done = {r.rid: r for r in sched.run()}
    assert len(done[r0].output) == 16 and len(done[r1].output) == 4
    # r0 tokens were interleaved with r1's admission: r1's first token
    # appears strictly before r0's last (no whole-prompt stall reordering)
    assert order.index(r1) < len(order) - 1 - order[::-1].index(r0)
    assert sched.stats["prefill_chunks"] >= 5
    # every mixed step also ran a decode step
    assert sched.stats["decode_steps"] >= sched.stats["prefill_chunks"]


def test_flash_prefill_engine_chunked():
    """Pallas flash-prefill wired through the chunked engine path
    (interpret mode): greedy outputs agree with the scan path on the same
    chunked schedule (fp32 kernel accumulation vs the scan's bf16 p@v can
    differ in low bits, so token agreement is checked on a short,
    well-separated greedy run)."""
    outs = {}
    for flash in (False, True):
        eng = greedy_engine("yi-9b", parallel=ParallelConfig(
            tp=1, dp=1, remat=False, use_pallas=True, flash_prefill=flash))
        sched = ContinuousScheduler(eng, n_slots=2, block_steps=4,
                                    prefill_chunk=8)
        rng = np.random.default_rng(11)
        for _ in range(2):
            sched.submit(rng.integers(0, eng.cfg.vocab_size, 24)
                         .astype(np.int32), 5)
        outs[flash] = {r.rid: r.output for r in sched.run()}
    for rid in outs[False]:
        assert_tokens_match(outs[True][rid], outs[False][rid])
