"""Training substrate: losses, optimizers, checkpointing, data pipeline."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import compat

from repro.configs import ParallelConfig, get_config
from repro.launch.mesh import make_local_mesh
from repro.models import model as M
from repro.models.common import Dist, ShardPlan, specs_of
from repro.training import checkpoint, data
from repro.training.loss import chunked_vocab_parallel_xent, vocab_parallel_xent
from repro.training.optimizer import AdamWConfig, adamw_update, init_opt_state, lr_schedule
from repro.training.train_loop import make_train_step
from repro.training.zero import init_zero_state, zero_state_defs


def test_vocab_parallel_xent_matches_reference(mesh11):
    b, s, v = 2, 8, 64
    logits = jax.random.normal(jax.random.key(0), (b, s, v))
    labels = jax.random.randint(jax.random.key(1), (b, s), 0, v)
    dist = Dist(tp=1, dp=1)
    cfg = get_config("yi-9b").reduced()
    import dataclasses

    plan = ShardPlan.make(dataclasses.replace(cfg, vocab_size=v), 1)

    def f(logits, labels):
        return vocab_parallel_xent(logits, labels, plan, dist)

    got = float(jax.jit(compat.shard_map(
        f, mesh=mesh11, in_specs=(P(), P()), out_specs=P(), check_vma=False))(
        logits, labels))
    lse = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
    expect = float(jnp.mean(lse - picked))
    assert abs(got - expect) < 1e-4


def test_chunked_xent_matches_unchunked(mesh11):
    b, s, d, v = 2, 16, 32, 64
    hidden = jax.random.normal(jax.random.key(0), (b, s, d))
    w = jax.random.normal(jax.random.key(1), (d, v)) * 0.1
    labels = jax.random.randint(jax.random.key(2), (b, s), 0, v)
    dist = Dist(tp=1, dp=1)
    import dataclasses

    plan = ShardPlan.make(dataclasses.replace(get_config("yi-9b").reduced(),
                                              vocab_size=v), 1)
    head = lambda h: (h @ w).astype(jnp.float32)

    def f(hidden, labels):
        a = chunked_vocab_parallel_xent(hidden, head, labels, plan, dist, chunk=4)
        bfull = vocab_parallel_xent(head(hidden), labels, plan, dist)
        return a, bfull

    a, bfull = jax.jit(compat.shard_map(f, mesh=mesh11, in_specs=(P(), P()),
                                     out_specs=(P(), P()), check_vma=False))(
        hidden, labels)
    assert abs(float(a) - float(bfull)) < 1e-4


def test_chunked_xent_gradient_matches(mesh11):
    b, s, d, v = 2, 8, 16, 32
    hidden = jax.random.normal(jax.random.key(0), (b, s, d))
    w = jax.random.normal(jax.random.key(1), (d, v)) * 0.1
    labels = jax.random.randint(jax.random.key(2), (b, s), 0, v)
    dist = Dist(tp=1, dp=1)
    import dataclasses

    plan = ShardPlan.make(dataclasses.replace(get_config("yi-9b").reduced(),
                                              vocab_size=v), 1)

    def run(loss_kind):
        def f(w, hidden, labels):
            head = lambda h: (h @ w).astype(jnp.float32)
            if loss_kind == "chunked":
                return chunked_vocab_parallel_xent(hidden, head, labels, plan,
                                                   dist, chunk=4)
            return vocab_parallel_xent(head(hidden), labels, plan, dist)

        g = jax.grad(f)
        return np.asarray(jax.jit(compat.shard_map(
            g, mesh=mesh11, in_specs=(P(), P(), P()), out_specs=P(),
            check_vma=False))(w, hidden, labels))

    np.testing.assert_allclose(run("chunked"), run("plain"), atol=1e-5, rtol=1e-4)


def test_lr_schedule_shape():
    c = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_frac=0.1)
    lrs = [float(lr_schedule(jnp.int32(s), c)) for s in [0, 9, 10, 55, 99, 200]]
    assert lrs[0] < lrs[1] <= lrs[2] == max(lrs)        # warmup up to peak
    assert lrs[3] < lrs[2] and lrs[4] < lrs[3]          # cosine down
    assert abs(lrs[5] - 0.1) < 0.02                     # floor


def test_loss_decreases_training(mesh11):
    cfg = get_config("qwen2.5-14b").reduced()
    ctx = M.ModelCtx.make(cfg, ParallelConfig(tp=1, dp=1, remat=True))
    params = M.init_params(ctx, jax.random.key(0))
    opt = init_opt_state(params)
    pspecs = M.param_specs(ctx)
    ospecs = {"m": pspecs, "v": pspecs, "step": P()}
    step_fn = make_train_step(ctx, AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=60))
    jstep = jax.jit(compat.shard_map(
        step_fn, mesh=mesh11,
        in_specs=(pspecs, ospecs, {"tokens": P("data", None), "labels": P("data", None)}),
        out_specs=(pspecs, ospecs, P()), check_vma=False), donate_argnums=(0, 1))
    dc = data.DataConfig(global_batch=8, seq_len=32)
    losses = []
    for i in range(30):
        b = data.make_batch(cfg, dc, i)
        params, opt, m = jstep(params, opt,
                               {k: jnp.asarray(v) for k, v in b.items()})
        losses.append(float(m["loss"]))
    assert min(losses[-5:]) < losses[0] - 0.15, losses[:3] + losses[-3:]


def test_zero1_equals_adamw_dp1(mesh11):
    cfg = get_config("yi-9b").reduced()
    ctx = M.ModelCtx.make(cfg, ParallelConfig(tp=1, dp=1, remat=False))
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=20)
    dc = data.DataConfig(global_batch=4, seq_len=16)
    outs = {}
    for zero1 in (False, True):
        params = M.init_params(ctx, jax.random.key(0))
        pspecs = M.param_specs(ctx)
        if zero1:
            opt = init_zero_state(M.model_defs(ctx), ctx.dist)
            ospecs = specs_of(zero_state_defs(M.model_defs(ctx), ctx.dist))
        else:
            opt = init_opt_state(params)
            ospecs = {"m": pspecs, "v": pspecs, "step": P()}
        step_fn = make_train_step(ctx, opt_cfg, zero1=zero1)
        jstep = jax.jit(compat.shard_map(
            step_fn, mesh=mesh11,
            in_specs=(pspecs, ospecs,
                      {"tokens": P("data", None), "labels": P("data", None)}),
            out_specs=(pspecs, ospecs, P()), check_vma=False))
        for i in range(3):
            b = data.make_batch(cfg, dc, i)
            params, opt, m = jstep(params, opt,
                                   {k: jnp.asarray(v) for k, v in b.items()})
        outs[zero1] = params
    for a, b in zip(jax.tree.leaves(outs[False]), jax.tree.leaves(outs[True])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=2e-2, rtol=2e-2)


def test_checkpoint_roundtrip(tmp_path):
    cfg = get_config("mamba2-1.3b").reduced()
    ctx = M.ModelCtx.make(cfg, ParallelConfig(tp=1, dp=1))
    params = M.init_params(ctx, jax.random.key(0))
    path = os.path.join(tmp_path, "ckpt.npz")
    checkpoint.save(path, params, step=42, meta={"arch": cfg.name})
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
    restored, step = checkpoint.restore(path, like)
    assert step == 42
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_data_determinism_and_structure():
    cfg = get_config("internvl2-26b").reduced()
    dc = data.DataConfig(global_batch=2, seq_len=24)
    b1 = data.make_batch(cfg, dc, 7)
    b2 = data.make_batch(cfg, dc, 7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (2, 24 - cfg.frontend.prefix_len)
    assert "features" in b1
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])
