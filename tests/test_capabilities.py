"""Architecture capability registry: derivation from every registered
config, the uniform ``require()`` gate, scheduler construction across the
full matrix, and drift checks (no stray per-family gating left in the
scheduler, README table matches the registry)."""
import inspect
import re

import numpy as np
import pytest

from repro.configs import (ALL_ARCHS, ParallelConfig, SamplingConfig,
                           get_config)
from repro.core.capabilities import (BLOCKERS, FALLBACKS, PATH_NAMES, PATHS,
                                     ArchCapabilities, as_dict,
                                     render_markdown, render_text, registry)
from repro.launch.mesh import make_local_mesh
from repro.runtime.engine import Engine


def greedy_engine(arch, max_len=64, **parallel_kw):
    cfg = get_config(arch).reduced()
    return Engine(cfg=cfg,
                  parallel=ParallelConfig(tp=1, dp=1, remat=False,
                                          **parallel_kw),
                  sampling=SamplingConfig(greedy=True, top_k=1),
                  mesh=make_local_mesh(1, 1), max_len=max_len)


# ---------------------------------------------------------------------------
# Derivation
# ---------------------------------------------------------------------------


def test_registry_covers_every_arch():
    reg = registry()
    assert sorted(reg) == sorted(ALL_ARCHS)
    for arch, caps in reg.items():
        assert caps.arch == arch
        # overlap is pure host-loop reordering: never blocked
        assert caps.supports("overlap")
        for path in PATHS:
            tag = caps.blocker(path)
            assert tag is None or tag in BLOCKERS, (arch, path, tag)


def test_derivation_matches_config_structure():
    for arch in ALL_ARCHS:
        cfg = get_config(arch)
        caps = ArchCapabilities.from_config(cfg)
        kinds = set(cfg.layer_pattern)
        ring = cfg.window > 0 and "local_attn" in kinds
        recurrent = bool(kinds & {"ssd", "rglru"})
        gated = cfg.frontend is not None or cfg.n_codebooks > 1 or recurrent
        assert caps.supports("chunked") == (not gated), arch
        assert caps.supports("spec") == (not gated), arch
        # ring caches additionally block the paged pool (view != position)
        assert caps.supports("paged") == (not ring and cfg.frontend is None
                                          and cfg.n_codebooks == 1), arch
        assert caps.supports("disagg") == (caps.supports("chunked")
                                           and caps.supports("paged")), arch
        assert (caps.max_prompt == cfg.window) if ring \
            else (caps.max_prompt is None), arch
        assert caps.sampling == ("per-codebook" if cfg.n_codebooks > 1
                                 else "single"), arch


def test_require_is_uniformly_worded():
    for arch, caps in registry().items():
        for path in PATHS:
            if caps.supports(path):
                caps.require(path)      # no-op
                continue
            with pytest.raises(ValueError) as ei:
                caps.require(path)
            msg = str(ei.value)
            assert msg == (f"arch {arch!r} does not support "
                           f"{PATH_NAMES[path]}: blocked by "
                           f"{BLOCKERS[caps.blocker(path)]} — use "
                           f"{FALLBACKS[path]} instead")


def test_unknown_path_rejected():
    caps = ArchCapabilities.from_config(get_config("yi-9b"))
    with pytest.raises(KeyError):
        caps.supports("warp")
    with pytest.raises(KeyError):
        caps.require("warp")


# ---------------------------------------------------------------------------
# Matrix sweep: every arch x every gated path either constructs a scheduler
# or raises the registry error — nothing falls through to ad-hoc gating.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", sorted(ALL_ARCHS))
def test_matrix_scheduler_construction(arch):
    from repro.runtime.scheduler import (ContinuousScheduler,
                                         PagedContinuousScheduler)

    eng = greedy_engine(arch)
    caps = eng.caps
    assert caps == ArchCapabilities.from_config(eng.cfg)

    for path, build in (
        ("chunked", lambda: ContinuousScheduler(eng, n_slots=2,
                                                prefill_chunk=8)),
        ("spec", lambda: ContinuousScheduler(eng, n_slots=2, spec_k=4)),
        ("paged", lambda: PagedContinuousScheduler(eng, n_slots=2,
                                                   block_size=8)),
    ):
        if caps.supports(path):
            build()
        else:
            with pytest.raises(ValueError,
                               match="does not support "
                                     + PATH_NAMES[path].split("/")[0]):
                build()
    # the plain slot engine serves every arch in the registry
    sched = ContinuousScheduler(eng, n_slots=2)
    assert sched.chunk == 0 or caps.supports("chunked")
    assert sched.spec_k == 0 or caps.supports("spec")


@pytest.mark.parametrize("arch", ["gptj-parallel", "mixtral-8x7b", "minicpm3-4b",
                                  "mamba2-1.3b", "musicgen-medium"])
def test_matrix_serving_smoke(arch):
    """Every cache family serves a short greedy stream through the plain
    slot engine (the path the registry never blocks)."""
    from repro.runtime.scheduler import ContinuousScheduler

    eng = greedy_engine(arch)
    sched = ContinuousScheduler(eng, n_slots=2)
    rng = np.random.default_rng(3)
    ncb = eng.cfg.n_codebooks
    shape = (8,) if ncb == 1 else (8, ncb)
    for _ in range(2):
        sched.submit(rng.integers(0, eng.cfg.vocab_size, shape)
                     .astype(np.int32), 3)
    done = sched.run()
    assert len(done) == 2
    for r in done:
        assert len(r.output) == 3


# ---------------------------------------------------------------------------
# Drift checks
# ---------------------------------------------------------------------------


def test_no_inline_family_gating_left_in_scheduler():
    """The registry is the ONLY eligibility source: the old per-family
    inline gates (``_chunk_eligible`` and friends) must not reappear."""
    from repro.runtime import scheduler

    src = inspect.getsource(scheduler)
    assert "_chunk_eligible" not in src
    # family sniffing like `cfg.mla is not None` must not gate serving paths
    assert not re.search(r"cfg\.mla\s+is\s+not\s+None", src)


def test_renderers_agree_with_registry():
    text = render_text()
    md = render_markdown()
    d = as_dict()
    assert sorted(d) == sorted(ALL_ARCHS)
    for arch, caps in registry().items():
        assert arch in text and f"`{arch}`" in md
        for path in PATHS:
            assert d[arch]["paths"][path]["supported"] == caps.supports(path)
            assert d[arch]["paths"][path]["blocker"] == caps.blocker(path)


def test_readme_matrix_in_sync():
    """The README support-matrix section is generated from the registry;
    regenerate it (core.capabilities.render_markdown) when archs change."""
    import pathlib

    readme = pathlib.Path(__file__).resolve().parent.parent / "README.md"
    assert render_markdown() in readme.read_text()
