"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs pure-jnp oracle."""
import jax
import jax.numpy as jnp

from repro import compat
import numpy as np
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize("b", [1, 3, 8])
@pytest.mark.parametrize("v", [128, 500, 2048, 9504])
@pytest.mark.parametrize("k", [1, 8, 40])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_topk_sweep(b, v, k, dtype):
    if k > v:
        pytest.skip("k>v")
    x = jax.random.normal(jax.random.key(b * v + k), (b, v)).astype(dtype)
    vals, idx = ops.topk(x, k)
    rvals, ridx = ref.topk_ref(x, k)
    np.testing.assert_allclose(np.asarray(vals), np.asarray(rvals), rtol=1e-6)
    # indices can differ on exact ties; values picked must match exactly
    picked = np.take_along_axis(np.asarray(x, np.float32), np.asarray(idx), 1)
    np.testing.assert_allclose(picked, np.asarray(rvals), rtol=1e-6)


@pytest.mark.parametrize("t,ka,kb,d", [(1, 64, 64, 64), (100, 300, 700, 200),
                                       (128, 512, 1728, 512), (257, 129, 65, 33)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_dual_matmul_sweep(t, ka, kb, d, dtype):
    ks = jax.random.split(jax.random.key(t), 4)
    a = jax.random.normal(ks[0], (t, ka)).astype(dtype)
    wa = jax.random.normal(ks[1], (ka, d)).astype(dtype) / np.sqrt(ka)
    b = jax.random.normal(ks[2], (t, kb)).astype(dtype)
    wb = jax.random.normal(ks[3], (kb, d)).astype(dtype) / np.sqrt(kb)
    out = ops.fused_dual_matmul(a, wa, b, wb)
    expect = ref.fused_residual_ref(a, wa, b, wb)
    tol = 1e-4 if dtype == jnp.float32 else 0.05
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("b,hq,hkv,S,hd", [
    (1, 4, 4, 128, 64), (2, 8, 2, 300, 64), (2, 16, 1, 1024, 128),
    (1, 2, 2, 64, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(b, hq, hkv, S, hd, dtype):
    ks = jax.random.split(jax.random.key(S + hd), 3)
    q = jax.random.normal(ks[0], (b, hq, 1, hd)).astype(dtype)
    k = jax.random.normal(ks[1], (b, hkv, S, hd)).astype(dtype)
    v = jax.random.normal(ks[2], (b, hkv, S, hd)).astype(dtype)
    valid = jnp.arange(S) < (S * 3) // 4
    scale = 1.0 / np.sqrt(hd)
    m1, l1, a1 = ops.decode_attention_partial(q, k, v, valid, scale)
    m2, l2, a2 = ref.decode_attention_ref(q, k, v, valid, scale)
    o1 = np.asarray(a1) / np.maximum(np.asarray(l1)[..., None], 1e-30)
    o2 = np.asarray(a2) / np.maximum(np.asarray(l2)[..., None], 1e-30)
    tol = 1e-5 if dtype == jnp.float32 else 0.03
    np.testing.assert_allclose(o1, o2, atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(m1), np.asarray(m2), atol=tol, rtol=tol)


@pytest.mark.parametrize("b,hq,hkv,bs,nbps,hd", [
    (1, 4, 4, 16, 4, 64), (2, 8, 2, 8, 6, 64), (3, 4, 1, 32, 2, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_decode_attention_matches_dense_kernel(b, hq, hkv, bs, nbps, hd,
                                                     dtype):
    """Pool + block-table gather (scalar-prefetch index maps) must agree
    with the dense kernel run on the gathered view, including a fully-dead
    trailing block (per-block masking skips its flash update)."""
    S = nbps * bs
    ks = jax.random.split(jax.random.key(b * S + hd), 3)
    nb = 1 + b * nbps
    kp = jax.random.normal(ks[0], (nb, hkv, bs, hd)).astype(dtype)
    vp = jax.random.normal(ks[1], (nb, hkv, bs, hd)).astype(dtype)
    rng = np.random.default_rng(S)
    bt = jnp.asarray(rng.permutation(np.arange(1, nb))[: b * nbps]
                     .reshape(b, nbps).astype(np.int32))
    q = jax.random.normal(ks[2], (b, hq, 1, hd)).astype(dtype)
    lens = rng.integers(1, S - bs + 1, size=b)       # last block fully dead
    valid = jnp.asarray(np.arange(S)[None, :] < lens[:, None])
    scale = 1.0 / np.sqrt(hd)
    m1, l1, a1 = ops.paged_decode_attention(q, kp, vp, bt, valid, scale)
    view = jnp.take(kp, bt, axis=0).transpose(0, 2, 1, 3, 4).reshape(b, hkv, S, hd)
    vview = jnp.take(vp, bt, axis=0).transpose(0, 2, 1, 3, 4).reshape(b, hkv, S, hd)
    o1 = np.asarray(a1) / np.maximum(np.asarray(l1)[..., None], 1e-30)
    tol = 1e-5 if dtype == jnp.float32 else 0.03
    for bi in range(b):      # dense kernel takes a shared (S,) mask: per row
        m2, l2, a2 = ops.decode_attention_partial(
            q[bi:bi + 1], view[bi:bi + 1], vview[bi:bi + 1], valid[bi], scale)
        o2 = np.asarray(a2) / np.maximum(np.asarray(l2)[..., None], 1e-30)
        np.testing.assert_allclose(o1[bi:bi + 1], o2, atol=tol, rtol=tol)
        np.testing.assert_allclose(np.asarray(m1)[bi:bi + 1], np.asarray(m2),
                                   atol=tol, rtol=tol)


def test_decode_attention_fully_masked_shard():
    """Seq-sharded decode: an all-invalid shard must contribute zero weight."""
    q = jnp.ones((1, 2, 1, 64))
    k = jnp.ones((1, 2, 64, 64))
    v = jnp.ones((1, 2, 64, 64))
    m, l, acc = ops.decode_attention_partial(q, k, v, jnp.zeros(64, bool), 0.125)
    assert not np.isfinite(np.asarray(m)).any()
    np.testing.assert_allclose(np.asarray(l), 0.0)


@pytest.mark.parametrize("b,s,w", [(1, 8, 64), (2, 37, 200), (3, 128, 256),
                                   (1, 256, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_lru_scan_sweep(b, s, w, dtype):
    ks = jax.random.split(jax.random.key(b * s + w), 3)
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (b, s, w))).astype(dtype)
    bb = jax.random.normal(ks[1], (b, s, w)).astype(dtype)
    h0 = jax.random.normal(ks[2], (b, w)).astype(jnp.float32)
    h1, hT1 = ops.lru_scan(a, bb, h0)
    h2, hT2 = ref.lru_scan_ref(a, bb, h0)
    tol = 1e-5 if dtype == jnp.float32 else 0.05
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(hT1), np.asarray(hT2), atol=tol, rtol=tol)


def test_rglru_pallas_path_matches_scan():
    """Model-level: RG-LRU forward with the Pallas linear-scan kernel equals
    the associative_scan path."""
    import dataclasses

    from repro.configs import get_config
    from repro.configs.base import RGLRUConfig
    from repro.models import rglru as rglru_mod
    from repro.models.common import Dist, materialize, specs_of
    from jax.sharding import PartitionSpec as P

    cfg = dataclasses.replace(
        get_config("recurrentgemma-9b").reduced(), d_model=64, n_heads=4,
        rglru=RGLRUConfig(lru_width=0, conv_width=4))
    dist = Dist(tp=1, dp=1)
    mesh = compat.make_mesh((1, 1), ("data", "model"))
    defs = rglru_mod.rglru_defs(cfg, dist)
    params = materialize(defs, jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model), jnp.float32)
    outs = {}
    for up in (False, True):
        def f(params, x, up=up):
            out, _ = rglru_mod.rglru_forward(params, x, cfg, dist, use_pallas=up)
            return out
        outs[up] = np.asarray(jax.jit(compat.shard_map(
            f, mesh=mesh, in_specs=(specs_of(defs), P()), out_specs=P(),
            check_vma=False))(params, x))
    np.testing.assert_allclose(outs[True], outs[False], atol=1e-3, rtol=1e-3)
