"""Overlapped host/device engine loop: certification that the async loop
(dispatch decode block N+1 against block N's device futures, land tokens one
step late, predicted host state with EOS-surprise rollback) is bit-identical
to the blocking loop under greedy decoding.

Seeded Poisson-arrival workloads run twice on one engine — sync then
overlapped — and must produce the same per-request token streams, the same
global (rid, token) emission trace, and (without EOS) the same retire
order, across dense / paged / disagg schedulers and plain / speculative
decode.  The overload test exercises the EngineService's bounded queue:
shed requests are rejected before the scheduler sees them, and everything
admitted still decodes to its full budget (no slot corruption).
"""
import asyncio

import jax
import numpy as np
import pytest

from repro.configs import ParallelConfig, SamplingConfig, get_config
from repro.launch.frontend import EngineService, TokenStream
from repro.launch.mesh import make_local_mesh
from repro.runtime.engine import Engine
from repro.runtime.scheduler import (ContinuousScheduler, DisaggScheduler,
                                     PagedContinuousScheduler)

needs2 = pytest.mark.skipif(
    jax.device_count() < 2,
    reason="needs 2 devices (JAX_NUM_CPU_DEVICES/XLA_FLAGS)")


def greedy_engine(arch: str = "yi-9b", max_len: int = 64, parallel=None,
                  mesh=None, **kw) -> Engine:
    cfg = get_config(arch).reduced()
    return Engine(cfg=cfg,
                  parallel=parallel or ParallelConfig(tp=1, dp=1, remat=False),
                  sampling=SamplingConfig(greedy=True, top_k=1),
                  mesh=mesh or make_local_mesh(1, 1), max_len=max_len, **kw)


def poisson_requests(cfg, n=8, seed=0, lam=3.0, eos_id=None,
                     plen=(4, 20), max_new=(4, 12)):
    """Seeded Poisson arrival process on the virtual decode-step clock."""
    rng = np.random.default_rng(seed)
    arrival, reqs = 0, []
    for _ in range(n):
        p = rng.integers(0, cfg.vocab_size,
                         int(rng.integers(*plen))).astype(np.int32)
        reqs.append((p, int(rng.integers(*max_new)), eos_id, arrival))
        arrival += int(rng.poisson(lam))
    return reqs


def run_pair(make_sched, eng, reqs, check_retire_order=True,
             expect_landings=True, check_trace=True):
    """Run the same workload sync then overlapped; certify identity.

    ``check_trace`` additionally pins the GLOBAL (rid, token) emission
    interleave — it holds for dense/paged (admission drains the pipeline,
    so cross-request order is preserved) but not for disagg, whose
    chunk-prefill completions emit while a decode block is still in
    flight; there only the per-request streams are contractual."""
    results = []
    for overlap in (False, True):
        sched = make_sched(eng, overlap)
        events = []
        sched.on_token = lambda rid, t, ev=events: ev.append((rid, int(t)))
        for p, mn, eos, arr in reqs:
            sched.submit(p, mn, eos_id=eos, arrival_step=arr)
        done = sched.run()
        results.append((sched, done, events))
    (s0, d0, e0), (s1, d1, e1) = results
    assert not s0.overlap and s1.overlap
    if check_trace:
        assert e0 == e1, "global (rid, token) emission trace diverged"
    # per-request streamed tokens must be bit-identical regardless
    for rid in {r for r, _ in e0}:
        assert ([t for r, t in e0 if r == rid]
                == [t for r, t in e1 if r == rid]), \
            f"streamed tokens diverged for rid {rid}"
    m0, m1 = ({r.rid: r for r in d} for d in (d0, d1))
    assert sorted(m0) == sorted(m1)
    for rid in m0:
        np.testing.assert_array_equal(m0[rid].output, m1[rid].output)
    if check_retire_order:
        assert [r.rid for r in d0] == [r.rid for r in d1]
    assert s0.stats["landings"] == 0
    if expect_landings:
        assert s1.stats["landings"] > 0
    assert s1.stats["host_overlap_s"] >= 0.0
    return s0, s1


# ---------------------------------------------------------------------------
# Greedy stream certification: dense / paged / disagg x plain / spec
# ---------------------------------------------------------------------------


def test_overlap_identity_dense():
    eng = greedy_engine()
    reqs = poisson_requests(eng.cfg, n=8, seed=0)
    s0, s1 = run_pair(
        lambda e, ov: ContinuousScheduler(e, n_slots=3, block_steps=2,
                                          overlap=ov),
        eng, reqs)
    # the async loop actually ran ahead of the host
    assert s1.stats["max_dispatch_ahead"] >= 2
    assert s1.stats["dispatch_ahead_steps"] > 0


def test_overlap_identity_dense_spec():
    eng = greedy_engine(parallel=ParallelConfig(tp=1, dp=1, remat=False,
                                                spec_k=2))
    reqs = poisson_requests(eng.cfg, n=6, seed=1)
    # spec drafting serializes on the host-side drafter, so the spec path
    # drains and runs blocking even in overlap mode — identity must still
    # hold (and the loop must not deadlock on the drained pipeline)
    run_pair(
        lambda e, ov: ContinuousScheduler(e, n_slots=3, block_steps=2,
                                          overlap=ov),
        eng, reqs, expect_landings=False)


def test_overlap_identity_paged():
    eng = greedy_engine()
    reqs = poisson_requests(eng.cfg, n=8, seed=2)
    s0, s1 = run_pair(
        lambda e, ov: PagedContinuousScheduler(e, n_slots=3, block_steps=2,
                                               block_size=8, overlap=ov),
        eng, reqs)
    assert s1.stats["max_dispatch_ahead"] >= 2


def test_overlap_identity_paged_spec():
    eng = greedy_engine(parallel=ParallelConfig(tp=1, dp=1, remat=False,
                                                spec_k=2))
    reqs = poisson_requests(eng.cfg, n=6, seed=3)
    run_pair(
        lambda e, ov: PagedContinuousScheduler(e, n_slots=3, block_steps=2,
                                               block_size=8, overlap=ov),
        eng, reqs, expect_landings=False)


def test_overlap_identity_paged_chunked_prefill():
    eng = greedy_engine()
    reqs = poisson_requests(eng.cfg, n=6, seed=4, plen=(16, 40))
    run_pair(
        lambda e, ov: PagedContinuousScheduler(e, n_slots=3, block_steps=2,
                                               block_size=8, prefill_chunk=8,
                                               overlap=ov),
        eng, reqs)


@needs2
def test_overlap_identity_disagg():
    eng = greedy_engine(parallel=ParallelConfig(tp=1, dp=2, remat=False,
                                                disagg_prefill_shards=1),
                        mesh=make_local_mesh(2, 1))
    reqs = poisson_requests(eng.cfg, n=6, seed=5, plen=(12, 40))
    s0, s1 = run_pair(
        lambda e, ov: DisaggScheduler(e, n_slots=4, block_steps=2,
                                      block_size=8, prefill_chunk=8,
                                      prefill_shards=1, overlap=ov),
        eng, reqs, check_trace=False)
    assert s1.stats["landings"] > 0


# ---------------------------------------------------------------------------
# EOS-surprise rollback
# ---------------------------------------------------------------------------


def test_overlap_eos_rollback():
    """EOS is the one event the predicted host state cannot see coming: the
    loop has already dispatched ahead when the landing reveals the stop, so
    it must roll back the speculative admission state — and streams must
    STILL be bit-identical (retire order may lag, so it isn't asserted)."""
    eng = greedy_engine()
    probe = ContinuousScheduler(eng, n_slots=3, block_steps=2)
    # all arrivals at step 0: after the admission rounds every token is
    # produced by an overlapped decode block (no later admission's mixed
    # steps, which run blocking-exact and would absorb the EOS unsurprised)
    reqs = poisson_requests(eng.cfg, n=3, seed=6, lam=0.0,
                            max_new=(12, 16))
    for p, mn, eos, arr in reqs:
        probe.submit(p, mn, eos_id=eos, arrival_step=arr)
    done = probe.run()
    # pick the most common token from deep mid-stream positions as EOS so
    # requests stop early at positions the predictor cannot anticipate
    toks = np.concatenate([r.output[4:-2].ravel() for r in done])
    eos_id = int(np.bincount(toks).argmax())
    reqs = [(p, mn, eos_id, arr) for p, mn, _, arr in reqs]
    s0, s1 = run_pair(
        lambda e, ov: ContinuousScheduler(e, n_slots=3, block_steps=2,
                                          overlap=ov),
        eng, reqs, check_retire_order=False)
    assert any(r.output[-1] == eos_id for r in s0.done), \
        "workload failed to exercise early EOS stops"
    assert s1.stats["eos_rollbacks"] >= 1


# ---------------------------------------------------------------------------
# Overload shedding (service level, no HTTP)
# ---------------------------------------------------------------------------


def test_service_shed_requests_cleanly():
    eng = greedy_engine()
    sched = ContinuousScheduler(eng, n_slots=2, block_steps=2, overlap=True)
    service = EngineService(sched, max_pending=2, idle_wait_s=0.002)
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, eng.cfg.vocab_size, 8).tolist()
               for _ in range(6)]

    async def drive():
        loop = asyncio.get_running_loop()
        service.start()
        pairs = []
        for p in prompts:
            s = TokenStream(loop)
            pairs.append((service.try_submit(p, 5, None, s), s))
        outs = []
        for verdict, s in pairs:
            if verdict != "ok":
                outs.append(None)
                continue
            toks = []
            while (t := await s.next_token()) is not None:
                toks.append(t)
            outs.append(toks)
        return [v for v, _ in pairs], outs

    verdicts, outs = asyncio.run(drive())
    shed = verdicts.count("shed")
    # 6 instant submissions against a 2-request bound: overload is certain
    assert shed >= 1 and verdicts.count("ok") == 6 - shed
    assert sched.stats["shed_requests"] == shed
    # every admitted request decoded to its full budget — shedding never
    # reached the scheduler, so no slot was corrupted
    for verdict, out in zip(verdicts, outs):
        if verdict == "ok":
            assert len(out) == 5
    assert service.drain(timeout=60)
    assert len(sched.done) == 6 - shed
    assert all(len(r.output) == 5 for r in sched.done)
