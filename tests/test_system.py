"""End-to-end behaviour tests for the paper's system: train a small model,
serve batched requests through the scheduler, verify determinism."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ParallelConfig, SamplingConfig, get_config
from repro.launch.mesh import make_local_mesh
from repro.runtime.engine import Engine
from repro.runtime.scheduler import WaveScheduler


@pytest.fixture(scope="module")
def engine():
    cfg = get_config("yi-9b").reduced()
    return Engine(
        cfg=cfg,
        parallel=ParallelConfig(tp=1, dp=1, remat=False),
        sampling=SamplingConfig(top_k=8),
        mesh=make_local_mesh(1, 1),
        max_len=96,
    )


def test_generate_shapes(engine):
    prompts = np.random.default_rng(0).integers(
        0, engine.cfg.vocab_size, (2, 8)).astype(np.int32)
    out = engine.generate(prompts, max_new=6)
    assert out.shape == (2, 6)
    assert (out >= 0).all() and (out < engine.cfg.vocab_size).all()


def test_greedy_determinism():
    cfg = get_config("yi-9b").reduced()
    eng = Engine(cfg=cfg, parallel=ParallelConfig(tp=1, dp=1, remat=False),
                 sampling=SamplingConfig(greedy=True, top_k=1),
                 mesh=make_local_mesh(1, 1), max_len=64)
    prompts = np.random.default_rng(1).integers(0, cfg.vocab_size, (2, 8)).astype(np.int32)
    a = eng.generate(prompts, max_new=5)
    b = eng.generate(prompts, max_new=5)
    np.testing.assert_array_equal(a, b)


def test_scheduler_drains_queue(engine):
    sched = WaveScheduler(engine, batch_size=3)
    rng = np.random.default_rng(2)
    rids = [sched.submit(rng.integers(0, engine.cfg.vocab_size,
                                      rng.integers(3, 9)).astype(np.int32),
                         max_new=4)
            for _ in range(7)]
    done = sched.run()
    assert sorted(r.rid for r in done) == sorted(rids)
    for r in done:
        assert r.output is not None and len(r.output) == 4
        assert r.stats["wave_batch"] <= 3


def test_scheduler_eos_cut(engine):
    sched = WaveScheduler(engine, batch_size=2)
    prompt = np.arange(4, dtype=np.int32)
    sched.submit(prompt, max_new=8, eos_id=None)
    done = sched.run()
    assert len(done[0].output) == 8


def test_train_driver_end_to_end():
    """The quickstart path: a few hundred steps would run the same code;
    here 12 steps must not diverge and must track the synthetic stream."""
    from repro.launch.train import main as train_main

    hist = train_main(["--arch", "mamba2-1.3b", "--steps", "12",
                       "--global-batch", "4", "--seq-len", "64",
                       "--lr", "5e-3", "--log-every", "1"])
    assert hist[-1]["loss"] < hist[0]["loss"] + 0.05
    assert np.isfinite(hist[-1]["grad_norm"])


def test_serve_driver_end_to_end():
    from repro.launch.serve import main as serve_main

    done = serve_main(["--arch", "qwen2.5-14b", "--requests", "4",
                       "--batch", "2", "--max-new", "4", "--prompt-len", "8"])
    assert len(done) == 4


def test_multi_step_decode_matches_per_token(engine):
    """§Perf H4: fused n-token decode == the per-token loop (greedy)."""
    import numpy as np

    from repro.configs import ParallelConfig, SamplingConfig, get_config
    from repro.launch.mesh import make_local_mesh
    from repro.runtime.engine import Engine

    cfg = get_config("mamba2-1.3b").reduced()
    eng = Engine(cfg=cfg, parallel=ParallelConfig(tp=1, dp=1, remat=False),
                 sampling=SamplingConfig(greedy=True, top_k=1),
                 mesh=make_local_mesh(1, 1), max_len=64)
    prompts = np.random.default_rng(3).integers(0, cfg.vocab_size, (2, 6)).astype(np.int32)
    a = eng.generate(prompts, max_new=12, multi_step=False)
    b = eng.generate(prompts, max_new=12, multi_step=True)
    np.testing.assert_array_equal(a, b)
