"""Speculative decoding: the n-gram drafter, the fused multi-token verify
step (dense + paged), and scheduler integration.

Correctness ladder:

* **lockstep certification** — at every step, the verify program's emitted
  tokens equal what the plain width-1 decode program produces from the
  SAME state: each emitted token is the greedy argmax of its own
  conditional.  This is the per-step guarantee and it is exact.
* **end-to-end greedy bit-identity** — whole served streams match plain
  decode across dense/paged/GQA/int8-KV.  The verify and decode programs
  are different XLA compilations whose written KV can differ by ±1 bf16
  ulp, which on very long cycle-locked streams can flip a recurring greedy
  near-tie (the same caveat class the chunked-prefill suite documents for
  multi-device); these tests run in the regime where bitwise equality
  holds, and the lockstep test covers the per-step property at any length.
* **degradation floor** — a drafter that never matches still emits exactly
  one token per step (= plain decode), never zero, never corrupt.
* **rewind invariants** — cache position rows mark exactly the accepted
  extent; paged block tables truncate past the frontier and the allocator
  refcounts return to zero after drain.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ParallelConfig, SamplingConfig, get_config
from repro.runtime.drafter import NgramDrafter

try:
    import hypothesis
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:                                     # pragma: no cover
    hypothesis = None

BITWISE = jax.device_count() == 1


def greedy_engine(arch="yi-9b", max_len=128, parallel=None, n_kv_heads=None):
    from repro.launch.mesh import make_local_mesh
    from repro.runtime.engine import Engine

    cfg = get_config(arch).reduced()
    if n_kv_heads is not None:
        cfg = dataclasses.replace(cfg, n_kv_heads=n_kv_heads)
    return Engine(cfg=cfg,
                  parallel=parallel or ParallelConfig(tp=1, dp=1, remat=False),
                  sampling=SamplingConfig(greedy=True, top_k=1),
                  mesh=make_local_mesh(1, 1), max_len=max_len)


@pytest.fixture(scope="module")
def yi_engine():
    return greedy_engine()


def requests_mix(cfg, n=5, seed=0, pmin=8, pmax=24, mmin=10, mmax=30):
    rng = np.random.default_rng(seed)
    return [(rng.integers(0, cfg.vocab_size,
                          int(rng.integers(pmin, pmax + 1))).astype(np.int32),
             int(rng.integers(mmin, mmax + 1)), i * 2)
            for i in range(n)]


def serve(eng, reqs, make_sched, spec_k, **kw):
    sched = make_sched(eng, spec_k, **kw)
    for p, mn, arr in reqs:
        sched.submit(p, mn, arrival_step=arr)
    done = {r.rid: r for r in sched.run()}
    return sched, done


def assert_tokens_match(actual, desired):
    actual, desired = np.asarray(actual), np.asarray(desired)
    if BITWISE:
        np.testing.assert_array_equal(actual, desired)
        return
    assert actual.shape == desired.shape
    if len(actual):
        assert actual[0] == desired[0]


# ---------------------------------------------------------------------------
# Drafter
# ---------------------------------------------------------------------------


def test_drafter_continues_recent_ngram():
    d = NgramDrafter(3, ngram_max=3)
    hist = np.array([5, 6, 7, 8, 1, 2, 5, 6, 7], np.int32)
    # trailing 3-gram (5,6,7) occurred at the start, followed by 8, 1, 2
    np.testing.assert_array_equal(d.propose(hist), [8, 1, 2])


def test_drafter_prefers_most_recent_match():
    d = NgramDrafter(2, ngram_max=2)
    hist = np.array([1, 2, 9, 3, 1, 2, 4, 7, 1, 2], np.int32)
    # (1,2) occurs at 0 (-> 9) and 4 (-> 4): the recent one wins
    np.testing.assert_array_equal(d.propose(hist), [4, 7])


def test_drafter_falls_through_ngram_lengths():
    d = NgramDrafter(2, ngram_max=3)
    # no 3-gram or 2-gram repeats; 1-gram (7) repeats -> its continuation
    hist = np.array([7, 3, 1, 7], np.int32)
    np.testing.assert_array_equal(d.propose(hist), [3, 1])


def test_drafter_fallback_repeats_last_token():
    d = NgramDrafter(4)
    out = d.propose(np.array([1, 2, 3], np.int32))   # no repeats at all
    np.testing.assert_array_equal(out, [3, 3, 3, 3])


def test_drafter_pads_short_continuation():
    d = NgramDrafter(5, ngram_max=2)
    # (1,2) matched at position 0; the 4-token continuation [9,8,1,2] pads
    # to k=5 by repeating its tail
    hist = np.array([1, 2, 9, 8, 1, 2], np.int32)
    np.testing.assert_array_equal(d.propose(hist), [9, 8, 1, 2, 2])


if hypothesis is not None:

    @settings(max_examples=50, deadline=None)
    @given(st.lists(st.integers(0, 7), min_size=1, max_size=64),
           st.integers(1, 6), st.integers(1, 4))
    def test_drafter_properties(hist, k, nmax):
        """Shape/type invariants + proposals are deterministic and, when a
        real match exists, are genuine history continuations."""
        d = NgramDrafter(k, ngram_max=nmax)
        h = np.asarray(hist, np.int32)
        out = d.propose(h)
        assert out.shape == (k,) and out.dtype == np.int32
        np.testing.assert_array_equal(out, d.propose(h))   # deterministic
        assert set(out.tolist()) <= set(h.tolist())        # lookup, not invention


# ---------------------------------------------------------------------------
# Engine-level verify: lockstep certification + rewind invariants
# ---------------------------------------------------------------------------


def _admit(eng, B, plens, seed=3):
    rng0 = np.random.default_rng(seed)
    Lp = int(max(plens))
    prompts = np.zeros((B, Lp), np.int32)
    for i, L in enumerate(plens):
        motif = rng0.integers(0, eng.cfg.vocab_size, 5).astype(np.int32)
        prompts[i, :L] = np.tile(motif, -(-L // 5))[:L]
    tok, caches = eng.prefill_into_slots(
        eng.init_slot_caches(B), prompts, np.ones(B, bool),
        np.asarray(plens, np.int32), jax.random.key(7))
    return jnp.asarray(tok), caches


def test_verify_matches_decode_lockstep(yi_engine):
    """THE spec-decode guarantee, certified per step: from every reachable
    state, the verify program's position-0 conditional equals the width-1
    decode program's — numerically (the two are different XLA
    compilations, so logits agree to bf16-accumulation tolerance, not
    bitwise) and in argmax except where the top-2 gap is inside that
    tolerance (a genuine tie either greedy answer is correct for)."""
    from functools import partial
    from jax.sharding import PartitionSpec as P
    from repro import compat
    from repro.models import model as M
    from repro.runtime import kvcache

    eng = yi_engine
    ctx = eng.ctx
    B, K = 4, 4
    pspecs = M.param_specs(ctx)
    cspec = kvcache.cache_pspecs(ctx, kv_seq_shard=False, batched_pos=True)
    sm = partial(compat.shard_map, mesh=eng.mesh, check_vma=False)

    def dec_fwd(params, t, caches, pos):
        h, _, _ = M.forward(params, t[:, None], ctx, caches=caches,
                            cur_pos=pos, kv_seq_axis=None, last_only=True,
                            seq_sharded=False, skip_head=True)
        return M.lm_head_local(params, h, ctx)[:, -1]

    def ver_fwd(params, vt, caches, pos):
        h, _, _ = M.forward(params, vt, ctx, caches=caches, last_only=False,
                            skip_head=True, seq_sharded=True, start_pos=pos)
        return M.lm_head_local(params, h, ctx)[:, 0]

    jd = jax.jit(sm(dec_fwd, in_specs=(pspecs, P("data"), cspec, P("data")),
                    out_specs=P("data", None)))
    jv = jax.jit(sm(ver_fwd, in_specs=(pspecs, P("data", None), cspec,
                                       P("data")),
                    out_specs=P("data", None)))

    plens = np.array([20, 28, 24, 30], np.int32)
    tok, caches = _admit(eng, B, plens)
    pos = plens.copy()
    done = np.zeros(B, bool)
    rem = np.full(B, 60, np.int32)
    eos = np.full(B, -1, np.int32)
    drafter = NgramDrafter(K)
    hists = [[] for _ in range(B)]
    ties = 0
    for step in range(40):
        r = jax.random.fold_in(jax.random.key(11), step)
        vt = np.zeros((B, K + 1), np.int32)
        vt[:, 0] = np.array(tok)
        for i in range(B):
            hist = np.asarray(hists[i] or [int(np.array(tok)[i])], np.int32)
            vt[i, 1:] = drafter.propose(hist)
        ld = np.asarray(jd(eng.params, jnp.asarray(np.array(tok)),
                           caches, jnp.asarray(pos)))
        lv = np.asarray(jv(eng.params, jnp.asarray(vt), caches,
                           jnp.asarray(pos)))
        # bf16 activations feed fp32 logits: one bf16 ulp at this logit
        # scale is ~0.01-0.06, so that is the agreement floor between the
        # two compilations
        np.testing.assert_allclose(ld, lv, atol=0.02, rtol=0)
        for i in range(B):
            if ld[i].argmax() != lv[i].argmax():
                top2 = np.sort(ld[i])[-2:]
                assert top2[1] - top2[0] < 0.02       # genuine near-tie
                ties += 1
        was_done = np.array(done)
        tg, ne, nxt, caches, pos, done, rem = eng.verify_slots(
            caches, jnp.asarray(vt), pos, done, rem, eos, r)
        tg, ne = np.array(tg), np.array(ne)
        for i in range(B):
            if was_done[i]:
                assert ne[i] == 0
                continue
            assert 1 <= ne[i] <= K + 1
            hists[i].extend(tg[i, :ne[i]].tolist())
        tok = nxt
        pos, done, rem = np.array(pos), np.array(done), np.array(rem)
        if done.all():
            break
    assert ties <= 4       # flips are rare ties, not systematic drift


def _pos_rows(caches):
    """Stacked pos leaves -> (layers, B, S) int arrays, one per group."""
    return [np.asarray(g["sub0"]["pos"]) for g in caches]


def test_verify_rewind_marks_exact_extent(yi_engine):
    """After a verify step, each active row's position leaf marks exactly
    [0, pos + n_emit) valid — accepted drafts in, rejected drafts out."""
    eng = yi_engine
    B, K = 2, 4
    plens = np.array([12, 16], np.int32)
    tok, caches = _admit(eng, B, plens)
    vt = np.zeros((B, K + 1), np.int32)
    vt[:, 0] = np.array(tok)
    vt[:, 1:] = eng.cfg.vocab_size - 1     # deliberately unlikely drafts
    tg, ne, nxt, caches, pos, done, rem = eng.verify_slots(
        caches, jnp.asarray(vt), plens, np.zeros(B, bool),
        np.full(B, 20, np.int32), np.full(B, -1, np.int32),
        jax.random.key(0))
    ne, pos = np.array(ne), np.array(pos)
    assert (pos == plens + ne).all()
    for rows in _pos_rows(caches):
        for i in range(B):
            row = rows[:, i]                       # (layers, S)
            S = row.shape[-1]
            want = np.where(np.arange(S) < pos[i], np.arange(S), -1)
            np.testing.assert_array_equal(row,
                                          np.broadcast_to(want, row.shape))


def test_verify_frozen_rows_untouched(yi_engine):
    """done/admitting rows keep their cache bit-for-bit through a verify
    step (dense: per-row merge; their state must not advance)."""
    eng = yi_engine
    B, K = 2, 3
    plens = np.array([10, 14], np.int32)
    tok, caches = _admit(eng, B, plens)
    before = [np.asarray(x).copy() for x in jax.tree.leaves(caches)]
    done = np.array([False, True])
    vt = np.zeros((B, K + 1), np.int32)
    vt[:, 0] = np.array(tok)
    tg, ne, nxt, caches, pos, done2, rem = eng.verify_slots(
        caches, jnp.asarray(vt), plens, done, np.full(B, 10, np.int32),
        np.full(B, -1, np.int32), jax.random.key(1))
    assert int(np.array(ne)[1]) == 0
    assert int(np.array(pos)[1]) == plens[1]
    assert int(np.array(nxt)[1]) == int(vt[1, 0])
    after = jax.tree.leaves(caches)
    for b, a in zip(before, after):
        a = np.asarray(a)
        if b.ndim >= 3 and b.shape[1] == B:        # per-slot leaves (l, B, ...)
            np.testing.assert_array_equal(b[:, 1], a[:, 1])


def test_verify_exact_fit_at_cache_end(yi_engine):
    """A slot whose budget exactly fills the cache: verify writes at view
    positions past the cache end are DROPPED — they must not race the real
    write at S-1 (the clamped-scatter duplicate-index winner is undefined)
    — so the final emitted tokens still match plain decode exactly."""
    eng = yi_engine                                     # max_len = 128
    B, K = 2, 4
    S = eng.max_len
    plens = np.array([S - 4, S - 6], np.int32)          # 4 and 6 tokens left
    tok, caches = _admit(eng, B, plens)
    state = dict(tok=jnp.asarray(tok), pos=plens.copy(),
                 done=np.zeros(B, bool),
                 rem=(S - plens).astype(np.int32),
                 eos=np.full(B, -1, np.int32))
    # reference: plain decode to the very end from a copy of the state
    cD = jax.tree.map(jnp.copy, caches)
    tokD, posD = state["tok"], state["pos"].copy()
    doneD, remD = state["done"].copy(), state["rem"].copy()
    ref = [[] for _ in range(B)]
    for step in range(8):
        was_active = (~np.array(doneD)) & (np.array(remD) > 0)
        toks, cD, posD, doneD, remD = eng.decode_slots(
            cD, tokD, posD, doneD, remD, state["eos"],
            jax.random.fold_in(jax.random.key(2), step), n=1)
        tokD = toks[-1]
        for i in range(B):
            if was_active[i]:
                ref[i].append(int(np.array(tokD)[i]))
        if np.array(doneD).all():
            break
    # spec decode with always-rejected drafts: every step writes K+1
    # entries, the last ones crossing the cache end
    tokV, posV = state["tok"], state["pos"].copy()
    doneV, remV = state["done"].copy(), state["rem"].copy()
    got = [[] for _ in range(B)]
    for step in range(8):
        vt = np.full((B, K + 1), eng.cfg.vocab_size - 1, np.int32)
        vt[:, 0] = np.array(tokV)
        tg, ne, tokV, caches, posV, doneV, remV = eng.verify_slots(
            caches, jnp.asarray(vt), posV, doneV, remV, state["eos"],
            jax.random.fold_in(jax.random.key(2), step))
        tg, ne = np.array(tg), np.array(ne)
        for i in range(B):
            got[i].extend(tg[i, :ne[i]].tolist())
        posV, doneV, remV = np.array(posV), np.array(doneV), np.array(remV)
        if doneV.all():
            break
    for i in range(B):
        # device never advances past the cache; the frontier is exact
        assert posV[i] == S
        assert_tokens_match(np.asarray(got[i]), np.asarray(ref[i]))


# ---------------------------------------------------------------------------
# Serving-level: greedy bit-identity, degradation, eos, stats
# ---------------------------------------------------------------------------


def make_dense(eng, spec_k, **kw):
    from repro.runtime.scheduler import ContinuousScheduler
    return ContinuousScheduler(eng, n_slots=3, block_steps=4, spec_k=spec_k,
                               **kw)


def make_paged(eng, spec_k, **kw):
    from repro.runtime.scheduler import PagedContinuousScheduler
    return PagedContinuousScheduler(eng, n_slots=3, block_steps=4,
                                    spec_k=spec_k, block_size=8, **kw)


@pytest.mark.parametrize("make_sched", [make_dense, make_paged],
                         ids=["dense", "paged"])
def test_spec_greedy_identity(yi_engine, make_sched):
    """Greedy speculative decode serves token-identical streams to plain
    greedy decode, dense and paged, with staggered in-flight admission."""
    reqs = requests_mix(yi_engine.cfg, n=6, seed=0)
    _, base = serve(yi_engine, reqs, make_sched, 0)
    sched, spec = serve(yi_engine, reqs, make_sched, 4)
    assert sched.stats["spec_steps"] > 0
    for rid in base:
        assert_tokens_match(spec[rid].output, base[rid].output)


def test_spec_greedy_identity_gqa():
    eng = greedy_engine(n_kv_heads=2)              # grouped heads, g=2
    reqs = requests_mix(eng.cfg, n=4, seed=1)
    _, base = serve(eng, reqs, make_dense, 0)
    _, spec = serve(eng, reqs, make_dense, 4)
    for rid in base:
        assert_tokens_match(spec[rid].output, base[rid].output)


@pytest.mark.parametrize("make_sched", [make_dense, make_paged],
                         ids=["dense", "paged"])
def test_spec_greedy_identity_int8_kv(make_sched):
    eng = greedy_engine(parallel=ParallelConfig(tp=1, dp=1, remat=False,
                                                kv_quant=True))
    reqs = requests_mix(eng.cfg, n=4, seed=2)
    _, base = serve(eng, reqs, make_sched, 0)
    sched, spec = serve(eng, reqs, make_sched, 4)
    assert any(l.dtype == np.int8 for l in jax.tree.leaves(sched.caches))
    for rid in base:
        assert_tokens_match(spec[rid].output, base[rid].output)


class _NeverRight:
    """Drafter stub proposing a constant far-fetched token."""

    def __init__(self, k, t):
        self.k, self.t = k, t

    def propose(self, hist):
        return np.full(self.k, self.t, np.int32)


def test_zero_acceptance_degrades_to_one_token_per_step(yi_engine):
    """Worst case: every draft rejected -> every verify step emits exactly
    its 1-token floor (plain-decode behavior), and a solo request takes
    exactly max_new - 1 steps (the first token comes from prefill)."""
    eng = yi_engine
    from repro.runtime.scheduler import ContinuousScheduler
    rng = np.random.default_rng(5)
    sched = ContinuousScheduler(eng, n_slots=1, block_steps=1, spec_k=4)
    sched.drafter = _NeverRight(4, eng.cfg.vocab_size - 1)
    p = rng.integers(0, eng.cfg.vocab_size - 1, 12).astype(np.int32)
    sched.submit(p, max_new=24)
    done = sched.run()
    assert sched.stats["spec_accepted"] == 0
    assert sched.stats["spec_emitted"] == sched.stats["spec_slot_steps"]
    # 23 verify steps (1-token floor each) after the first token; admission
    # itself rides ONE fused mixed step (chunk-eligible prompts always take
    # the one-compile chunked path now), whose decode half counts too
    assert sched.stats["spec_steps"] == 23
    assert sched.stats["decode_steps"] == 24
    assert len(done[0].output) == 24
    solo = eng.generate(p[None], 24)[0]
    assert_tokens_match(done[0].output, solo)


def test_spec_eos_cut_inside_verify(yi_engine):
    """EOS appearing mid-run is honored inside the fused verify step: the
    stream cuts at EOS exactly as plain decode's does."""
    eng = yi_engine
    rng = np.random.default_rng(9)
    p = rng.integers(0, eng.cfg.vocab_size, 14).astype(np.int32)
    # pick the 6th token plain greedy decode emits as the EOS id, so the
    # spec run must stop exactly there
    ref = eng.generate(p[None], 30)[0]
    eos_id = int(ref[5])
    sA = make_dense(eng, 0)
    sA.submit(p, 30, eos_id=eos_id)
    base = {r.rid: r for r in sA.run()}
    sB = make_dense(eng, 4)
    sB.submit(p, 30, eos_id=eos_id)
    spec = {r.rid: r for r in sB.run()}
    assert_tokens_match(spec[0].output, base[0].output)
    flat = np.asarray(spec[0].output)
    assert flat[-1] == eos_id and (flat[:-1] != eos_id).all()


def test_spec_paged_refcounts_consistent(yi_engine):
    """Rewind + block-table truncation leave the allocator consistent:
    per-slot tables only reference live blocks while serving, everything
    drains to zero at the end, and shared-prefix refcounts survive."""
    eng = yi_engine
    from repro.runtime.scheduler import PagedContinuousScheduler
    rng = np.random.default_rng(13)
    shared = rng.integers(0, eng.cfg.vocab_size, 16).astype(np.int32)
    sched = PagedContinuousScheduler(eng, n_slots=3, block_steps=2,
                                     spec_k=4, block_size=8)
    for i in range(4):
        sfx = rng.integers(0, eng.cfg.vocab_size, 12).astype(np.int32)
        sched.submit(np.concatenate([shared, sfx]), max_new=20,
                     arrival_step=i)
    checked = {"n": 0}
    orig = sched._post_verify

    def check_and_truncate(active):
        orig(active)
        for i in active:
            blocks = sched.slot_blocks[i]
            shard = sched._shard_of(i)
            # table references exactly the owned blocks, all live
            assert all(sched.alloc.refcount(shard, b) >= 1 for b in blocks)
            np.testing.assert_array_equal(sched.bt[i, :len(blocks)], blocks)
            assert (sched.bt[i, len(blocks):] == 0).all()
            # truncated to the accepted frontier
            assert len(blocks) == -(-int(sched.pos[i]) // sched.bs)
            checked["n"] += 1

    sched._post_verify = check_and_truncate
    done = sched.run()
    assert checked["n"] > 0 and len(done) == 4
    assert sched.stats["shared_block_hits"] > 0
    assert sched.alloc.total_used() == 0


def test_spec_stats_and_itl_accounting(yi_engine):
    """request_summary reports tokens_per_step percentiles and spec rates;
    the ITL stream carries one sample per accepted token (multi-token
    steps divide their interval), so sample count matches emissions."""
    eng = yi_engine
    from repro.runtime.scheduler import ContinuousScheduler
    rng = np.random.default_rng(4)
    sched = ContinuousScheduler(eng, n_slots=2, block_steps=1, spec_k=4)
    motif = rng.integers(0, eng.cfg.vocab_size, 5).astype(np.int32)
    sched.submit(np.tile(motif, 4), max_new=40)
    sched.submit(np.tile(motif, 5), max_new=40, arrival_step=2)
    sched.run()
    summ = sched.request_summary()
    assert "tokens_per_step" in summ and "spec" in summ
    tps = summ["tokens_per_step"]
    assert 1.0 <= tps["p50"] <= 5.0 and tps["max"] <= 5.0
    sp = summ["spec"]
    assert 0.0 <= sp["acceptance_rate"] <= 1.0
    assert sp["mean_tokens_per_step"] >= 1.0
    # one ITL sample per token emitted by decode-frontier steps — spec
    # verify steps AND the mixed admission steps' decode half (short
    # prompts stream through the one-compile chunked path now); the first
    # timestamped step seeds the clock and contributes none
    emitted_in_spec = sched.stats["spec_emitted"]
    itl_n = len(sched._itl)
    assert itl_n <= sched.stats["emitted"]
    assert itl_n >= emitted_in_spec - 2 * (sched.spec_k + 1)


def test_spec_capability_gating_recurrent():
    """Recurrent archs stay spec-ineligible under the capability registry:
    an EXPLICIT per-scheduler spec_k raises the uniform registry error,
    while the config-default path (engine-level spec_k, no constructor
    override) silently clamps to plain decode."""
    from repro.runtime.scheduler import ContinuousScheduler

    with pytest.raises(ValueError, match="does not support speculative"):
        ContinuousScheduler(greedy_engine("mamba2-1.3b", max_len=64),
                            n_slots=2, spec_k=4)
    eng = greedy_engine("mamba2-1.3b", max_len=64,
                        parallel=ParallelConfig(tp=1, dp=1, remat=False,
                                                spec_k=4))
    sched = ContinuousScheduler(eng, n_slots=2)
    assert sched.spec_k == 0 and sched.drafter is None


@pytest.mark.parametrize("arch", ["minicpm3-4b", "mixtral-8x7b"])
def test_spec_matches_plain_newly_eligible(arch):
    """MLA latent caches (decode-congruent two-dot verify chunk) and
    sliding-window ring caches (spec_k slack entries so rejected drafts
    never clobber in-window history) verify speculative drafts now: served
    greedy streams are bit-identical to plain decode."""
    from repro.runtime.scheduler import ContinuousScheduler

    eng = greedy_engine(arch, max_len=96)
    reqs = requests_mix(eng.cfg, n=4, seed=13, mmin=8, mmax=16)

    def mk(e, k):
        return ContinuousScheduler(e, n_slots=2, block_steps=2, spec_k=k)

    _, base = serve(eng, reqs, mk, 0)
    sched, spec = serve(eng, reqs, mk, 4)
    assert sched.stats["spec_steps"] > 0
    for rid in base:
        assert_tokens_match(spec[rid].output, base[rid].output)


def test_spec_with_chunked_admission(yi_engine):
    """Spec decode composes with chunked prefill: long prompts stream
    chunks (decode advancing 1 token/step through the mixed program) and
    switch to multi-token verify once admitted — outputs unchanged."""
    eng = yi_engine
    from repro.runtime.scheduler import ContinuousScheduler
    rng = np.random.default_rng(21)
    reqs = [(rng.integers(0, eng.cfg.vocab_size, 40).astype(np.int32), 12, 0),
            (rng.integers(0, eng.cfg.vocab_size, 10).astype(np.int32), 20, 1)]

    def mk(e, k, **kw):
        return ContinuousScheduler(e, n_slots=2, block_steps=2,
                                   prefill_chunk=8, spec_k=k, **kw)

    _, base = serve(eng, reqs, mk, 0)
    sched, spec = serve(eng, reqs, mk, 4)
    assert sched.stats["chunked_admissions"] >= 1
    assert sched.stats["spec_steps"] > 0
    for rid in base:
        assert_tokens_match(spec[rid].output, base[rid].output)


# ---------------------------------------------------------------------------
# Verify-width kernel specialization (interpret mode)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("Sq", [2, 3, 5, 8, 9])
def test_flash_verify_width_sweep(Sq):
    """The narrow-q specialization must match the streaming-softmax oracle
    at every verify width (spec_k+1 = 2..9), including sublane padding."""
    from repro.kernels import prefill_attention as pa
    from repro.models.attention import chunked_causal_attention

    b, hq, hkv, Sk, hd = 2, 4, 2, 96, 64
    ks = jax.random.split(jax.random.key(Sq), 3)
    q = jax.random.normal(ks[0], (b, hq, Sq, hd), jnp.float32)
    k = jax.random.normal(ks[1], (b, hkv, Sk, hd), jnp.float32)
    v = jax.random.normal(ks[2], (b, hkv, Sk, hd), jnp.float32)
    starts = np.array([17, 40], np.int32)
    qpos = (jnp.asarray(starts)[:, None]
            + jnp.arange(Sq, dtype=jnp.int32)[None, :])
    scale = 1.0 / np.sqrt(hd)
    out = pa.flash_verify(q, k, v, qpos, float(scale), block_k=32)
    ref = chunked_causal_attention(q, k, v, qpos,
                                   jnp.arange(Sk, dtype=jnp.int32), 0, scale)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("Sq", [3, 5])
def test_paged_narrow_q_matches_dense(Sq):
    """Verify-width queries through the paged kernel (which rounds narrow
    q tiles up to sublane groups in its shared clamp — no separate entry
    point) must agree with the dense verify kernel on the gathered view."""
    from repro.kernels import prefill_attention as pa

    b, hq, hkv, bs, nbps, hd = 2, 4, 2, 16, 4, 64
    S = bs * nbps
    ks = jax.random.split(jax.random.key(100 + Sq), 3)
    nb = 1 + b * nbps
    kp = jax.random.normal(ks[0], (nb, hkv, bs, hd), jnp.float32)
    vp = jax.random.normal(ks[1], (nb, hkv, bs, hd), jnp.float32)
    rng = np.random.default_rng(Sq)
    bt = jnp.asarray(rng.permutation(np.arange(1, nb))[: b * nbps]
                     .reshape(b, nbps).astype(np.int32))
    q = jax.random.normal(ks[2], (b, hq, Sq, hd), jnp.float32)
    starts = rng.integers(0, S - Sq + 1, size=b).astype(np.int32)
    qpos = (jnp.asarray(starts)[:, None]
            + jnp.arange(Sq, dtype=jnp.int32)[None, :])
    scale = 1.0 / np.sqrt(hd)
    out = pa.paged_flash_prefill(q, kp, vp, bt, qpos, float(scale))
    view = jnp.take(kp, bt, axis=0).transpose(0, 2, 1, 3, 4).reshape(
        b, hkv, S, hd)
    vview = jnp.take(vp, bt, axis=0).transpose(0, 2, 1, 3, 4).reshape(
        b, hkv, S, hd)
    ref = pa.flash_verify(q, view, vview, qpos, float(scale), block_k=bs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_spec_engine_flash_verify_path():
    """Spec decode through the Pallas flash-verify kernel (interpret mode)
    agrees with the scan path on a short well-separated greedy run.
    Admission is pinned to the legacy single-shot path (prefill_chunk=0) so
    the comparison isolates the VERIFY kernel — chunked-admission flash-vs-
    scan agreement has its own test in the chunked-prefill suite, and the
    two kernels' fp32-vs-bf16 accumulation can flip different near-ties."""
    outs = {}
    for flash in (False, True):
        eng = greedy_engine(parallel=ParallelConfig(
            tp=1, dp=1, remat=False, use_pallas=True, flash_prefill=flash))
        reqs = requests_mix(eng.cfg, n=3, seed=6, mmin=6, mmax=10)
        _, done = serve(eng, reqs, make_dense, 4, prefill_chunk=0)
        outs[flash] = {rid: done[rid].output for rid in done}
    for rid in outs[False]:
        assert_tokens_match(outs[True][rid], outs[False][rid])
