"""Hypothesis property tests on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (see requirements-dev.txt)")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.collectives import CommStats
from repro.core.zero_copy import count_copies
from repro.kernels import ops, ref
from repro.models.common import causal_mask, pad_to, window_mask

SETTINGS = dict(max_examples=25, deadline=None)


# ---------------------------------------------------------------------------
# §2.1b invariant: distributed top-k over vocab shards == global top-k
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    st.integers(1, 4),                  # batch
    st.integers(2, 16).map(lambda x: 2 * x),  # vocab per shard
    st.sampled_from([1, 2, 4, 8]),      # shards
    st.integers(1, 8),                  # k
    st.randoms(use_true_random=False),
)
def test_local_topk_then_merge_equals_global_topk(b, vs, shards, k, rnd):
    if k > vs:
        k = vs
    x = np.array([[rnd.gauss(0, 1) for _ in range(vs * shards)] for _ in range(b)],
                 dtype=np.float32)
    # simulate the per-shard local top-k + k-candidate merge
    cand_v, cand_i = [], []
    for s in range(shards):
        sl = x[:, s * vs:(s + 1) * vs]
        idx = np.argsort(-sl, axis=1)[:, :k]
        cand_i.append(idx + s * vs)
        cand_v.append(np.take_along_axis(sl, idx, 1))
    cand_v = np.concatenate(cand_v, 1)
    cand_i = np.concatenate(cand_i, 1)
    order = np.argsort(-cand_v, axis=1)[:, :k]
    merged_v = np.take_along_axis(cand_v, order, 1)
    # ground truth
    gt_idx = np.argsort(-x, axis=1)[:, :k]
    gt_v = np.take_along_axis(x, gt_idx, 1)
    np.testing.assert_allclose(merged_v, gt_v, rtol=1e-6)


@settings(**SETTINGS)
@given(st.integers(1, 3), st.integers(130, 600), st.integers(1, 16))
def test_pallas_topk_matches_lax(b, v, k):
    x = jax.random.normal(jax.random.key(b * 7919 + v), (b, v))
    vals, idx = ops.topk(x, k)
    rv, ri = ref.topk_ref(x, k)
    np.testing.assert_allclose(np.asarray(vals), np.asarray(rv), rtol=1e-6)


# ---------------------------------------------------------------------------
# masks
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(st.integers(1, 32), st.integers(1, 48), st.integers(0, 16), st.integers(1, 24))
def test_window_mask_subset_of_causal(q, kv, off, w):
    cm = np.asarray(causal_mask(q, kv, off))
    wm = np.asarray(window_mask(q, kv, off, w))
    assert not (wm & ~cm).any()                 # window ⊂ causal
    # each row allows at most w positions
    assert wm.sum(axis=1).max() <= w


@settings(**SETTINGS)
@given(st.integers(1, 1000), st.integers(1, 128))
def test_pad_to(x, m):
    p = pad_to(x, m)
    assert p >= x and p % m == 0 and p - x < m


# ---------------------------------------------------------------------------
# flash-decode LSE merge: splitting the cache must not change the result
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(st.integers(2, 6), st.integers(1, 4), st.randoms(use_true_random=False))
def test_lse_merge_split_invariance(splits, heads, rnd):
    S = splits * 16
    q = jax.random.normal(jax.random.key(1), (1, heads, 1, 32))
    k = jax.random.normal(jax.random.key(2), (1, heads, S, 32))
    v = jax.random.normal(jax.random.key(3), (1, heads, S, 32))
    valid = jnp.ones(S, bool)
    m, l, acc = ref.decode_attention_ref(q, k, v, valid, 0.2)
    full = np.asarray(acc / l[..., None])
    # split shards, merge with the LSE rule
    parts = []
    for s in range(splits):
        sl = slice(s * 16, (s + 1) * 16)
        parts.append(ref.decode_attention_ref(q, k[:, :, sl], v[:, :, sl],
                                              valid[sl], 0.2))
    ms = np.stack([np.asarray(p[0]) for p in parts])
    gm = ms.max(0)
    num = sum(np.asarray(p[2]) * np.exp(np.asarray(p[0]) - gm)[..., None] for p in parts)
    den = sum(np.asarray(p[1]) * np.exp(np.asarray(p[0]) - gm) for p in parts)
    np.testing.assert_allclose(num / den[..., None], full, rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# comm accounting
# ---------------------------------------------------------------------------


def test_comm_stats_accounting():
    from repro.core import collectives as cc

    mesh = compat.make_mesh((1, 1), ("data", "model"))
    from jax.sharding import PartitionSpec as P

    def f(x):
        y = cc.psum(x, "model", tag="t1")
        z = cc.all_gather(y, "model", gather_axis=0, tag="t2")
        return z

    with cc.comm_stats() as stats:
        jax.jit(compat.shard_map(f, mesh=mesh, in_specs=P(), out_specs=P(),
                              check_vma=False)).lower(
            jax.ShapeDtypeStruct((8, 4), jnp.float32))
    assert stats.count("all_reduce") == 1
    assert stats.count("all_gather") == 1
    assert stats.total_bytes("all_reduce") == 2 * 8 * 4 * 4   # wire factor 2x
    assert stats.total_bytes("all_gather") == 8 * 4 * 4


def test_count_copies_parser():
    hlo = """
  %copy.1 = f32[4]{0} copy(%x)
  %transpose.2 = f32[4,2]{1,0} transpose(%y), dimensions={1,0}
  %add.3 = f32[4] add(%a, %b)
    """
    c = count_copies(hlo)
    assert c["copy"] == 1 and c["transpose"] == 1
