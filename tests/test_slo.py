"""Overload-resilient serving: priority classes, SLO accounting, and the
adaptive degradation ladder.

Covers the tentpole end to end: priority validation and class-ordered
admission, the interactive slot/block reserves, lowest-class-youngest
preemption (allocator audited after every eviction, requeued streams
bit-identical to an unconstrained run), the shed-batch -> spec-off ->
tight-admission ladder engaging AND fully recovering under a synthetic
``burst:`` fault-plan wave, per-class latency/SLO summaries, and the
frontend's class-aware inbox (priority displacement with 429 verdicts,
reserve headroom, per-class /health counters).

The load-bearing invariant throughout: degradation changes WHICH requests
run and WHEN — admitted survivors' greedy streams stay bit-identical to
an unloaded run."""
import jax
import numpy as np
import pytest

from repro.configs import ParallelConfig, SamplingConfig, get_config
from repro.launch.frontend import EngineService, TokenStream
from repro.launch.mesh import make_local_mesh
from repro.runtime.engine import Engine
from repro.runtime.faults import FaultPlan
from repro.runtime.overload import LADDER, OverloadController
from repro.runtime.scheduler import (PRIORITY_CLASSES, PRIORITY_RANK,
                                     ContinuousScheduler, DisaggScheduler,
                                     PagedContinuousScheduler)

needs2 = pytest.mark.skipif(
    jax.device_count() < 2,
    reason="needs 2 devices (JAX_NUM_CPU_DEVICES/XLA_FLAGS)")


@pytest.fixture(scope="module")
def yi_engine():
    cfg = get_config("yi-9b").reduced()
    return Engine(cfg=cfg,
                  parallel=ParallelConfig(tp=1, dp=1, remat=False),
                  sampling=SamplingConfig(greedy=True, top_k=1),
                  mesh=make_local_mesh(1, 1), max_len=64)


def mixed_requests(cfg, n=10, seed=9, max_new=6):
    """Deterministic prompts with a fixed class rotation (i, s, b, s, ...)."""
    rng = np.random.default_rng(seed)
    rot = ("interactive", "standard", "batch", "standard")
    return [(rng.integers(0, cfg.vocab_size,
                          int(rng.integers(4, 12))).astype(np.int32),
             max_new, rot[i % len(rot)]) for i in range(n)]


def run_mixed(sched, reqs, arrival_every=0):
    rids = {}
    for i, (p, mn, cls) in enumerate(reqs):
        rid = sched.submit(p, mn, arrival_step=i * arrival_every,
                           priority=cls)
        rids[rid] = cls
    return {r.rid: r for r in sched.run()}, rids


# -- validation & controller unit behavior ---------------------------------

def test_priority_validation(yi_engine):
    sched = ContinuousScheduler(yi_engine, n_slots=2, block_steps=2)
    with pytest.raises(ValueError, match="unknown priority class"):
        sched.submit(np.arange(2, 8, dtype=np.int32), 4, priority="vip")
    assert PRIORITY_CLASSES == ("interactive", "standard", "batch")
    assert PRIORITY_RANK["interactive"] < PRIORITY_RANK["batch"]


def test_overload_controller_hysteresis():
    ctl = OverloadController(queue_hi=4, queue_lo=1, patience=2, cooldown=3)
    # one pressured round is not enough (patience 2)
    assert ctl.observe(10) == 0
    assert ctl.observe(10) == 1 and ctl.shed_classes == ("batch",)
    # dead band holds the level and resets both streaks
    assert ctl.observe(2) == 1
    assert ctl.observe(0) == 1 and ctl.observe(0) == 1
    assert ctl.observe(0) == 0          # third clear round restores
    for _ in range(8):
        ctl.observe(10)
    assert ctl.level == LADDER.index("tight-admission")
    assert ctl.spec_off and ctl.admission_cap == 1
    s = ctl.summary()
    assert s["max_level_name"] == "tight-admission"
    assert s["escalations"] == 4 and s["restorations"] == 1
    with pytest.raises(ValueError):
        OverloadController(queue_hi=1, queue_lo=2)


def test_burst_clause_parse_and_schedule():
    plan = FaultPlan.parse("burst:at=4,count=3,plen=6,new=5,cls=batch,"
                           "times=2,every=8")
    assert plan.burst(3) == []
    assert plan.burst(4) == [(3, 6, 5, "batch", 4)]
    # second fire is due at at + every, stamped with its SCHEDULED step
    # even when the observing round lands later
    assert plan.burst(15) == [(3, 6, 5, "batch", 12)]
    assert plan.burst(99) == []          # times exhausted
    with pytest.raises(ValueError, match="burst clause needs count="):
        FaultPlan.parse("burst:at=4")
    with pytest.raises(ValueError, match="unknown fault key"):
        FaultPlan.parse("burst:count=1,nope=2")


# -- class-aware admission --------------------------------------------------

def test_admission_prefers_interactive(yi_engine):
    """With everything arrived at once and 2 slots, both interactive
    requests are admitted in the first round even though they were
    submitted LAST."""
    sched = ContinuousScheduler(yi_engine, n_slots=2, block_steps=2)
    rng = np.random.default_rng(1)
    rids = {}
    for cls in ("batch", "standard", "interactive", "interactive"):
        p = rng.integers(0, yi_engine.cfg.vocab_size, 6).astype(np.int32)
        rids[sched.submit(p, 4, priority=cls)] = cls
    done = {r.rid: r for r in sched.run()}
    first = {cls for rid, cls in rids.items()
             if done[rid].stats["admitted_step"] == 0}
    assert first == {"interactive"}
    # FIFO preserved within a class: the two interactive keep rid order
    ia = [done[rid].stats["admitted_step"] for rid, c in rids.items()
          if c == "interactive"]
    assert ia == sorted(ia)
    assert all(r.finish_reason in ("stop", "length") for r in done.values())


def test_interactive_reserve_slots(yi_engine):
    """reserve_slots=1 on 2 slots: only one standard admits up front; the
    held-back slot serves the interactive arrival immediately."""
    sched = ContinuousScheduler(yi_engine, n_slots=2, block_steps=2,
                                reserve_slots=1)
    rng = np.random.default_rng(2)
    p = lambda: rng.integers(0, yi_engine.cfg.vocab_size, 6).astype(np.int32)
    s1 = sched.submit(p(), 8, priority="standard")
    s2 = sched.submit(p(), 8, priority="standard")
    it = sched.submit(p(), 4, arrival_step=2, priority="interactive")
    done = {r.rid: r for r in sched.run()}
    assert done[s1].stats["admitted_step"] == 0
    assert done[it].stats["admitted_step"] <= 4
    # the second standard had to wait for a slot to FREE, not just for its
    # arrival: it admits strictly after the interactive request
    assert (done[s2].stats["admitted_step"]
            > done[it].stats["admitted_step"])
    assert all(r.finish_reason in ("stop", "length") for r in done.values())


# -- preemption priority + audit + identity --------------------------------

def test_preempt_victims_lowest_class_youngest_first(yi_engine):
    """Overcommitted paged pool with mixed classes: every preemption victim
    is the worst-class / youngest-admission running request (never
    interactive while a batch slot exists), the allocator audits clean
    after every eviction, and every request's final stream is bit-identical
    to an uncontended run."""
    reqs = mixed_requests(yi_engine.cfg, n=8, seed=7, max_new=8)
    big = PagedContinuousScheduler(yi_engine, n_slots=3, block_steps=2,
                                   block_size=4, prefix_cache=False)
    ref, _ = run_mixed(big, reqs, arrival_every=2)

    sched = PagedContinuousScheduler(yi_engine, n_slots=3, block_steps=2,
                                     block_size=4, n_blocks=12,
                                     prefix_cache=False)
    victims = []
    orig = sched._preempt_youngest

    def spy(shard):
        running = {i: (PRIORITY_RANK[s.req.priority], s.admitted_step,
                       s.req.rid)
                   for i, s in enumerate(sched.slots)
                   if s.req is not None and sched._shard_of(i) == shard
                   and ((not sched.dones[i] and sched.remaining[i] > 0)
                        or s.chunk_next is not None)}
        before = {i: s.req.rid if s.req else None
                  for i, s in enumerate(sched.slots)}
        ok = orig(shard)
        if ok:
            evicted = [i for i, s in enumerate(sched.slots)
                       if before[i] is not None
                       and (s.req is None or s.req.rid != before[i])]
            assert len(evicted) == 1
            victims.append((running, running[evicted[0]]))
            sched.alloc.audit(expect_no_migration=True)
        return ok

    sched._preempt_youngest = spy
    done, rids = run_mixed(sched, reqs, arrival_every=2)
    assert sched.stats["preemptions"] >= 1
    for running, chosen in victims:
        assert chosen == max(running.values()), \
            "victim was not the lowest-class, youngest running request"
    sched.alloc.audit(expect_no_migration=True)
    # requeue-recompute preserves every greedy stream exactly
    for rid, r in done.items():
        assert r.finish_reason in ("stop", "length")
        np.testing.assert_array_equal(r.output, ref[rid].output)


# -- degradation ladder ----------------------------------------------------

def test_ladder_sheds_batch_and_recovers(yi_engine):
    """A burst: fault-plan wave drives the queue past the threshold; the
    ladder engages, batch is shed at admission, and once the wave drains
    the ladder walks all the way back to normal."""
    sched = ContinuousScheduler(
        yi_engine, n_slots=2, block_steps=2,
        fault_plan="burst:at=2,count=8,cls=batch,new=4",
        overload_opts={"enabled": True, "queue_hi": 4, "queue_lo": 1,
                       "patience": 1, "cooldown": 2})
    rng = np.random.default_rng(5)
    keep = [sched.submit(rng.integers(0, yi_engine.cfg.vocab_size, 6)
                         .astype(np.int32), 10, arrival_step=4 * i,
                         priority="interactive") for i in range(6)]
    done = {r.rid: r for r in sched.run()}
    st = sched.stats
    assert st["burst_injected"] == 8
    assert st["classes"]["batch"]["shed"] >= 1
    assert st["classes"]["interactive"]["shed"] == 0
    ov = sched.request_summary()["overload"]
    assert ov["max_level"] >= 1, "ladder never engaged"
    assert ov["level"] == 0, "ladder did not restore to normal"
    assert ov["escalations"] >= 1 and ov["restorations"] >= 1
    assert st["overload_transitions"] == ov["transitions"]
    for rid in keep:
        assert done[rid].finish_reason in ("stop", "length")


def test_burst_injection_deterministic(yi_engine):
    """Two runs of the same burst plan inject bit-identical traffic."""
    outs = []
    for _ in range(2):
        sched = ContinuousScheduler(
            yi_engine, n_slots=2, block_steps=2,
            fault_plan="burst:at=0,count=3,plen=6,new=5,cls=standard")
        sched.submit(np.arange(2, 8, dtype=np.int32), 4)
        done = sched.run()
        assert sched.stats["burst_injected"] == 3
        outs.append({r.rid: r.output for r in done})
    assert sorted(outs[0]) == sorted(outs[1])
    for rid in outs[0]:
        np.testing.assert_array_equal(outs[0][rid], outs[1][rid])


def test_spec_off_lever_token_identical(yi_engine):
    """Force the ladder to spec-off while speculative decoding is on: the
    lever must fire (spec_off_rounds > 0) without changing any stream
    relative to a plain unloaded run."""
    reqs = mixed_requests(yi_engine.cfg, n=8, seed=3, max_new=8)
    reqs = [(p, mn, "interactive") for p, mn, _ in reqs]  # nothing shed
    plain = ContinuousScheduler(yi_engine, n_slots=2, block_steps=2)
    ref, _ = run_mixed(plain, reqs)
    sched = ContinuousScheduler(
        yi_engine, n_slots=2, block_steps=2, spec_k=2,
        overload_opts={"enabled": True, "queue_hi": 2, "queue_lo": 1,
                       "patience": 1, "cooldown": 1})
    done, _ = run_mixed(sched, reqs)
    assert sched.stats["spec_off_rounds"] > 0
    assert sched.request_summary()["overload"]["max_level"] >= 2
    for rid, r in done.items():
        np.testing.assert_array_equal(r.output, ref[rid].output)


def test_overlap_degradation_identity(yi_engine):
    """Ladder + priorities under the overlapped engine loop: survivors stay
    bit-identical to a blocking unloaded run."""
    reqs = mixed_requests(yi_engine.cfg, n=10, seed=6, max_new=6)
    plain = ContinuousScheduler(yi_engine, n_slots=4, block_steps=2)
    ref, _ = run_mixed(plain, reqs, arrival_every=4)
    sched = ContinuousScheduler(
        yi_engine, n_slots=2, block_steps=2, overlap=True, reserve_slots=1,
        overload_opts={"enabled": True, "queue_hi": 3, "queue_lo": 1,
                       "patience": 1, "cooldown": 2})
    done, rids = run_mixed(sched, reqs)
    assert sched.request_summary()["overload"]["max_level"] >= 1
    survivors = [rid for rid, r in done.items()
                 if r.finish_reason in ("stop", "length")]
    assert survivors, "everything was shed"
    for rid in survivors:
        np.testing.assert_array_equal(done[rid].output, ref[rid].output)
    shed = [rid for rid, r in done.items() if r.finish_reason == "shed"]
    assert all(rids[rid] == "batch" for rid in shed)


# -- per-class telemetry ---------------------------------------------------

def test_class_summary_and_slo_attainment(yi_engine):
    sched = ContinuousScheduler(yi_engine, n_slots=3, block_steps=2,
                                slo_targets={"interactive": 60.0,
                                             "batch": 1e-9})
    done, rids = run_mixed(sched, mixed_requests(yi_engine.cfg, n=8))
    classes = sched.request_summary()["classes"]
    for cls in PRIORITY_CLASSES:
        n = sum(1 for c in rids.values() if c == cls)
        assert classes[cls]["requests"] == n
        assert classes[cls]["served"] == n
        assert classes[cls]["itl_s"]["p50"] > 0.0
        assert classes[cls]["ttft_s"]["p95"] >= classes[cls]["ttft_s"]["p50"]
    # a 60 s/token target is unmissable; a 1 ns target unmeetable
    assert classes["interactive"]["slo_attainment"] == 1.0
    assert classes["batch"]["slo_attainment"] == 0.0
    assert "slo_target_s" not in classes["standard"]
    # stats counters mirror the summary
    assert sched.stats["classes"]["interactive"]["served"] == \
        classes["interactive"]["served"]


# -- frontend class-aware inbox --------------------------------------------

class _Loop:
    """Minimal stand-in for the asyncio loop TokenStream schedules onto."""

    def call_soon_threadsafe(self, fn, *a):
        fn(*a)


def test_frontend_priority_displacement_and_reserve(yi_engine):
    sched = ContinuousScheduler(yi_engine, n_slots=2, block_steps=2)
    svc = EngineService(sched, max_pending=2, pending_reserve=1)
    # worker NOT started: submissions stay queued in the inbox
    prompt = [2, 3, 4, 5]
    streams = [TokenStream(_Loop()) for _ in range(4)]
    assert svc.try_submit(prompt, 4, None, streams[0],
                          priority="batch") == "ok"
    # the reserve keeps the last inbox slot for interactive
    assert svc.try_submit(prompt, 4, None, streams[1],
                          priority="standard") == "shed"
    assert svc.try_submit(prompt, 4, None, streams[1],
                          priority="interactive") == "ok"
    # full inbox: a newcomer displaces the strictly lower batch entry...
    assert svc.try_submit(prompt, 4, None, streams[2],
                          priority="standard") == "ok"
    assert streams[0].error is not None
    assert streams[0].error_status.startswith("429")
    assert streams[0].error_type == "overloaded_error"
    # ...but an equal-or-lower newcomer is shed, not a displacer
    assert svc.try_submit(prompt, 4, None, streams[3],
                          priority="standard") == "shed"
    assert sched.stats["classes"]["batch"]["shed"] == 1
    assert sched.stats["classes"]["standard"]["shed"] == 2
    assert sched.stats["shed_requests"] == 3


def test_frontend_batch_door_shed_under_degradation(yi_engine):
    sched = ContinuousScheduler(
        yi_engine, n_slots=2, block_steps=2,
        overload_opts={"enabled": True, "queue_hi": 1, "queue_lo": 1,
                       "patience": 1, "cooldown": 1})
    sched.overload_ctl.observe(5)          # force level 1 (shed-batch)
    assert sched.overload_level() == 1
    svc = EngineService(sched, max_pending=8)
    s = TokenStream(_Loop())
    assert svc.try_submit([2, 3, 4], 4, None, s, priority="batch") == "shed"
    assert svc.try_submit([2, 3, 4], 4, None, s,
                          priority="interactive") == "ok"
    assert sched.stats["classes"]["batch"]["shed"] == 1


# -- disagg ----------------------------------------------------------------

@needs2
def test_disagg_priority_classes_and_reserves():
    cfg = get_config("yi-9b").reduced()
    eng = Engine(cfg=cfg, parallel=ParallelConfig(tp=1, dp=2, remat=False),
                 sampling=SamplingConfig(greedy=True, top_k=1),
                 mesh=make_local_mesh(2, 1), max_len=64)
    reqs = mixed_requests(cfg, n=10, seed=8, max_new=6)
    plain = DisaggScheduler(eng, n_slots=4, block_steps=2, block_size=8,
                            prefill_chunk=8, prefill_shards=1,
                            prefix_cache=False)
    ref, _ = run_mixed(plain, reqs, arrival_every=4)
    sched = DisaggScheduler(
        eng, n_slots=4, block_steps=2, block_size=8, prefill_chunk=8,
        prefill_shards=1, prefix_cache=False, reserve_blocks=1,
        overload_opts={"enabled": True, "queue_hi": 4, "queue_lo": 1,
                       "patience": 1, "cooldown": 2})
    done, rids = run_mixed(sched, reqs)
    sched.alloc.audit()
    st = sched.stats
    assert st["classes"]["interactive"]["shed"] == 0
    assert sched.request_summary()["overload"]["max_level"] >= 1
    for rid, r in done.items():
        if r.finish_reason in ("stop", "length"):
            np.testing.assert_array_equal(r.output, ref[rid].output)
        else:
            assert r.finish_reason == "shed" and rids[rid] == "batch"
