"""Integration test for the multi-pod dry-run launcher (deliverable e).

Runs in a subprocess (dryrun.py forces 512 virtual devices before importing
jax) for one cheap combo per mesh and checks the recorded artifact schema.
"""
import json
import os
import subprocess
import sys

import pytest

HERE = os.path.dirname(__file__)


@pytest.mark.parametrize("flags", [[], ["--multi-pod"]])
def test_dryrun_one_combo(tmp_path, flags):
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.join(HERE, "..", "src")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "mamba2-1.3b", "--shape", "decode_32k",
         "--out", str(tmp_path)] + flags,
        capture_output=True, text=True, timeout=800, env=env,
        cwd=os.path.join(HERE, ".."),
    )
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    assert "All dry-run combinations compiled successfully" in r.stdout
    tag = "pod2x16x16" if flags else "pod16x16"
    rec = json.load(open(tmp_path / f"mamba2-1.3b__decode_32k__{tag}.json"))
    assert rec["chips"] == (512 if flags else 256)
    assert rec["flops"] > 0 and rec["bytes_accessed"] > 0
    assert rec["memory"]["alias_bytes"] > 0          # donated caches (§2.3)
    assert "collectives" in rec and rec["copies"]["copy"] >= 0
