"""Continuous-batching slot engine: correctness against the wave baseline
and against solo generation, across attention families (GQA, MLA, SSM,
RG-LRU ring window)."""
import numpy as np
import pytest

from repro.configs import ParallelConfig, SamplingConfig, get_config
from repro.launch.mesh import make_local_mesh
from repro.runtime.engine import Engine
from repro.runtime.scheduler import ContinuousScheduler, WaveScheduler


def greedy_engine(arch: str, max_len: int = 64) -> Engine:
    cfg = get_config(arch).reduced()
    return Engine(cfg=cfg, parallel=ParallelConfig(tp=1, dp=1, remat=False),
                  sampling=SamplingConfig(greedy=True, top_k=1),
                  mesh=make_local_mesh(1, 1), max_len=max_len)


@pytest.fixture(scope="module")
def yi_engine():
    return greedy_engine("yi-9b")


def test_matches_wave_token_for_token(yi_engine):
    """Equal-length prompts (the wave baseline conditions on right-padding
    for shorter rows, so equal lengths isolate the scheduling change), mixed
    max_new, some EOS cuts, staggered arrivals: greedy outputs must be
    IDENTICAL per request across both serving cores."""
    eng = yi_engine
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(7):
        p = rng.integers(0, eng.cfg.vocab_size, 8).astype(np.int32)
        reqs.append((p, int(rng.integers(2, 9)), None if i % 3 else 5,
                     (i // 3) * 2))

    wave = WaveScheduler(eng, batch_size=3)
    cont = ContinuousScheduler(eng, n_slots=3, block_steps=4)
    for sched in (wave, cont):
        for p, mn, eos, arr in reqs:
            sched.submit(p, mn, eos_id=eos, arrival_step=arr)
    wdone = {r.rid: r for r in wave.run()}
    cdone = {r.rid: r for r in cont.run()}
    assert sorted(wdone) == sorted(cdone) == list(range(len(reqs)))
    for rid in wdone:
        np.testing.assert_array_equal(wdone[rid].output, cdone[rid].output)
    # the staggered arrivals really were admitted into a live batch
    assert cont.stats["in_flight_admissions"] > 0
    assert cont.stats["admission_rounds"] >= 2


@pytest.mark.parametrize("arch", ["yi-9b", "minicpm3-4b", "mamba2-1.3b",
                                  "recurrentgemma-9b"])
def test_mixed_prompt_lengths_match_solo(arch, yi_engine):
    """Per-slot positions + padded admission prefill must reproduce each
    request EXACTLY as if it ran alone — covers GQA position masks, MLA
    latent cache, SSM state + conv tail masking, RG-LRU + ring window."""
    eng = yi_engine if arch == "yi-9b" else greedy_engine(arch)
    rng = np.random.default_rng(1)
    cont = ContinuousScheduler(eng, n_slots=2, block_steps=4)
    reqs = [(rng.integers(0, eng.cfg.vocab_size, int(l)).astype(np.int32), mn)
            for l, mn in ((5, 6), (9, 3), (4, 8))]
    for p, mn in reqs:
        cont.submit(p, mn)
    done = {r.rid: r for r in cont.run()}
    for rid, (p, mn) in enumerate(reqs):
        solo = eng.generate(p[None], mn)[0]
        np.testing.assert_array_equal(solo, done[rid].output)
    assert cont.stats["in_flight_admissions"] > 0


def test_int8_kv_slot_engine_matches_wave():
    """Quantized-cache coverage for the slot path: k_scale/v_scale leaves
    must be reset on admission and merged per slot, so a reused slot starts
    bit-identical to a fresh wave cache.  Greedy outputs must match the
    wave baseline token-for-token with kv_quant=True."""
    cfg = get_config("yi-9b").reduced()
    eng = Engine(cfg=cfg,
                 parallel=ParallelConfig(tp=1, dp=1, remat=False, kv_quant=True),
                 sampling=SamplingConfig(greedy=True, top_k=1),
                 mesh=make_local_mesh(1, 1), max_len=64)
    rng = np.random.default_rng(4)
    reqs = [(rng.integers(0, cfg.vocab_size, 8).astype(np.int32),
             int(rng.integers(2, 9))) for _ in range(5)]
    wave = WaveScheduler(eng, batch_size=2)
    cont = ContinuousScheduler(eng, n_slots=2, block_steps=4)
    for sched in (wave, cont):
        for p, mn in reqs:
            sched.submit(p, mn)
    wdone = {r.rid: r for r in wave.run()}
    cdone = {r.rid: r for r in cont.run()}
    for rid in wdone:
        np.testing.assert_array_equal(wdone[rid].output, cdone[rid].output)
    # slot reuse happened (5 requests through 2 slots), with a quantized cache
    assert cont.stats["admission_rounds"] >= 2
    import jax
    assert any(l.dtype == np.int8 for l in jax.tree.leaves(cont.caches))


def test_streaming_and_stats(yi_engine):
    eng = yi_engine
    rng = np.random.default_rng(2)
    streamed = []
    cont = ContinuousScheduler(eng, n_slots=2, block_steps=2,
                               on_token=lambda rid, t: streamed.append((rid, t)))
    rids = [cont.submit(rng.integers(0, eng.cfg.vocab_size, 6).astype(np.int32),
                        max_new=4) for _ in range(3)]
    done = {r.rid: r for r in cont.run()}
    assert sorted(done) == sorted(rids)
    for rid, r in done.items():
        assert len(r.output) == 4
        assert r.stats["emitted"] == 4
        assert "ttft_s" in r.stats and "queue_s" in r.stats
        # the stream saw exactly this request's tokens, in order
        got = [t for sid, t in streamed if sid == rid]
        assert got == r.output.tolist()
    assert cont.stats["emitted"] == 12


def test_rejects_oversized_and_tiny_requests(yi_engine):
    cont = ContinuousScheduler(yi_engine, n_slots=2)
    with pytest.raises(ValueError):
        cont.submit(np.arange(60, dtype=np.int32), max_new=10)  # 60+10 > 64
    with pytest.raises(ValueError):
        cont.submit(np.arange(1, dtype=np.int32), max_new=2)


def test_rejects_longer_than_window_prompts():
    """Admission right-pads to a bucket; a ring (sliding-window) cache keeps
    the LAST S tokens of the padded batch, so prompts longer than the window
    cache must be refused rather than silently losing in-window history."""
    eng = greedy_engine("recurrentgemma-9b", max_len=96)   # reduced window=64
    cont = ContinuousScheduler(eng, n_slots=2)
    with pytest.raises(ValueError, match="sliding-window"):
        cont.submit(np.arange(70, dtype=np.int32), max_new=4)  # 70+4 <= 96
    # at the limit is fine: bucket caps at the window, slot == position
    assert cont._bucket(64) == 64


def test_wave_stats_count_actual_tokens(yi_engine):
    """Satellite fix: tok_per_s must come from delivered tokens (EOS-cut,
    per-request max_new), and a partial tail wave must not bill for the full
    configured batch."""
    eng = yi_engine
    rng = np.random.default_rng(3)
    wave = WaveScheduler(eng, batch_size=4)
    wave.submit(rng.integers(0, eng.cfg.vocab_size, 6).astype(np.int32),
                max_new=2)
    wave.submit(rng.integers(0, eng.cfg.vocab_size, 6).astype(np.int32),
                max_new=8)
    done = wave.run()
    emitted = sum(len(r.output) for r in done)
    assert emitted == 2 + 8
    for r in done:
        assert r.stats["wave_batch"] == 2
        assert r.stats["emitted"] == len(r.output)
        # throughput derived from emitted tokens, not batch * wave max_new
        expected = emitted / r.stats["wave_s"]
        assert r.stats["tok_per_s"] == pytest.approx(expected, rel=1e-6)
