"""Multi-device (8 virtual CPU devices) collective-schedule tests.

Each check runs in a SUBPROCESS so this pytest process keeps its 1-device
view (jax locks the device count at first init)."""
import os
import subprocess
import sys

import pytest

HERE = os.path.dirname(__file__)
CHECKS = [
    "tp_equiv",
    "train_grads",
    "zero1_multidev",
    "topk_sync",
    "one_shot_sync",
    "kv_seq_shard",
    "embed_modes",
    "engine_tp",
]


@pytest.mark.parametrize("check", CHECKS)
def test_distributed(check):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(HERE, "..", "src")
    r = subprocess.run(
        [sys.executable, os.path.join(HERE, "dist_checks.py"), check],
        capture_output=True, text=True, timeout=900, env=env,
    )
    assert r.returncode == 0, f"{check} failed:\n{r.stdout[-3000:]}\n{r.stderr[-3000:]}"
    assert f"PASS {check}" in r.stdout
