"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches must
see 1 device (the dry-run sets its own 512-device flag in its own process,
and multi-device collective tests run via subprocess in test_distributed.py).
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro import compat


@pytest.fixture(scope="session")
def mesh11():
    return compat.make_mesh((1, 1), ("data", "model"))


def run_sharded(mesh, fn, in_specs, out_specs, *args):
    import functools

    return jax.jit(
        compat.shard_map(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_vma=False)
    )(*args)
