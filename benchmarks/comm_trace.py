"""Subprocess helper: trace a decode round at TP=N virtual devices and print
the collective schedule (JSON).  Run by benchmarks/run.py — keeps the parent
process at 1 device."""
import os
import sys

if __name__ == "__main__":
    tp = int(sys.argv[1]) if len(sys.argv) > 1 else 8
    os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={tp}"

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import json

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro import compat

from repro.configs import ParallelConfig, SamplingConfig, get_config
from repro.core import collectives as cc
from repro.launch.inputs import _globalize, _sds, rng_spec
from repro.models import model as M
from repro.runtime import kvcache
from repro.runtime.engine import make_decode_step


def trace_decode(arch: str, tp: int, **flags):
    cfg = get_config(arch).reduced()
    par = ParallelConfig(tp=tp, dp=1, remat=False, **flags)
    ctx = M.ModelCtx.make(cfg, par)
    mesh = compat.make_mesh((1, tp), ("data", "model"))
    pspecs = M.param_specs(ctx)
    p_in = jax.tree.map(
        lambda s, sp: _sds(s.shape, s.dtype, mesh, sp),
        M.param_shapes(ctx), pspecs,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    local = jax.eval_shape(lambda: M.init_caches(ctx, 2, 32))
    cspecs = kvcache.cache_pspecs(ctx)
    caches_in = _globalize(local, cspecs, mesh)
    step = make_decode_step(ctx, SamplingConfig(top_k=16))
    tshape = (2,) if cfg.n_codebooks == 1 else (2, cfg.n_codebooks)
    tok_spec = P("data") if cfg.n_codebooks == 1 else P("data", None)
    tok = _sds(tshape, jnp.int32, mesh, tok_spec)
    cur = _sds((), jnp.int32, mesh, P())
    with cc.comm_stats() as stats:
        jax.jit(compat.shard_map(
            step, mesh=mesh,
            in_specs=(pspecs, tok_spec, cspecs, P(), P()),
            out_specs=(tok_spec, cspecs), check_vma=False,
        )).lower(p_in, tok, caches_in, cur, rng_spec(mesh))
    per_tag = {}
    for r in stats.records:
        d = per_tag.setdefault(r.tag or r.kind, {"count": 0, "bytes": 0})
        d["count"] += 1
        d["bytes"] += r.bytes
    return {"per_tag": per_tag, "total_bytes": stats.total_bytes(),
            "n_collectives": stats.count()}


if __name__ == "__main__":
    arch = sys.argv[2] if len(sys.argv) > 2 else "yi-9b"
    flags = json.loads(sys.argv[3]) if len(sys.argv) > 3 else {}
    print(json.dumps(trace_decode(arch, tp, **flags)))
