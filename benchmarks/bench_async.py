"""Overlapped host/device engine loop vs the blocking loop under load.

Two workloads on one warm mid-size engine (d_model=512, 3 layers — big
enough per decode step that the block-end materialize in the blocking loop
pays a real wait on the final step's thunk tail):

* **host-blocked time per decode step** — a decode-heavy back-to-back batch
  (32 requests, all arriving at step 0, 32 slots) run R times per mode,
  interleaved sync/overlap.  The per-mode estimate is the MIN over repeats
  (the standard noise-filtering estimator for microbenchmarks: scheduler
  jitter only ever adds time).  The blocking loop materializes tokens at
  block end, right after the last dispatch returns, and waits out the
  final step's async tail; the overlapped loop lands tokens one block
  late, when the tail has long drained, so its wait is the bare copy
  floor.  The overlapped loop must strictly reduce the per-step blocked
  time, and its ``host_overlap_fraction`` must be > 0.

* **goodput under a per-token SLO** — a seeded Poisson arrival process,
  SLO calibrated from a warm blocking run (1.25x its median per-request
  completion-latency per emitted token), goodput (fraction of requests
  meeting the SLO) reported for both modes.

Every run in both workloads must serve token-identical greedy streams —
asserted against the first sync run, not assumed.

Run directly:  PYTHONPATH=src python benchmarks/bench_async.py
(--no-json to skip writing BENCH_async.json)
"""
from __future__ import annotations

import dataclasses
import json
import os
import sys
import time

import numpy as np

try:
    from benchmarks import loadgen
except ImportError:           # executed directly: benchmarks/ is sys.path[0]
    import loadgen

HERE = os.path.dirname(__file__)
BENCH_JSON = os.path.join(HERE, "..", "BENCH_async.json")

ARCH = "yi-9b"
N_SLOTS = 32
N_REQUESTS = 32
PROMPT_MIN, PROMPT_MAX = 8, 16
MAX_NEW_MIN, MAX_NEW_MAX = 24, 32
BLOCK_STEPS = 8
PREFILL_CHUNK = 16
MAX_LEN = 64
REPEATS = 5
GOODPUT_LAM = 3.0
SLO_FACTOR = 1.25


def _mid_cfg():
    from repro.configs import get_config

    # reduced() caps at smoke scale where a decode step finishes inside the
    # dispatch call and there is nothing left to overlap; widen it so the
    # device still owes work when the blocking loop asks for its tokens
    return dataclasses.replace(get_config(ARCH).reduced(), d_model=512,
                               n_heads=8, n_kv_heads=8, d_ff=1536,
                               vocab_size=2048, n_layers=3)


def _requests(cfg, n, seed=0, lam=0.0):
    return loadgen.make_requests(cfg.vocab_size, n, seed=seed,
                                 prompt_len=(PROMPT_MIN, PROMPT_MAX),
                                 max_new=(MAX_NEW_MIN, MAX_NEW_MAX), lam=lam)


def _serve(eng, reqs, overlap: bool):
    from repro.runtime.scheduler import ContinuousScheduler

    sched = ContinuousScheduler(eng, n_slots=N_SLOTS,
                                block_steps=BLOCK_STEPS,
                                prefill_chunk=PREFILL_CHUNK, overlap=overlap)
    for p, mn, arr, _cls in reqs:
        sched.submit(p, mn, arrival_step=arr)
    t0 = time.perf_counter()
    done = sched.run()
    dt = time.perf_counter() - t0
    summ = sched.request_summary()
    emitted = sum(len(r.output) for r in done)
    # per-request completion latency per emitted token (arrivals are on the
    # virtual step clock, so every request is wall-submitted at t0)
    per_tok = np.array([(r.stats["finished_at"] - t0) / len(r.output)
                        for r in done if t0 < r.stats["finished_at"]])
    rec = {
        "overlap": overlap, "requests": len(done), "emitted": emitted,
        "wall_s": dt, "tok_per_s": emitted / dt,
        "per_token_latency_s": sorted(per_tok.tolist()),
        "overlap_stats": summ["overlap"],
    }
    return rec, {r.rid: np.asarray(r.output) for r in done}


def _check_identity(ref, out):
    assert sorted(ref) == sorted(out)
    for rid in ref:
        np.testing.assert_array_equal(ref[rid], out[rid])


def _goodput(rec, slo_s):
    lat = np.asarray(rec["per_token_latency_s"])
    return float((lat <= slo_s).mean()) if lat.size else 0.0


def main(emit=None, json_path=BENCH_JSON):
    emit = emit or (lambda n, u, d="": print(f"{n},{u:.3f},{d}"))
    sys.path.insert(0, os.path.join(HERE, "..", "src"))
    from repro.configs import ParallelConfig, SamplingConfig
    from repro.launch.mesh import make_local_mesh
    from repro.runtime.engine import Engine

    cfg = _mid_cfg()
    eng = Engine(cfg=cfg,
                 parallel=ParallelConfig(tp=1, dp=1, remat=False,
                                         prefill_chunk=PREFILL_CHUNK),
                 sampling=SamplingConfig(greedy=True, top_k=1),
                 mesh=make_local_mesh(1, 1), max_len=MAX_LEN)

    # -- host-blocked per step: decode-heavy back-to-back batch ------------
    reqs = _requests(cfg, N_REQUESTS, seed=0, lam=0.0)
    _, ref = _serve(eng, reqs, overlap=False)      # warm sync (compiles)
    _serve(eng, reqs, overlap=True)                # warm overlap
    runs = {False: [], True: []}
    for _ in range(REPEATS):
        for overlap in (False, True):              # interleaved repeats
            rec, out = _serve(eng, reqs, overlap)
            _check_identity(ref, out)
            runs[overlap].append(rec)

    def blk(rec):
        return rec["overlap_stats"]["host_blocked_per_step_s"]

    s_blk = min(blk(r) for r in runs[False])
    o_blk = min(blk(r) for r in runs[True])
    frac = float(np.median(
        [r["overlap_stats"]["host_overlap_fraction"] for r in runs[True]]))
    ahead = max(r["overlap_stats"]["max_dispatch_ahead"] for r in runs[True])
    assert frac > 0.0, "overlapped run hid no host time"
    assert o_blk < s_blk, (
        f"overlap must strictly reduce host-blocked time per step "
        f"({o_blk*1e6:.1f}us vs {s_blk*1e6:.1f}us)")

    # -- goodput under a per-token SLO at Poisson arrivals -----------------
    greqs = _requests(cfg, N_REQUESTS, seed=1, lam=GOODPUT_LAM)
    cal, _ = _serve(eng, greqs, overlap=False)     # warm + SLO calibration
    slo_s = SLO_FACTOR * float(np.median(cal["per_token_latency_s"]))
    sync, s_out = _serve(eng, greqs, overlap=False)
    over, o_out = _serve(eng, greqs, overlap=True)
    _check_identity(s_out, o_out)
    s_good, o_good = _goodput(sync, slo_s), _goodput(over, slo_s)

    line_s = (f"min of {REPEATS} runs; {sync['requests']} reqs, "
              f"{sync['emitted']} toks, {sync['tok_per_s']:.1f} tok/s; "
              f"goodput {s_good:.0%} @ {slo_s*1e3:.1f} ms/token SLO")
    line_o = (f"{frac:.0%} of host time hidden, dispatch-ahead max {ahead}; "
              f"goodput {o_good:.0%}")
    print(f"blocking   host-blocked {s_blk*1e6:.1f} us/step; {line_s}",
          flush=True)
    print(f"overlapped host-blocked {o_blk*1e6:.1f} us/step; {line_o}",
          flush=True)
    emit("async/sync_host_blocked_per_step", 1e6 * s_blk, line_s)
    emit("async/overlap_host_blocked_per_step", 1e6 * o_blk, line_o)
    emit("async/host_overlap_fraction", 1e6 * frac,
         f"{frac:.1%} of host wait hidden behind device compute")
    emit("async/goodput_sync", 1e6 * s_good,
         f"{s_good:.0%} of requests within {slo_s*1e3:.1f} ms/token")
    emit("async/goodput_overlap", 1e6 * o_good,
         f"{o_good:.0%} of requests within {slo_s*1e3:.1f} ms/token")
    if json_path:
        payload = {
            "meta": {"bench": "async_overlap_serving", "arch": ARCH,
                     "d_model": cfg.d_model, "n_layers": cfg.n_layers,
                     "n_requests": N_REQUESTS, "n_slots": N_SLOTS,
                     "block_steps": BLOCK_STEPS, "repeats": REPEATS,
                     "arrival_poisson_lambda": GOODPUT_LAM,
                     "slo_s_per_token": slo_s, "slo_factor": SLO_FACTOR,
                     "sync_host_blocked_per_step_s": s_blk,
                     "overlap_host_blocked_per_step_s": o_blk,
                     "host_blocked_reduction": (s_blk - o_blk) / s_blk,
                     "host_overlap_fraction": frac,
                     "goodput_sync": s_good, "goodput_overlap": o_good,
                     "token_identical_requests": len(ref)},
            "blocked_runs": {"sync": runs[False], "overlapped": runs[True]},
            "sync": sync,
            "overlapped": over,
        }
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {os.path.normpath(json_path)}")
    return {"sync": sync, "overlapped": over}


if __name__ == "__main__":
    main(json_path=None if "--no-json" in sys.argv else BENCH_JSON)
