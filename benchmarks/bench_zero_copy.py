"""Paper Fig. 3: minimize memory copy (zero-copy), on XLA terms.

(a) buffer donation: alias bytes of the decode step with and without donated
    KV caches — the donated bytes are buffers the runtime does NOT copy;
(b) layout-stable epilogue: HLO copy/transpose ops with the fused
    (b,h,s,hd)x(h,hd,d) out-projection vs the naive reshape-then-matmul.

Writes BENCH_zero_copy.json (--no-json to skip).
"""
from __future__ import annotations

import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro import compat

from repro.core.zero_copy import count_copies, fused_out_projection

BENCH_JSON = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_zero_copy.json")


def _decode_step_alias(donate: bool) -> int:
    from jax.sharding import PartitionSpec as P

    from repro.configs import ParallelConfig, SamplingConfig, get_config
    from repro.launch.mesh import make_local_mesh
    from repro.models import model as M
    from repro.runtime import kvcache
    from repro.runtime.engine import make_decode_step

    cfg = get_config("yi-9b").reduced()
    ctx = M.ModelCtx.make(cfg, ParallelConfig(tp=1, dp=1, remat=False))
    mesh = make_local_mesh(1, 1)
    params = M.init_params(ctx, jax.random.key(0))
    caches = M.init_caches(ctx, 2, 64)
    cspecs = kvcache.cache_pspecs(ctx)
    step = make_decode_step(ctx, SamplingConfig(top_k=8))
    f = compat.shard_map(step, mesh=mesh,
                      in_specs=(M.param_specs(ctx), P("data"), cspecs, P(), P()),
                      out_specs=(P("data"), cspecs), check_vma=False)
    jf = jax.jit(f, donate_argnums=(2,) if donate else ())
    c = jf.lower(params, jnp.zeros((2,), jnp.int32), caches, jnp.int32(8),
                 jax.random.key(0)).compile()
    return int(c.memory_analysis().alias_size_in_bytes)


def _epilogue_copies(fused: bool) -> dict:
    b, h, s, hd, d = 4, 8, 32, 64, 512
    x = jax.ShapeDtypeStruct((b, h, s, hd), jnp.bfloat16)
    w = jax.ShapeDtypeStruct((h, hd, d), jnp.bfloat16)

    if fused:
        fn = lambda x, w: fused_out_projection(x, w)
    else:
        def fn(x, w):  # naive: materialise (b,s,h*hd) then 2-D matmul
            xt = x.transpose(0, 2, 1, 3).reshape(b, s, h * hd)
            return xt @ w.reshape(h * hd, d)

    txt = jax.jit(fn).lower(x, w).compile().as_text()
    return count_copies(txt)


def main(emit=None, json_path=BENCH_JSON):
    emit = emit or (lambda n, u, d="": print(f"{n},{u:.3f},{d}"))
    a_on = _decode_step_alias(True)
    a_off = _decode_step_alias(False)
    emit("zero_copy/donated_alias_bytes", a_on,
         f"{a_on} B aliased in-place vs {a_off} without donation")
    c_f = _epilogue_copies(True)
    c_n = _epilogue_copies(False)
    emit("zero_copy/epilogue_copy_ops", c_f["copy"] + c_f["transpose"],
         f"fused {c_f} vs naive {c_n} (CPU backend; TPU layouts differ)")
    # the Pallas dual-matmul epilogue is the hard zero-copy artifact:
    # one fp32 VMEM tile, one HBM write, vs write+write+read+write naive.
    T, D = 4096, 5120
    saved = 3 * T * D * 2  # bytes of HBM traffic eliminated (bf16)
    from repro.kernels import ops as kops
    import numpy as np
    import time

    a = jnp.ones((256, 512), jnp.bfloat16)
    wa = jnp.ones((512, 256), jnp.bfloat16)
    b = jnp.ones((256, 1024), jnp.bfloat16)
    wb = jnp.ones((1024, 256), jnp.bfloat16)
    out = kops.fused_dual_matmul(a, wa, b, wb)  # correctness ping
    t0 = time.perf_counter()
    kops.fused_dual_matmul(a, wa, b, wb).block_until_ready()
    us = (time.perf_counter() - t0) * 1e6
    emit("zero_copy/fused_epilogue_kernel", us,
         f"dual-matmul accumulate; saves {saved/1e6:.1f} MB HBM traffic/layer "
         f"at (T,D)=({T},{D})")
    if json_path:
        payload = {
            "meta": {"bench": "zero_copy"},
            "donation_alias_bytes": {"donated": a_on, "undonated": a_off},
            "epilogue_copy_ops": {"fused": c_f, "naive": c_n,
                                  "note": "CPU backend; TPU layouts differ"},
            "fused_dual_matmul": {"us_per_call_interpret": us,
                                  "hbm_bytes_saved_per_layer": saved,
                                  "at_T": T, "at_D": D},
        }
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {os.path.normpath(json_path)}")


if __name__ == "__main__":
    main(json_path=None if "--no-json" in sys.argv else BENCH_JSON)
