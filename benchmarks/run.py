"""Benchmark harness — one bench per paper table/figure.

  token_latency       — paper §3 (the headline ms/token measurement)
  sync_minimization   — paper Fig. 1 (§2.1a token-ID broadcast, §2.1b top-k)
  one_shot            — paper Fig. 2 (§2.2 one sync per decoder layer)
  zero_copy           — paper Fig. 3 (§2.3 zero-copy handoff)
  continuous_batching — slot engine vs wave baseline on a straggler-heavy mix
  paged_kv            — paged block pool vs dense slot stripes (prefix reuse,
                        overcommitted pool, memory high-water mark)
  wquant              — weight-only quantization: bytes swept per token +
                        serving tok/s at bf16/int8/int4 (dense/paged x
                        plain/spec)
  disagg              — disaggregated prefill/decode pools: decode ITL p95
                        under concurrent prefill load vs unified chunked
                        admission + KV-block migration traffic
  async               — overlapped host/device engine loop vs blocking:
                        host-blocked time per decode step + goodput under a
                        per-token SLO at Poisson arrivals
  chaos               — fault-injected serving vs clean across all three
                        schedulers: survivor token identity (must be 100%),
                        survival rate, finish_reason mix, ITL degradation
  slo                 — priority-aware serving under a 2x-capacity burst vs
                        a class-blind baseline on the same trace:
                        interactive SLO attainment/p95, batch shedding,
                        degradation-ladder engage + recover, survivor
                        token identity
  roofline            — §Roofline terms from the dry-run artifacts (if present)

Prints ``name,us_per_call,derived`` CSV; every bench also writes its own
machine-readable ``BENCH_*.json`` at the repo root (seed benches included).
"""
from __future__ import annotations

import os
import sys
import time
import traceback

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

ROWS = []


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.3f},{derived}", flush=True)


def main() -> None:
    print("name,us_per_call,derived")
    t0 = time.time()
    from benchmarks import (bench_async, bench_chaos,
                            bench_continuous_batching, bench_disagg,
                            bench_one_shot, bench_paged_kv, bench_prefill,
                            bench_slo, bench_specdecode,
                            bench_sync_minimization, bench_token_latency,
                            bench_wquant, bench_zero_copy)

    benches = [
        ("token_latency", bench_token_latency.main),
        ("sync_minimization", bench_sync_minimization.main),
        ("one_shot", bench_one_shot.main),
        ("zero_copy", bench_zero_copy.main),
        ("continuous_batching", bench_continuous_batching.main),
        ("paged_kv", bench_paged_kv.main),
        ("prefill", bench_prefill.main),
        ("spec_decode", bench_specdecode.main),
        ("wquant", bench_wquant.main),
        ("disagg", bench_disagg.main),
        ("async", bench_async.main),
        ("chaos", bench_chaos.main),
        ("slo", bench_slo.main),
    ]
    failures = []
    for name, fn in benches:
        try:
            fn(emit)
        except Exception as e:  # noqa: BLE001
            failures.append(name)
            traceback.print_exc()
            emit(f"{name}/FAILED", 0.0, repr(e))
    # roofline summary (only if the dry-run artifacts exist)
    try:
        from benchmarks.roofline import build_table

        rows = build_table()
        if rows:
            doms = {}
            for r in rows:
                doms[r["dominant"]] = doms.get(r["dominant"], 0) + 1
            emit("roofline/combos", len(rows), f"dominant terms: {doms}")
            worst = max(rows, key=lambda r: r["bound_est_s"])
            emit("roofline/worst_bound_s", worst["bound_est_s"] * 1e6,
                 f"{worst['arch']}x{worst['shape']} ({worst['dominant']})")
    except Exception:  # noqa: BLE001
        pass
    print(f"# total {time.time()-t0:.0f}s", flush=True)
    if failures:
        raise SystemExit(f"benches failed: {failures}")


if __name__ == "__main__":
    main()
