"""Paper Fig. 1: minimize synchronization — bytes per decode round.

Traces the decode step at TP=8 (subprocess, virtual devices) with the paper
techniques ON vs OFF and reports the collective bytes that cross the wire per
round on the embedding path (§2.1a) and the sampling path (§2.1b).

Writes BENCH_sync_minimization.json (--no-json to skip).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

HERE = os.path.dirname(__file__)
BENCH_JSON = os.path.join(HERE, "..", "BENCH_sync_minimization.json")


def trace(tp: int, arch: str, **flags) -> dict:
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, os.path.join(HERE, "comm_trace.py"), str(tp), arch,
         json.dumps(flags)],
        capture_output=True, text=True, timeout=600, env=env,
    )
    if r.returncode != 0:
        raise RuntimeError(r.stderr[-2000:])
    return json.loads(r.stdout.strip().splitlines()[-1])


def main(emit=None, json_path=BENCH_JSON):
    emit = emit or (lambda n, u, d="": print(f"{n},{u:.3f},{d}"))
    arch = "mixtral-8x7b"          # replicated-table arch: §2.1a is exact
    on = trace(8, arch, topk_sync=True, id_broadcast=True)
    off = trace(8, arch, topk_sync=False, id_broadcast=False)

    def path_bytes(t, tags):
        return sum(v["bytes"] for k, v in t["per_tag"].items() if k in tags)

    samp_on = path_bytes(on, ("topk_vals", "topk_idx"))
    samp_off = path_bytes(off, ("full_logits",))
    emb_on = path_bytes(on, ("embed_bcast", "embed_shard_merge"))
    emb_off = path_bytes(off, ("embed_bcast", "embed_shard_merge"))
    emit("sync_min/sampling_bytes_on", samp_on,
         f"{samp_off/max(samp_on,1):.1f}x fewer than full-gather {samp_off}B")
    emit("sync_min/embed_bytes_on", emb_on,
         f"baseline bcast {emb_off}B -> id-broadcast {emb_on}B")
    emit("sync_min/total_round_bytes", on["total_bytes"],
         f"{off['total_bytes']/max(on['total_bytes'],1):.2f}x reduction total "
         f"({off['total_bytes']}B -> {on['total_bytes']}B)")
    # full-scale projection (reduced configs shrink the vocab, hiding the
    # real O(vocab)->O(k*tp) ratio): qwen2.5 vocab=152064, k=40, tp=16, b=1
    from repro.configs import get_config

    vocab = get_config("qwen2.5-32b").vocab_size
    k, tp = 40, 16
    full_gather = vocab * 4                       # fp32 logits row
    topk_wire = k * tp * (4 + 4)                  # (val, idx) candidates
    emit("sync_min/fullscale_sampling_ratio", topk_wire,
         f"{full_gather/topk_wire:.0f}x fewer bytes at vocab={vocab}, k={k}, "
         f"tp={tp} ({full_gather}B -> {topk_wire}B per sequence)")
    if json_path:
        payload = {
            "meta": {"bench": "sync_minimization", "arch": arch, "tp": 8},
            "sampling_bytes": {"topk_sync_on": samp_on,
                               "full_gather_off": samp_off},
            "embed_bytes": {"id_broadcast_on": emb_on,
                            "activation_bcast_off": emb_off},
            "total_round_bytes": {"on": on["total_bytes"],
                                  "off": off["total_bytes"]},
            "fullscale_projection": {"vocab": vocab, "k": k, "tp": tp,
                                     "full_gather_bytes": full_gather,
                                     "topk_wire_bytes": topk_wire,
                                     "ratio": full_gather / topk_wire},
        }
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {os.path.normpath(json_path)}")


if __name__ == "__main__":
    sys.path.insert(0, os.path.join(HERE, "..", "src"))
    main(json_path=None if "--no-json" in sys.argv else BENCH_JSON)
