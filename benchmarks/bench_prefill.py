"""Chunked prefill: kernel microbench + decode-stall serving bench.

Two measurements, one machine-readable artifact (BENCH_prefill.json):

1. **flash vs scan prefill** — the fused Pallas flash-prefill kernel against
   the pure-JAX ``chunked_causal_attention`` streaming-softmax scan on one
   prefill attention shape (tok/s through the attention op).  NOTE: on this
   CPU container Pallas runs in interpret mode (the kernel body executes in
   Python), so the scan wins wall-clock here — the number documents the
   overhead honestly; the kernel's value is the fused single-pass program
   that lowers to Mosaic on a real TPU.

2. **decode-stall elimination** — a long-prompt/short-decode serving mix on
   the slot engine, chunked admission (fused mixed prefill/decode steps)
   vs whole-prompt admission.  The metric is decode inter-token latency
   DURING ADMISSION WINDOWS (p50/p95/max): whole-prompt admission stalls
   every in-flight decode for the full prompt's prefill; chunked admission
   bounds the stall at one chunk.

Run directly:  PYTHONPATH=src python benchmarks/bench_prefill.py
(--no-json to skip writing BENCH_prefill.json)
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

BENCH_JSON = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_prefill.json")


# ---------------------------------------------------------------------------
# 1. kernel micro: flash vs scan
# ---------------------------------------------------------------------------


def bench_kernel(b=1, hq=8, hkv=2, S=256, hd=64, iters=5):
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops
    from repro.models.attention import chunked_causal_attention

    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (b, hq, S, hd), jnp.bfloat16)
    k = jax.random.normal(ks[1], (b, hkv, S, hd), jnp.bfloat16)
    v = jax.random.normal(ks[2], (b, hkv, S, hd), jnp.bfloat16)
    qpos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (b, S))
    pos1 = jnp.arange(S, dtype=jnp.int32)
    scale = 1.0 / np.sqrt(hd)

    scan = jax.jit(lambda q, k, v: chunked_causal_attention(
        q, k, v, pos1, pos1, 0, scale))

    def timed(fn, *args):
        fn(*args).block_until_ready()          # compile + warm
        t0 = time.perf_counter()
        for _ in range(iters):
            out = fn(*args)
        out.block_until_ready()
        return (time.perf_counter() - t0) / iters

    t_scan = timed(scan, q, k, v)
    t_flash = timed(lambda q, k, v: ops.flash_prefill(q, k, v, qpos, scale),
                    q, k, v)
    toks = b * S
    rec = {
        "shape": {"b": b, "hq": hq, "hkv": hkv, "S": S, "hd": hd},
        "scan_s": t_scan, "flash_s": t_flash,
        "scan_tok_per_s": toks / t_scan, "flash_tok_per_s": toks / t_flash,
        "interpret_mode": True,
    }
    print(f"kernel     prefill attention {b}x{hq}x{S}x{hd}: "
          f"scan {toks/t_scan:.0f} tok/s, flash(interpret) "
          f"{toks/t_flash:.0f} tok/s "
          f"(interpret-mode Python overhead; flash wins on real TPUs)")
    return rec


# ---------------------------------------------------------------------------
# 2. serving: decode ITL during admission, chunked vs whole-prompt
# ---------------------------------------------------------------------------


def make_requests(cfg, n_requests, prompt_min, prompt_max, max_new,
                  arrival_every, seed=0):
    rng = np.random.default_rng(seed)
    return [(rng.integers(0, cfg.vocab_size,
                          int(rng.integers(prompt_min, prompt_max + 1)))
             .astype(np.int32), max_new, i * arrival_every)
            for i in range(n_requests)]


def run_serving(eng, reqs, n_slots, chunk):
    from repro.runtime.scheduler import ContinuousScheduler

    # block_steps=1: every decode step is its own dispatch, so a prompt's
    # admission stall lands on exactly one inter-token sample — the honest
    # per-token-latency setting (fused blocks would dilute the stall across
    # the block and hide exactly the effect this bench measures)
    sched = ContinuousScheduler(eng, n_slots=n_slots, block_steps=1,
                                prefill_chunk=chunk)
    for p, mn, arr in reqs:
        sched.submit(p, mn, arrival_step=arr)
    t0 = time.perf_counter()
    done = sched.run()
    dt = time.perf_counter() - t0
    emitted = sum(len(r.output) for r in done)
    summ = sched.request_summary()
    rec = {
        "prefill_chunk": chunk, "requests": len(done), "emitted": emitted,
        "wall_s": dt, "tok_per_s": emitted / dt if dt > 0 else float("inf"),
        "prefill_chunks": sched.stats["prefill_chunks"],
        "chunked_admissions": sched.stats["chunked_admissions"],
        "latency": summ,
    }
    return rec, {r.rid: r.output for r in done}


def run(arch="yi-9b", n_requests=10, n_slots=3, prompt_min=384,
        prompt_max=512, max_new=10, arrival_every=3, chunk=128, max_len=640):
    from repro.configs import ParallelConfig, SamplingConfig, get_config
    from repro.launch.mesh import make_local_mesh
    from repro.runtime.engine import Engine

    cfg = get_config(arch).reduced()
    eng = Engine(cfg=cfg, parallel=ParallelConfig(tp=1, dp=1, remat=False),
                 sampling=SamplingConfig(greedy=True, top_k=1),
                 mesh=make_local_mesh(1, 1), max_len=max_len)
    reqs = make_requests(cfg, n_requests, prompt_min, prompt_max, max_new,
                         arrival_every)
    # warm both paths (compile time out of the measurement)
    warm = reqs[: n_slots + 1]
    for c in (0, chunk):
        run_serving(eng, warm, n_slots, c)

    results, outputs = {}, {}
    for name, c in (("whole", 0), ("chunked", chunk)):
        results[name], outputs[name] = run_serving(eng, reqs, n_slots, c)
    # the two admission modes must serve identical tokens
    for rid in outputs["whole"]:
        np.testing.assert_array_equal(outputs["whole"][rid],
                                      outputs["chunked"][rid])
    return results


def main(emit=None, json_path=BENCH_JSON, **kw):
    kernel_rec = bench_kernel()
    results = run(**kw)
    for name, rec in results.items():
        lat = rec["latency"]
        adm = lat.get("decode_itl_admission_s", {})
        line = (f"{rec['requests']} reqs, {rec['emitted']} toks, "
                f"{rec['wall_s']:.2f}s; decode ITL during admission "
                f"p50 {adm.get('p50', 0)*1e3:.1f} ms, "
                f"p95 {adm.get('p95', 0)*1e3:.1f} ms, "
                f"max {adm.get('max', 0)*1e3:.1f} ms")
        print(f"{name:8s} {line}", flush=True)
        if emit is not None:
            emit(f"prefill/{name}_itl_admission_p95",
                 1e6 * adm.get("p95", 0), line)
    w = results["whole"]["latency"]["decode_itl_admission_s"]
    c = results["chunked"]["latency"]["decode_itl_admission_s"]
    imp = w["p95"] / c["p95"] if c["p95"] > 0 else float("inf")
    stall = w["max"] - c["max"]
    print(f"admission-window decode ITL p95: {imp:.2f}x better chunked; "
          f"max stall reduced by {stall*1e3:.1f} ms", flush=True)
    if json_path:
        payload = {
            "meta": {"bench": "chunked_prefill",
                     "itl_admission_p95_improvement": imp,
                     "decode_stall_max_reduction_s": stall, **kw},
            "kernel": kernel_rec,
            "serving": results,
        }
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {os.path.normpath(json_path)}")
    return results


if __name__ == "__main__":
    import sys

    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    main(json_path=None if "--no-json" in sys.argv else BENCH_JSON)
