"""Shared deterministic load generator for the serving benchmarks.

One seeded ``numpy`` Generator produces the whole trace, so a (seed,
parameters) pair names a reproducible workload that two runs — or two
scheduler modes under comparison — consume identically.

Draw-order contract (load-bearing: BENCH_async/BENCH_chaos traces predate
this module and must stay bit-identical).  Per request the generator
consumes, in order:

1. prompt length       — ``integers(lo, hi + 1)`` over the INCLUSIVE
                         ``prompt_len`` range;
2. prompt content      — ``integers(0, vocab, plen)``;
3. decode budget       — only when the ``max_new`` range is non-degenerate
                         (an int or ``(k, k)`` burns no draw);
4. Poisson gap         — only when ``lam > 0`` (the gap lands AFTER the
                         current request: the first arrival is step 0).

Priority classes draw from a SEPARATE rng stream derived from the seed,
so a class-aware trace carries the exact prompts/budgets/arrivals of its
class-blind baseline — the apples-to-apples property the SLO bench's
blind-vs-aware comparison rests on.

``arrival_fn`` (e.g. ``lambda i: 2 * (i // 3)``) replaces the Poisson
clock with a deterministic stride and burns no draws.
"""
from __future__ import annotations

from typing import Callable, Dict, List, NamedTuple, Optional, Tuple, Union

import numpy as np


class GenRequest(NamedTuple):
    prompt: np.ndarray
    max_new: int
    arrival: int            # virtual decode-step clock
    priority: str


def normalize_mix(class_mix) -> Optional[Tuple[List[str], List[float]]]:
    """``{"interactive": 1, "batch": 3}`` (or ``[(cls, w), ...]``) into
    ``(classes, probabilities)``; None passes through."""
    if not class_mix:
        return None
    items = (list(class_mix.items()) if isinstance(class_mix, dict)
             else [tuple(x) for x in class_mix])
    classes = [c for c, _ in items]
    weights = [float(w) for _, w in items]
    total = sum(weights)
    if total <= 0:
        raise ValueError("class mix weights must sum to > 0")
    return classes, [w / total for w in weights]


def make_requests(vocab: int,
                  n: int,
                  seed: int = 0,
                  *,
                  prompt_len: Tuple[int, int] = (8, 16),
                  max_new: Union[int, Tuple[int, int]] = (24, 32),
                  lam: float = 0.0,
                  arrival_fn: Optional[Callable[[int], int]] = None,
                  class_mix: Optional[Union[Dict[str, float],
                                            List[Tuple[str, float]]]] = None,
                  ) -> List[GenRequest]:
    """Generate ``n`` requests under the documented draw order."""
    rng = np.random.default_rng(seed)
    mix = normalize_mix(class_mix)
    cls_rng = np.random.default_rng((seed, 0xC1A55)) if mix else None
    if isinstance(max_new, int):
        max_new = (max_new, max_new)
    arrival = 0
    reqs: List[GenRequest] = []
    for i in range(n):
        plen = int(rng.integers(prompt_len[0], prompt_len[1] + 1))
        prompt = rng.integers(0, vocab, plen).astype(np.int32)
        mn = (int(rng.integers(max_new[0], max_new[1] + 1))
              if max_new[0] != max_new[1] else max_new[0])
        arr = arrival_fn(i) if arrival_fn is not None else arrival
        if arrival_fn is None and lam > 0.0:
            arrival += int(rng.poisson(lam))
        cls = (mix[0][int(cls_rng.choice(len(mix[0]), p=mix[1]))]
               if mix else "standard")
        reqs.append(GenRequest(prompt, mn, arr, cls))
    return reqs
