"""Weight-only quantization bench: bytes swept per token + serving tok/s.

Two measurements, one machine-readable artifact (BENCH_wquant.json):

1. **weight bytes swept per decode token** — computed from the param-tree
   shapes (``models.model.decode_weight_bytes``): every decode token reads
   every projection weight once, so stored bytes of the sweep set (packed
   values + scales vs bf16) ARE the per-token weight traffic on a
   bandwidth-bound decode.  Reported for the reduced bench config and,
   analytically, for the full-size qwen-72b shapes the paper serves.  The
   acceptance bar is int4-g128 >= 3.5x below bf16.

2. **serving tok/s** — the same request mix served at bf16 / int8 / int4
   across dense × paged backends and plain × speculative decode, with the
   greedy streams cross-checked for the acceptance invariant (identical
   across modes within each quantization).  HONESTY CAVEATS: this CPU
   container runs the pure-JAX dequant reference path (the fused Pallas
   kernels execute in interpret mode — Python per tile — which benchmarks
   the interpreter, not the program), so the dequant shows up as EXTRA
   compute per step and quantized tok/s is typically at or below bf16
   here.  The bandwidth win the bytes-swept column quantifies is realised
   by the fused kernels on hardware where the weight stream, not Python
   dispatch, is the bottleneck — exactly the regime of the source papers.

Run directly:  PYTHONPATH=src python benchmarks/bench_wquant.py
(--no-json to skip writing BENCH_wquant.json)
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

BENCH_JSON = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_wquant.json")

MODES = ("none", "int8", "int4")


def bytes_swept(arch: str, tp: int = 1):
    from repro.configs import ParallelConfig, get_config
    from repro.models import model as M

    cfg = get_config(arch)
    if arch != "qwen-72b":
        cfg = cfg.reduced()
    out = {}
    for mode in MODES:
        ctx = M.ModelCtx.make(cfg, ParallelConfig(
            tp=tp, dp=1, remat=False, weight_quant=mode, wq_group_size=128))
        out[mode] = M.decode_weight_bytes(ctx)
    out["ratio_int8"] = out["none"]["swept"] / out["int8"]["swept"]
    out["ratio_int4_g128"] = out["none"]["swept"] / out["int4"]["swept"]
    return out


def make_requests(cfg, n=6, seed=0):
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        motif = rng.integers(0, cfg.vocab_size, 4).astype(np.int32)
        plen = int(rng.integers(10, 25))
        prompt = np.tile(motif, -(-plen // 4))[:plen]
        reqs.append((prompt, int(rng.integers(8, 15)), i * 2))
    return reqs


def serve_one(eng, reqs, kind: str):
    from repro.runtime.scheduler import (ContinuousScheduler,
                                         PagedContinuousScheduler)

    if kind == "dense_plain":
        sched = ContinuousScheduler(eng, n_slots=3, block_steps=4,
                                    prefill_chunk=0)
    elif kind == "dense_spec":
        sched = ContinuousScheduler(eng, n_slots=3, block_steps=4,
                                    prefill_chunk=0, spec_k=4)
    elif kind == "paged_plain":
        sched = PagedContinuousScheduler(eng, n_slots=3, block_steps=4,
                                         prefill_chunk=0, block_size=8)
    else:
        sched = PagedContinuousScheduler(eng, n_slots=3, block_steps=4,
                                         prefill_chunk=0, spec_k=4,
                                         block_size=8)
    for p, mn, arr in reqs:
        sched.submit(p, mn, arrival_step=arr)
    t0 = time.perf_counter()
    done = sched.run()
    dt = time.perf_counter() - t0
    emitted = sum(len(r.output) for r in done)
    return ({"wall_s": dt, "emitted": emitted,
             "tok_per_s": emitted / dt if dt > 0 else float("inf")},
            {r.rid: r.output for r in done})


def run_serving(arch="yi-9b", max_len=96, seed=0):
    import jax

    from repro.configs import ParallelConfig, SamplingConfig, get_config
    from repro.launch.mesh import make_local_mesh
    from repro.runtime.engine import Engine

    cfg = get_config(arch).reduced()
    reqs = make_requests(cfg, seed=seed)
    kinds = ("dense_plain", "dense_spec", "paged_plain", "paged_spec")
    results = {}
    for mode in MODES:
        eng = Engine(cfg=cfg,
                     parallel=ParallelConfig(tp=1, dp=1, remat=False,
                                             weight_quant=mode),
                     sampling=SamplingConfig(greedy=True, top_k=1),
                     mesh=make_local_mesh(1, 1), max_len=max_len)
        per, streams = {}, {}
        for kind in kinds:
            serve_one(eng, make_requests(cfg, n=3, seed=seed + 1), kind)  # warm
            per[kind], streams[kind] = serve_one(eng, reqs, kind)
        # acceptance invariant: one quantization, one stream — every
        # scheduling mode and backend serves identical greedy tokens
        identical = all(
            np.array_equal(streams["dense_plain"][rid], streams[k][rid])
            for k in kinds for rid in streams["dense_plain"])
        results[mode] = {"runs": per, "streams_identical": identical}
        if mode != "none":
            base = results["none"]["runs"]
            for kind in kinds:
                per[kind]["vs_bf16"] = (per[kind]["tok_per_s"]
                                        / base[kind]["tok_per_s"])
    return results


def main(emit=None, json_path=BENCH_JSON, **kw):
    sweep = {"reduced_yi9b": bytes_swept("yi-9b"),
             "full_qwen72b": bytes_swept("qwen-72b")}
    for name, rec in sweep.items():
        line = (f"bf16 {rec['none']['swept']/2**20:.1f} MiB/token -> "
                f"int8 {rec['int8']['swept']/2**20:.1f} "
                f"({rec['ratio_int8']:.2f}x), "
                f"int4-g128 {rec['int4']['swept']/2**20:.1f} "
                f"({rec['ratio_int4_g128']:.2f}x)")
        print(f"{name:14s} {line}", flush=True)
        if emit is not None:
            emit(f"wquant/{name}_int4_ratio", rec["ratio_int4_g128"], line)
    assert sweep["reduced_yi9b"]["ratio_int4_g128"] >= 3.5
    assert sweep["full_qwen72b"]["ratio_int4_g128"] >= 3.5

    serving = run_serving(**kw)
    for mode, rec in serving.items():
        for kind, r in rec["runs"].items():
            extra = (f" ({r['vs_bf16']:.2f}x vs bf16)"
                     if "vs_bf16" in r else "")
            print(f"{mode:5s} {kind:12s} {r['tok_per_s']:8.1f} tok/s, "
                  f"{r['emitted']} toks in {r['wall_s']:.2f}s{extra}",
                  flush=True)
        assert rec["streams_identical"], f"{mode}: streams diverged"
        if emit is not None:
            emit(f"wquant/{mode}_dense_plain_tok_s",
                 rec["runs"]["dense_plain"]["tok_per_s"],
                 f"streams identical across modes: {rec['streams_identical']}")
    print("greedy streams bit-identical across dense/paged x plain/spec "
          "for every weight precision", flush=True)

    if json_path:
        payload = {
            "meta": {
                "bench": "weight_quant",
                "caveat": ("serving runs use the pure-JAX dequant reference "
                           "path on CPU (Pallas kernels are interpret-mode "
                           "here): dequant is EXTRA per-step compute, so "
                           "quantized tok/s ~ bf16 or below on this "
                           "container; bytes_swept is the hardware-bandwidth "
                           "model the fused kernels realise on real "
                           "accelerators.  quantized_ref_einsum flags the "
                           "packed bytes served via to_dense (w_o, MoE "
                           "expert blocks) whose realization additionally "
                           "needs dequant fused into the contraction — see "
                           "decode_weight_bytes docs and the ROADMAP "
                           "batched-kernel backlog item"),
                **kw,
            },
            "bytes_swept_per_token": sweep,
            "serving": serving,
        }
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {os.path.normpath(json_path)}")
    return sweep, serving


if __name__ == "__main__":
    import sys

    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    main(json_path=None if "--no-json" in sys.argv else BENCH_JSON)
