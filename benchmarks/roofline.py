"""Roofline derivation (deliverable (g)) from the dry-run artifacts.

Per (arch x shape) on the single-pod 16x16 mesh:

  compute term    = HLO_FLOPs_per_device / PEAK_FLOPS        (s)
  memory term     = HLO_bytes_per_device / HBM_BW            (s)
  collective term = wire_bytes_per_device / ICI_BW           (s)

cost_analysis() on the SPMD module reports PER-DEVICE flops/bytes;
wire bytes come from the HLO collective parse (ring estimates).

Hardware constants (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (we charge one link — a conservative upper bound on the
collective term; v5e has 4 links usable in a 2D torus).

MODEL_FLOPS: 6·N_active·tokens (train: fwd+bwd) or 2·N_active·tokens
(prefill/decode fwd), divided over chips; the ratio MODEL_FLOPS/HLO_FLOPs
shows how much compiled compute is "useful" (catches remat/redundancy).

The CPU-backend memory numbers include a known scan-staging artifact
(~2x per-device scanned params of spurious temp; measured in
EXPERIMENTS.md §Dry-run) — we report temp both raw and adjusted.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s
ICI_BW = 50e9                # B/s per link

SHAPE_TOKENS = {
    "train_4k": 4096 * 256,
    "prefill_32k": 32768 * 32,
    "decode_32k": 128,        # one token per sequence
    "long_500k": 1,
}
TRAIN_FACTOR = {"train": 6, "prefill": 2, "decode": 2}


def load_records(dryrun_dir: str, mesh_tag: str = "pod16x16") -> List[Dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, f"*__{mesh_tag}.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def analytic_memory_floor(rec: Dict, tp: int = 16, dp: int = 16) -> float:
    """Lower bound on per-device HBM traffic in seconds: weights read once
    (3x for train: fwd + bwd + remat-recompute) + decode KV-cache read +
    layer-boundary activations.  The HLO `bytes accessed` metric counts every
    op's operands as if nothing fused, so the truth lies between floor and
    bound; the floor is what a perfectly-fused TPU program would move."""
    params_dev = rec["model_params"] * 2 / tp          # bf16
    kind = rec["kind"]
    shape = rec["shape"]
    toks = SHAPE_TOKENS[shape]
    if kind == "decode":
        # cache bytes: stored per device in the dry-run record's argument size
        cache_dev = max(0, rec["memory"]["argument_bytes"] - params_dev)
        active_dev = rec["active_params"] * 2 / tp
        return (active_dev + cache_dev) / HBM_BW
    weights_passes = 3 if kind == "train" else 1
    acts = 0.0  # boundary activations are second-order vs score tensors
    return (weights_passes * params_dev + acts) / HBM_BW


def roofline_row(rec: Dict) -> Dict:
    chips = rec["chips"]
    flops_dev = rec["flops"]                      # per-device (SPMD module)
    bytes_dev = rec["bytes_accessed"]
    wire_dev = sum(v["wire_bytes"] for v in rec["collectives"].values())
    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_coll = wire_dev / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    tokens = SHAPE_TOKENS[rec["shape"]]
    factor = TRAIN_FACTOR[rec["kind"]]
    model_flops_dev = factor * rec["active_params"] * tokens / chips
    return {
        "arch": rec["arch"],
        "shape": rec["shape"],
        "kind": rec["kind"],
        "chips": chips,
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_memory_floor_s": analytic_memory_floor(rec),
        "t_collective_s": t_coll,
        "dominant": dominant,
        "hlo_gflops_dev": flops_dev / 1e9,
        "hbm_gb_dev": bytes_dev / 1e9,
        "wire_mb_dev": wire_dev / 1e6,
        "model_flops_ratio": model_flops_dev / max(flops_dev, 1.0),
        "bound_est_s": max(terms.values()),
    }


def build_table(dryrun_dir: str = "experiments/dryrun") -> List[Dict]:
    return [roofline_row(r) for r in load_records(dryrun_dir)]


def fmt_markdown(rows: List[Dict]) -> str:
    hdr = ("| arch | shape | compute (ms) | memory bound/floor (ms) | "
           "collective (ms) | dominant | useful-FLOP ratio |")
    sep = "|" + "---|" * 7
    lines = [hdr, sep]
    for r in sorted(rows, key=lambda r: (r["shape"], r["arch"])):
        lines.append(
            f"| {r['arch']} | {r['shape']} | {1e3*r['t_compute_s']:.3f} | "
            f"{1e3*r['t_memory_s']:.2f} / {1e3*r['t_memory_floor_s']:.2f} | "
            f"{1e3*r['t_collective_s']:.3f} | "
            f"**{r['dominant']}** | {r['model_flops_ratio']:.2f} |"
        )
    return "\n".join(lines)


def main():
    rows = build_table()
    os.makedirs("experiments", exist_ok=True)
    with open("experiments/roofline.json", "w") as f:
        json.dump(rows, f, indent=1)
    print(fmt_markdown(rows))
    # CSV for benchmarks/run.py
    with open("experiments/roofline.csv", "w") as f:
        cols = list(rows[0].keys())
        f.write(",".join(cols) + "\n")
        for r in rows:
            f.write(",".join(str(r[c]) for c in cols) + "\n")


if __name__ == "__main__":
    main()
