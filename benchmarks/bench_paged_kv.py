"""Paged KV pool vs dense slot stripes on shared-system-prompt traffic.

Every request carries the same system preamble (the chat-serving common
case) plus a private tail.  The dense slot engine re-prefills the preamble
for every admission and pins ``n_slots x max_len`` cache rows forever; the
paged backend prefills the shared blocks once, refcounts them across slots
(copy-on-write sharing), and its memory high-water mark tracks blocks
actually touched — with the pool deliberately sized BELOW the dense
footprint to show admission by occupancy.

Metrics land in BENCH_paged.json: aggregate tok/s, KV memory high-water
mark, prefill tokens computed vs saved by prefix reuse, TTFT / queue-wait
summaries.

Run directly:  PYTHONPATH=src python benchmarks/bench_paged_kv.py
"""
from __future__ import annotations

import os
import time

import numpy as np

BENCH_JSON = os.path.join(os.path.dirname(__file__), "..", "BENCH_paged.json")


def paged_bytes_hwm(caches, blocks_hwm: int, n_blocks: int) -> int:
    """Paged high-water mark: pool leaves scale with blocks actually
    touched; per-slot leaves (positions, recurrent state) are a fixed
    resident cost and count at full size."""
    from repro.runtime.kvcache import POOL_KEYS

    pool = fixed = 0

    def walk(sub):
        nonlocal pool, fixed
        for k, v in sub.items():
            if isinstance(v, dict):
                walk(v)
            elif k in POOL_KEYS:
                pool += v.size * v.dtype.itemsize
            else:
                fixed += v.size * v.dtype.itemsize

    for g in caches:
        walk(g)
    return int(pool * blocks_hwm / max(1, n_blocks)) + int(fixed)


def make_requests(cfg, n_requests: int, sys_len: int, tail_max: int,
                  max_new_head: int, max_new_tail: int, arrival_every: int,
                  seed: int = 0):
    rng = np.random.default_rng(seed)
    system = rng.integers(0, cfg.vocab_size, sys_len).astype(np.int32)
    reqs = []
    for i in range(n_requests):
        tail = rng.integers(0, cfg.vocab_size,
                            int(rng.integers(1, tail_max + 1))).astype(np.int32)
        max_new = int(rng.integers(max_new_tail, max_new_head + 1))
        reqs.append((np.concatenate([system, tail]), max_new,
                     i * arrival_every))
    return reqs


def run_one(sched_name: str, eng, reqs, slots: int, block_steps: int,
            block_size: int, n_blocks):
    from repro.runtime.scheduler import (ContinuousScheduler,
                                         PagedContinuousScheduler)

    try:
        from benchmarks.bench_continuous_batching import cache_bytes
    except ImportError:
        from bench_continuous_batching import cache_bytes

    if sched_name == "paged":
        sched = PagedContinuousScheduler(eng, n_slots=slots,
                                         block_steps=block_steps,
                                         block_size=block_size,
                                         n_blocks=n_blocks)
    else:
        sched = ContinuousScheduler(eng, n_slots=slots,
                                    block_steps=block_steps)
    for prompt, max_new, arrival in reqs:
        sched.submit(prompt, max_new, arrival_step=arrival)
    t0 = time.perf_counter()
    done = sched.run()
    dt = time.perf_counter() - t0
    emitted = sum(len(r.output) for r in done)
    s = sched.stats
    rec = {
        "requests": len(done), "emitted": emitted, "wall_s": dt,
        "tok_per_s": emitted / dt if dt > 0 else float("inf"),
        "decode_steps": s["decode_steps"],
        "slot_util": s["active_slot_steps"] / max(1, s["slot_steps"]),
        "prefill_tokens": s["prefill_tokens"],
        "latency": sched.request_summary(),
        "kv_bytes_hwm": cache_bytes(sched.caches),
    }
    if sched_name == "paged":
        rec.update({
            "prefill_tokens_saved": s["prefill_tokens_saved"],
            "shared_block_hits": s["shared_block_hits"],
            "preemptions": s["preemptions"],
            "blocks_hwm": s["blocks_hwm"],
            "pool_blocks": sched.n_blocks,
            "kv_bytes_hwm": paged_bytes_hwm(sched.caches, s["blocks_hwm"],
                                            sched.n_blocks),
        })
    return rec, {r.rid: r.output for r in done}


def run(arch: str = "yi-9b", n_requests: int = 24, slots: int = 4,
        sys_len: int = 24, tail_max: int = 8, max_new_head: int = 24,
        max_new_tail: int = 4, arrival_every: int = 2, block_steps: int = 8,
        block_size: int = 8, max_len: int = 96, pool_frac: float = 0.5):
    from repro.configs import ParallelConfig, SamplingConfig, get_config
    from repro.launch.mesh import make_local_mesh
    from repro.runtime.engine import Engine

    cfg = get_config(arch).reduced()
    eng = Engine(cfg=cfg, parallel=ParallelConfig(tp=1, dp=1, remat=False),
                 sampling=SamplingConfig(greedy=True, top_k=1),
                 mesh=make_local_mesh(1, 1), max_len=max_len)
    reqs = make_requests(cfg, n_requests, sys_len, tail_max, max_new_head,
                         max_new_tail, arrival_every)
    # overcommitted pool: pool_frac of the dense n_slots x max_len footprint
    dense_blocks = slots * (-(-max_len // block_size))
    n_blocks = max(slots + 1, int(dense_blocks * pool_frac)) + 1
    warm = reqs[: slots + 1]
    for name in ("dense", "paged"):
        run_one(name, eng, warm, slots, block_steps, block_size, n_blocks)
    results = {}
    outputs = {}
    for name in ("dense", "paged"):
        results[name], outputs[name] = run_one(
            name, eng, reqs, slots, block_steps, block_size, n_blocks)
    for rid in outputs["dense"]:
        np.testing.assert_array_equal(outputs["dense"][rid],
                                      outputs["paged"][rid])
    results["paged"]["pool_vs_dense_capacity"] = (
        (n_blocks - 1) * block_size / (slots * max_len))
    return results


def main(emit=None, json_path=BENCH_JSON, **kw):
    try:
        from benchmarks.bench_continuous_batching import write_json
    except ImportError:
        from bench_continuous_batching import write_json

    results = run(**kw)
    for name, rec in results.items():
        line = (f"{rec['requests']} reqs, {rec['emitted']} toks, "
                f"{rec['wall_s']:.2f}s -> {rec['tok_per_s']:.1f} tok/s, "
                f"kv_hwm={rec['kv_bytes_hwm'] / 1024:.0f} KiB, "
                f"prefill={rec['prefill_tokens']}")
        if name == "paged":
            line += (f" (saved {rec['prefill_tokens_saved']}; "
                     f"preempt {rec['preemptions']})")
        print(f"{name:6s} {line}", flush=True)
        if emit is not None:
            emit(f"paged_kv/{name}",
                 1e6 * rec["wall_s"] / max(1, rec["emitted"]), line)
    saved = results["paged"]["prefill_tokens_saved"]
    total = results["dense"]["prefill_tokens"]
    mem = results["paged"]["kv_bytes_hwm"] / max(1, results["dense"]["kv_bytes_hwm"])
    print(f"prefix reuse skipped {saved}/{total} prefill tokens; "
          f"kv high-water {mem:.0%} of dense", flush=True)
    if json_path:
        write_json(json_path, results, {"bench": "paged_kv", **kw})
    return results


if __name__ == "__main__":
    import sys

    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    main(json_path=None if "--no-json" in sys.argv else BENCH_JSON)
