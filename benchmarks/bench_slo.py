"""Priority-aware serving under overload vs a class-blind baseline.

One deterministic ~2x-capacity Poisson burst (seeded loadgen trace: 20
burst arrivals + 4 spaced tail arrivals so the degradation ladder can
drain and restore) is served by every scheduler backend (dense
continuous, paged, disagg prefill/decode) in both engine-loop modes
(blocking, overlapped), twice per mode:

* **blind** — every request submitted as ``standard``: admission is FIFO,
  nothing is shed, no degradation.  The true classes ride in a side
  table so the same per-class metrics can be computed.
* **aware** — real priority classes + one interactive reserve slot (+ a
  block reserve on the paged pool) + the overload degradation ladder
  (queue-depth hysteresis; shed batch -> spec-off -> tight admission).

Both consume the IDENTICAL trace (loadgen draws classes from a side rng
stream), so the comparison is apples-to-apples.  Latency is measured on
the VIRTUAL decode-step clock — per-token latency of a request is
``(finished_step - arrival_step) / emitted`` — so every number here is
exactly reproducible run to run and across machines (scheduling under
the overload controller's queue-depth signal is fully deterministic;
wall-clock only enters the advisory ITL signal, unused here).  The
per-token SLO is calibrated per backend from an unloaded blocking
reference run (``SLO_FACTOR`` x its median steps/token, which is queue-
free) — the same reference provides the greedy streams for the identity
check.

Asserted, per backend x mode:

* interactive SLO attainment strictly better aware than blind, and
  interactive p95 per-token step latency strictly lower;
* the aware run sheds only batch (>= 1 shed; interactive sheds = 0);
* the ladder engages (max level >= 1) and fully recovers (final level 0);
* every request the aware run completed streams exactly the unloaded
  reference's tokens — degradation changes which/when, never what.

Runs in a subprocess with 2 virtual CPU devices (bench_chaos idiom) so
the disagg pool split is real.

Run directly:  PYTHONPATH=src python benchmarks/bench_slo.py
(--no-json to skip writing BENCH_slo.json)
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

import numpy as np

try:
    from benchmarks import loadgen
except ImportError:           # executed directly: benchmarks/ is sys.path[0]
    import loadgen

HERE = os.path.dirname(__file__)
BENCH_JSON = os.path.join(HERE, "..", "BENCH_slo.json")

ARCH = "yi-9b"
N_REQUESTS = 24
TAIL = 4                      # spaced arrivals after the burst (recovery)
TAIL_GAP = 18
N_SLOTS = 4
MAX_NEW = 8
MAX_LEN = 64
BLOCK_SIZE = 8
BLOCK_STEPS = 2
CHUNK = 8
LAM = 1.0                     # ~1 arrival/step vs ~0.5/step service rate
MIX = {"interactive": 1, "standard": 1, "batch": 2}
SLO_FACTOR = 2.0
OVERLOAD = {"enabled": True, "queue_hi": 8, "queue_lo": 2,
            "patience": 3, "cooldown": 2}
CLASSES = ("interactive", "standard", "batch")


def _trace(cfg):
    reqs = loadgen.make_requests(cfg.vocab_size, N_REQUESTS, seed=11,
                                 prompt_len=(6, 14), max_new=MAX_NEW,
                                 lam=LAM, class_mix=MIX)
    burst_end = reqs[N_REQUESTS - TAIL - 1].arrival
    tail = [r._replace(arrival=burst_end + 16 + TAIL_GAP * j)
            for j, r in enumerate(reqs[-TAIL:])]
    return reqs[:-TAIL] + tail


def _serve(sched, reqs, blind):
    info = {}
    for r in reqs:
        rid = sched.submit(r.prompt, r.max_new, arrival_step=r.arrival,
                           priority="standard" if blind else r.priority)
        info[rid] = (r.priority, r.arrival)
    done = {r.rid: r for r in sched.run()}
    return done, info


def _metrics(done, info, slo_steps):
    """Per-class SLO metrics over the BURST portion of the trace (the
    spaced tail exists to let the ladder drain and restore, not to be
    measured).  Per-token latency is deterministic virtual-clock steps
    from arrival to retirement."""
    burst = sorted(info)[:N_REQUESTS - TAIL]
    per = {}
    for cls in CLASSES:
        recs = [(done[rid], info[rid][1]) for rid in burst
                if info[rid][0] == cls]
        fin = [(r, arr) for r, arr in recs
               if r.finish_reason in ("stop", "length")]
        lat = sorted((r.stats["finished_step"] - arr) / r.stats["emitted"]
                     for r, arr in fin if r.stats.get("emitted", 0) > 0)
        per[cls] = {
            "requests": len(recs),
            "completed": len(fin),
            "shed": sum(1 for r, _ in recs if r.finish_reason == "shed"),
            "slo_attainment": (sum(1 for v in lat if v <= slo_steps)
                               / max(1, len(recs))),
            "p95_steps_per_token": (float(np.percentile(lat, 95))
                                    if lat else None),
        }
    return per


def _identity_pct(done, ref):
    fin = [r for r in done.values() if r.finish_reason in ("stop", "length")]
    same = sum(1 for r in fin
               if np.array_equal(r.output, ref[r.rid].output))
    return 100.0 * same / max(1, len(fin))


def inner() -> dict:
    from repro.configs import ParallelConfig, SamplingConfig, get_config
    from repro.launch.mesh import make_local_mesh
    from repro.runtime.engine import Engine
    from repro.runtime.scheduler import (ContinuousScheduler, DisaggScheduler,
                                         PagedContinuousScheduler)

    cfg = get_config(ARCH).reduced()
    eng1 = Engine(cfg=cfg, parallel=ParallelConfig(tp=1, dp=1, remat=False),
                  sampling=SamplingConfig(greedy=True, top_k=1),
                  mesh=make_local_mesh(1, 1), max_len=MAX_LEN)
    eng2 = Engine(cfg=cfg, parallel=ParallelConfig(tp=1, dp=2, remat=False),
                  sampling=SamplingConfig(greedy=True, top_k=1),
                  mesh=make_local_mesh(2, 1), max_len=MAX_LEN)
    trace = _trace(cfg)
    unloaded = [r._replace(arrival=12 * i) for i, r in enumerate(trace)]

    def make(kind, overlap, aware):
        kw = dict(n_slots=N_SLOTS, block_steps=BLOCK_STEPS, overlap=overlap)
        if aware:
            kw.update(reserve_slots=1, overload_opts=dict(OVERLOAD))
        if kind == "dense":
            return ContinuousScheduler(eng1, **kw)
        if aware:
            kw.update(reserve_blocks=2)
        kw.update(block_size=BLOCK_SIZE, prefix_cache=False)
        if kind == "paged":
            return PagedContinuousScheduler(eng1, **kw)
        return DisaggScheduler(eng2, prefill_chunk=CHUNK, prefill_shards=1,
                               **kw)

    out = {}
    for kind in ("dense", "paged", "disagg"):
        # unloaded blocking reference: greedy streams + SLO calibration.
        # Arrivals are spread far apart, so (finished_step - arrival) /
        # emitted is the backend's queue-free service cost in steps per
        # token; the SLO grants SLO_FACTOR of queueing headroom over it.
        ref, rinfo = _serve(make(kind, overlap=False, aware=False), unloaded,
                            blind=False)
        cal = sorted((r.stats["finished_step"] - rinfo[rid][1])
                     / r.stats["emitted"] for rid, r in ref.items())
        slo_steps = SLO_FACTOR * float(np.median(cal))
        rec = {"slo_steps_per_token": slo_steps, "modes": {}}
        for overlap in (False, True):
            mode = "overlapped" if overlap else "blocking"
            blind_done, info = _serve(
                make(kind, overlap, aware=False), trace, blind=True)
            aware_sched = make(kind, overlap, aware=True)
            aware_done, _ = _serve(aware_sched, trace, blind=False)
            if hasattr(aware_sched, "alloc"):
                aware_sched.alloc.audit(
                    expect_no_migration=(kind != "disagg"))
            blind_m = _metrics(blind_done, info, slo_steps)
            aware_m = _metrics(aware_done, info, slo_steps)
            ov = aware_sched.overload_ctl.summary()
            ident = _identity_pct(aware_done, ref)
            tag = f"{kind}/{mode}"
            bi, ai = blind_m["interactive"], aware_m["interactive"]
            assert ai["slo_attainment"] > bi["slo_attainment"], (
                f"{tag}: aware interactive attainment "
                f"{ai['slo_attainment']:.2f} not above blind "
                f"{bi['slo_attainment']:.2f}")
            assert ai["p95_steps_per_token"] < bi["p95_steps_per_token"], (
                f"{tag}: aware interactive p95 not below blind")
            assert aware_m["batch"]["shed"] >= 1, \
                f"{tag}: batch absorbed no shedding"
            assert ai["shed"] == 0, f"{tag}: interactive was shed"
            assert ov["max_level"] >= 1, f"{tag}: ladder never engaged"
            assert ov["level"] == 0, \
                f"{tag}: ladder did not recover (level {ov['level']})"
            assert ident == 100.0, \
                f"{tag}: aware survivors diverged from unloaded reference"
            rec["modes"][mode] = {
                "blind": blind_m, "aware": aware_m, "overload": ov,
                "aware_survivor_token_identity_pct": ident,
                "aware_classes": aware_sched.stats["classes"],
            }
        out[kind] = rec
    return out


def run_inner_subprocess() -> dict:
    env = dict(os.environ)
    env["JAX_NUM_CPU_DEVICES"] = "2"
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    env["PYTHONPATH"] = os.path.join(HERE, "..", "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, os.path.abspath(__file__), "--inner"],
                       capture_output=True, text=True, timeout=3000, env=env)
    if r.returncode != 0:
        raise RuntimeError(r.stderr[-2000:])
    return json.loads(r.stdout.strip().splitlines()[-1])


def main(emit=None, json_path=BENCH_JSON):
    emit = emit or (lambda n, u, d="": print(f"{n},{u:.3f},{d}"))
    slo = run_inner_subprocess()
    for kind, rec in slo.items():
        for mode, m in rec["modes"].items():
            bi, ai = m["blind"]["interactive"], m["aware"]["interactive"]
            ov = m["overload"]
            line = (f"interactive SLO {ai['slo_attainment']:.0%} aware vs "
                    f"{bi['slo_attainment']:.0%} blind "
                    f"@ {rec['slo_steps_per_token']:.1f} steps/token; "
                    f"p95 {ai['p95_steps_per_token']:.1f} vs "
                    f"{bi['p95_steps_per_token']:.1f} steps; "
                    f"batch shed {m['aware']['batch']['shed']}, "
                    f"ladder peak {ov['max_level_name']} "
                    f"({ov['escalations']} esc/{ov['restorations']} rst), "
                    f"identity {m['aware_survivor_token_identity_pct']:.0f}%")
            print(f"{kind:7s} {mode:10s} {line}", flush=True)
            emit(f"slo/{kind}_{mode}_interactive_attainment",
                 1e6 * ai["slo_attainment"], line)
    if json_path:
        payload = {"meta": {"bench": "slo_priority_serving", "arch": ARCH,
                            "n_requests": N_REQUESTS, "n_slots": N_SLOTS,
                            "max_new": MAX_NEW, "poisson_lambda": LAM,
                            "class_mix": MIX, "slo_factor": SLO_FACTOR,
                            "overload": OVERLOAD},
                   "slo": slo}
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {os.path.normpath(json_path)}")
    return slo


if __name__ == "__main__":
    sys.path.insert(0, os.path.join(HERE, "..", "src"))
    if "--inner" in sys.argv:
        print(json.dumps(inner()))
    else:
        main(json_path=None if "--no-json" in sys.argv else BENCH_JSON)
