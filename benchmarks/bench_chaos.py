"""Chaos serving: fault-injected runs vs clean runs across all three
schedulers (dense continuous, paged, disagg prefill/decode).

Each backend serves the same greedy workload twice — once clean, once under
a fault plan combining a poisoned slot (non-finite-logit stand-in), a burst
of transient step failures (absorbed by bounded pre-dispatch retry), an
expired deadline, and (disagg) a migration failure mid-handoff.  The
headline number is **survivor token identity**: every request the faults
did NOT touch must stream exactly the tokens of the clean run — 100.0 or
the bench fails loudly.  Also recorded: survival rate, the finish_reason
histogram (error/timeout casualties vs stop/length survivors), fault
counters, allocator audit status, and decode-ITL degradation under chaos
(retry drains + quarantine bookkeeping are host work; device math is never
touched).

Runs in a subprocess with 2 virtual CPU devices (bench_disagg idiom) so the
disagg pool split is real.

Run directly:  PYTHONPATH=src python benchmarks/bench_chaos.py
(--no-json to skip writing BENCH_chaos.json)
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

import numpy as np

try:
    from benchmarks import loadgen
except ImportError:           # executed directly: benchmarks/ is sys.path[0]
    import loadgen

HERE = os.path.dirname(__file__)
BENCH_JSON = os.path.join(HERE, "..", "BENCH_chaos.json")

ARCH = "yi-9b"
N_REQUESTS = 8
N_SLOTS = 4
MAX_NEW = 8
MAX_LEN = 64
BLOCK_SIZE = 8
CHUNK = 8

# poison hits an early-occupied slot; the step burst is retried; the
# deadline victim is request N_REQUESTS (submitted with deadline_s=0)
PLAN = "poison:slot=1,at=2;step:at=4,times=2"
PLAN_DISAGG = "poison:slot=2,at=3;step:at=4,times=2;migrate:handoff=0"


def _requests(cfg, lo=6, hi=16, seed=4):
    # loadgen's prompt_len range is inclusive; the original inline
    # generator drew integers(lo, hi) exclusive, hence hi - 1
    return loadgen.make_requests(cfg.vocab_size, N_REQUESTS, seed=seed,
                                 prompt_len=(lo, hi - 1), max_new=MAX_NEW,
                                 arrival_fn=lambda i: 2 * (i // 3))


def _serve(sched, reqs, deadline_victim):
    import time

    for p, mn, arr, _cls in reqs:
        sched.submit(p, mn, arrival_step=arr)
    if deadline_victim:
        sched.submit(np.arange(2, 10, dtype=np.int32), MAX_NEW,
                     deadline_s=0.0)
    t0 = time.perf_counter()
    done = {r.rid: r for r in sched.run()}
    dt = time.perf_counter() - t0
    return done, dt, sched


def _chaos_pair(make_sched, reqs, plan):
    """Serve clean then injected; return the comparison record."""
    clean, _, csched = _serve(make_sched(""), reqs, deadline_victim=False)
    done, dt, sched = _serve(make_sched(plan), reqs, deadline_victim=True)
    reasons = {}
    for r in done.values():
        reasons[r.finish_reason] = reasons.get(r.finish_reason, 0) + 1
    survivors = [rid for rid, r in done.items()
                 if r.finish_reason in ("stop", "length")]
    identical = sum(
        1 for rid in survivors
        if np.array_equal(done[rid].output, clean[rid].output))
    if hasattr(sched, "alloc"):
        sched.alloc.audit(expect_no_migration=True)
    st = sched.stats
    itl = sched.request_summary().get("decode_itl_s", {})
    c_itl = csched.request_summary().get("decode_itl_s", {})
    return {
        "requests": len(done),
        "survivors": len(survivors),
        "survivor_token_identity_pct": 100.0 * identical / max(1, len(survivors)),
        "finish_reasons": reasons,
        "faults": {k: st[k] for k in
                   ("step_faults", "step_retries", "quarantined", "timeouts",
                    "migration_faults", "aborts_exhaustion",
                    "livelock_aborts")},
        "allocator_audit": "ok" if hasattr(sched, "alloc") else "n/a",
        "wall_s": dt,
        "itl_p50_clean_s": c_itl.get("p50"),
        "itl_p50_chaos_s": itl.get("p50"),
    }


def inner() -> dict:
    from repro.configs import ParallelConfig, SamplingConfig, get_config
    from repro.launch.mesh import make_local_mesh
    from repro.runtime.engine import Engine
    from repro.runtime.scheduler import (ContinuousScheduler, DisaggScheduler,
                                         PagedContinuousScheduler)

    cfg = get_config(ARCH).reduced()
    eng1 = Engine(cfg=cfg, parallel=ParallelConfig(tp=1, dp=1, remat=False),
                  sampling=SamplingConfig(greedy=True, top_k=1),
                  mesh=make_local_mesh(1, 1), max_len=MAX_LEN)
    eng2 = Engine(cfg=cfg, parallel=ParallelConfig(tp=1, dp=2, remat=False),
                  sampling=SamplingConfig(greedy=True, top_k=1),
                  mesh=make_local_mesh(2, 1), max_len=MAX_LEN)
    reqs = _requests(cfg)
    long_reqs = _requests(cfg, lo=10, hi=22, seed=5)

    out = {}
    out["dense"] = _chaos_pair(
        lambda plan: ContinuousScheduler(
            eng1, n_slots=N_SLOTS, block_steps=2, fault_plan=plan,
            retry_backoff_s=0.0),
        reqs, PLAN)
    eng1.dispatch_hook = None
    out["paged"] = _chaos_pair(
        lambda plan: PagedContinuousScheduler(
            eng1, n_slots=N_SLOTS, block_steps=2, block_size=BLOCK_SIZE,
            prefix_cache=False, fault_plan=plan, retry_backoff_s=0.0),
        reqs, PLAN)
    eng1.dispatch_hook = None
    out["disagg"] = _chaos_pair(
        lambda plan: DisaggScheduler(
            eng2, n_slots=N_SLOTS, block_steps=2, block_size=BLOCK_SIZE,
            prefill_chunk=CHUNK, prefill_shards=1, prefix_cache=False,
            fault_plan=plan, retry_backoff_s=0.0),
        long_reqs, PLAN_DISAGG)

    for name, rec in out.items():
        assert rec["survivor_token_identity_pct"] == 100.0, \
            f"{name}: survivors diverged from the clean run"
        assert rec["faults"]["step_faults"] >= 2, name
        assert rec["faults"]["quarantined"] >= 1, name
        assert rec["faults"]["timeouts"] == 1, name
    assert out["disagg"]["faults"]["migration_faults"] == 1
    return out


def run_inner_subprocess() -> dict:
    env = dict(os.environ)
    env["JAX_NUM_CPU_DEVICES"] = "2"
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    env["PYTHONPATH"] = os.path.join(HERE, "..", "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, os.path.abspath(__file__), "--inner"],
                       capture_output=True, text=True, timeout=3000, env=env)
    if r.returncode != 0:
        raise RuntimeError(r.stderr[-2000:])
    return json.loads(r.stdout.strip().splitlines()[-1])


def main(emit=None, json_path=BENCH_JSON):
    emit = emit or (lambda n, u, d="": print(f"{n},{u:.3f},{d}"))
    chaos = run_inner_subprocess()
    for name, rec in chaos.items():
        f = rec["faults"]
        line = (f"{rec['survivors']}/{rec['requests']} survived "
                f"({rec['survivor_token_identity_pct']:.0f}% token-identical"
                f" to clean); reasons {rec['finish_reasons']}; "
                f"{f['step_faults']} step faults ({f['step_retries']} "
                f"retried), {f['quarantined']} quarantined, "
                f"{f['timeouts']} timeouts, {f['migration_faults']} "
                f"migration faults; audit {rec['allocator_audit']}")
        print(f"{name:7s} {line}", flush=True)
        c, x = rec["itl_p50_clean_s"], rec["itl_p50_chaos_s"]
        deg = (x / c) if (c and x) else 1.0
        emit(f"chaos/{name}_itl_p50", 1e6 * (x or 0.0),
             f"{deg:.2f}x clean p50; {line}")
    if json_path:
        payload = {"meta": {"bench": "chaos_serving", "arch": ARCH,
                            "fault_plan": PLAN,
                            "fault_plan_disagg": PLAN_DISAGG,
                            "n_requests": N_REQUESTS + 1,
                            "max_new": MAX_NEW, "n_slots": N_SLOTS},
                   "chaos": chaos}
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {os.path.normpath(json_path)}")
    return chaos


if __name__ == "__main__":
    sys.path.insert(0, os.path.join(HERE, "..", "src"))
    if "--inner" in sys.argv:
        print(json.dumps(inner()))
    else:
        main(json_path=None if "--no-json" in sys.argv else BENCH_JSON)
