"""Paper Fig. 2: one-time synchronization per decoder layer.

Counts the per-layer residual-stream reductions in the traced schedule for
the parallel-residual (GPT-J) config with §2.2 ON vs OFF, and times the two
variants end-to-end on CPU (reduced config, tp=1 semantics identical)."""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

HERE = os.path.dirname(__file__)


def _trace(one_shot: bool) -> dict:
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, os.path.join(HERE, "comm_trace.py"), "4",
         "gptj-parallel",
         json.dumps({"one_shot_sync": one_shot, "seq_parallel": False})],
        capture_output=True, text=True, timeout=600, env=env,
    )
    if r.returncode != 0:
        raise RuntimeError(r.stderr[-2000:])
    return json.loads(r.stdout.strip().splitlines()[-1])


def main(emit):
    on, off = _trace(True), _trace(False)
    n_on = sum(v["count"] for k, v in on["per_tag"].items()
               if k in ("one_shot", "attn_reduce", "ffn_reduce"))
    n_off = sum(v["count"] for k, v in off["per_tag"].items()
                if k in ("one_shot", "attn_reduce", "ffn_reduce"))
    emit("one_shot/reductions_per_layer", n_on,
         f"{n_on} vs {n_off} baseline (paper §2.2: 1 vs 2)")
    b_on = sum(v["bytes"] for k, v in on["per_tag"].items()
               if k in ("one_shot", "attn_reduce", "ffn_reduce"))
    b_off = sum(v["bytes"] for k, v in off["per_tag"].items()
                if k in ("one_shot", "attn_reduce", "ffn_reduce"))
    emit("one_shot/layer_sync_bytes", b_on,
         f"{b_off/max(b_on,1):.2f}x fewer wire bytes per layer")
