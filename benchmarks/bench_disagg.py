"""Disaggregated prefill/decode serving vs the unified chunked engine.

A long-prompt/short-decode serving mix on two data shards (subprocess with
virtual devices, like the sync bench): the unified paged engine admits with
chunked mixed steps — every admission window still costs each in-flight
decode one fused chunk of prefill compute — while the disaggregated
scheduler runs chunk-only prefill on shard 0 and decode on shard 1 with
hash-chained KV blocks migrating between the pools in batched
device-to-device copy steps.

The headline comparison is the ISSUE's deliverable: the decode pool's
inter-token latency p95 UNDER CONCURRENT PREFILL LOAD (disagg samples taken
in rounds that also carried prefill work) against the unified engine's
admission-window ITL p95, with the migration traffic accounted
(``migration_bytes = migrated_blocks x pool_block_bytes``).  Both engines
must serve token-identical greedy streams — asserted, not assumed.

Honest caveat (also in the scheduler docstring): one process serializes the
two pools' dispatches, so disagg WALL-CLOCK here is not the win — the
decode-dispatch ITL is, because on the deployment this models the pools run
on disjoint shard groups concurrently.  block_steps=1 keeps every decode
dispatch its own ITL sample.

Run directly:  PYTHONPATH=src python benchmarks/bench_disagg.py
(--no-json to skip writing BENCH_disagg.json)
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

import numpy as np

HERE = os.path.dirname(__file__)
BENCH_JSON = os.path.join(HERE, "..", "BENCH_disagg.json")

ARCH = "yi-9b"
N_REQUESTS = 10
N_SLOTS = 4
PROMPT_MIN, PROMPT_MAX = 96, 160
MAX_NEW = 10
ARRIVAL_EVERY = 2
CHUNK = 32
BLOCK_SIZE = 16
MAX_LEN = 256


def _requests(cfg, n, seed=0):
    rng = np.random.default_rng(seed)
    return [(rng.integers(0, cfg.vocab_size,
                          int(rng.integers(PROMPT_MIN, PROMPT_MAX + 1)))
             .astype(np.int32), MAX_NEW, i * ARRIVAL_EVERY)
            for i in range(n)]


def _serve(eng, sched_cls, reqs, **kw):
    import time

    sched = sched_cls(eng, n_slots=N_SLOTS, block_steps=1,
                      block_size=BLOCK_SIZE, prefill_chunk=CHUNK, **kw)
    for p, mn, arr in reqs:
        sched.submit(p, mn, arrival_step=arr)
    t0 = time.perf_counter()
    done = sched.run()
    dt = time.perf_counter() - t0
    summ = sched.request_summary()
    emitted = sum(len(r.output) for r in done)
    rec = {
        "requests": len(done), "emitted": emitted, "wall_s": dt,
        "latency": summ,
    }
    return rec, {r.rid: np.asarray(r.output) for r in done}


def inner() -> dict:
    from repro.configs import ParallelConfig, SamplingConfig, get_config
    from repro.launch.mesh import make_local_mesh
    from repro.runtime.engine import Engine
    from repro.runtime.scheduler import (DisaggScheduler,
                                         PagedContinuousScheduler)

    cfg = get_config(ARCH).reduced()
    eng = Engine(cfg=cfg, parallel=ParallelConfig(tp=1, dp=2, remat=False),
                 sampling=SamplingConfig(greedy=True, top_k=1),
                 mesh=make_local_mesh(2, 1), max_len=MAX_LEN)
    reqs = _requests(cfg, N_REQUESTS)
    # warm both paths (compile time out of the measurement)
    warm = reqs[: N_SLOTS + 1]
    _serve(eng, PagedContinuousScheduler, warm)
    _serve(eng, DisaggScheduler, warm, prefill_shards=1)

    uni, u_out = _serve(eng, PagedContinuousScheduler, reqs)
    dis, d_out = _serve(eng, DisaggScheduler, reqs, prefill_shards=1)
    for rid in u_out:                       # greedy streams must be identical
        np.testing.assert_array_equal(u_out[rid], d_out[rid])
    return {"chunked_unified": uni, "disagg": dis,
            "token_identical_requests": len(u_out)}


def run_inner_subprocess() -> dict:
    env = dict(os.environ)
    env["JAX_NUM_CPU_DEVICES"] = "2"
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    env["PYTHONPATH"] = os.path.join(HERE, "..", "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, os.path.abspath(__file__), "--inner"],
                       capture_output=True, text=True, timeout=3000, env=env)
    if r.returncode != 0:
        raise RuntimeError(r.stderr[-2000:])
    return json.loads(r.stdout.strip().splitlines()[-1])


def main(emit=None, json_path=BENCH_JSON):
    emit = emit or (lambda n, u, d="": print(f"{n},{u:.3f},{d}"))
    serving = run_inner_subprocess()
    uni, dis = serving["chunked_unified"], serving["disagg"]
    u_adm = uni["latency"]["decode_itl_admission_s"]
    pools = dis["latency"]["pools"]
    d_all = pools["decode_itl_s"]
    d_adm = dis["latency"].get("decode_itl_admission_s", d_all)

    mib = pools["migration_bytes"] / 2**20
    line_u = (f"{uni['requests']} reqs; admission-window decode ITL "
              f"p50 {u_adm['p50']*1e3:.1f} ms, p95 {u_adm['p95']*1e3:.1f} ms")
    line_d = (f"{dis['requests']} reqs; decode-pool ITL under prefill load "
              f"p50 {d_adm['p50']*1e3:.1f} ms, p95 {d_adm['p95']*1e3:.1f} ms "
              f"(overall p95 {d_all['p95']*1e3:.1f} ms); migrated "
              f"{pools['migrated_blocks']} blocks = {mib:.2f} MiB in "
              f"{pools['handoffs']} handoffs, "
              f"{pools['migration_skipped_blocks']} skipped via prefix hits")
    print(f"unified  {line_u}", flush=True)
    print(f"disagg   {line_d}", flush=True)
    imp = u_adm["p95"] / d_adm["p95"] if d_adm["p95"] > 0 else float("inf")
    flat = (d_adm["p95"] / d_all["p95"]) if d_all["p95"] > 0 else 1.0
    print(f"decode ITL p95 under prefill load: {imp:.2f}x better disagg; "
          f"prefill-load p95 is {flat:.2f}x the overall decode p95 "
          f"(1.0 = perfectly flat)", flush=True)
    emit("disagg/unified_itl_admission_p95", 1e6 * u_adm["p95"], line_u)
    emit("disagg/decode_pool_itl_p95", 1e6 * d_adm["p95"], line_d)
    emit("disagg/migration_bytes", pools["migration_bytes"],
         f"{pools['migrated_blocks']} blocks, "
         f"{pools['migration_skipped_blocks']} skipped")
    if json_path:
        payload = {
            "meta": {"bench": "disagg_serving", "arch": ARCH,
                     "prefill_shards": 1, "decode_shards": 1,
                     "itl_p95_improvement_vs_unified_admission": imp,
                     "prefill_load_p95_over_overall_p95": flat,
                     "n_requests": N_REQUESTS, "prompt_min": PROMPT_MIN,
                     "prompt_max": PROMPT_MAX, "max_new": MAX_NEW,
                     "prefill_chunk": CHUNK, "block_size": BLOCK_SIZE},
            "serving": serving,
        }
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {os.path.normpath(json_path)}")
    return serving


if __name__ == "__main__":
    sys.path.insert(0, os.path.join(HERE, "..", "src"))
    if "--inner" in sys.argv:
        print(json.dumps(inner()))
    else:
        main(json_path=None if "--no-json" in sys.argv else BENCH_JSON)
