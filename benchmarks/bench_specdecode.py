"""Speculative decoding: serving bench, spec vs plain decode (BENCH_spec.json).

Two traffic mixes through the continuous-batching slot engine, each served
twice — plain one-token decode vs n-gram-drafted speculative decode with the
fused multi-token verify step:

1. **repetitive-text** — prompts tile a short motif and decode runs long:
   greedy generation locks into the model's own attractor cycles, exactly
   the regime prompt-lookup drafting predicts (the proxy for high
   context-overlap workloads: summarization, code edit, extraction).  Spec
   decode should win big here (acceptance -> ~k once locked).
2. **random-text** — incompressible random prompts, short decode: the
   drafter rarely matches, so most verify steps emit the 1-token floor
   while paying a width-(k+1) forward.  The honest floor datapoint: on
   this toy-scale CPU setup dispatch overhead dominates, so even low
   acceptance can break even; at real model scale the wider forward makes
   this mix a net loss (see README for the tradeoff).

Both modes run ``block_steps=1`` (one dispatch per step): spec decode
cannot fuse steps — each step's drafts depend on the previous step's
emissions — so fusing the baseline would conflate dispatch amortization
with the verify win.  The metrics are tok/s, acceptance rate, and
tokens/step against the same-requests baseline.

``greedy_token_agreement`` counts requests whose spec output is bit-equal
to the baseline's.  Every emitted token is the greedy argmax of its own
conditional in both modes, but the width-(k+1) verify program and the
width-1 decode program are different XLA compilations whose written KV can
differ by ±1 bf16 ulp — on long cycle-locked streams (recurring logit
near-ties) that can flip a tie mid-stream, after which the two runs follow
different (equally greedy) trajectories.  Same caveat class the chunked-
prefill suite documents for multi-device compilation differences.

Run directly:  PYTHONPATH=src python benchmarks/bench_specdecode.py
(--no-json to skip writing BENCH_spec.json)
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

BENCH_JSON = os.path.join(os.path.dirname(__file__), "..", "BENCH_spec.json")


def make_requests(cfg, mix: str, n_requests: int, arrival_every: int,
                  seed: int = 0):
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n_requests):
        if mix == "repetitive":
            motif = rng.integers(0, cfg.vocab_size, 5).astype(np.int32)
            prompt, max_new = np.tile(motif, 6), 256
        else:
            plen = int(rng.integers(16, 33))
            prompt = rng.integers(0, cfg.vocab_size, plen).astype(np.int32)
            max_new = 48
        reqs.append((prompt, max_new, i * arrival_every))
    return reqs


def run_serving(eng, reqs, n_slots: int, spec_k: int):
    from repro.runtime.scheduler import ContinuousScheduler

    sched = ContinuousScheduler(eng, n_slots=n_slots, block_steps=1,
                                spec_k=spec_k)
    for p, mn, arr in reqs:
        sched.submit(p, mn, arrival_step=arr)
    t0 = time.perf_counter()
    done = sched.run()
    dt = time.perf_counter() - t0
    emitted = sum(len(r.output) for r in done)
    summ = sched.request_summary()
    rec = {
        "spec_k": spec_k, "requests": len(done), "emitted": emitted,
        "wall_s": dt, "tok_per_s": emitted / dt if dt > 0 else float("inf"),
        "decode_steps": sched.stats["decode_steps"],
        "latency": {k: v for k, v in summ.items()
                    if k not in ("spec", "requests")},
    }
    if spec_k:
        rec["spec"] = summ["spec"]
    return rec, {r.rid: r.output for r in done}


def run(arch="yi-9b", n_requests=8, n_slots=4, spec_k=6, arrival_every=2,
        max_len=320, seed=0, repeats=3):
    from repro.configs import ParallelConfig, SamplingConfig, get_config
    from repro.launch.mesh import make_local_mesh
    from repro.runtime.engine import Engine

    cfg = get_config(arch).reduced()
    eng = Engine(cfg=cfg, parallel=ParallelConfig(tp=1, dp=1, remat=False),
                 sampling=SamplingConfig(greedy=True, top_k=1),
                 mesh=make_local_mesh(1, 1), max_len=max_len)

    def best_of(reqs, k):
        # wall-clock on a shared CPU container is noisy; each mode runs
        # `repeats` times (identical deterministic schedules) and reports
        # its best run, suppressing OS scheduling noise without touching
        # the token/acceptance numbers (those are identical every repeat)
        best = None
        for _ in range(repeats):
            rec, out = run_serving(eng, reqs, n_slots, k)
            if best is None or rec["tok_per_s"] > best[0]["tok_per_s"]:
                best = (rec, out)
        return best

    results = {}
    for mix in ("repetitive", "random"):
        reqs = make_requests(cfg, mix, n_requests, arrival_every, seed)
        for k in (0, spec_k):                       # warm both programs
            run_serving(eng, reqs[: n_slots - 1], n_slots, k)
        base, out_b = best_of(reqs, 0)
        spec, out_s = best_of(reqs, spec_k)
        agree = sum(1 for rid in out_b
                    if out_b[rid].shape == out_s[rid].shape
                    and (out_b[rid] == out_s[rid]).all())
        results[mix] = {
            "baseline": base,
            "spec": spec,
            "tok_per_s_speedup": spec["tok_per_s"] / base["tok_per_s"],
            "greedy_token_agreement": f"{agree}/{len(out_b)}",
        }
    return results


def main(emit=None, json_path=BENCH_JSON, **kw):
    results = run(**kw)
    for mix, rec in results.items():
        sp = rec["spec"]["spec"]
        line = (f"{rec['baseline']['tok_per_s']:.0f} -> "
                f"{rec['spec']['tok_per_s']:.0f} tok/s "
                f"({rec['tok_per_s_speedup']:.2f}x); acceptance "
                f"{sp['acceptance_rate']:.0%}, accepted/step "
                f"{sp['mean_accepted_per_step']:.2f}, emitted/step "
                f"{sp['mean_tokens_per_step']:.2f}; token agreement "
                f"{rec['greedy_token_agreement']}")
        print(f"{mix:12s} {line}", flush=True)
        if emit is not None:
            emit(f"spec/{mix}_tok_per_s", rec["spec"]["tok_per_s"], line)
    rep = results["repetitive"]
    print(f"repetitive-text speedup {rep['tok_per_s_speedup']:.2f}x at "
          f"{rep['spec']['spec']['mean_tokens_per_step']:.2f} tokens/step "
          f"(plain decode floor = 1.0)", flush=True)
    if json_path:
        payload = {
            "meta": {"bench": "spec_decode",
                     "repetitive_speedup": rep["tok_per_s_speedup"],
                     "repetitive_mean_tokens_per_step":
                         rep["spec"]["spec"]["mean_tokens_per_step"], **kw},
            "mixes": results,
        }
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {os.path.normpath(json_path)}")
    return results


if __name__ == "__main__":
    import sys

    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    main(json_path=None if "--no-json" in sys.argv else BENCH_JSON)
