"""Paper §3 reproduction: time-per-output-token of batched decode.

The paper measures Qwen-72B at TP=4 on 4 Xeon sockets: 140 ms/token,
input 512, batch 1.  This container has one CPU, so we run the REDUCED
configs end-to-end (real prefill + decode through the Engine) and report
measured ms/token; the full-size, full-mesh projection comes from
§Roofline (memory term of the decode row = the ms/token bound).
"""
from __future__ import annotations

import time

import numpy as np


def run(arch: str = "qwen-72b", prompt_len: int = 64, decode_tokens: int = 24,
        batch: int = 1, topk_sync: bool = True):
    import jax

    from repro.configs import ParallelConfig, SamplingConfig, get_config
    from repro.launch.mesh import make_local_mesh
    from repro.runtime.engine import Engine

    cfg = get_config(arch).reduced()
    eng = Engine(
        cfg=cfg,
        parallel=ParallelConfig(tp=1, dp=1, remat=False, topk_sync=topk_sync),
        sampling=SamplingConfig(top_k=40),
        mesh=make_local_mesh(1, 1),
        max_len=prompt_len + decode_tokens + 8,
    )
    rng = np.random.default_rng(0)
    shape = (batch, prompt_len) if cfg.n_codebooks == 1 else (
        batch, prompt_len, cfg.n_codebooks)
    prompts = rng.integers(0, cfg.vocab_size, shape).astype(np.int32)
    eng.generate(prompts, max_new=decode_tokens)  # warmup: compiles the same
    t0 = time.perf_counter()                      # prefill + n-step programs
    out = eng.generate(prompts, max_new=decode_tokens)
    dt = time.perf_counter() - t0
    ms_per_tok = 1000 * dt / decode_tokens
    return ms_per_tok, out.shape


def main(emit):
    for arch in ["qwen-72b", "yi-9b", "mamba2-1.3b"]:
        ms, _ = run(arch)
        emit(f"token_latency/{arch}", ms * 1000, f"{ms:.1f} ms/token (reduced cfg)")
    ms_on, _ = run("qwen-72b", topk_sync=True)
    ms_off, _ = run("qwen-72b", topk_sync=False)
    emit("token_latency/topk_sync_speedup", ms_on * 1000,
         f"{ms_off/ms_on:.2f}x vs full-gather baseline")
