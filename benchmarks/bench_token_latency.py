"""Paper §3 reproduction: time-per-output-token of batched decode.

The paper measures Qwen-72B at TP=4 on 4 Xeon sockets: 140 ms/token,
input 512, batch 1.  This container has one CPU, so we run the REDUCED
configs end-to-end (real prefill + decode through the Engine) and report
measured ms/token; the full-size, full-mesh projection comes from
§Roofline (memory term of the decode row = the ms/token bound).

Writes BENCH_token_latency.json (--no-json to skip).
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

BENCH_JSON = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_token_latency.json")


def run(arch: str = "qwen-72b", prompt_len: int = 64, decode_tokens: int = 24,
        batch: int = 1, topk_sync: bool = True):
    import jax

    from repro.configs import ParallelConfig, SamplingConfig, get_config
    from repro.launch.mesh import make_local_mesh
    from repro.runtime.engine import Engine

    cfg = get_config(arch).reduced()
    eng = Engine(
        cfg=cfg,
        parallel=ParallelConfig(tp=1, dp=1, remat=False, topk_sync=topk_sync),
        sampling=SamplingConfig(top_k=40),
        mesh=make_local_mesh(1, 1),
        max_len=prompt_len + decode_tokens + 8,
    )
    rng = np.random.default_rng(0)
    shape = (batch, prompt_len) if cfg.n_codebooks == 1 else (
        batch, prompt_len, cfg.n_codebooks)
    prompts = rng.integers(0, cfg.vocab_size, shape).astype(np.int32)
    eng.generate(prompts, max_new=decode_tokens)  # warmup: compiles the same
    t0 = time.perf_counter()                      # prefill + n-step programs
    out = eng.generate(prompts, max_new=decode_tokens)
    dt = time.perf_counter() - t0
    ms_per_tok = 1000 * dt / decode_tokens
    return ms_per_tok, out.shape


def main(emit=None, json_path=BENCH_JSON):
    emit = emit or (lambda n, u, d="": print(f"{n},{u:.3f},{d}"))
    per_arch = {}
    for arch in ["qwen-72b", "yi-9b", "mamba2-1.3b"]:
        ms, shape = run(arch)
        per_arch[arch] = {"ms_per_token": ms, "out_shape": list(shape),
                          "reduced_cfg": True}
        emit(f"token_latency/{arch}", ms * 1000, f"{ms:.1f} ms/token (reduced cfg)")
    ms_on, _ = run("qwen-72b", topk_sync=True)
    ms_off, _ = run("qwen-72b", topk_sync=False)
    emit("token_latency/topk_sync_speedup", ms_on * 1000,
         f"{ms_off/ms_on:.2f}x vs full-gather baseline")
    if json_path:
        payload = {
            "meta": {"bench": "token_latency",
                     "paper_reference_ms_per_token": 140.0,
                     "note": "reduced configs on one CPU; the full-size "
                             "projection lives in the roofline artifacts"},
            "per_arch": per_arch,
            "topk_sync": {"on_ms": ms_on, "off_ms": ms_off,
                          "speedup": ms_off / ms_on},
        }
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {os.path.normpath(json_path)}")


if __name__ == "__main__":
    import sys

    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    main(json_path=None if "--no-json" in sys.argv else BENCH_JSON)
