"""Continuous batching vs wave scheduling on a straggler-heavy workload.

The wave baseline pads every request in a wave to the longest prompt and
decodes the whole batch to the wave's max ``max_new`` — one straggler holds
the batch while finished rows burn full decode FLOPs.  The slot engine
(``ContinuousScheduler``) masks finished slots in-program and admits new
requests in-flight, so aggregate tokens/s tracks how much real work fits in
the fixed batch, not the worst row.

Workload: mixed prompt lengths, per-request ``max_new`` spanning >= 4x
(uniform over {tail..head}), staggered arrivals.  Both schedulers serve the
IDENTICAL request set (the wave baseline ignores arrivals — it drains the
queue, which only helps it).

Run directly:  PYTHONPATH=src python benchmarks/bench_continuous_batching.py
(writes machine-readable results to BENCH_continuous.json for the
cross-PR perf trajectory; --no-json to skip)
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

BENCH_JSON = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_continuous.json")


def cache_bytes(caches) -> int:
    """Persistent cache footprint of a cache pytree (the dense engine's
    high-water mark: it allocates n_slots x max_len up front)."""
    import jax

    return int(sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(caches)))


def make_requests(cfg, n_requests: int, prompt_max: int, max_new_head: int,
                  max_new_tail: int, arrival_every: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n_requests):
        plen = int(rng.integers(4, prompt_max + 1))
        prompt = rng.integers(0, cfg.vocab_size, plen).astype(np.int32)
        max_new = int(rng.integers(max_new_tail, max_new_head + 1))
        reqs.append((prompt, max_new, i * arrival_every))
    return reqs


def run_one(sched_name: str, eng, reqs, batch: int, block_steps: int):
    from repro.runtime.scheduler import ContinuousScheduler, WaveScheduler

    if sched_name == "continuous":
        sched = ContinuousScheduler(eng, n_slots=batch, block_steps=block_steps)
    else:
        sched = WaveScheduler(eng, batch_size=batch)
    for prompt, max_new, arrival in reqs:
        sched.submit(prompt, max_new, arrival_step=arrival)
    t0 = time.perf_counter()
    done = sched.run()
    dt = time.perf_counter() - t0
    emitted = sum(len(r.output) for r in done)
    rec = {"requests": len(done), "emitted": emitted, "wall_s": dt,
           "tok_per_s": emitted / dt if dt > 0 else float("inf")}
    if sched_name == "continuous":
        s = sched.stats
        rec["decode_steps"] = s["decode_steps"]
        rec["slot_util"] = s["active_slot_steps"] / max(1, s["slot_steps"])
        rec["in_flight_admissions"] = s["in_flight_admissions"]
        rec["prefill_tokens"] = s["prefill_tokens"]
        rec["latency"] = sched.request_summary()
        rec["kv_bytes_hwm"] = cache_bytes(sched.caches)
    return rec, done


def write_json(path, results, meta):
    payload = {"meta": meta, "results": results}
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {os.path.normpath(path)}")


def run(arch: str = "yi-9b", n_requests: int = 24, batch: int = 4,
        prompt_max: int = 16, max_new_head: int = 32, max_new_tail: int = 4,
        arrival_every: int = 2, block_steps: int = 8, max_len: int = 96):
    from repro.configs import ParallelConfig, SamplingConfig, get_config
    from repro.launch.mesh import make_local_mesh
    from repro.runtime.engine import Engine

    assert max_new_head >= 4 * max_new_tail, "straggler mix must span >= 4x"
    cfg = get_config(arch).reduced()
    eng = Engine(cfg=cfg, parallel=ParallelConfig(tp=1, dp=1, remat=False),
                 sampling=SamplingConfig(greedy=True, top_k=1),
                 mesh=make_local_mesh(1, 1), max_len=max_len)
    reqs = make_requests(cfg, n_requests, prompt_max, max_new_head,
                         max_new_tail, arrival_every)
    # warmup both paths on a tiny set so compile time stays out of the timing
    warm = reqs[: batch + 1]
    for name in ("wave", "continuous"):
        run_one(name, eng, warm, batch, block_steps)

    results = {}
    outputs = {}
    for name in ("wave", "continuous"):
        results[name], done = run_one(name, eng, reqs, batch, block_steps)
        outputs[name] = {r.rid: r.output for r in done}
    return results, outputs


def main(emit=None, json_path=BENCH_JSON, **kw):
    results, _ = run(**kw)
    for name, rec in results.items():
        extra = ""
        if "slot_util" in rec:
            extra = (f" util={rec['slot_util']:.0%}"
                     f" in_flight={rec['in_flight_admissions']}"
                     f" steps={rec['decode_steps']}")
        line = (f"{rec['requests']} reqs, {rec['emitted']} toks, "
                f"{rec['wall_s']:.2f}s -> {rec['tok_per_s']:.1f} tok/s{extra}")
        print(f"{name:11s} {line}", flush=True)
        if emit is not None:
            emit(f"continuous_batching/{name}",
                 1e6 * rec["wall_s"] / max(1, rec["emitted"]), line)
    speedup = results["continuous"]["tok_per_s"] / results["wave"]["tok_per_s"]
    print(f"continuous/wave aggregate tokens/s: {speedup:.2f}x", flush=True)
    if emit is not None:
        emit("continuous_batching/speedup", speedup * 1000, f"{speedup:.2f}x")
    if json_path:
        write_json(json_path, results,
                   {"bench": "continuous_batching", "speedup": speedup, **kw})
    return results


if __name__ == "__main__":
    import sys

    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    main(json_path=None if "--no-json" in sys.argv else BENCH_JSON)
