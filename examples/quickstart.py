"""Quickstart: build a model from a config, run one forward pass, one train
step, and generate a few tokens — the whole public API in ~60 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat

from repro.configs import ParallelConfig, SamplingConfig, get_config
from repro.launch.mesh import make_local_mesh
from repro.models import model as M
from repro.runtime.engine import Engine

# 1. pick an architecture (any of the 10 assigned ids work: --arch style)
cfg = get_config("mixtral-8x7b").reduced()      # reduced: CPU-sized variant
par = ParallelConfig(tp=1, dp=1, remat=False)
ctx = M.ModelCtx.make(cfg, par)
mesh = make_local_mesh(dp=1, tp=1)

# 2. parameters (a plain pytree; partition specs live alongside)
params = M.init_params(ctx, jax.random.key(0))
print(f"{cfg.name}: {sum(x.size for x in jax.tree.leaves(params))/1e6:.1f}M params")

# 3. one forward pass under shard_map (explicit collective schedule)
tokens = jax.random.randint(jax.random.key(1), (2, 16), 0, cfg.vocab_size)


def step(params, tokens):
    logits, _, aux = M.forward(params, tokens, ctx, seq_sharded=True)
    return logits


logits = jax.jit(compat.shard_map(
    step, mesh=mesh, in_specs=(M.param_specs(ctx), P("data", None)),
    out_specs=P("data", None, "model"), check_vma=False))(params, tokens)
print("logits:", logits.shape, "finite:", bool(jnp.isfinite(logits).all()))

# 4. serve: prefill + decode with the paper's distributed-sampling path
eng = Engine(cfg=cfg, parallel=par, sampling=SamplingConfig(top_k=20),
             mesh=mesh, max_len=64, params=params)
prompts = np.random.default_rng(0).integers(0, cfg.vocab_size, (2, 8)).astype(np.int32)
out = eng.generate(prompts, max_new=8)
print("generated:", out)
