"""Serve a small model with batched requests through the wave scheduler —
the paper-kind end-to-end driver (§3 measures exactly this loop).

    PYTHONPATH=src python examples/serve_batch.py [arch]
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.serve import main

arch = sys.argv[1] if len(sys.argv) > 1 else "qwen-72b"
main(["--arch", arch, "--requests", "8", "--batch", "4",
      "--prompt-len", "24", "--max-new", "16"])
