"""Demonstrate the paper's §2.1 on real (virtual) shards: run the decode
sampling path at TP=8 with and without the optimizations and print the wire
bytes each schedule moves.

    PYTHONPATH=src python examples/distributed_sampling_demo.py
"""
import json
import os
import subprocess
import sys

HERE = os.path.dirname(__file__)
TRACE = os.path.join(HERE, "..", "benchmarks", "comm_trace.py")

env = dict(os.environ)
env.pop("XLA_FLAGS", None)
env["PYTHONPATH"] = os.path.join(HERE, "..", "src")

for label, flags in [
    ("paper-optimized (topk-sync + id-broadcast)",
     {"topk_sync": True, "id_broadcast": True}),
    ("baseline (full-vocab gather + embedding broadcast)",
     {"topk_sync": False, "id_broadcast": False}),
]:
    out = subprocess.run(
        [sys.executable, TRACE, "8", "mixtral-8x7b", json.dumps(flags)],
        capture_output=True, text=True, env=env, timeout=600,
    )
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    print(f"\n{label}:")
    print(f"  collectives per decode round: {rec['n_collectives']}")
    print(f"  bytes on the wire:            {rec['total_bytes']:,}")
    for tag, d in sorted(rec["per_tag"].items()):
        print(f"    {tag:24s} x{d['count']}  {d['bytes']:,} B")
