"""Train a ~100M-class model for a few hundred steps on the synthetic stream
(end-to-end training driver; checkpoints at the end).

    PYTHONPATH=src python examples/train_small.py [arch] [steps]
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.train import main

arch = sys.argv[1] if len(sys.argv) > 1 else "yi-9b"
steps = sys.argv[2] if len(sys.argv) > 2 else "200"
main(["--arch", arch, "--steps", steps, "--global-batch", "8",
      "--seq-len", "128", "--lr", "3e-3", "--zero1",
      "--ckpt", "/tmp/repro_ckpt/last.npz"])
